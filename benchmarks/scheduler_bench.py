"""Continuous-batching throughput: aggregate tokens/sec vs concurrency.

The scheduler's perf contract, asserted here and recorded in
results/benchmarks.json:

  * aggregate decode throughput *increases* with the number of
    concurrent requests -- the point of continuous batching: one
    compiled step serves every active slot, so admission turns idle
    step capacity into tokens;
  * the decode step compiles exactly ONCE per scheduler regardless of
    how many requests are admitted and retired (compile count flat in
    traffic), and its pallas-launch count is 1 (the fused paged
    attention inside the layer scan) at every pool size;
  * injection is a runtime schedule, not a shape: clean / guardband /
    deep-undervolt serving all ride the same compiled step, and the
    injected step stays within budget of the guardband (uninjected)
    step.

Timing is interleaved min-of-reps (one rep of every concurrency per
pass) like decode_bench, so machine-load drift hits all variants
equally and CI ratios stay robust.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch
from repro.serving.engine import ServeConfig
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

V_DEEP = 0.88
V_GUARD = 0.98
PAGE_SLOTS = 8
MAX_LEN = 64
PROMPT = 8
NEW_TOKENS = 9                 # 8 decode steps per request
N_REQUESTS = 8
CONCURRENCY = (1, 4, 8)
REPS = 3


def _setup():
    bundle = get_arch("llama3.2-3b")
    # test-sized KV geometry, realistic compute mix (cf. decode_bench)
    cfg = dataclasses.replace(bundle.reduced, d_model=96, d_ff=384,
                              vocab=4096)
    bundle = dataclasses.replace(bundle, reduced=cfg)
    params = trainer.init_state(bundle, cfg,
                                jax.random.PRNGKey(0))["params"]
    return bundle, cfg, params


def _plan(v):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _requests(cfg):
    rng = np.random.RandomState(0)
    return [Request(rid=i, tokens=rng.randint(0, cfg.vocab, (PROMPT,)),
                    max_new_tokens=NEW_TOKENS, tier="cheap",
                    key=jax.random.PRNGKey(i))
            for i in range(N_REQUESTS)]


def _make_sched(bundle, cfg, params, plan, max_active):
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
                     undervolt=plan,
                     kv_injection="auto" if plan is None else "read",
                     kv_method="word")
    return ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=max(CONCURRENCY),
        num_pages=max(CONCURRENCY) * (MAX_LEN // PAGE_SLOTS),
        page_slots=PAGE_SLOTS, max_active=max_active)


def _drain_seconds(sched, cfg):
    """(wall seconds, decode steps) to serve the fixed request stream
    (prefill+scatter warm, decode timed -- the steady-state serving
    cost).  Steps are the per-drain delta: ``sched.steps`` itself keeps
    accumulating across warm-up and reps."""
    for r in _requests(cfg):
        sched.submit(r)
    steps0 = sched.steps
    t0 = time.perf_counter()
    sched.run()
    dt = time.perf_counter() - t0
    sched.results.clear()
    return dt, sched.steps - steps0


def run():
    bundle, cfg, params = _setup()
    total_tokens = N_REQUESTS * NEW_TOKENS
    rows = []

    # ---- throughput vs concurrency (one scheduler per concurrency,
    # compiled once, reused across reps) ----------------------------
    voltages = {"clean": (None, 0.0), "guardband": (_plan(V_DEEP), V_GUARD),
                "faulty": (_plan(V_DEEP), V_DEEP)}
    tput = {}
    scheds = {}
    drain_steps = {}
    for name, (plan, v) in voltages.items():
        for c in CONCURRENCY:
            s = _make_sched(bundle, cfg, params, plan, c)
            if plan is not None:
                s._voltage = v          # runtime schedule, no recompile
            scheds[(name, c)] = s
            _drain_seconds(s, cfg)      # warm-up: compiles step+prefill
    best = {k: np.inf for k in scheds}
    for _ in range(REPS):
        for k, s in scheds.items():     # interleaved
            dt, drain_steps[k] = _drain_seconds(s, cfg)
            best[k] = min(best[k], dt)
    for (name, c), dt in sorted(best.items(), key=lambda kv: kv[0]):
        tput[(name, c)] = total_tokens / dt
        rows.append({
            "name": f"sched_tokens_per_sec_{name}_c{c}",
            "us_per_call": dt / total_tokens * 1e6,
            "derived": (f"tokens_per_sec={total_tokens / dt:.1f};"
                        f"concurrency={c};requests={N_REQUESTS};"
                        f"steps={drain_steps[(name, c)]};decode_traces="
                        f"{len(scheds[(name, c)].traces)}")})

    # ---- acceptance asserts ----------------------------------------
    for name in voltages:
        lo, hi = tput[(name, CONCURRENCY[0])], tput[(name, CONCURRENCY[-1])]
        assert hi > lo, (
            f"{name}: aggregate throughput did not increase with "
            f"concurrency ({lo:.1f} -> {hi:.1f} tok/s)")
        # compile count flat in traffic: every scheduler saw
        # N_REQUESTS x (1 + REPS) admissions/retirements on ONE trace
        for c in CONCURRENCY:
            s = scheds[(name, c)]
            assert len(s.traces) == 1, (name, c, len(s.traces))
    # Guardband and faulty run the IDENTICAL compiled step (injection
    # is a runtime threshold schedule); the residual CPU-side gap is
    # denormal/NaN-heavy arithmetic on corrupted tiles in interpret
    # mode, so the budget is looser than decode_bench's on-path 1.3x.
    slow = tput[("guardband", 8)] / tput[("faulty", 8)]
    assert slow <= 1.6, (
        f"injected serving {slow:.2f}x its uninjected (guardband) "
        f"throughput (budget 1.6x)")

    # ---- pallas-launch budget: 1 fused launch, flat in pool size ----
    launches = {}
    for c in (2, 8):
        s = _make_sched(bundle, cfg, params, _plan(V_DEEP), c)
        jaxpr = jax.make_jaxpr(s._step_fn)(params, s.state,
                                           jnp.float32(V_DEEP))
        launches[c] = arena.count_pallas_calls(jaxpr.jaxpr)
    assert launches[2] == launches[8] == 1, launches

    rows.append({
        "name": "sched_scaling_summary",
        "us_per_call": 0.0,
        "derived": (
            f"clean_c1={tput[('clean', 1)]:.1f};"
            f"clean_c8={tput[('clean', 8)]:.1f};"
            f"faulty_c8={tput[('faulty', 8)]:.1f};"
            f"guardband_over_faulty_x={slow:.2f};"
            f"pallas_launches={launches[8]};decode_traces=1")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
