"""Continuous-batching throughput: aggregate tokens/sec vs concurrency.

The scheduler's perf contract, asserted here and recorded in
results/benchmarks.json:

  * aggregate decode throughput *increases* with the number of
    concurrent requests -- the point of continuous batching: one
    compiled step serves every active slot, so admission turns idle
    step capacity into tokens;
  * the decode step compiles exactly ONCE per scheduler regardless of
    how many requests are admitted and retired (compile count flat in
    traffic), and its pallas-launch count is 1 (the fused paged
    attention inside the layer scan) at every pool size;
  * injection is a runtime schedule, not a shape: clean / guardband /
    deep-undervolt serving all ride the same compiled step, and the
    injected step stays within budget of the guardband (uninjected)
    step;
  * chunked prefill + the shared-prefix cache pay off at high
    concurrency with long shared prompts: time-to-first-token (in
    steps and wall time) and newly-written pages per tenant both drop
    strictly when ``share_prefix`` is on, at every voltage point, and
    the warm chunked TTFT beats the per-prompt-length ``jax.jit``
    prefill a phase-separated scheduler would pay on first sight of a
    new length.

  * mesh sharding scales capacity linearly: at shard counts 1/2/4/8
    (forced host devices via ``XLA_FLAGS=--xla_force_host_platform_
    device_count=8``; counts above the visible device count are
    skipped) the admissible concurrency and the pool's page capacity
    are exactly ``shards x`` the per-shard provision, while the
    per-shard budgets stay flat: ONE decode trace and one pallas
    launch per shard, zero collectives -- at clean, guardband and
    deep-undervolt voltage points.

  * the model zoo prices every cache family through the ONE scheduler
    front door: ``sched_zoo_{family}_{arch}`` rows record tokens/sec
    and joules/token for one arch per family (paged and state-arena
    routes alike, each on ONE decode trace), with a structured ``zoo``
    object for dashboards;

  * energy rows price the fleet in joules/token and $/1M tokens via
    the in-step counters (``repro.obs``): ``sched_energy_priced_v*``
    re-prices one fixed clean c=8 workload across rails and must
    reproduce the paper's savings (>=1.4x @ 0.98 V, >=2.2x @ 0.85 V
    vs nominal); ``sched_energy_*_ecc_{off,on}_c8`` price the storm
    configurations at their shards' actual governed voltages; and
    ``sched_energy_efficiency_governor_c8`` asserts the
    ``mode='efficiency'`` governor lands on a tokens-per-joule point
    no worse than every fixed setpoint under the same fault-rate SLO.

Timing is interleaved min-of-reps (one rep of every concurrency per
pass) like decode_bench, so machine-load drift hits all variants
equally and CI ratios stay robust.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.faultmodel import V_NOM
from repro.core.hbm import VCU128
from repro.launch.mesh import make_serve_mesh
from repro.models.base import get_arch
from repro.serving.engine import ServeConfig
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SelfHealConfig)
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

V_DEEP = 0.88
V_GUARD = 0.98
PAGE_SLOTS = 8
MAX_LEN = 64
PROMPT = 8
NEW_TOKENS = 9                 # 8 decode steps per request
N_REQUESTS = 8
CONCURRENCY = (1, 4, 8)
REPS = 3
SYS_PROMPT = 40                # shared system prefix: 5 full pages
USER_TOKENS = 6                # distinct per-tenant tail (46-token prompts)
SHARD_COUNTS = (1, 2, 4, 8)    # counts above len(jax.devices()) skip
SHARD_SLOTS = 2                # per-shard slot provision
SHARD_PAGES = 2 * (MAX_LEN // PAGE_SLOTS)   # per-shard page provision
SHARD_REPS = 2

# ---- model-zoo pricing (one arch per family) ------------------------
ZOO_ARCHS = ("llama3.2-3b", "gemma3-4b", "deepseek-v2-lite-16b",
             "recurrentgemma-9b", "xlstm-350m", "whisper-large-v3",
             "internvl2-2b")
ZOO_SLOTS = 4
ZOO_NEW = 5                    # decode tokens per zoo request
ZOO_MAX_LEN = 32
ZOO_REPS = 2

# ---- migration storm (self-healing recovery cost) -------------------
V_STORM = 0.91                 # deep point where weak rows throw SECDED
                               # corrections but strong rows stay clean
STORM_PCS = (8, 15, 18, 29)    # least-reliable VCU128 pseudo-channels:
                               # on the full-PC domain the reliability-
                               # ordered pool parks every page on
                               # channels whose weak rows stay silent
STORM_AT = 8                   # decode step at which the rows flip weak
STORM_ROWS = 2                 # distinct DRAM rows flipped mid-stream
STORM_NEW_TOKENS = 33          # 32 decode steps: room to heal in-stream
STORM_PAGES = N_REQUESTS * (MAX_LEN // PAGE_SLOTS) + 32  # mig headroom
STORM_POINTS = ("clean", "guardband", "faulty")


def _setup():
    bundle = get_arch("llama3.2-3b")
    # test-sized KV geometry, realistic compute mix (cf. decode_bench)
    cfg = dataclasses.replace(bundle.reduced, d_model=96, d_ff=384,
                              vocab=4096)
    bundle = dataclasses.replace(bundle, reduced=cfg)
    params = trainer.init_state(bundle, cfg,
                                jax.random.PRNGKey(0))["params"]
    return bundle, cfg, params


def _plan(v):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _requests(cfg):
    rng = np.random.RandomState(0)
    return [Request(rid=i, tokens=rng.randint(0, cfg.vocab, (PROMPT,)),
                    max_new_tokens=NEW_TOKENS, tier="cheap",
                    key=jax.random.PRNGKey(i))
            for i in range(N_REQUESTS)]


def _make_sched(bundle, cfg, params, plan, max_active, share=False,
                num_pages=None):
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
                     undervolt=plan,
                     kv_injection="auto" if plan is None else "read",
                     kv_method="word", share_prefix=share)
    if num_pages is None:
        num_pages = max(CONCURRENCY) * (MAX_LEN // PAGE_SLOTS)
    return ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=max(CONCURRENCY),
        num_pages=num_pages, page_slots=PAGE_SLOTS, max_active=max_active)


def _make_sharded(bundle, cfg, params, plan, n_shards):
    """Scheduler over a 1-D serve mesh with fixed PER-SHARD provision:
    2 slots and 16 pages per shard, so the global capacity row at each
    shard count is exactly the linear-scaling claim under test."""
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
                     undervolt=plan,
                     kv_injection="auto" if plan is None else "read",
                     kv_method="word")
    return ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=SHARD_SLOTS * n_shards,
        num_pages=SHARD_PAGES * n_shards, page_slots=PAGE_SLOTS,
        mesh=make_serve_mesh(n_shards))


def _shared_requests(cfg):
    """N_REQUESTS long prompts opening with the same system prefix."""
    rng = np.random.RandomState(7)
    system = rng.randint(0, cfg.vocab, (SYS_PROMPT,))
    return [Request(rid=f"s{i}",
                    tokens=np.concatenate(
                        [system, rng.randint(0, cfg.vocab, (USER_TOKENS,))]),
                    max_new_tokens=NEW_TOKENS, tier="cheap",
                    key=jax.random.PRNGKey(100 + i))
            for i in range(N_REQUESTS)]


def _storm_sched(bundle, cfg, params, point):
    """One scheduler per storm point.  'clean' has no undervolt plan
    (and therefore no self-healing loop -- the uninjected baseline);
    'guardband' and 'faulty' share the SAME heal-enabled scheduler
    shape with the worst-PC ECC plan, differing only in the runtime
    voltage schedule: at V_GUARD the flipped rows stay silent, at
    V_STORM they throw correctable SECDED events every read."""
    if point == "clean":
        plan, v, heal = None, 0.0, None
    else:
        plan = UndervoltPlan(
            domains={"kv": MemoryDomain("kv", V_STORM, STORM_PCS,
                                        ecc=True)},
            policy={"kv_cache": "kv"}, geometry=VCU128)
        v = V_GUARD if point == "guardband" else V_STORM
        heal = SelfHealConfig()
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=STORM_NEW_TOKENS,
                     undervolt=plan,
                     kv_injection="auto" if plan is None else "read",
                     kv_method="word")
    s = ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=N_REQUESTS,
        num_pages=STORM_PAGES, page_slots=PAGE_SLOTS, self_heal=heal)
    if plan is not None:
        s._voltage = v
    return s


def _storm_requests(cfg):
    rng = np.random.RandomState(5)
    return [Request(rid=f"m{i}",
                    tokens=rng.randint(0, cfg.vocab, (PROMPT,)),
                    max_new_tokens=STORM_NEW_TOKENS, tier="cheap",
                    key=jax.random.PRNGKey(200 + i))
            for i in range(N_REQUESTS)]


def _flip_rows(s):
    """Flip STORM_ROWS distinct live DRAM rows weak at runtime; returns
    the set of affected page ids."""
    hit, seen = set(), set()
    for pid in sorted(s.pool._owned):
        pc, row = s.pool.page_rows(pid)[0]
        if (pc, row) in seen:
            continue
        seen.add((pc, row))
        hit.update(int(p) for p in s.weaken_row(0, pc, row))
        if len(seen) == STORM_ROWS:
            break
    return hit


def _storm_drain(s, cfg, chaos):
    """Step the full request stream manually, wall-timing every decode
    step; at step STORM_AT (``chaos`` on) flip STORM_ROWS live rows
    weak.  Returns (per-step seconds, flipped page ids, index of the
    last step that performed a migration, migrations THIS drain ran
    before the flip -- static weak pages are healed and quarantined
    during the warm-up drain, so a nonzero pre-storm delta means the
    steady state never converged).
    """
    for r in _storm_requests(cfg):
        s.submit(r)
    times, flipped, last_heal = [], set(), None

    def _migs():
        return sum(sh.migrations for sh in s._shards)

    base = _migs()
    mig_pre = 0
    while s.queue or s.n_active:
        s.admit_pending()
        if not s.n_active:
            break
        if chaos and len(times) == STORM_AT:
            mig_pre = _migs() - base
            flipped = _flip_rows(s)
        m0 = _migs()
        t0 = time.perf_counter()
        s.step_once()
        times.append(time.perf_counter() - t0)
        if _migs() > m0:
            last_heal = len(times) - 1
    s.results.clear()
    return times, flipped, last_heal, mig_pre


def _drain_collect(sched, cfg):
    """Like _drain_seconds but also returns the per-request results of
    the drain (TTFT in steps, page rows, shared-page counts)."""
    for r in _shared_requests(cfg):
        sched.submit(r)
    steps0 = sched.steps
    t0 = time.perf_counter()
    sched.run()
    dt = time.perf_counter() - t0
    out = dict(sched.results)
    sched.results.clear()
    return dt, sched.steps - steps0, out


def _drain_seconds(sched, cfg):
    """(wall seconds, decode steps) to serve the fixed request stream
    (prefill+scatter warm, decode timed -- the steady-state serving
    cost).  Steps are the per-drain delta: ``sched.steps`` itself keeps
    accumulating across warm-up and reps."""
    for r in _requests(cfg):
        sched.submit(r)
    steps0 = sched.steps
    t0 = time.perf_counter()
    sched.run()
    dt = time.perf_counter() - t0
    sched.results.clear()
    return dt, sched.steps - steps0


def _zoo_drain(sched, cfg):
    """Wall seconds to serve ZOO_SLOTS requests of a zoo arch, with
    the modality extras its family needs (audio frames / VLM patches)."""
    rng = np.random.RandomState(11)
    for i in range(ZOO_SLOTS):
        extras = None
        if cfg.family == "audio":
            extras = {"frames": rng.standard_normal(
                (cfg.enc_len, cfg.d_model)).astype(np.float32)}
        elif cfg.family == "vlm":
            extras = {"patches": rng.standard_normal(
                (cfg.enc_len, cfg.frontend_dim)).astype(np.float32)}
        sched.submit(Request(rid=f"z{i}",
                             tokens=rng.randint(0, cfg.vocab, (PROMPT,)),
                             max_new_tokens=ZOO_NEW, tier="cheap",
                             key=jax.random.PRNGKey(300 + i),
                             extras=extras))
    t0 = time.perf_counter()
    sched.run()
    dt = time.perf_counter() - t0
    sched.results.clear()
    return dt


def run():
    bundle, cfg, params = _setup()
    total_tokens = N_REQUESTS * NEW_TOKENS
    rows = []

    # ---- throughput vs concurrency (one scheduler per concurrency,
    # compiled once, reused across reps) ----------------------------
    voltages = {"clean": (None, 0.0), "guardband": (_plan(V_DEEP), V_GUARD),
                "faulty": (_plan(V_DEEP), V_DEEP)}
    tput = {}
    scheds = {}
    drain_steps = {}
    for name, (plan, v) in voltages.items():
        for c in CONCURRENCY:
            s = _make_sched(bundle, cfg, params, plan, c)
            if plan is not None:
                s._voltage = v          # runtime schedule, no recompile
            scheds[(name, c)] = s
            _drain_seconds(s, cfg)      # warm-up: compiles step+prefill
    best = {k: np.inf for k in scheds}
    for _ in range(REPS):
        for k, s in scheds.items():     # interleaved
            dt, drain_steps[k] = _drain_seconds(s, cfg)
            best[k] = min(best[k], dt)
    for (name, c), dt in sorted(best.items(), key=lambda kv: kv[0]):
        tput[(name, c)] = total_tokens / dt
        rows.append({
            "name": f"sched_tokens_per_sec_{name}_c{c}",
            "us_per_call": dt / total_tokens * 1e6,
            "derived": (f"tokens_per_sec={total_tokens / dt:.1f};"
                        f"concurrency={c};requests={N_REQUESTS};"
                        f"steps={drain_steps[(name, c)]};decode_traces="
                        f"{len(scheds[(name, c)].traces)}")})

    # ---- acceptance asserts ----------------------------------------
    for name in voltages:
        lo, hi = tput[(name, CONCURRENCY[0])], tput[(name, CONCURRENCY[-1])]
        assert hi > lo, (
            f"{name}: aggregate throughput did not increase with "
            f"concurrency ({lo:.1f} -> {hi:.1f} tok/s)")
        # compile count flat in traffic: every scheduler saw
        # N_REQUESTS x (1 + REPS) admissions/retirements on ONE trace
        for c in CONCURRENCY:
            s = scheds[(name, c)]
            assert len(s.traces) == 1, (name, c, len(s.traces))
    # Guardband and faulty run the IDENTICAL compiled step (injection
    # is a runtime threshold schedule); the residual CPU-side gap is
    # denormal/NaN-heavy arithmetic on corrupted tiles in interpret
    # mode, so the budget is looser than decode_bench's on-path 1.3x.
    slow = tput[("guardband", 8)] / tput[("faulty", 8)]
    assert slow <= 1.6, (
        f"injected serving {slow:.2f}x its uninjected (guardband) "
        f"throughput (budget 1.6x)")

    # ---- pallas-launch budget: 1 fused launch, flat in pool size ----
    launches = {}
    for c in (2, 8):
        s = _make_sched(bundle, cfg, params, _plan(V_DEEP), c)
        jaxpr = jax.make_jaxpr(s._step_fn)(params, s.state,
                                           jnp.float32(V_DEEP))
        launches[c] = arena.count_pallas_calls(jaxpr.jaxpr)
    assert launches[2] == launches[8] == 1, launches

    # ---- chunked prefill + shared-prefix cache: TTFT & pages/tenant --
    # High concurrency, long prompts sharing a 5-page system prefix.
    # The warm-up drain compiles the step and (sharing on) publishes
    # the prefix; the timed drains are the steady state, where tenants
    # map the cached prefix pages read-only instead of re-prefilling.
    # The pool is larger here so the prefix cache never has to evict --
    # the comparison isolates sharing, not capacity pressure.
    share_scheds = {}
    for name, (plan, v) in voltages.items():
        for share in (False, True):
            s = _make_sched(bundle, cfg, params, plan, max(CONCURRENCY),
                            share=share, num_pages=128)
            if plan is not None:
                s._voltage = v
            share_scheds[(name, share)] = s
            _drain_collect(s, cfg)      # warm-up + prefix publication
    sbest = {k: np.inf for k in share_scheds}
    sres, ssteps = {}, {}
    for _ in range(REPS):
        for k, s in share_scheds.items():       # interleaved
            dt, ssteps[k], sres[k] = _drain_collect(s, cfg)
            sbest[k] = min(sbest[k], dt)
    ttft, pages_new = {}, {}
    for (name, share), res in sorted(sres.items(),
                                     key=lambda kv: (kv[0][0], kv[0][1])):
        dt = sbest[(name, share)]
        step_us = dt / ssteps[(name, share)] * 1e6
        tt = float(np.mean([r.ttft_steps for r in res.values()]))
        pp = float(np.mean([len(r.page_ids) - r.pages_shared
                            for r in res.values()]))
        ttft[(name, share)] = tt
        pages_new[(name, share)] = pp
        rows.append({
            "name": (f"sched_shared_prefix_{name}_"
                     f"{'share' if share else 'noshare'}_"
                     f"c{max(CONCURRENCY)}"),
            "us_per_call": step_us * tt,        # wall TTFT
            "derived": (f"ttft_steps_mean={tt:.2f};"
                        f"ttft_us_mean={step_us * tt:.0f};"
                        f"tokens_per_sec={total_tokens / dt:.1f};"
                        f"pages_written_per_tenant={pp:.2f};"
                        f"prompt={SYS_PROMPT + USER_TOKENS};"
                        f"concurrency={max(CONCURRENCY)};decode_traces="
                        f"{len(share_scheds[(name, share)].traces)}")})
    # PR4 phase-separated baseline: admission ran a per-prompt-length
    # jitted prefill, so the first request at any new length paid a
    # fresh trace+compile before its first token could exist.
    toks = jnp.asarray(_shared_requests(cfg)[0].tokens, jnp.int32)[None]
    cold = jax.jit(lambda p, t: bundle.module.prefill(
        p, {"tokens": t}, cfg, MAX_LEN))
    t0 = time.perf_counter()
    jax.block_until_ready(cold(params, toks))
    pr4_us = (time.perf_counter() - t0) * 1e6
    rows.append({
        "name": "sched_ttft_pr4_jit_prefill_baseline",
        "us_per_call": pr4_us,
        "derived": (f"prompt={SYS_PROMPT + USER_TOKENS};cold_compile=1;"
                    "note=per-length admission prefill of the "
                    "phase-separated scheduler")})

    # ---- chunked/shared acceptance asserts ---------------------------
    for name in voltages:
        for share in (False, True):
            assert len(share_scheds[(name, share)].traces) == 1, (
                name, share, len(share_scheds[(name, share)].traces))
        # sharing: later tenants map the prefix pages instead of
        # re-prefilling them -- strictly fewer steps to first token,
        # strictly fewer pages written, at every voltage point
        assert ttft[(name, True)] < ttft[(name, False)], (name, ttft)
        assert pages_new[(name, True)] < pages_new[(name, False)], (
            name, pages_new)
    # warm chunked TTFT (sharing off or on) beats the cold per-length
    # jit prefill a phase-separated admission would pay
    worst_ttft_us = max(sbest[k] / ssteps[k] * 1e6 * ttft[k]
                        for k in share_scheds)
    assert worst_ttft_us < pr4_us, (worst_ttft_us, pr4_us)

    # ---- mesh-shard scaling: capacity/concurrency linear, budgets flat
    counts = [n for n in SHARD_COUNTS if n <= len(jax.devices())]
    shard_scheds = {}
    for name, (plan, v) in voltages.items():
        for n in counts:
            s = _make_sharded(bundle, cfg, params, plan, n)
            if plan is not None:
                s._voltage = v
            shard_scheds[(name, n)] = s
            _drain_seconds(s, cfg)      # warm-up compile
    shbest = {k: np.inf for k in shard_scheds}
    shsteps = {}
    for _ in range(SHARD_REPS):
        for k, s in shard_scheds.items():       # interleaved
            dt, shsteps[k] = _drain_seconds(s, cfg)
            shbest[k] = min(shbest[k], dt)
    # snapshot trace counts BEFORE the make_jaxpr launch probe below:
    # tracing s._step_fn for the jaxpr appends a diagnostic trace that
    # is not part of the serving budget
    shtraces = {k: len(s.traces) for k, s in shard_scheds.items()}
    shard_launches = {}
    for n in counts:
        s = shard_scheds[("faulty", n)]
        st = s.stats
        # linear scaling is structural, not wall-clock: shard count
        # multiplies the admissible concurrency and the page capacity
        assert s.max_active == SHARD_SLOTS * n, (n, s.max_active)
        assert st["peak_active"] == min(N_REQUESTS, SHARD_SLOTS * n), (
            n, st["peak_active"])
        assert st["free_pages"] == SHARD_PAGES * n, (n, st["free_pages"])
        assert all(sh["free_pages"] == SHARD_PAGES
                   for sh in st["shards"]), st["shards"]
        # ...while the per-shard budgets stay flat: ONE trace for the
        # whole fleet, one pallas launch per shard branch
        for name in voltages:
            assert shtraces[(name, n)] == 1, (
                name, n, shtraces[(name, n)])
        jaxpr = jax.make_jaxpr(s._step_fn)(params, s.state,
                                           jnp.float32(V_DEEP))
        shard_launches[n] = arena.count_pallas_calls(jaxpr.jaxpr)
        assert shard_launches[n] == n, (n, shard_launches[n])
        hlo = s._step.lower(params, s.state,
                            s._volt_vec()).compile().as_text()
        assert not any(c in hlo for c in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute")), n
        assert "input_output_alias" in hlo, n   # donation survives
    for (name, n), dt in sorted(shbest.items(),
                                key=lambda kv: (kv[0][0], kv[0][1])):
        rows.append({
            "name": f"sched_shard_scaling_{name}_s{n}",
            "us_per_call": dt / total_tokens * 1e6,
            "derived": (f"tokens_per_sec={total_tokens / dt:.1f};"
                        f"shards={n};"
                        f"concurrency={SHARD_SLOTS * n};"
                        f"pool_pages={SHARD_PAGES * n};"
                        f"steps={shsteps[(name, n)]};"
                        f"launches_per_shard=1;decode_traces="
                        f"{shtraces[(name, n)]}")})
    rows.append({
        "name": "sched_shard_scaling_summary",
        "us_per_call": 0.0,
        "derived": (
            f"shard_counts={'/'.join(str(n) for n in counts)};"
            f"devices={len(jax.devices())};"
            f"concurrency_per_shard={SHARD_SLOTS};"
            f"pages_per_shard={SHARD_PAGES};"
            f"launches={'/'.join(str(shard_launches[n]) for n in counts)};"
            "linear_capacity=pass;decode_traces=1;collectives=0")})

    # ---- migration storm: rows flip weak mid-stream at c=8 -----------
    # The self-healing contract's perf half: after the posterior
    # accuses the flipped rows and the in-step migration drains their
    # pages into quarantine, the steady-state decode step must return
    # to its pre-storm cost -- post-recovery median step time within
    # 10% of pre-storm.  At V_GUARD the same flip is silent (no
    # corrections -> no migrations); 'clean' is the no-plan baseline.
    storm = {}
    for point in STORM_POINTS:
        s = _storm_sched(bundle, cfg, params, point)
        _storm_drain(s, cfg, chaos=False)        # warm-up: compiles step
        times, flipped, last_heal, mig_pre = _storm_drain(
            s, cfg, chaos=(point != "clean"))
        st = s.stats
        pre = float(np.median(times[2:STORM_AT]))
        rec = (0 if last_heal is None
               else max(0, last_heal - STORM_AT + 1))
        post_w = times[STORM_AT + rec + 1:-1] or times[STORM_AT + rec:]
        post = float(np.median(post_w))
        storm[point] = dict(
            s=s, pre=pre, post=post, rec=rec, flipped=flipped,
            mig_pre=mig_pre,
            migrations=st.get("migrations", 0),
            quarantined=st.get("quarantined_pages", 0),
            corrected=int(st.get("corrected", 0)),
            uncorrectable=int(st.get("uncorrectable", 0)))
        rows.append({
            "name": f"sched_migration_storm_{point}_c{N_REQUESTS}",
            "us_per_call": post * 1e6,
            "derived": (
                f"pre_storm_step_us={pre * 1e6:.0f};"
                f"post_recovery_step_us={post * 1e6:.0f};"
                f"tokens_per_sec_pre={N_REQUESTS / pre:.1f};"
                f"tokens_per_sec_post={N_REQUESTS / post:.1f};"
                f"post_over_pre_x={post / pre:.2f};"
                f"storm_rows={0 if point == 'clean' else STORM_ROWS};"
                f"storm_pages={len(flipped)};"
                f"recovery_steps={rec};"
                f"migrations={storm[point]['migrations']};"
                f"quarantined_pages={storm[point]['quarantined']};"
                f"corrected={storm[point]['corrected']};"
                f"uncorrectable={storm[point]['uncorrectable']};"
                f"concurrency={N_REQUESTS};decode_traces="
                f"{len(s.traces)}")})

    # ---- migration-storm acceptance asserts --------------------------
    for point in STORM_POINTS:
        assert len(storm[point]["s"].traces) == 1, (
            point, len(storm[point]["s"].traces))
    f, g = storm["faulty"], storm["guardband"]
    assert f["mig_pre"] == 0, (
        f"{f['mig_pre']} migrations before the storm: static weak "
        "pages are driving the healing loop, not the flipped rows")
    assert f["migrations"] >= 1 and f["quarantined"] >= 1, f
    assert f["rec"] >= 1, (
        "the storm never triggered an in-stream migration", f)
    assert f["corrected"] > 0 and f["uncorrectable"] == 0, f
    assert g["migrations"] == 0 and g["corrected"] == 0, (
        "the flipped rows must stay silent at V_GUARD", g)
    slow_storm = f["post"] / f["pre"]
    assert slow_storm <= 1.10, (
        f"post-recovery step time {slow_storm:.2f}x pre-storm "
        f"(budget 1.10x): self-healing did not restore throughput")

    # ---- energy accounting: joules/token across the voltage points ---
    # Two families of rows off the observability plane's donated
    # counters.  (a) PRICED: the clean c=8 scheduler's recorded
    # workload (bytes moved + wall time), re-priced at nominal /
    # guardband / deep rail voltage -- identical traffic, so the
    # joules/token ratios are exactly the paper's power ratios (Fig 2:
    # ~1.5x at the guardband, ~2.3x at the deepest point).  (b)
    # MEASURED: each scheduler's own counters at its own operating
    # voltage, ECC off (throughput scheds) and on (storm scheds).
    s8 = scheds[("clean", 8)]
    E_DEEP = 0.85
    priced = {}
    for v in (V_NOM, V_GUARD, E_DEEP):
        en = s8.metrics.energy(s8.state, [v] * s8.n_shards)
        priced[v] = en
        rows.append({
            "name": f"sched_energy_priced_v{int(round(v * 100)):03d}",
            "us_per_call": en["wall_seconds"] / en["tokens"] * 1e6,
            "derived": (
                f"voltage={v:.2f};"
                f"joules_per_token={en['joules_per_token']:.4f};"
                f"usd_per_mtok={en['usd_per_mtok']:.4f};"
                f"tokens_per_joule={en['tokens_per_joule']:.4f};"
                f"kv_bytes_moved={en['kv_bytes_moved']};"
                f"tokens={en['tokens']};workload=clean_c8_repriced")})
    save_guard = (priced[V_NOM]["joules_per_token"]
                  / priced[V_GUARD]["joules_per_token"])
    save_deep = (priced[V_NOM]["joules_per_token"]
                 / priced[E_DEEP]["joules_per_token"])
    assert save_guard >= 1.4, (
        f"guardband joules/token improvement {save_guard:.2f}x < 1.4x "
        "over nominal (paper Fig 2 guardband ratio)")
    assert save_deep >= 2.2, (
        f"deepest-point joules/token improvement {save_deep:.2f}x < "
        "2.2x over nominal (paper Fig 2 deep ratio)")
    for name in STORM_POINTS:
        for ecc, s in (("off", scheds[(name, 8)]),
                       ("on", storm[name]["s"])):
            if name == "clean" and ecc == "on":
                continue           # the clean storm sched has no plan
            en = s.metrics.energy(s.state, s.pricing_voltages)
            rows.append({
                "name": f"sched_energy_{name}_ecc_{ecc}_c{N_REQUESTS}",
                "us_per_call": (en["wall_seconds"]
                                / max(en["tokens"], 1) * 1e6),
                "derived": (
                    f"voltage={s.pricing_voltages[0]:.2f};ecc={ecc};"
                    f"joules_per_token={en['joules_per_token']:.4f};"
                    f"usd_per_mtok={en['usd_per_mtok']:.4f};"
                    f"tokens_per_joule={en['tokens_per_joule']:.4f};"
                    f"kv_bytes_moved={en['kv_bytes_moved']};"
                    f"tokens={en['tokens']}")})

    # ---- mode='efficiency': tokens-per-joule argmax under a rate SLO -
    plan_e = _plan(V_DEEP)
    gov_e = plan_e.make_governor("kv", mode="efficiency",
                                 tolerable_rate=1e-4, setpoint=1e-4,
                                 v_lo=0.85)
    sc_e = ServeConfig(max_len=MAX_LEN, max_new_tokens=NEW_TOKENS,
                       undervolt=plan_e, governor=gov_e,
                       kv_injection="read", kv_method="word")
    s_e = ContinuousBatchingScheduler(
        bundle, cfg, params, sc_e, num_slots=max(CONCURRENCY),
        num_pages=max(CONCURRENCY) * (MAX_LEN // PAGE_SLOTS),
        page_slots=PAGE_SLOTS)
    _drain_seconds(s_e, cfg)                 # warm-up compile
    dt_e, steps_e = _drain_seconds(s_e, cfg)
    assert len(s_e.traces) == 1, len(s_e.traces)
    v_eff = float(s_e._shards[0].voltage)
    tpj_eff = float(gov_e.efficiency_at(v_eff))
    fixed_pts = (V_GUARD, 0.95, 0.92, 0.90, V_DEEP)
    tpj_fixed = {v: float(gov_e.efficiency_at(v)) for v in fixed_pts}
    assert tpj_eff + 1e-9 >= max(tpj_fixed.values()), (
        f"mode='efficiency' picked {v_eff:.2f} V "
        f"(tpj={tpj_eff:.3f}) but a fixed setpoint beats it: "
        f"{tpj_fixed}")
    en_e = s_e.metrics.energy(s_e.state, s_e.pricing_voltages)
    rows.append({
        "name": "sched_energy_efficiency_governor_c8",
        "us_per_call": dt_e / total_tokens * 1e6,
        "derived": (
            f"v_eff={v_eff:.2f};slo_rate=1e-4;"
            f"tpj_norm={tpj_eff:.4f};"
            + ";".join(f"tpj_norm_v{int(round(v * 100)):03d}="
                       f"{tpj_fixed[v]:.4f}" for v in fixed_pts) + ";"
            f"joules_per_token={en_e['joules_per_token']:.4f};"
            f"usd_per_mtok={en_e['usd_per_mtok']:.4f};"
            f"tokens_per_sec={total_tokens / dt_e:.1f};"
            f"steps={steps_e};decode_traces={len(s_e.traces)}")})

    # ---- model zoo: tokens/sec + joules/token per family -------------
    # One arch per family through the ONE scheduler front door (paged
    # or state-arena by dispatch), same undervolted write-path point,
    # interleaved min-of-reps like everything above.  Each row carries
    # a structured "zoo" object (schema-checked by
    # repro.obs.schema.BENCHMARKS_SCHEMA) so fleet dashboards can
    # compare families without parsing the derived string.
    zoo_scheds = {}
    for arch in ZOO_ARCHS:
        zb = get_arch(arch)
        zc = zb.reduced
        zp = trainer.init_state(zb, zc, jax.random.PRNGKey(0))["params"]
        zsc = ServeConfig(max_len=ZOO_MAX_LEN, max_new_tokens=ZOO_NEW,
                          undervolt=_plan(V_DEEP),
                          kv_injection="write", kv_method="bitwise")
        s = ContinuousBatchingScheduler(
            zb, zc, zp, zsc, num_slots=ZOO_SLOTS,
            num_pages=ZOO_SLOTS * (ZOO_MAX_LEN // PAGE_SLOTS),
            page_slots=PAGE_SLOTS)
        zoo_scheds[arch] = (s, zb, zc)
        _zoo_drain(s, zc)               # warm-up: compiles the step
    zbest = {k: np.inf for k in zoo_scheds}
    for _ in range(ZOO_REPS):
        for arch, (s, _, zc) in zoo_scheds.items():     # interleaved
            zbest[arch] = min(zbest[arch], _zoo_drain(s, zc))
    zoo_tokens = ZOO_SLOTS * ZOO_NEW
    for arch, (s, zb, zc) in zoo_scheds.items():
        st = s.stats
        assert st["decode_traces"] == 1, (arch, st)
        en = s.metrics.energy(s.state, s.pricing_voltages)
        dt = zbest[arch]
        rows.append({
            "name": f"sched_zoo_{zc.family}_{arch.replace('.', '_')}",
            "us_per_call": dt / zoo_tokens * 1e6,
            "zoo": {
                "arch": arch,
                "family": zc.family,
                "route": st["route"],
                "cache_layouts": sorted(set(st["cache_layouts"])),
                "tokens_per_sec": zoo_tokens / dt,
                "joules_per_token": float(en["joules_per_token"]),
                "decode_traces": st["decode_traces"],
            },
            "derived": (
                f"family={zc.family};route={st['route']};"
                f"layouts={'+'.join(sorted(set(st['cache_layouts'])))};"
                f"tokens_per_sec={zoo_tokens / dt:.1f};"
                f"joules_per_token={en['joules_per_token']:.4f};"
                f"usd_per_mtok={en['usd_per_mtok']:.4f};"
                f"voltage={V_DEEP:.2f};concurrency={ZOO_SLOTS};"
                f"decode_traces={st['decode_traces']}")})

    rows.append({
        "name": "sched_scaling_summary",
        "us_per_call": 0.0,
        "derived": (
            f"clean_c1={tput[('clean', 1)]:.1f};"
            f"clean_c8={tput[('clean', 8)]:.1f};"
            f"faulty_c8={tput[('faulty', 8)]:.1f};"
            f"guardband_over_faulty_x={slow:.2f};"
            f"ttft_steps_share={ttft[('faulty', True)]:.1f};"
            f"ttft_steps_noshare={ttft[('faulty', False)]:.1f};"
            f"pallas_launches={launches[8]};decode_traces=1")})
    return rows


if __name__ == "__main__":
    # --merge-json: splice this module's rows into the existing
    # results/benchmarks.json under the driver's "scheduler_bench" key
    # (the multi-device CI job runs only this module under forced host
    # devices, and its shard-scaling rows must land in the same file
    # benchmarks/run.py writes).
    out_rows = run()
    from benchmarks.run import _attach_telemetry
    totals = {}
    _attach_telemetry(out_rows, totals)
    for r in out_rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
    if totals:
        print("# telemetry_counter_totals: " + ";".join(
            f"{k}={v}" for k, v in sorted(totals.items())))
    if "--merge-json" in sys.argv:
        path = os.path.join("results", "benchmarks.json")
        all_rows = {}
        if os.path.exists(path):
            with open(path) as f:
                all_rows = json.load(f)
        all_rows["scheduler_bench"] = out_rows
        os.makedirs("results", exist_ok=True)
        with open(path, "w") as f:
            json.dump(all_rows, f, indent=1, default=str)
        print(f"# merged {len(out_rows)} rows into {path}")
