"""Paper-reproduction benchmarks: one function per figure/table.

Every function returns rows (list of dicts) and ASSERTS the paper's
quantitative claims (C1-C11 in DESIGN.md) -- a failed anchor fails the
benchmark run.  The reliability figures run the actual injection kernel
through Algorithm 1 on scaled-down arrays; the power figures evaluate
the calibrated model the measurements were fitted to.
"""
from __future__ import annotations

import numpy as np

from repro.core import reliability as rel
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.faultmodel import (DEFAULT_FAULT_MODEL, V_CRITICAL, V_MIN,
                                   V_NOM)
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, voltage_grid
from repro.core.voltage import DEFAULT_POWER_MODEL

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
PM = DEFAULT_POWER_MODEL
FM = DEFAULT_FAULT_MODEL


def fig2_power():
    """Fig. 2: normalized power vs voltage at 0/25/50/75/100% bandwidth."""
    rows = []
    for v in voltage_grid(step=0.05):
        for util in (0.0, 0.25, 0.5, 0.75, 1.0):
            rows.append({"fig": "fig2", "voltage": float(v), "util": util,
                         "power": float(PM.power(v, util))})
    # anchors: 1.5x at V_min for every utilization; 2.3x at 0.85 V;
    # idle = 1/3 of full load (C2, C3, C10)
    for util in (0.0, 0.5, 1.0):
        assert abs(float(PM.savings(V_MIN, util)) - 1.5) < 0.01
    assert abs(float(PM.savings(0.85)) - 2.3) < 0.05
    assert abs(float(PM.power(V_NOM, 0.0)) - 1 / 3) < 1e-6
    return rows


def fig3_capacitance():
    """Fig. 3: normalized alpha*C_L*f vs voltage."""
    rows = [{"fig": "fig3", "voltage": float(v),
             "alpha_clf": float(PM.alpha_clf(v))}
            for v in voltage_grid(step=0.05)]
    assert abs(float(PM.alpha_clf(0.98)) - 1.0) < 0.03   # flat in guardband
    assert abs((1 - float(PM.alpha_clf(0.85))) - 0.14) < 0.01  # 14% drop
    return rows


def fig4_faultrate():
    """Fig. 4: faulty fraction per stack vs voltage (analytic + empirical
    via Algorithm 1 on a scaled-down PC)."""
    rows = []
    for v in voltage_grid(step=0.01):
        rows.append({"fig": "fig4", "voltage": float(v),
                     "hbm0": FMAP.stack_mean_rate(float(v), 0),
                     "hbm1": FMAP.stack_mean_rate(float(v), 1)})
    # C1/C5/C7 anchors
    assert FMAP.stack_mean_rate(0.98, 0) == 0.0
    assert FMAP.stack_mean_rate(0.83, 0) > 0.99
    r0, r1 = FMAP.stack_mean_rate(0.92, 0), FMAP.stack_mean_rate(0.92, 1)
    assert r1 > r0
    # empirical spot-check with the injection kernel (C5 growth)
    counts = []
    for v in (0.92, 0.90, 0.88):
        t = rel.run_pc_test(FMAP, v, pc=19, mem_words=1 << 18,
                            pattern=rel.ALL_ZEROS, method="auto")
        counts.append(t.fault_counts[0])
        rows.append({"fig": "fig4_empirical", "voltage": v, "pc": 19,
                     "faults": t.fault_counts[0]})
    assert counts[0] < counts[1] < counts[2]
    return rows


def fig5_pcmap():
    """Fig. 5: per-PC fault rates at representative voltages + pattern
    asymmetry (C4, C6, C8)."""
    rows = []
    for v in (0.95, 0.93, 0.91, 0.89, 0.87):
        total = FMAP.pc_total_rate(v)
        for pc in range(FMAP.geometry.num_pcs):
            rows.append({"fig": "fig5", "voltage": v, "pc": pc,
                         "rate": float(total[pc]),
                         "nf": bool(total[pc] * FMAP.geometry.bits_per_pc
                                    < 1.0)})
    total = FMAP.pc_total_rate(0.92)
    med = float(np.median(total))
    hot = [total[pc] for pc in (4, 5, 18, 19, 20)]
    assert np.mean(hot) > 3 * med                       # C8
    r01, r10 = FM.rates(0.90)
    assert abs(float(r01) / float(r10) - 1.21) < 0.03   # C6
    assert float(FM.rate_10(0.97)) > 0                  # C4 onsets
    assert float(FM.rate_01(0.97)) < float(FM.rate_10(0.97)) * 1e-3
    assert float(FM.rate_01(0.96)) > 0
    return rows


def fig6_tradeoff():
    """Fig. 6: usable PCs vs voltage per tolerable fault rate, plus the
    section III-C worked examples (C11)."""
    solver = TradeoffSolver(FMAP)
    rates = [0.0, 1e-8, 1e-6, 1e-4, 1e-2]
    grid = voltage_grid()
    matrix = solver.fig6_matrix(rates, grid)
    rows = [{"fig": "fig6", "tolerable_rate": t, "voltage": float(v),
             "usable_pcs": n}
            for t in rates for v, n in zip(grid, matrix[t])]
    # worked examples
    p = solver.solve(VCU128.total_bytes, 0.0)
    assert abs(p.voltage - 0.98) < 1e-6 and abs(p.savings - 1.5) < 0.01
    p7 = solver.solve(7 * VCU128.bytes_per_pc, 0.0)
    assert p7.savings >= 1.55                            # ~1.6x at 0.95V
    ph = solver.solve(VCU128.total_bytes // 2, 1e-6)
    assert abs(ph.voltage - 0.90) < 0.015
    assert abs(ph.savings - 1.8) < 0.1
    rows.append({"fig": "fig6_examples",
                 "full_zero_fault_x": p.savings,
                 "seven_pc_x": p7.savings,
                 "half_cap_1e6_x": ph.savings})
    return rows


def guardband_table():
    """Headline table: guardband fraction + region boundaries."""
    assert abs(FM.guardband_fraction() - 0.19) < 0.005
    return [{"fig": "guardband", "v_nom": V_NOM, "v_min": V_MIN,
             "v_critical": V_CRITICAL,
             "guardband_frac": FM.guardband_fraction()}]


ALL = {
    "fig2_power": fig2_power,
    "fig3_capacitance": fig3_capacitance,
    "fig4_faultrate": fig4_faultrate,
    "fig5_pcmap": fig5_pcmap,
    "fig6_tradeoff": fig6_tradeoff,
    "guardband_table": guardband_table,
}
