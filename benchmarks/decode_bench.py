"""Decode throughput: read-path fused injection vs the legacy
full-cache re-inject path, short vs long context.

The acceptance contract of the read-path refactor, asserted here and
recorded in results/benchmarks.json: injected decode must sit within
1.3x of the uninjected decode step at max_len=512, against the PR2
full-cache re-inject path shown >= 3x slower in the same bench.

"Uninjected decode step" means the same scanned engine driven at a
traced guardband voltage -- the zero-recompile serving contract is that
one compiled step serves every voltage, so injection on/off is purely a
runtime schedule.  Both fast modes are asserted:

  * write mode (incremental write path): injecting the O(new-token)
    slice adds < 1.3x over its guardband no-op -- injection work no
    longer scales with total cache size;
  * read mode (fused read path): the step is voltage-insensitive within
    1.3x -- corruption mask math is part of the attention tile pass, so
    turning faults on costs ~nothing *marginal*.  (In interpret mode
    that mask math runs as real CPU compute; the plain-XLA-attention
    row is reported for context, and the gap to it is CPU-emulation
    overhead of the Pallas kernel, not an HBM cost -- on TPU the masks
    ride the VPU while the tile loads.)
  * the legacy PR2-style path (python loop, full-cache re-injection
    every token) is >= 3x slower than read-path decode on the same
    workload -- injection work that scales with cache size, not tokens;
  * the jitted decode's pallas-launch count is flat in sequence length
    (read-path corruption rides the attention launch);
  * a 5-point traced KV-voltage sweep over the scanned decode compiles
    exactly once.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch
from repro.models.cache import init_cache
from repro.serving.engine import ServeConfig, build_decode_engine
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

BATCH = 2
PROMPT = 8
NEW_TOKENS = 17            # 16 scanned steps after the prefill token
V_DEEP = 0.88              # ~1e-4 per-bit rates: the word path's regime
V_GUARD = 0.98
SHORT, LONG = 128, 512
REPS = 5


def _plan():
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", V_DEEP,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _setup():
    bundle = get_arch("llama3.2-3b")
    # The tier-1 reduced config is sized for test latency; the bench
    # model keeps its tiny KV geometry but restores a realistic compute
    # mix (MLP + vocab dominate a decode step, as at production scale).
    cfg = dataclasses.replace(bundle.reduced, d_model=96, d_ff=384,
                              vocab=4096)
    bundle = dataclasses.replace(bundle, reduced=cfg)
    params = trainer.init_state(bundle, cfg,
                                jax.random.PRNGKey(0))["params"]
    return bundle, cfg, params


def _engine(bundle, cfg, max_len, mode):
    """clean: no undervolt (plain XLA attention).  Other modes: the
    undervolted engine built for a *traced* voltage, so one engine
    serves any runtime voltage (including the guardband no-op used as
    the uninjected baseline)."""
    if mode == "clean":
        sc = ServeConfig(max_len=max_len, max_new_tokens=NEW_TOKENS)
        return build_decode_engine(bundle, cfg, sc, BATCH, PROMPT,
                                   static_voltage=None)
    sc = ServeConfig(max_len=max_len, max_new_tokens=NEW_TOKENS,
                     undervolt=_plan(), kv_injection=mode,
                     kv_method="word")
    return build_decode_engine(bundle, cfg, sc, BATCH, PROMPT,
                               static_voltage=None)


def _time_scan_cases(bundle, cfg, params, cases):
    """Seconds per decoded token for a list of (name, eng, max_len, v)
    scanned-driver cases, measured *interleaved*: one rep of every case
    per pass, min over passes.  Interleaving makes the ratio asserts
    robust to machine-load drift (a slow phase hits all variants), and
    min-of-reps is the noise-robust estimator.  The cache is donated,
    so every rep gets a fresh one -- built off the clock."""
    tok0 = jnp.zeros((BATCH, 1), jnp.int32)
    key = jax.random.PRNGKey(0)

    def fresh(max_len):
        return init_cache(bundle.module.cache_specs(cfg, BATCH, max_len))

    for name, eng, max_len, v in cases:       # compile off the clock
        jax.block_until_ready(eng.decode_all(
            params, fresh(max_len), tok0, key, jnp.float32(v)))
    best = {name: np.inf for name, *_ in cases}
    for _ in range(REPS):
        for name, eng, max_len, v in cases:
            c = fresh(max_len)
            t0 = time.perf_counter()
            jax.block_until_ready(eng.decode_all(params, c, tok0, key,
                                                 jnp.float32(v)))
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / eng.n_more)
    return best


def _time_loop(bundle, cfg, params, eng, max_len, v=V_DEEP):
    """Seconds per decoded token for the PR2-style python loop with
    full-cache re-injection inside each jitted step."""
    tok0 = jnp.zeros((BATCH, 1), jnp.int32)
    varr = jnp.float32(v)
    step = jax.jit(eng.step_core, donate_argnums=(1,))

    def run_once():
        c = init_cache(bundle.module.cache_specs(cfg, BATCH, max_len))
        c = eng.init_inject(c, varr)
        tok = tok0
        for i in range(eng.n_more):
            logits, c = step(params, c, tok, jnp.int32(PROMPT + i), varr)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return tok

    jax.block_until_ready(run_once())
    times = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(run_once())
        times.append(time.perf_counter() - t0)
    return float(np.min(times)) / eng.n_more


def run():
    bundle, cfg, params = _setup()
    rows = []
    per_tok = {}
    engines = {}
    for max_len, tag in ((SHORT, "short"), (LONG, "long")):
        spec = [("clean", "clean", V_DEEP),
                ("write_guardband", "write", V_GUARD),
                ("write", "write", V_DEEP),
                ("read_guardband", "read", V_GUARD),
                ("read", "read", V_DEEP)]
        cases = []
        for name, mode, v in spec:
            eng = engines.setdefault((mode, max_len),
                                     _engine(bundle, cfg, max_len, mode))
            cases.append((name, eng, max_len, v))
        best = _time_scan_cases(bundle, cfg, params, cases)
        for name, eng, max_len_, v in cases:
            s = best[name]
            per_tok[(name, tag)] = s
            rows.append({
                "name": f"decode_tokens_per_sec_{name}_{tag}",
                "us_per_call": s * 1e6,
                "derived": (f"tokens_per_sec={1.0 / s:.1f};batch={BATCH};"
                            f"max_len={max_len_};voltage={v};"
                            f"fused={eng.use_fused}")})
    # the PR2 path: python loop + full-cache re-inject per token
    eng_rw = _engine(bundle, cfg, LONG, "rewrite")
    s = _time_loop(bundle, cfg, params, eng_rw, LONG)
    per_tok[("rewrite_loop", "long")] = s
    rows.append({
        "name": "decode_tokens_per_sec_rewrite_loop_long",
        "us_per_call": s * 1e6,
        "derived": (f"tokens_per_sec={1.0 / s:.1f};batch={BATCH};"
                    f"max_len={LONG};voltage={V_DEEP};driver=loop")})

    # ---- acceptance asserts ----------------------------------------
    slow = per_tok[("rewrite_loop", "long")] / per_tok[("read", "long")]
    r_write = (per_tok[("write", "long")]
               / per_tok[("write_guardband", "long")])
    r_read = (per_tok[("read", "long")]
              / per_tok[("read_guardband", "long")])
    assert slow >= 3.0, (
        f"full-cache re-inject loop only {slow:.2f}x slower than "
        f"read-path decode (expected >= 3x)")
    assert r_write <= 1.3, (
        f"incremental write-path injection {r_write:.2f}x its "
        f"uninjected (guardband) step (budget 1.3x)")
    assert r_read <= 1.3, (
        f"read-path injected decode {r_read:.2f}x its uninjected "
        f"(guardband) step (budget 1.3x)")

    # pallas-launch budget: flat in sequence length
    launches = {}
    for max_len in (SHORT, LONG):
        eng = _engine(bundle, cfg, max_len, "read")
        cache = init_cache(bundle.module.cache_specs(cfg, BATCH, max_len))
        jaxpr = jax.make_jaxpr(lambda *a: eng.decode_all(*a))(
            params, cache, jnp.zeros((BATCH, 1), jnp.int32),
            jax.random.PRNGKey(0), jnp.float32(V_DEEP))
        launches[max_len] = arena.count_pallas_calls(jaxpr.jaxpr)
    assert launches[SHORT] == launches[LONG] == 1, launches

    # 5-point traced sweep over the scanned decode compiles once
    eng = _engine(bundle, cfg, SHORT, "read")
    traces = []

    @jax.jit
    def sweep_point(c, v):
        traces.append(1)
        return eng.decode_all(params, c,
                              jnp.zeros((BATCH, 1), jnp.int32),
                              jax.random.PRNGKey(0), v)

    for v in (0.92, 0.91, 0.90, 0.89, 0.88):
        c = init_cache(bundle.module.cache_specs(cfg, BATCH, SHORT))
        jax.block_until_ready(sweep_point(c, jnp.float32(v)))
    assert len(traces) == 1, f"sweep retraced {len(traces)} times"

    rows.append({
        "name": "decode_readpath_vs_rewrite",
        "us_per_call": per_tok[("read", "long")] * 1e6,
        "derived": (f"rewrite_loop_slowdown_x={slow:.2f};"
                    f"write_injected_over_uninjected_x={r_write:.2f};"
                    f"read_injected_over_uninjected_x={r_read:.2f};"
                    f"clean_xla_us={per_tok[('clean', 'long')] * 1e6:.0f};"
                    f"pallas_launches={launches[LONG]};sweep_traces=1")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
