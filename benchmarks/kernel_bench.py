"""Kernel microbenchmarks (CPU interpret mode: correctness-path timing;
the derived column reports the modeled TPU-side traffic so the roofline
claims are auditable)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.kernels.bitflip import ops as bops
from repro.kernels.ecc import ops as eops
from repro.kernels.flash_attention import ops as fops
from repro.kernels.rglru import ops as rops

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    n = 1 << 20
    x = jnp.zeros((n,), jnp.uint32)
    thr = FMAP.thresholds(0.90, pc=4)
    us = _time(bops.inject_u32, x, thresholds=thr, seed=1)
    rows.append({"name": "bitflip_word_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})
    thr2 = FMAP.thresholds(0.86, pc=4)
    us = _time(bops.inject_u32, x, thresholds=thr2, seed=1,
               method="bitwise")
    rows.append({"name": "bitflip_bitwise_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})
    us = _time(eops.inject_and_correct_u32, x, thresholds=thr, seed=1)
    rows.append({"name": "ecc_fused_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 1024, 128),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 1024, 128),
                          jnp.bfloat16)
    us = _time(fops.flash_attention, q, k, k, causal=True)
    flops = 4 * 1024 * 1024 * 8 * 128
    rows.append({"name": "flash_attn_1k_8h", "us_per_call": us,
                 "derived": f"flops={flops}"})

    a = jax.random.uniform(jax.random.PRNGKey(2), (8, 1024, 256),
                           jnp.float32, 0.9, 0.999)
    b = jax.random.normal(jax.random.PRNGKey(3), (8, 1024, 256)) * 0.1
    h0 = jnp.zeros((8, 256), jnp.float32)
    us = _time(rops.rglru_scan, a, b, h0)
    rows.append({"name": "rglru_scan_8x1k", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={3*8*1024*256*4}"})
    return rows
