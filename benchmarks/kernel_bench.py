"""Kernel microbenchmarks (CPU interpret mode: correctness-path timing;
the derived column reports the modeled TPU-side traffic so the roofline
claims are auditable)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, injection
from repro.core.domains import MemoryDomain, place_groups
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128, HBMGeometry
from repro.kernels.bitflip import ops as bops
from repro.kernels.ecc import ops as eops
from repro.kernels.flash_attention import ops as fops
from repro.kernels.rglru import ops as rops

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)

# Small-PC geometry for the arena rows: a multi-leaf domain spanning
# several pseudo-channels, the case the legacy path paid O(segments)
# launches for.  Shared with voltage_sweep.py so both benchmarks
# measure the same workload.
ARENA_GEOM = HBMGeometry(name="bench", num_stacks=2, channels_per_stack=2,
                         pcs_per_channel=2, bytes_per_pc=1024 * 1024)
ARENA_FMAP = FaultMap.from_seed(ARENA_GEOM, seed=7)


def arena_tree():
    """The multi-leaf (~640k-word) tensor group used by the arena rows."""
    rng = np.random.RandomState(0)
    return {"w": jnp.asarray(rng.rand(1 << 19), jnp.float32),
            "kv": jnp.asarray(rng.rand(64, 4096), jnp.bfloat16)}


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    n = 1 << 20
    x = jnp.zeros((n,), jnp.uint32)
    thr = FMAP.thresholds(0.90, pc=4)
    us = _time(bops.inject_u32, x, thresholds=thr, seed=1)
    rows.append({"name": "bitflip_word_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})
    thr2 = FMAP.thresholds(0.86, pc=4)
    us = _time(bops.inject_u32, x, thresholds=thr2, seed=1,
               method="bitwise")
    rows.append({"name": "bitflip_bitwise_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})
    us = _time(eops.inject_and_correct_u32, x, thresholds=thr, seed=1)
    rows.append({"name": "ecc_fused_1M_words", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={2*4*n}"})

    # Arena engine: one fused launch per domain, thresholds as runtime
    # data (voltage sweeps recompile nothing).
    tree = arena_tree()
    for ecc in (False, True):
        domains = {"d": MemoryDomain("d", 0.90, tuple(range(6)), ecc=ecc)}
        placement = place_groups({"g": tree}, {"g": "d"}, domains,
                                 ARENA_GEOM)["g"]
        n_segments = sum(len(l.segments) for l in placement.leaves)
        inject = jax.jit(lambda t, v, p=placement: injection.inject_group(
            t, p, ARENA_FMAP, voltage=v, method="word")[0])
        legacy = jax.jit(lambda t, p=placement: injection.inject_group(
            t, p, ARENA_FMAP, method="word", engine="segments")[0])
        launches = engine.count_pallas_calls(jax.make_jaxpr(
            lambda t: injection.inject_group(
                t, placement, ARENA_FMAP, method="word"))(tree).jaxpr)
        tag = "ecc" if ecc else "word"
        us = _time(inject, tree, jnp.float32(0.90))
        rows.append({"name": f"arena_{tag}_domain_640k_words",
                     "us_per_call": us,
                     "derived": (f"launches_per_domain={launches};"
                                 f"legacy_launches={n_segments}")})
        us = _time(legacy, tree)
        rows.append({"name": f"legacy_{tag}_domain_640k_words",
                     "us_per_call": us,
                     "derived": f"launches_per_domain={n_segments}"})

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 1024, 128),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 1024, 128),
                          jnp.bfloat16)
    us = _time(fops.flash_attention, q, k, k, causal=True)
    flops = 4 * 1024 * 1024 * 8 * 128
    rows.append({"name": "flash_attn_1k_8h", "us_per_call": us,
                 "derived": f"flops={flops}"})

    a = jax.random.uniform(jax.random.PRNGKey(2), (8, 1024, 256),
                           jnp.float32, 0.9, 0.999)
    b = jax.random.normal(jax.random.PRNGKey(3), (8, 1024, 256)) * 0.1
    h0 = jnp.zeros((8, 256), jnp.float32)
    us = _time(rops.rglru_scan, a, b, h0)
    rows.append({"name": "rglru_scan_8x1k", "us_per_call": us,
                 "derived": f"hbm_rw_bytes={3*8*1024*256*4}"})
    return rows
