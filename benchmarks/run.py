"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full
structured results to results/benchmarks.json.  Paper anchors are
asserted inside each figure benchmark -- a calibration regression
fails the run.

A *failing* benchmark module never publishes an error string as a
result or kills the later sections: every section uniformly records a
``status: skipped`` entry (the same shape the roofline table uses for
its unbuildable cells) and the driver moves on, so one broken section
cannot hide the others' results.  Regressions still fail the run: after
every section has executed and results/benchmarks.json is written, the
driver exits non-zero if any section was skipped, with each skip entry
carrying the original assertion/exception text.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _skip_row(name: str, exc: Exception):
    return [{"name": name, "status": "skipped",
             "error": f"{type(exc).__name__}: {exc}"}]


# ECC telemetry counters the self-healing serving rows pack into their
# ``derived`` strings.  The driver lifts them into structured row
# metadata (``row["telemetry"]``) and accumulates run-level totals, so
# results/benchmarks.json carries machine-readable fault telemetry
# next to every timing that was measured under injection.
TELEMETRY_KEYS = ("corrected", "uncorrectable", "migrations",
                  "quarantined_pages", "quarantined_blocks")

# Energy-accounting fields the observability rows pack the same way;
# lifted as floats (they are continuous, not counters) and NOT summed
# into run-level totals -- joules/token is a ratio, not additive.
ENERGY_KEYS = ("joules_per_token", "usd_per_mtok", "tokens_per_joule",
               "kv_bytes_moved")


def _attach_telemetry(rows, totals) -> None:
    for r in rows:
        if r.get("status") == "skipped" or "derived" not in r:
            continue
        telem = {}
        for field in str(r["derived"]).split(";"):
            k, eq, v = field.partition("=")
            if not eq:
                continue
            if k in TELEMETRY_KEYS:
                try:
                    telem[k] = int(float(v))
                except ValueError:
                    pass
            elif k in ENERGY_KEYS:
                try:
                    telem[k] = float(v)
                except ValueError:
                    pass
        if telem:
            r["telemetry"] = telem
            for k, v in telem.items():
                if k in TELEMETRY_KEYS:
                    totals[k] = totals.get(k, 0) + v


def _print_rows(rows) -> None:
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['name']},0,status=skipped")
        elif "us_per_call" in r:
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")


def main() -> None:
    from benchmarks import (decode_bench, kernel_bench, paper_figs,
                            roofline_table, scheduler_bench,
                            voltage_sweep)

    all_rows = {}
    n_skipped = 0
    telemetry_totals = {}
    print("name,us_per_call,derived")
    for name, fn in paper_figs.ALL.items():
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:
            all_rows[name] = _skip_row(name, e)
            n_skipped += 1
            _print_rows(all_rows[name])
            continue
        us = (time.perf_counter() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{us:.0f},rows={len(rows)};anchors=pass")

    for name, fn in (("kernel_bench", kernel_bench.run),
                     ("voltage_sweep", voltage_sweep.run),
                     ("decode_bench", decode_bench.run),
                     ("scheduler_bench", scheduler_bench.run)):
        try:
            rows = fn()
        except Exception as e:
            rows = _skip_row(name, e)
            n_skipped += 1
        _attach_telemetry(rows, telemetry_totals)
        all_rows[name] = rows
        _print_rows(rows)

    try:
        rows = roofline_table.run()   # also skips per cell internally
    except Exception as e:
        rows = _skip_row("roofline", e)
        n_skipped += 1
    all_rows["roofline"] = rows
    n_ok = sum(1 for r in rows if "bottleneck" in r)
    n_skip = sum(1 for r in rows if r.get("status") == "skipped")
    print(f"roofline_table,0,cells_ok={n_ok};skipped={n_skip}")

    if telemetry_totals:
        derived = ";".join(f"{k}={v}"
                           for k, v in sorted(telemetry_totals.items()))
        all_rows["telemetry"] = [{
            "name": "telemetry_counter_totals",
            "us_per_call": 0.0,
            "derived": derived,
            "telemetry": dict(telemetry_totals)}]
        print(f"telemetry_counter_totals,0,{derived}")

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    print(f"# wrote results/benchmarks.json"
          f" ({n_skipped} section(s) skipped)")
    if n_skipped:
        for name, rows in all_rows.items():
            for r in rows:
                # section-level skip rows carry "name"; the roofline
                # table's expected per-cell skips carry "cell" instead
                # and are not failures of the section
                if (r.get("status") == "skipped" and "error" in r
                        and "name" in r):
                    print(f"# SKIPPED {name}: {r['error']}",
                          file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
