"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full
structured results to results/benchmarks.json.  Paper anchors are
asserted inside each figure benchmark -- a calibration regression
fails the run.
"""
from __future__ import annotations

import json
import os
import time


def main() -> None:
    from benchmarks import (decode_bench, kernel_bench, paper_figs,
                            roofline_table, voltage_sweep)

    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in paper_figs.ALL.items():
        t0 = time.perf_counter()
        rows = fn()
        us = (time.perf_counter() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{us:.0f},rows={len(rows)};anchors=pass")

    rows = kernel_bench.run()
    all_rows["kernel_bench"] = rows
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")

    rows = voltage_sweep.run()
    all_rows["voltage_sweep"] = rows
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")

    rows = decode_bench.run()
    all_rows["decode_bench"] = rows
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")

    rows = roofline_table.run()
    all_rows["roofline"] = rows
    n_ok = sum(1 for r in rows if "bottleneck" in r)
    n_skip = sum(1 for r in rows if r.get("status") == "skipped")
    print(f"roofline_table,0,cells_ok={n_ok};skipped={n_skip}")

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    print("# wrote results/benchmarks.json")


if __name__ == "__main__":
    main()
