"""Zero-recompile voltage sweep: the arena engine's headline property.

The paper's methodology is a 10 mV-step voltage sweep (Figs. 4-6); with
the legacy per-segment path every sweep point retraced and recompiled
the injection kernels (thresholds were static jit arguments).  The arena
engine folds the voltage->threshold synthesis into the trace, so one
compiled function serves the whole sweep.  This benchmark runs a jitted
sweep over a multi-leaf, multi-PC domain, *asserts* trace-count == 1 and
launches-per-domain == 1, and reports per-point execution time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

try:  # run as a package module (python -m benchmarks.run) ...
    from benchmarks.kernel_bench import (ARENA_FMAP as FMAP,
                                         ARENA_GEOM as GEOM, arena_tree)
except ImportError:  # ... or as a file (python benchmarks/voltage_sweep.py)
    from kernel_bench import (ARENA_FMAP as FMAP, ARENA_GEOM as GEOM,
                              arena_tree)
from repro.core import engine, injection
from repro.core.domains import MemoryDomain, place_groups

VOLTAGES = (0.93, 0.92, 0.91, 0.90, 0.89)


def run():
    tree = arena_tree()
    domains = {"cheap": MemoryDomain("cheap", 0.91, tuple(range(6)))}
    placement = place_groups({"g": tree}, {"g": "cheap"}, domains, GEOM)["g"]

    traces = []

    @jax.jit
    def sweep_point(t, v):
        traces.append(1)
        out, _ = injection.inject_group(t, placement, FMAP, voltage=v,
                                        method="word")
        return out

    jaxpr = jax.make_jaxpr(lambda t: injection.inject_group(
        t, placement, FMAP, method="word"))(tree)
    launches = engine.count_pallas_calls(jaxpr.jaxpr)
    assert launches == 1, f"expected 1 launch per domain, saw {launches}"

    times = []
    for v in VOLTAGES:
        t0 = time.perf_counter()
        jax.block_until_ready(sweep_point(tree, jnp.float32(v)))
        times.append((time.perf_counter() - t0) * 1e6)
    assert len(traces) == 1, f"sweep retraced {len(traces)} times"

    n_blocks = placement.block_table().num_blocks
    rows = [{"name": "voltage_sweep_5pt",
             "us_per_call": float(np.mean(times[1:])),
             "derived": (f"traces=1;launches_per_domain={launches};"
                         f"blocks={n_blocks};first_call_us={times[0]:.0f}")}]
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
