"""Zero-recompile voltage sweep: the arena engine's headline property.

The paper's methodology is a 10 mV-step voltage sweep (Figs. 4-6); with
the legacy per-segment path every sweep point retraced and recompiled
the injection kernels (thresholds were static jit arguments).  The arena
engine folds the voltage->threshold synthesis into the trace, so one
compiled function serves the whole sweep.  This benchmark runs a jitted
sweep over a multi-leaf, multi-PC domain, *asserts* trace-count == 1 and
launches-per-domain == 1, and reports per-point execution time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

try:  # run as a package module (python -m benchmarks.run) ...
    from benchmarks.kernel_bench import (ARENA_FMAP as FMAP,
                                         ARENA_GEOM as GEOM, arena_tree)
except ImportError:  # ... or as a file (python benchmarks/voltage_sweep.py)
    from kernel_bench import (ARENA_FMAP as FMAP, ARENA_GEOM as GEOM,
                              arena_tree)
from repro.core import engine, injection
from repro.core.domains import MemoryDomain, place_groups
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, voltage_grid
from repro.training.undervolt import UndervoltPlan

VOLTAGES = (0.93, 0.92, 0.91, 0.90, 0.89)
BUDGETS = (1.0, 0.7, 0.62, 0.58, 0.55)


def run():
    tree = arena_tree()
    domains = {"cheap": MemoryDomain("cheap", 0.91, tuple(range(6)))}
    placement = place_groups({"g": tree}, {"g": "cheap"}, domains, GEOM)["g"]

    traces = []

    @jax.jit
    def sweep_point(t, v):
        traces.append(1)
        out, _ = injection.inject_group(t, placement, FMAP, voltage=v,
                                        method="word")
        return out

    jaxpr = jax.make_jaxpr(lambda t: injection.inject_group(
        t, placement, FMAP, method="word"))(tree)
    launches = engine.count_pallas_calls(jaxpr.jaxpr)
    assert launches == 1, f"expected 1 launch per domain, saw {launches}"

    times = []
    for v in VOLTAGES:
        t0 = time.perf_counter()
        jax.block_until_ready(sweep_point(tree, jnp.float32(v)))
        times.append((time.perf_counter() - t0) * 1e6)
    assert len(traces) == 1, f"sweep retraced {len(traces)} times"

    n_blocks = placement.block_table().num_blocks
    rows = [{"name": "voltage_sweep_5pt",
             "us_per_call": float(np.mean(times[1:])),
             "derived": (f"traces=1;launches_per_domain={launches};"
                         f"blocks={n_blocks};first_call_us={times[0]:.0f}")}]

    # --- governor-in-the-loop: re-planning voltage every step ----------
    # The governor maps a traced power budget to a frontier voltage
    # inside the compiled step (searchsorted over precomputed arrays),
    # so per-step re-planning must cost ~nothing vs the fixed-voltage
    # step and, critically, must not retrace.
    plan = UndervoltPlan(
        domains={"cheap": MemoryDomain("cheap", 0.91, tuple(range(6)))},
        policy={"g": "cheap"}, geometry=GEOM, map_seed=7)
    gov = plan.make_governor("cheap", mode="power", tolerable_rate=1.0,
                             v_lo=0.89)
    gov_traces = []

    @jax.jit
    def governed_step(t, budget):
        gov_traces.append(1)
        v = gov.voltage_at(budget)
        out, _ = injection.inject_group(t, placement, FMAP, voltage=v,
                                        method="word")
        return out

    @jax.jit
    def fixed_step(t):
        out, _ = injection.inject_group(t, placement, FMAP,
                                        voltage=jnp.float32(0.91),
                                        method="word")
        return out

    jax.block_until_ready(fixed_step(tree))   # compile
    t0 = time.perf_counter()
    for _ in range(len(BUDGETS)):
        jax.block_until_ready(fixed_step(tree))
    fixed_us = (time.perf_counter() - t0) / len(BUDGETS) * 1e6

    gov_times = []
    for b in BUDGETS:
        t0 = time.perf_counter()
        jax.block_until_ready(governed_step(tree, jnp.float32(b)))
        gov_times.append((time.perf_counter() - t0) * 1e6)
    assert len(gov_traces) == 1, (
        f"governed step retraced {len(gov_traces)} times")
    gov_us = float(np.mean(gov_times[1:]))
    rows.append({
        "name": "governor_in_loop_5pt",
        "us_per_call": gov_us,
        "derived": (f"traces=1;fixed_voltage_us={fixed_us:.0f};"
                    f"replan_overhead_pct="
                    f"{100.0 * (gov_us - fixed_us) / max(fixed_us, 1e-9):.1f};"
                    f"steps_per_sec={1e6 / max(gov_us, 1e-9):.1f};"
                    f"fixed_steps_per_sec={1e6 / max(fixed_us, 1e-9):.1f}")})

    # --- frontier-solve latency -----------------------------------------
    # One vectorized solve over the paper's full 40-point grid x 32 PCs
    # (what a plan/governor rebuild costs at runtime).
    solver = TradeoffSolver(FaultMap.from_seed(VCU128,
                                               seed=PAPER_MAP_SEED))
    grid = np.sort(voltage_grid())
    f = solver.frontier(grid, 1e-6)        # compile
    jax.block_until_ready(f.num_usable)
    reps = 20
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(solver.frontier(grid, 1e-6).num_usable)
    rows.append({
        "name": "frontier_solve_40v_32pc",
        "us_per_call": (time.perf_counter() - t0) / reps * 1e6,
        "derived": f"grid_points={len(grid)};pcs={VCU128.num_pcs}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")
