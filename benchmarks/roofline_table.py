"""Roofline table: renders results/dryrun.json (produced by
``python -m repro.launch.dryrun``) into the §Roofline rows."""
from __future__ import annotations

import json
import os


def run(path: str = "results/dryrun.json"):
    if not os.path.exists(path):
        # The dryrun input takes minutes of AOT compiles per cell and
        # must configure 512 host-platform devices *before* jax starts,
        # so it cannot be generated from inside this process: skip the
        # table cleanly instead of publishing an error string as a
        # result row.
        return [{"cell": "all", "status": "skipped",
                 "reason": f"{path} not present; generate it with "
                           "`PYTHONPATH=src python -m repro.launch.dryrun`"}]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") == "skipped":
            rows.append({"cell": key, "status": "skipped",
                         "reason": r.get("reason", "")[:80]})
            continue
        if r.get("status") != "ok":
            rows.append({"cell": key, "status": r.get("status"),
                         "error": r.get("error", "")[:120]})
            continue
        if r["mesh"] != "single":
            continue          # the roofline table is single-pod only
        rows.append({
            "cell": key,
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "bottleneck": r["bottleneck"],
            "useful_ratio": round(r["useful_ratio"], 3),
            "peak_gib": round(r["memory_gb"]["peak"], 2),
            "energy_098V": r["energy_savings"]["guardband_0.98V_x"],
            "energy_085V": r["energy_savings"]["deep_0.85V_x"],
        })
    return rows
