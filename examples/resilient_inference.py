"""EDEN-style resilient inference: serve a model with its KV cache in an
undervolted HBM domain and measure output degradation vs. power saved.

The paper's three-factor trade-off, application-level: at each voltage
the trade-off solver picks the most reliable PCs for the cache, faults
are injected through the real kernel every decode step, and we compare
greedy generations against the V_nom reference.

  PYTHONPATH=src python examples/resilient_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hbm import VCU128
from repro.models.base import get_arch, init_params
from repro.serving.engine import ServeConfig, generate
from repro.training.undervolt import UndervoltPlan
from repro.core.domains import MemoryDomain
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.voltage import DEFAULT_POWER_MODEL


def plan_at(v: float) -> UndervoltPlan:
    fmap = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
    pcs = tuple(int(p) for p in fmap.usable_pcs(v, 1.0))[:16] or tuple(
        range(16))
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, pcs)},
        policy={"kv_cache": "kv"}, geometry=VCU128,
        map_seed=PAPER_MAP_SEED)


def main():
    bundle = get_arch("gemma3-4b")
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                            (4, 12), 0, cfg.vocab)}

    ref = None
    for v in (1.20, 0.98, 0.93, 0.89, 0.86):
        sc = ServeConfig(max_len=64, max_new_tokens=16,
                         undervolt=plan_at(v) if v < 1.2 else None)
        toks = np.asarray(generate(bundle, cfg, params, prompts, sc))
        if ref is None:
            ref = toks
        agreement = float((toks == ref).mean())
        savings = float(DEFAULT_POWER_MODEL.savings(v, 0.5))
        print(f"V={v:.2f}  power_savings={savings:4.2f}x  "
              f"token_agreement_vs_nominal={agreement:5.1%}")

    print("\nguardband serving is bit-identical; deeper voltages trade "
          "fidelity for power -- the paper's capacity/fault-rate/power "
          "triangle at the application level.")


if __name__ == "__main__":
    main()
