"""Interactive-style exploration of the paper's three-factor trade-off,
driven by the vectorized frontier solver: one call evaluates every
voltage at once, and the same stacked arrays back the runtime voltage
governor (examples below print the governor's walk too).

  PYTHONPATH=src python examples/tradeoff_explorer.py [cap_gb] [rate]
"""
import sys

import numpy as np

from repro.core.domains import MemoryDomain
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, voltage_grid
from repro.training.undervolt import UndervoltPlan


def main():
    cap_gb = float(sys.argv[1]) if len(sys.argv) > 1 else 4.0
    rate = float(sys.argv[2]) if len(sys.argv) > 2 else 1e-6
    fmap = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
    solver = TradeoffSolver(fmap)

    p = solver.solve(int(cap_gb * 2**30), rate)
    print(f"requirement: {cap_gb} GB at fault rate <= {rate:g}")
    print(f"  -> run HBM at {p.voltage:.2f} V on {len(p.pc_ids)} PCs")
    print(f"     power savings {p.savings:.2f}x, worst PC rate "
          f"{p.worst_pc_rate:.2e}")

    # One vectorized frontier solve per tolerance: stacked per-voltage
    # arrays (savings, usable PCs, capacity) straight off the solver.
    print("\nFig. 6 frontier (usable PCs | savings):")
    rates = [0.0, 1e-8, 1e-6, 1e-4]
    grid = np.asarray([v for v in voltage_grid()
                       if round(v * 100) % 2 == 0])
    fronts = {r: solver.frontier(grid, r) for r in rates}
    print("   V    save " + "".join(f"  tol={r:<8g}" for r in rates))
    for i, v in enumerate(grid):
        cols = "".join(
            f"  {int(fronts[r].num_usable[i]):4d} PCs   " for r in rates)
        print(f"  {v:.2f} {float(fronts[rates[0]].savings[i]):4.2f}x{cols}")

    # The same frontier as a control loop: a runtime governor walking
    # voltage against a power budget for a cheap KV-cache domain.
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain(
            "kv", 0.91, tuple(int(x) for x in fmap.reliability_order(0.91)[:16]))},
        policy={"kv_cache": "kv"}, geometry=VCU128,
        map_seed=PAPER_MAP_SEED)
    gov = plan.make_governor("kv", mode="power", tolerable_rate=1e-3)
    print("\ngovernor walk (power budget -> planned voltage):")
    for budget in (1.0, 0.75, 0.65, 0.6, 0.55):
        print(f"  budget {budget:4.2f}x nominal -> "
              f"{float(gov.voltage_at(budget)):.2f} V")


if __name__ == "__main__":
    main()
