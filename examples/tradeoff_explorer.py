"""Interactive-style exploration of the paper's three-factor trade-off:
given a capacity requirement and a tolerable fault rate, print the
optimal operating point and the Fig. 6 frontier.

  PYTHONPATH=src python examples/tradeoff_explorer.py [cap_gb] [rate]
"""
import sys

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, voltage_grid


def main():
    cap_gb = float(sys.argv[1]) if len(sys.argv) > 1 else 4.0
    rate = float(sys.argv[2]) if len(sys.argv) > 2 else 1e-6
    fmap = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
    solver = TradeoffSolver(fmap)

    p = solver.solve(int(cap_gb * 2**30), rate)
    print(f"requirement: {cap_gb} GB at fault rate <= {rate:g}")
    print(f"  -> run HBM at {p.voltage:.2f} V on {len(p.pc_ids)} PCs")
    print(f"     power savings {p.savings:.2f}x, worst PC rate "
          f"{p.worst_pc_rate:.2e}")

    print("\nFig. 6 frontier (usable PCs):")
    rates = [0.0, 1e-8, 1e-6, 1e-4]
    grid = [v for v in voltage_grid() if round(v * 100) % 2 == 0]
    m = solver.fig6_matrix(rates, grid)
    hdr = "   V   " + "".join(f"  tol={r:g}" for r in rates)
    print(hdr)
    for i, v in enumerate(grid):
        print(f"  {v:.2f} " + "".join(
            f"  {m[r][i]:7d}" for r in rates))


if __name__ == "__main__":
    main()
