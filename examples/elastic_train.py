"""Fault-tolerant training: undervolt crash -> checkpoint restore ->
elastic re-mesh.

The paper observes that below V_critical = 0.81 V the HBM part stops
responding and needs a power cycle.  At fleet scale that IS a node
failure.  This example drives a training run where an over-aggressive
voltage plan crashes a domain mid-run; the driver catches the crash,
power-cycles (resets the domain to the guardband), restores the last
checkpoint, and continues -- bit-exact with an uninterrupted run thanks
to the deterministic data pipeline.

  PYTHONPATH=src python examples/elastic_train.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core.domains import DeviceCrashError, MemoryDomain
from repro.core.hbm import TPU_V5E
from repro.data.pipeline import DataConfig, make_batch
from repro.models.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan, guardband_plan


def main():
    bundle = get_arch("xlstm-350m")
    cfg = bundle.reduced
    dc = DataConfig(vocab=cfg.vocab, seq_len=48, global_batch=4, seed=9)
    adamw = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=100)

    def make_step(plan):
        tc = trainer.TrainConfig(adamw=adamw, undervolt=plan)
        return jax.jit(trainer.make_train_step(bundle, cfg, tc))

    with tempfile.TemporaryDirectory() as ckdir:
        step = make_step(guardband_plan(TPU_V5E))
        state = trainer.init_state(bundle, cfg, jax.random.PRNGKey(0))
        i = 0
        while i < 10:
            state, m = step(state, {k: jnp.asarray(v) for k, v in
                                    make_batch(dc, i).items()})
            i += 1
        ckpt.save(ckdir, i, state)
        print(f"checkpointed at step {i}, loss {float(m['loss']):.4f}")

        # operator pushes the rail below V_critical: the part crashes
        try:
            bad = UndervoltPlan(
                domains={"all": MemoryDomain(
                    "all", 0.80, tuple(range(TPU_V5E.num_pcs)))},
                policy={"params": "all", "mu": "all", "nu": "all"},
                geometry=TPU_V5E)
            make_step(bad)
            raise AssertionError("should have crashed")
        except DeviceCrashError as e:
            print(f"CRASH detected: {e}")
            print("power-cycling domain, restoring last checkpoint...")

        restored, meta = ckpt.restore(ckdir, state)
        state = jax.tree_util.tree_map(jnp.asarray, restored)
        i = meta["step"]
        step = make_step(guardband_plan(TPU_V5E))   # recovered voltage
        for _ in range(5):
            state, m = step(state, {k: jnp.asarray(v) for k, v in
                                    make_batch(dc, i).items()})
            i += 1
        print(f"resumed to step {i}, loss {float(m['loss']):.4f}")
        print("elastic restart complete -- the deterministic pipeline "
              "replays the exact same batches after restore.")


if __name__ == "__main__":
    main()
