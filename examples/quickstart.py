"""Quickstart: train a small LM with the paper's undervolting feature on.

Runs on CPU in ~2 minutes: a reduced llama3.2 config, synthetic Markov
data, AdamW, checkpointing, and an undervolt plan that keeps optimizer
state in the guardband-safe domain (1.5x HBM power) while weights ride
an unsafe 0.93 V domain.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.hbm import TPU_V5E
from repro.data.pipeline import DataConfig, make_batch
from repro.models.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training import trainer
from repro.training.undervolt import aggressive_plan


def main():
    bundle = get_arch("llama3.2-3b")
    cfg = bundle.reduced
    plan = aggressive_plan(v_unsafe=0.93, geometry=TPU_V5E)
    tc = trainer.TrainConfig(
        microbatches=2,
        adamw=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200),
        undervolt=plan)
    step = jax.jit(trainer.make_train_step(bundle, cfg, tc))
    state = trainer.init_state(bundle, cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=1)

    report = plan.power_report(utilization=0.7)
    print(f"undervolt plan: blended HBM power savings "
          f"{report['blended_savings_x']:.2f}x "
          f"({report['pcs_powered']} PCs powered)")
    for name, d in report["domains"].items():
        print(f"  domain {name}: {d['voltage']:.2f} V ({d['region']}), "
              f"{d['pcs']} PCs, savings {d['savings_x']:.2f}x")

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dc, i).items()}
        state, m = step(state, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"grad_norm {float(m['grad_norm']):.3f}  "
                  f"faults(uncorrectable) "
                  f"{int(m.get('uncorrectable_faults', 0))}")
    print("final loss:", float(m["loss"]))
    assert float(m["loss"]) < 5.0, "training should make progress"


if __name__ == "__main__":
    main()
