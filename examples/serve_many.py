"""Serving at scale: a mixed-tier request stream with a shared system
prompt through the continuous-batching scheduler -- on one device or
sharded across a heterogeneous-voltage fleet.

A stream of requests with different prompts, generation lengths and
criticality tiers is pushed through one scheduler: strict-tier requests
get weak-row-free pages, tolerant requests soak up the weak pages first,
the admission governor walks the KV-domain voltage along the
power/reliability frontier as load changes, and every request --
prompt prefill included, chunked through the same program -- rides ONE
compiled step (watch ``decode_traces`` stay 1).

Half the stream opens with the same system prompt: after the first
tenant publishes it, later tenants map the cached prefix pages
read-only (copy-on-write) instead of recomputing and re-storing it --
watch ``pages_shared`` and the flat ``ttft`` of sharing tenants.

With ``--devices N`` the scheduler shards over an N-way serve mesh:
every shard draws its OWN fault map (independent weak-row draws --
real HBM parts differ) and admits against its own governor setpoint,
so the fleet runs heterogeneous voltages: strict shards stay shallow,
tolerant shards undervolt deep, and the fleet report aggregates the
power/reliability mix.  The decode step is still ONE compiled program
with zero cross-shard traffic.

  PYTHONPATH=src python examples/serve_many.py
  PYTHONPATH=src python examples/serve_many.py --devices 4
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1,
                    help="serve-mesh shard count (forces that many "
                    "host devices; must be set before jax imports)")
    return ap.parse_args()


ARGS = _parse()
if ARGS.devices > 1 and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={ARGS.devices}")

import jax                                            # noqa: E402
import numpy as np                                    # noqa: E402

from repro.core.domains import MemoryDomain           # noqa: E402
from repro.core.hbm import VCU128                     # noqa: E402
from repro.launch.mesh import make_serve_mesh         # noqa: E402
from repro.models.base import get_arch, init_params   # noqa: E402
from repro.serving.engine import ServeConfig          # noqa: E402
from repro.serving.scheduler import (                 # noqa: E402
    ContinuousBatchingScheduler, Request)
from repro.training.undervolt import UndervoltPlan    # noqa: E402


def main():
    n_shards = ARGS.devices
    bundle = get_arch("llama3.2-3b")
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))

    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.90,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    governor = plan.make_governor("kv", mode="rate",
                                  tolerable_rate=1e-3, v_lo=0.87)
    sc = ServeConfig(max_len=64, max_new_tokens=8, undervolt=plan,
                     governor=governor, kv_injection="read",
                     kv_method="bitwise", prefill_chunk=8,
                     share_prefix=True)
    kw = {}
    if n_shards > 1:
        # heterogeneous rate setpoints: shard 0 is the strict end of
        # the fleet (tight stuck-cell cap -> shallow undervolt), the
        # last shard the tolerant end (deep undervolt, max savings)
        setpoints = list(np.geomspace(1e-9, 1e-4, n_shards))
        kw = dict(mesh=make_serve_mesh(n_shards),
                  shard_setpoints=setpoints)
    sched = ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=4 * n_shards,
        num_pages=40 * n_shards, page_slots=8, **kw)

    rng = np.random.RandomState(0)
    system = rng.randint(0, cfg.vocab, (19,))   # shared system prompt
    tiers = ["cheap", "critical", "cheap", "hedged", "cheap", "cheap",
             "critical", "cheap"] * n_shards
    print(f"fleet: {sched.stats['n_shards']} shard(s), "
          f"{sched.stats['free_pages']} pages total, "
          f"{sched.pool.n_logical_pages} pages/request")
    for i, tier in enumerate(tiers):
        user = rng.randint(0, cfg.vocab, (4 + i % 8,))
        toks = np.concatenate([system, user]) if i % 2 else user
        sched.submit(Request(
            rid=f"req{i}", tokens=toks,
            max_new_tokens=4 + 2 * (i % 3), tier=tier,
            key=jax.random.PRNGKey(i)))

    results = sched.run()
    for i, tier in enumerate(tiers):
        r = results[f"req{i}"]
        pool_k = sched._shards[r.shard].pool
        weak = sum(1 for p in r.page_ids
                   if int(p) in pool_k._weak_set)
        print(f"req{i:<2d} [{tier:8s}] shard={r.shard} "
              f"v={r.voltage:.2f} ({weak} weak, "
              f"{r.pages_shared} shared) ttft={r.ttft_steps} "
              f"tokens={r.tokens[0].tolist()}")
    st = sched.stats
    for sh in st["shards"]:
        sp = ("-" if sh["setpoint"] is None
              else f"{sh['setpoint']:.1e}")
        print(f"shard {sh['shard']}: seed={sh['map_seed']} "
              f"setpoint={sp} v={sh['voltage']:.2f} "
              f"weak_pages={sh['weak_pages']} "
              f"free_pages={sh['free_pages']}")
    if "fleet" in st:
        fl = st["fleet"]
        print(f"fleet: power_factor mean={fl['power_factor_mean']:.3f} "
              f"max={fl['power_factor_max']:.3f} "
              f"worst_rate={fl.get('worst_rate', 0):.2e}")
    assert st["decode_traces"] == 1
    shared = [results[f"req{i}"].pages_shared
              for i in range(len(tiers)) if i % 2]
    assert any(s > 0 for s in shared[1:]), shared
    if n_shards > 1:
        vs = [sh["voltage"] for sh in st["shards"]]
        assert len(set(f"{v:.3f}" for v in vs)) > 1, (
            f"expected heterogeneous shard voltages, got {vs}")
        assert vs[0] >= vs[-1], vs   # strict shard runs shallower


if __name__ == "__main__":
    main()
