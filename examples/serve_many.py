"""Serving at scale: a mixed-tier request stream with a shared system
prompt through the continuous-batching scheduler.

A stream of requests with different prompts, generation lengths and
criticality tiers is pushed through one scheduler: strict-tier requests
get weak-row-free pages, tolerant requests soak up the weak pages first,
the admission governor walks the KV-domain voltage along the
power/reliability frontier as load changes, and every request --
prompt prefill included, chunked through the same program -- rides ONE
compiled step (watch ``decode_traces`` stay 1).

Half the stream opens with the same system prompt: after the first
tenant publishes it, later tenants map the cached prefix pages
read-only (copy-on-write) instead of recomputing and re-storing it --
watch ``pages_shared`` and the flat ``ttft`` of sharing tenants.

  PYTHONPATH=src python examples/serve_many.py
"""
import jax
import numpy as np

from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch, init_params
from repro.serving.engine import ServeConfig
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.training.undervolt import UndervoltPlan


def main():
    bundle = get_arch("llama3.2-3b")
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))

    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.90,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    governor = plan.make_governor("kv", mode="rate",
                                  tolerable_rate=1e-3, v_lo=0.87)
    sc = ServeConfig(max_len=64, max_new_tokens=8, undervolt=plan,
                     governor=governor, kv_injection="read",
                     kv_method="bitwise", prefill_chunk=8,
                     share_prefix=True)
    sched = ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=4, num_pages=40, page_slots=8)

    rng = np.random.RandomState(0)
    system = rng.randint(0, cfg.vocab, (19,))   # shared system prompt
    tiers = ["cheap", "critical", "cheap", "hedged", "cheap", "cheap",
             "critical", "cheap"]
    print(f"pool: {sched.pool.free_pages} pages "
          f"({len(sched.pool._weak)} weak, "
          f"{len(sched.pool._strong)} weak-free), "
          f"{sched.pool.n_logical_pages} pages/request")
    for i, tier in enumerate(tiers):
        user = rng.randint(0, cfg.vocab, (4 + i,))
        toks = np.concatenate([system, user]) if i % 2 else user
        sched.submit(Request(
            rid=f"req{i}", tokens=toks,
            max_new_tokens=4 + 2 * (i % 3), tier=tier,
            key=jax.random.PRNGKey(i)))

    results = sched.run()
    for i, tier in enumerate(tiers):
        r = results[f"req{i}"]
        weak = sum(1 for p in r.page_ids
                   if int(p) in sched.pool._weak_set)
        print(f"req{i} [{tier:8s}] v={r.voltage:.2f} "
              f"pages={r.page_ids.tolist()} ({weak} weak, "
              f"{r.pages_shared} shared) ttft={r.ttft_steps} "
              f"tokens={r.tokens[0].tolist()}")
    print("stats:", sched.stats)
    assert sched.stats["decode_traces"] == 1
    shared = [results[f"req{i}"].pages_shared for i in range(8) if i % 2]
    assert any(s > 0 for s in shared[1:]), shared


if __name__ == "__main__":
    main()
