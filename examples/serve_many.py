"""Serving at scale: a mixed-tier request stream with a shared system
prompt through the continuous-batching scheduler -- on one device or
sharded across a heterogeneous-voltage fleet.

A stream of requests with different prompts, generation lengths and
criticality tiers is pushed through one scheduler: strict-tier requests
get weak-row-free pages, tolerant requests soak up the weak pages first,
the admission governor walks the KV-domain voltage along the
power/reliability frontier as load changes, and every request --
prompt prefill included, chunked through the same program -- rides ONE
compiled step (watch ``decode_traces`` stay 1).

Half the stream opens with the same system prompt: after the first
tenant publishes it, later tenants map the cached prefix pages
read-only (copy-on-write) instead of recomputing and re-storing it --
watch ``pages_shared`` and the flat ``ttft`` of sharing tenants.

With ``--devices N`` the scheduler shards over an N-way serve mesh:
every shard draws its OWN fault map (independent weak-row draws --
real HBM parts differ) and admits against its own governor setpoint,
so the fleet runs heterogeneous voltages: strict shards stay shallow,
tolerant shards undervolt deep, and the fleet report aggregates the
power/reliability mix.  The decode step is still ONE compiled program
with zero cross-shard traffic.

With ``--chaos`` the stream runs on an ECC'd worst-channel domain and
a live DRAM row is flipped weak mid-stream: the fused read path's
SECDED correction counters feed the fault-map posterior, the accused
row's pages migrate inside the decode step, and the row is
quarantined -- watch the printed migration/quarantine counters while
every request still finishes (and ``decode_traces`` still stays 1).

With ``--arch NAME`` the stream runs any registered architecture
through the SAME scheduler front door: paged families take the lane
above, everything else (MoE, recurrent hybrids, xLSTM, whisper, VLM)
transparently dispatches to the state-arena route -- modality extras
(audio frames, image patches) ride the requests, one request is
replayed solo through ``generate()`` on its placement to prove
bit-equivalence, and ``decode_traces`` still stays 1.

  PYTHONPATH=src python examples/serve_many.py
  PYTHONPATH=src python examples/serve_many.py --devices 4
  PYTHONPATH=src python examples/serve_many.py --chaos
  PYTHONPATH=src python examples/serve_many.py --arch whisper-large-v3
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1,
                    help="serve-mesh shard count (forces that many "
                    "host devices; must be set before jax imports)")
    ap.add_argument("--chaos", action="store_true",
                    help="flip a live DRAM row weak mid-stream and "
                    "watch the self-healing loop detect it from the "
                    "SECDED counters, migrate its pages and "
                    "quarantine the row")
    ap.add_argument("--arch", default=None,
                    help="serve this registered architecture instead "
                    "of the default llama3.2-3b stream: the scheduler "
                    "front door dispatches paged vs state-arena by "
                    "family (incompatible with --devices/--chaos)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the observability plane after the "
                    "drain: the Prometheus text exposition (in-step "
                    "counters, step-latency quantiles, joules/token) "
                    "and the structured event trace as JSONL")
    return ap.parse_args()


ARGS = _parse()
if ARGS.devices > 1 and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={ARGS.devices}")

import jax                                            # noqa: E402
import numpy as np                                    # noqa: E402

from repro.core.domains import MemoryDomain           # noqa: E402
from repro.core.hbm import VCU128                     # noqa: E402
from repro.launch.mesh import make_serve_mesh         # noqa: E402
from repro.models.base import get_arch, init_params   # noqa: E402
from repro.serving.engine import ServeConfig          # noqa: E402
from repro.serving.scheduler import (                 # noqa: E402
    ContinuousBatchingScheduler, Request, SelfHealConfig)
from repro.training.undervolt import UndervoltPlan    # noqa: E402


def zoo_main(arch):
    """Any-family lane: run ``--arch`` through the one scheduler front
    door, print the route it dispatched to, and prove one request
    bit-identical to its solo ``generate()`` replay."""
    import dataclasses

    from repro.serving.engine import generate

    if ARGS.devices > 1 or ARGS.chaos:
        raise SystemExit("--arch is a single-shard lane; drop "
                         "--devices/--chaos")
    bundle = get_arch(arch)
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.90,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    sc = ServeConfig(max_len=32, max_new_tokens=6, undervolt=plan,
                     kv_injection="write", kv_method="bitwise")
    sched = ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=2, num_pages=16,
        page_slots=8)
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(3):
        extras = None
        if cfg.family == "audio":
            extras = {"frames": rng.standard_normal(
                (cfg.enc_len, cfg.d_model)).astype(np.float32)}
        elif cfg.family == "vlm":
            extras = {"patches": rng.standard_normal(
                (cfg.enc_len, cfg.frontend_dim)).astype(np.float32)}
        reqs.append((rng.randint(0, cfg.vocab, (4 + 2 * i,)), 3 + i,
                     extras))
        sched.submit(Request(
            rid=f"req{i}", tokens=reqs[-1][0], max_new_tokens=3 + i,
            key=jax.random.PRNGKey(7 * i), extras=extras))
    results = sched.run()
    st = sched.stats
    print(f"{arch} [{cfg.family}] route={st['route']} "
          f"layouts={sorted(set(st['cache_layouts']))} "
          f"steps={st['steps']} decode_traces={st['decode_traces']}")
    for i in range(3):
        r = results[f"req{i}"]
        print(f"req{i} v={r.voltage:.2f} tokens={r.tokens[0].tolist()}")
    assert st["decode_traces"] == 1, st

    # solo replay of req1 on its placement: the bit-equivalence
    # contract, same as tests/test_zoo_serving.py's matrix
    toks, n_new, extras = reqs[1]
    batch = {"tokens": toks[None]}
    for k, v in (extras or {}).items():
        batch[k] = v[None]
    solo = generate(bundle, cfg, params, batch,
                    dataclasses.replace(sc, max_new_tokens=n_new),
                    key=jax.random.PRNGKey(7),
                    kv_placement=results["req1"].placement)
    np.testing.assert_array_equal(np.asarray(solo),
                                  results["req1"].tokens)
    print("solo replay: bit-identical")


def main():
    if ARGS.arch is not None:
        zoo_main(ARGS.arch)
        return
    n_shards = ARGS.devices
    bundle = get_arch("llama3.2-3b")
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))

    kw = {}
    if ARGS.chaos:
        # Self-healing demo: an ECC'd domain on the four least-
        # reliable pseudo-channels, where a weak row at 0.91 V throws
        # correctable SECDED events on every read -- the telemetry the
        # healing loop feeds on.  (SelfHealConfig needs the fused ECC
        # read path: kv_injection='read', kv_method='word'.)
        plan = UndervoltPlan(
            domains={"kv": MemoryDomain("kv", 0.91, (8, 15, 18, 29),
                                        ecc=True)},
            policy={"kv_cache": "kv"}, geometry=VCU128)
        sc = ServeConfig(max_len=64, max_new_tokens=8, undervolt=plan,
                         kv_injection="read", kv_method="word",
                         prefill_chunk=8, share_prefix=True)
        kw["self_heal"] = SelfHealConfig()
        if n_shards > 1:
            kw["mesh"] = make_serve_mesh(n_shards)
    else:
        plan = UndervoltPlan(
            domains={"kv": MemoryDomain("kv", 0.90,
                                        tuple(range(VCU128.num_pcs)))},
            policy={"kv_cache": "kv"}, geometry=VCU128)
        governor = plan.make_governor("kv", mode="rate",
                                      tolerable_rate=1e-3, v_lo=0.87)
        sc = ServeConfig(max_len=64, max_new_tokens=8, undervolt=plan,
                         governor=governor, kv_injection="read",
                         kv_method="bitwise", prefill_chunk=8,
                         share_prefix=True)
        if n_shards > 1:
            # heterogeneous rate setpoints: shard 0 is the strict end
            # of the fleet (tight stuck-cell cap -> shallow
            # undervolt), the last shard the tolerant end (deep
            # undervolt, max savings)
            setpoints = list(np.geomspace(1e-9, 1e-4, n_shards))
            kw = dict(mesh=make_serve_mesh(n_shards),
                      shard_setpoints=setpoints)
    sched = ContinuousBatchingScheduler(
        bundle, cfg, params, sc, num_slots=4 * n_shards,
        num_pages=40 * n_shards, page_slots=8, **kw)

    rng = np.random.RandomState(0)
    system = rng.randint(0, cfg.vocab, (19,))   # shared system prompt
    tiers = ["cheap", "critical", "cheap", "hedged", "cheap", "cheap",
             "critical", "cheap"] * n_shards
    print(f"fleet: {sched.stats['n_shards']} shard(s), "
          f"{sched.stats['free_pages']} pages total, "
          f"{sched.pool.n_logical_pages} pages/request")
    for i, tier in enumerate(tiers):
        user = rng.randint(0, cfg.vocab, (4 + i % 8,))
        toks = np.concatenate([system, user]) if i % 2 else user
        sched.submit(Request(
            rid=f"req{i}", tokens=toks,
            max_new_tokens=4 + 2 * (i % 3), tier=tier,
            key=jax.random.PRNGKey(i)))

    if ARGS.chaos:
        # drain manually so the chaos hook fires mid-stream: after two
        # steps, flip the DRAM row under the oldest live page weak
        weakened = None
        step_i = 0
        while sched.queue or sched.n_active:
            sched.admit_pending()
            if not sched.n_active:
                break
            if weakened is None and step_i == 2:
                owned = sorted(sched.pool._owned)
                pc, row = sched.pool.page_rows(owned[0])[0]
                pids = sched.weaken_row(0, pc, row)
                weakened = (pc, row)
                print(f"CHAOS @step {step_i}: pc{pc} row {row} went "
                      f"weak ({len(pids)} live pages affected)")
            sched.step_once()
            step_i += 1
        results = sched.results
    else:
        results = sched.run()
    for i, tier in enumerate(tiers):
        r = results[f"req{i}"]
        pool_k = sched._shards[r.shard].pool
        weak = sum(1 for p in r.page_ids
                   if int(p) in pool_k._weak_set)
        print(f"req{i:<2d} [{tier:8s}] shard={r.shard} "
              f"v={r.voltage:.2f} ({weak} weak, "
              f"{r.pages_shared} shared) ttft={r.ttft_steps} "
              f"tokens={r.tokens[0].tolist()}")
    st = sched.stats
    for sh in st["shards"]:
        sp = ("-" if sh["setpoint"] is None
              else f"{sh['setpoint']:.1e}")
        print(f"shard {sh['shard']}: seed={sh['map_seed']} "
              f"setpoint={sp} v={sh['voltage']:.2f} "
              f"weak_pages={sh['weak_pages']} "
              f"free_pages={sh['free_pages']}")
    if "fleet" in st:
        fl = st["fleet"]
        print(f"fleet: power_factor mean={fl['power_factor_mean']:.3f} "
              f"max={fl['power_factor_max']:.3f} "
              f"worst_rate={fl.get('worst_rate', 0):.2e}")
    if ARGS.chaos:
        sh0 = st["shards"][0]
        print(f"self-heal: corrected={st['corrected']} "
              f"uncorrectable={st['uncorrectable']} "
              f"suspect_rows={sh0['suspect_rows']} "
              f"migrations={st['migrations']} "
              f"quarantined_pages={st['quarantined_pages']} "
              f"quarantined_blocks={st['quarantined_blocks']}")
        assert st["corrected"] > 0, "chaos row never produced telemetry"
        assert st["uncorrectable"] == 0, st
        assert st["migrations"] >= 1 and st["quarantined_pages"] >= 1, st
    assert st["decode_traces"] == 1
    shared = [results[f"req{i}"].pages_shared
              for i in range(len(tiers)) if i % 2]
    if not ARGS.chaos:
        # (under --chaos the reliability pin keeps the prefix cache
        # from publishing on the deep worst-PC domain, so sharing is
        # legitimately absent)
        assert any(s > 0 for s in shared[1:]), shared
    if n_shards > 1 and not ARGS.chaos:
        # (--chaos runs the fleet at one deep voltage, no governor)
        vs = [sh["voltage"] for sh in st["shards"]]
        assert len(set(f"{v:.3f}" for v in vs)) > 1, (
            f"expected heterogeneous shard voltages, got {vs}")
        assert vs[0] >= vs[-1], vs   # strict shard runs shallower

    if ARGS.metrics:
        from repro.obs import export
        print("\n---- prometheus exposition " + "-" * 38)
        print(export.prometheus_text(sched), end="")
        print("---- event trace (JSONL tail) " + "-" * 35)
        tail = sched.trace.events()[-8:]
        for ev in tail:
            import json
            print(json.dumps(ev.to_dict()))
        # Cross-check the donated counters against what the drain
        # provably did: every request spends n_new-1 decode steps (the
        # first token samples at the prefill transition) and consumes
        # its whole prompt through chunked prefill.
        tot = st["obs"]["totals"]
        want_dec = sum(r.tokens.shape[1] - 1 for r in results.values())
        assert tot["tokens_decoded"] == want_dec, (tot, want_dec)
        assert tot["kv_bytes_moved"] > 0
        assert st["obs"]["step_latency"]["count"] == st["steps"]
        en = st["obs"]["energy"]
        assert en["tokens"] == tot["tokens_decoded"]
        assert en["joules_per_token"] > 0
        assert st["events"]["admission"] == len(results)
        assert st["events"]["retirement"] == len(results)
        print(f"metrics OK: {tot['tokens_decoded']} tokens, "
              f"{tot['kv_bytes_moved']} KV bytes, "
              f"{en['joules_per_token']:.3f} J/token "
              f"(${en['usd_per_mtok']:.2f}/Mtok)")


if __name__ == "__main__":
    main()
