"""Undervolt plan: the paper's technique as a first-class training/serving
feature.

A plan assigns each tensor *group* (params / optimizer moments / KV
cache) to a MemoryDomain (voltage + pseudo-channel subset + ECC).  The
physical placement is computed once from avals; every step, groups in
unsafe domains pass through the stuck-at injection kernel after being
written -- exactly the semantics of storing them in undervolted HBM
(writes to stuck bits don't take).

``power_report`` integrates the calibrated power model over the domains:
the headline numbers (1.5x guardband / up to 2.3x deep undervolt) carry
straight through to training-step energy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.domains import (GroupPlacement, MemoryDomain, place_groups,
                                place_groups_tiered)
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.faultmodel import V_MIN, V_NOM
from repro.core.hbm import HBMGeometry, TPU_V5E
from repro.core.engine import inject_groups
from repro.core.injection import clamp_nonfinite
from repro.core.voltage import DEFAULT_POWER_MODEL


@functools.lru_cache(maxsize=None)
def _fault_map(geometry: HBMGeometry, map_seed: int) -> FaultMap:
    """Synthesizing a FaultMap runs numpy RNG over every PC; plans are
    frozen, so memoize on (geometry, seed) instead of rebuilding it on
    every ``apply``/``fault_map`` call."""
    return FaultMap.from_seed(geometry, map_seed)


@dataclasses.dataclass(frozen=True)
class UndervoltPlan:
    domains: Dict[str, MemoryDomain]
    policy: Optional[Dict[str, str]] = None  # tensor group -> domain name
    geometry: HBMGeometry = TPU_V5E
    map_seed: int = PAPER_MAP_SEED
    mitigation: str = "none"                # none | clamp
    enabled: bool = True
    # Criticality-aware alternative to ``policy``: tensor group -> tier
    # (name in repro.core.domains.TIERS or a CriticalityTier).  The
    # placement planner then routes each group to the most power-saving
    # domain whose predicted fault rate meets the tier, most-reliable
    # PCs first, with optional weak-row avoidance.
    tiers: Optional[Dict[str, Any]] = None

    def fault_map(self) -> FaultMap:
        return _fault_map(self.geometry, self.map_seed)

    def place(self, groups: Dict[str, Any]) -> Dict[str, GroupPlacement]:
        if self.tiers is not None:
            tiers = {g: self.tiers[g] for g in groups}
            return place_groups_tiered(groups, tiers, self.domains,
                                       self.geometry, self.fault_map())
        if self.policy is None:
            raise ValueError("UndervoltPlan needs a policy or tiers")
        return place_groups(groups, self.policy, self.domains,
                            self.geometry)

    def covers(self, group: str) -> bool:
        """Whether this plan places ``group`` (policy- or tier-driven)."""
        mapping = self.tiers if self.tiers is not None else self.policy
        return mapping is not None and group in mapping

    def make_governor(self, domain: str, **config_kw):
        """Frontier-walking runtime governor for one of this plan's
        domains (see :mod:`repro.training.governor`)."""
        from repro.training.governor import GovernorConfig, VoltageGovernor
        return VoltageGovernor(self, GovernorConfig(domain=domain,
                                                    **config_kw))

    def apply(self, groups: Dict[str, Any],
              placements: Dict[str, GroupPlacement], *, voltage=None,
              method: str = "auto"):
        """Inject each group's domain faults; returns (groups, metrics).

        ``voltage`` optionally overrides the *unsafe* domains' voltages
        and may be a *traced* scalar (e.g. a per-step schedule or an
        online V_min search): the arena engine folds it into the
        threshold-table computation, so sweeping it re-executes one
        compiled step instead of retracing.  Guardband-safe domains are
        never affected by a scalar override; pass a
        ``{domain name: voltage}`` dict to target domains explicitly.
        Dict keys are validated against the *plan's* domains, so one
        schedule dict can be shared across calls (train step, serve
        step) that each cover only some domains.

        ``method`` picks the injection math ('auto' | 'word' |
        'bitwise'); traced sweeps into the collapse regime (per-bit
        rates > ~1e-3) should pass 'bitwise', since 'auto' cannot see a
        traced voltage and dispatches from the configured domain
        voltages.
        """
        if isinstance(voltage, dict):
            unknown = set(voltage) - set(self.domains)
            if unknown:
                raise ValueError(
                    f"voltage override names unknown domains "
                    f"{sorted(unknown)}; plan has {sorted(self.domains)}")
            present = {placements[name].domain.name for name in groups}
            voltage = {k: v for k, v in voltage.items() if k in present}
        out, total_bad, total_corr = inject_groups(
            groups, placements, self.fault_map(), voltage=voltage,
            method=method, with_corrected=True)
        if self.mitigation == "clamp":
            out = {name: clamp_nonfinite(tree) for name, tree in out.items()}
        return out, {"uncorrectable_faults": total_bad,
                     "corrected_faults": total_corr}

    def power_report(self, utilization: float = 1.0) -> Dict[str, Any]:
        """Per-domain and blended power factors vs. nominal."""
        pm = DEFAULT_POWER_MODEL
        per = {}
        total_pcs = 0
        blended = 0.0
        for name, d in self.domains.items():
            s = float(pm.savings(d.voltage, utilization))
            per[name] = {"voltage": d.voltage, "savings_x": s,
                         "pcs": len(d.pc_ids), "ecc": d.ecc,
                         "region": ("guardband" if d.voltage >= V_MIN
                                    else "unsafe")}
            total_pcs += len(d.pc_ids)
            blended += len(d.pc_ids) * float(
                pm.power(d.voltage, utilization))
        unused = self.geometry.num_pcs - total_pcs
        # PCs not in any domain are powered off (capacity sacrifice).
        blended = blended / max(total_pcs, 1)
        nominal = float(pm.power(V_NOM, utilization))
        return {"domains": per,
                "pcs_powered": total_pcs,
                "pcs_off": unused,
                "blended_savings_x": nominal / max(blended, 1e-9)}


def guardband_plan(geometry: HBMGeometry = TPU_V5E) -> UndervoltPlan:
    """The zero-risk default: everything at V_min, 1.5x savings (C2)."""
    all_pcs = tuple(range(geometry.num_pcs))
    return UndervoltPlan(
        domains={"safe": MemoryDomain("safe", V_MIN, all_pcs)},
        policy={"params": "safe", "mu": "safe", "nu": "safe",
                "kv_cache": "safe"},
        geometry=geometry)


def aggressive_plan(v_unsafe: float = 0.91, mitigation: str = "clamp",
                    ecc: bool = False,
                    geometry: HBMGeometry = TPU_V5E,
                    map_seed: int = PAPER_MAP_SEED) -> UndervoltPlan:
    """Three-factor trade-off in action: optimizer moments + master params
    stay in a guardband-safe domain on the most reliable PCs; bulk
    read-mostly tensors ride the unsafe region for extra savings."""
    fmap = _fault_map(geometry, map_seed)
    order = list(fmap.usable_pcs(v_unsafe, 1.0))  # most reliable first
    order += [p for p in range(geometry.num_pcs) if p not in order]
    safe_pcs = tuple(int(p) for p in order[:16])
    cheap_pcs = tuple(int(p) for p in order[16:])
    return UndervoltPlan(
        domains={
            "safe": MemoryDomain("safe", V_MIN, safe_pcs),
            "cheap": MemoryDomain("cheap", v_unsafe, cheap_pcs, ecc=ecc),
        },
        policy={"params": "cheap", "mu": "safe", "nu": "safe",
                "kv_cache": "cheap"},
        geometry=geometry, map_seed=map_seed, mitigation=mitigation)


def tiered_plan(v_unsafe: float = 0.91, mitigation: str = "clamp",
                ecc: bool = False,
                geometry: HBMGeometry = TPU_V5E,
                map_seed: int = PAPER_MAP_SEED,
                tiers: Optional[Dict[str, Any]] = None) -> UndervoltPlan:
    """Criticality-tiered variant of :func:`aggressive_plan`: the same
    safe/cheap domain split, but groups declare *tiers* and the planner
    routes them -- optimizer state must stay provably clean, bulk
    read-mostly tensors ride the deepest domain their tolerance admits,
    each on the most reliable PCs still free."""
    fmap = _fault_map(geometry, map_seed)
    order = list(fmap.reliability_order(v_unsafe))
    safe_pcs = tuple(int(p) for p in order[:16])
    cheap_pcs = tuple(int(p) for p in order[16:])
    if tiers is None:
        tiers = {"params": "cheap", "mu": "safe", "nu": "safe",
                 "kv_cache": "cheap"}
    return UndervoltPlan(
        domains={
            "safe": MemoryDomain("safe", V_MIN, safe_pcs),
            "cheap": MemoryDomain("cheap", v_unsafe, cheap_pcs, ecc=ecc),
        },
        tiers=dict(tiers),
        geometry=geometry, map_seed=map_seed, mitigation=mitigation)
