"""Runtime voltage governor: the paper's Fig. 6 trade-off as a control
loop.

The offline story (Section III-C) is a table: at each voltage, some
pseudo-channels are reliable enough and the power model prices the rail.
Voltron's observation is that reduced-voltage operation pays off when the
system picks operating points *dynamically* from a characterized profile;
this module is that profile, precomputed once as the vectorized
:meth:`~repro.core.tradeoff.TradeoffSolver.frontier` and then walked
every step with *traced* setpoints:

  * ``mode='power'``: given a power budget (normalized power factor, as
    from a datacenter power cap), run the governed domain at the highest
    voltage -- i.e. the most reliable point -- whose power fits the
    budget.
  * ``mode='rate'``: given a tolerable worst-PC stuck-cell rate, run at
    the deepest voltage -- maximum savings -- that still meets it.
  * ``mode='efficiency'``: walk the frontier to *maximize tokens per
    joule* under a fault-rate SLO.  Undervolting preserves frequency
    (bandwidth and step time are constant), so at fixed throughput
    tokens/joule is 1/power -- but the deepest point is not free:
    tokens served through an uncorrectable-prone cache must be
    retried, and the expected retry fraction grows with the worst-PC
    stuck rate.  The efficiency score
    ``(1 - rate)^read_words_per_token / power(v)`` prices both, and
    its argmax over the SLO-feasible frontier is an *interior* point,
    not merely the deepest feasible voltage.

All walks are pure jnp over precomputed monotone arrays, so a
jitted train step re-plans voltage *every step* and still compiles
exactly once: the chosen voltage flows into the arena injection engine
through the PR-1 traced-voltage override path.

Serving admission is the third entry point: :meth:`VoltageGovernor.admit`
picks the deepest voltage at which the governed domain retains enough
*usable* capacity (tolerable-rate-clean PCs) for a request's KV cache --
the paper's capacity/rate/power triangle applied per admission.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.domains import CapacityError
from repro.core.faultmodel import V_MIN
from repro.core.tradeoff import TradeoffSolver, voltage_grid
from repro.core.voltage import DEFAULT_POWER_MODEL, PowerModel


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Static policy of a :class:`VoltageGovernor`.

    ``tolerable_rate`` defines which PCs count as *usable* for the
    capacity constraint (same semantics as the trade-off solver);
    ``required_bytes`` is the capacity the governed domain must keep
    usable at any chosen voltage.  ``setpoint`` is the default walk
    target when a step supplies none: a normalized power factor in
    ``mode='power'`` (1.0 = nominal power), a worst-PC stuck-cell rate
    in ``mode='rate'`` / ``'adaptive'`` / ``'efficiency'`` (for
    efficiency it is the fault-rate SLO constraining the
    tokens-per-joule argmax).

    ``read_words_per_token`` (``mode='efficiency'`` only) is the
    exposure scale converting a per-word stuck rate into a per-token
    retry probability: the governed KV-cache words one decoded token
    reads through the paged attention gather.
    """

    domain: str
    mode: str = "power"    # 'power' | 'rate' | 'adaptive' | 'efficiency'
    tolerable_rate: float = 1e-6
    required_bytes: int = 0
    setpoint: float = 1.0
    v_hi: float = V_MIN
    v_lo: float = 0.86
    step: float = 0.01
    read_words_per_token: int = 4096


class VoltageGovernor:
    """Walks one domain's voltage along the precomputed frontier.

    Built once per plan (host-side numpy + one vectorized frontier
    solve); :meth:`voltage_at` is pure jnp on captured constants, so it
    can be called with traced setpoints inside a compiled step.
    """

    def __init__(self, plan, config: GovernorConfig,
                 power_model: PowerModel = DEFAULT_POWER_MODEL):
        if config.mode not in ("power", "rate", "adaptive", "efficiency"):
            raise ValueError(f"unknown governor mode {config.mode!r}")
        if config.read_words_per_token < 1:
            raise ValueError(
                f"read_words_per_token={config.read_words_per_token} "
                "must be >= 1 (the per-token fault exposure scale)")
        if config.domain not in plan.domains:
            raise ValueError(
                f"governor domain {config.domain!r} not in plan domains "
                f"{sorted(plan.domains)}")
        self.config = config
        self.plan = plan
        domain = plan.domains[config.domain]
        fmap = plan.fault_map()
        geometry = fmap.geometry
        solver = TradeoffSolver(fmap, power_model)
        grid = np.sort(voltage_grid(config.v_hi, config.v_lo, config.step))
        f = solver.frontier(grid, config.tolerable_rate)

        dom_pcs = np.asarray(domain.pc_ids, np.int64)
        usable = np.asarray(f.usable)[:, dom_pcs]           # (V, |dom|)
        cap = usable.sum(axis=1) * geometry.bytes_per_pc    # (V,)
        worst = np.asarray(f.pc_rate)[:, dom_pcs].max(axis=1)
        power = np.asarray(f.power)

        self._v_np = np.asarray(grid, np.float32)
        self._cap_np = cap
        self._power_np = power
        self._rate_np = worst
        feasible = cap >= config.required_bytes
        if not feasible.any():
            raise CapacityError(
                config.domain, config.required_bytes, int(cap.max()),
                f"no voltage in [{config.v_lo}, {config.v_hi}] keeps "
                f"enough usable capacity at tolerable rate "
                f"{config.tolerable_rate:g}")
        # Feasible sub-frontier, ascending voltage.  Power is monotone
        # increasing and worst-rate monotone non-increasing in voltage,
        # so both walks are a single searchsorted.
        self._v = jnp.asarray(self._v_np[feasible])
        self._power = jnp.asarray(power[feasible], jnp.float32)
        self._rate_rev = jnp.asarray(worst[feasible][::-1], jnp.float32)
        self._rate_asc = jnp.asarray(worst[feasible], jnp.float32)
        self._n = int(feasible.sum())
        self._feasible = feasible
        self._dom_pcs = dom_pcs
        # Tokens-per-joule score for mode='efficiency': the expected
        # fraction of tokens NOT needing a retry (a token is clean iff
        # none of the read_words_per_token governed words it reads is
        # stuck) over the normalized power factor.  Relative units --
        # only the argmax and ratios matter.
        self._tpj_np = self._tpj_from(self._rate_np)
        self._tpj = jnp.asarray(self._tpj_np[feasible], jnp.float32)
        self.replans = 0

    def _tpj_from(self, worst: np.ndarray) -> np.ndarray:
        k = float(self.config.read_words_per_token)
        p_clean = np.exp(k * np.log1p(-np.minimum(worst, 0.5)))
        return p_clean / self._power_np

    # ---- online re-plan (mode='adaptive') -------------------------------
    def replan(self, posterior) -> None:
        """Refresh the rate frontier from a live fault-map posterior.

        ``mode='adaptive'`` walks the same rate frontier as
        ``mode='rate'`` but lets telemetry move it: worst-PC rates are
        recomputed from ``posterior.predicted_rates(v)`` over the
        precomputed voltage grid, so a channel whose rows drifted weak
        shows a higher rate and the same setpoint now resolves to a
        shallower (safer) voltage.  MoRS-approximate on purpose: the
        *capacity* arrays stay prior-based (usable-PC census is a
        placement-time property), only the rate walk adapts.  Host-side
        and cheap -- O(grid x PCs) numpy; the per-step walk stays a
        searchsorted over captured constants.
        """
        if self.config.mode != "adaptive":
            raise ValueError(
                f"replan() requires mode='adaptive', got "
                f"{self.config.mode!r}")
        worst = np.asarray(
            [posterior.predicted_rates(float(v))[self._dom_pcs].max()
             for v in self._v_np])
        # Keep the frontier walkable: rates must be non-increasing in
        # voltage (posterior deltas preserve this analytically; enforce
        # against float dust).
        worst = np.maximum.accumulate(worst[::-1])[::-1]
        self._rate_np = worst
        self._rate_rev = jnp.asarray(worst[self._feasible][::-1],
                                     jnp.float32)
        self.replans += 1

    # ---- per-step walk (traced-setpoint capable) ------------------------
    def voltage_at(self, setpoint=None):
        """Frontier voltage for ``setpoint`` (may be a traced scalar).

        ``mode='power'``: highest feasible voltage with power factor <=
        setpoint (clamped to the deepest feasible voltage when even that
        exceeds the budget).  ``mode='rate'``: deepest feasible voltage
        with worst-PC rate <= setpoint (clamped to the highest feasible
        voltage when even it is too faulty).  ``mode='efficiency'``:
        among feasible points with worst-PC rate <= setpoint (the
        fault-rate SLO), the tokens-per-joule argmax -- clamped to the
        highest feasible voltage when nothing meets the SLO.
        """
        if setpoint is None:
            setpoint = self.config.setpoint
        s = jnp.asarray(setpoint, jnp.float32)
        if self.config.mode == "power":
            idx = jnp.searchsorted(self._power, s, side="right") - 1
        elif self.config.mode == "efficiency":
            ok = self._rate_asc <= s
            idx = jnp.argmax(jnp.where(ok, self._tpj, -1.0))
            idx = jnp.where(ok.any(), idx, self._n - 1)
        else:
            idx = self._n - jnp.searchsorted(self._rate_rev, s,
                                             side="right")
        return self._v[jnp.clip(idx, 0, self._n - 1)]

    def override(self, setpoint=None) -> Dict[str, object]:
        """Voltage-override dict for ``UndervoltPlan.apply`` /
        ``inject_groups`` targeting the governed domain."""
        return {self.config.domain: self.voltage_at(setpoint)}

    # ---- host-side frontier lookups (fleet reporting) -------------------
    def power_at(self, voltage: float) -> float:
        """Normalized power factor of the governed domain at ``voltage``
        (host-side interpolation on the precomputed frontier grid)."""
        return float(np.interp(float(voltage), self._v_np, self._power_np))

    def rate_at(self, voltage: float) -> float:
        """Worst governed-PC stuck-cell rate at ``voltage`` (host-side,
        log-domain interpolation on the frontier grid)."""
        with np.errstate(divide="ignore"):
            lr = np.log10(np.maximum(self._rate_np, 1e-300))
        return float(10.0 ** np.interp(float(voltage), self._v_np, lr))

    def efficiency_at(self, voltage: float) -> float:
        """Relative tokens-per-joule score at ``voltage`` (host-side
        interpolation of the ``mode='efficiency'`` objective: expected
        retry-free token fraction over normalized power).  Comparable
        across voltages of the SAME governor only."""
        return float(np.interp(float(voltage), self._v_np, self._tpj_np))

    # ---- admission-time re-plan (host-side, concrete) -------------------
    def admit(self, required_bytes: int,
              setpoint: Optional[float] = None) -> float:
        """Deepest voltage keeping ``required_bytes`` of usable capacity.

        Host-side (concrete float out): serving calls this once per
        admitted request, then threads the voltage into the decode loop
        through the traced override path.  In ``mode='rate'`` a
        ``setpoint`` additionally caps the worst-PC rate; in
        ``mode='power'`` it caps the power factor (a *floor* on voltage
        never helps admission, so the budget only rules out voltages
        above it).  ``mode='efficiency'`` always applies its fault-rate
        SLO (the passed setpoint, else the configured one) and picks
        the tokens-per-joule argmax among the surviving points instead
        of the deepest.
        """
        if setpoint is None and self.config.mode == "efficiency":
            setpoint = self.config.setpoint
        ok = self._cap_np >= max(int(required_bytes), 0)
        if setpoint is not None:
            if self.config.mode in ("rate", "adaptive", "efficiency"):
                ok &= self._rate_np <= float(setpoint)
            else:
                ok &= self._power_np <= float(setpoint)
        hits = np.flatnonzero(ok)
        if hits.size == 0:
            raise CapacityError(
                self.config.domain, int(required_bytes),
                int(self._cap_np.max()),
                f"admission infeasible on [{self.config.v_lo}, "
                f"{self.config.v_hi}] at tolerable rate "
                f"{self.config.tolerable_rate:g}")
        if self.config.mode == "efficiency":
            return float(self._v_np[hits[np.argmax(self._tpj_np[hits])]])
        return float(self._v_np[hits[0]])       # ascending grid: deepest


def fleet_report(governors, voltages, setpoints=None,
                 energy=None) -> Dict[str, object]:
    """Aggregate heterogeneous per-shard operating points into one
    fleet-level power/rate/energy summary.

    ``governors`` is one :class:`VoltageGovernor` per shard (entries may
    be ``None`` for ungoverned shards -- they are skipped in the rate
    aggregation and priced at their raw voltage); ``voltages`` is the
    per-shard operating voltage.  The fleet's power factor is the mean
    over shards (stacks draw independently, so the fleet's power is the
    sum and the normalized factor is the mean) and the fleet's fault
    exposure is the *worst* shard's worst-PC rate -- a fleet SLO is only
    as good as its most aggressive shard.

    ``energy`` (an :class:`repro.obs.energy.EnergyModel`, default the
    shared one) prices each shard's operating point absolutely:
    full-load watts and dynamic pJ/byte at its voltage, plus the
    fleet-total watts -- the bridge from normalized power factors to
    the joules/token accounting in :mod:`repro.obs`.
    """
    if energy is None:
        from repro.obs.energy import DEFAULT_ENERGY_MODEL
        energy = DEFAULT_ENERGY_MODEL
    per_shard = []
    powers, rates, watts = [], [], []
    for k, (gov, v) in enumerate(zip(governors, voltages)):
        entry = {"shard": k, "voltage": float(v)}
        if setpoints is not None and setpoints[k] is not None:
            entry["setpoint"] = float(setpoints[k])
        if gov is not None:
            entry["power_factor"] = gov.power_at(v)
            entry["worst_rate"] = gov.rate_at(v)
            rates.append(entry["worst_rate"])
        else:
            entry["power_factor"] = float(
                DEFAULT_POWER_MODEL.power(float(v)))
        entry["watts"] = energy.watts(float(v), 1.0)
        entry["pj_per_byte"] = energy.pj_per_byte(float(v))
        powers.append(entry["power_factor"])
        watts.append(entry["watts"])
        per_shard.append(entry)
    out: Dict[str, object] = {
        "shards": per_shard,
        "power_factor_mean": float(np.mean(powers)),
        "power_factor_max": float(np.max(powers)),
        "watts_total": float(np.sum(watts)),
    }
    if rates:
        out["worst_rate"] = float(np.max(rates))
    return out
