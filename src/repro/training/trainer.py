"""Train-step factory: microbatched grad accumulation, AdamW, optional
undervolt plan (stuck-at injection after the optimizer write), optional
int8+error-feedback gradient compression at the DP boundary.

The returned step is a pure function (state, batch) -> (state, metrics)
suitable for jit with in_shardings/out_shardings -- the same function the
multi-pod dry-run lowers AOT.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ArchBundle, ArchConfig, spec_avals
from repro.models.dist import DistContext
from repro.optim import adamw
from repro.optim.compress import ef_quantize_grads
from repro.training.undervolt import UndervoltPlan


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    undervolt: Optional[UndervoltPlan] = None
    grad_compression: str = "none"          # none | int8_ef
    # When set, batches may carry a scalar under this key that overrides
    # the undervolt plan's *unsafe* domain voltages for the step
    # (guardband-safe domains keep their protection).  The arena engine
    # treats it as traced data, so a dynamic voltage schedule (online
    # V_min search, per-step DVFS) runs inside one compiled step.
    # Schedules reaching the collapse regime (per-bit rates > ~1e-3)
    # should set undervolt_method='bitwise': 'auto' cannot see a traced
    # voltage and dispatches from the configured domain voltages.
    undervolt_voltage_key: Optional[str] = None
    undervolt_method: str = "auto"
    # Frontier-walking runtime governor (repro.training.governor): each
    # step re-plans the governed domain's voltage from a setpoint -- a
    # power budget or rate target carried in the batch under
    # ``governor_key`` (falling back to the governor's configured
    # setpoint) -- through the same traced override path, so re-planning
    # every step still compiles exactly once.  Mutually exclusive with
    # undervolt_voltage_key, and requires an explicit undervolt_method
    # ('word' | 'bitwise'): the governed voltage is traced, so 'auto'
    # dispatch cannot see it.
    governor: Optional[Any] = None
    governor_key: Optional[str] = None


def init_state(bundle: ArchBundle, cfg: ArchConfig, key) -> Dict[str, Any]:
    from repro.models.base import init_params
    params = init_params(bundle.module.param_specs(cfg), key)
    state = {"params": params, "opt": adamw.init(params)}
    return state


def state_specs(bundle: ArchBundle, cfg: ArchConfig,
                tc: Optional[TrainConfig] = None) -> Dict[str, Any]:
    """ParamSpecs for the full train state (dry-run / sharding rules)."""
    pspecs = bundle.module.param_specs(cfg)
    out = {"params": pspecs, "opt": adamw.moment_specs(pspecs)}
    if tc is not None and tc.grad_compression == "int8_ef":
        out["ef"] = adamw.moment_specs(pspecs)["mu"]
    return out


def _placements(bundle, cfg, tc):
    if tc.undervolt is None or not tc.undervolt.enabled:
        return None
    pspecs = bundle.module.param_specs(cfg)
    avals = spec_avals(pspecs)
    mspecs = spec_avals(adamw.moment_specs(pspecs))
    groups = {"params": avals, "mu": mspecs["mu"], "nu": mspecs["nu"]}
    return tc.undervolt.place(groups)


def make_train_step(bundle: ArchBundle, cfg: ArchConfig,
                    tc: TrainConfig, dist: Optional[DistContext] = None):
    """Build the jit-able train step."""
    module = bundle.module
    placements = _placements(bundle, cfg, tc)
    if tc.governor is not None:
        if tc.undervolt_voltage_key is not None:
            raise ValueError(
                "TrainConfig.governor and undervolt_voltage_key are "
                "mutually exclusive voltage controls")
        if tc.undervolt is None or tc.governor.plan is not tc.undervolt:
            raise ValueError("tc.governor must be built from tc.undervolt")
        if tc.undervolt_method == "auto":
            raise ValueError(
                "TrainConfig.governor drives a traced voltage, which "
                "'auto' method dispatch cannot see (it would silently "
                "dispatch from the configured domain voltages); set "
                "undervolt_method='word' or 'bitwise' explicitly")

    def loss_fn(params, mb):
        loss, metrics = module.forward_train(params, mb, cfg, dist)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state, batch):
        params = state["params"]

        uv_voltage = None
        governed_v = None
        if tc.governor is not None:
            setpoint = None
            if tc.governor_key is not None:
                batch = dict(batch)
                setpoint = batch.pop(tc.governor_key, None)
            governed_v = tc.governor.voltage_at(setpoint)
            uv_voltage = {tc.governor.config.domain: governed_v}
        elif tc.undervolt_voltage_key is not None:
            batch = dict(batch)
            uv_voltage = batch.pop(tc.undervolt_voltage_key, None)

        if tc.microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            m = tc.microbatches

            def resh(x):
                b = x.shape[0]
                assert b % m == 0, (b, m)
                return x.reshape(m, b // m, *x.shape[1:])

            mbs = jax.tree_util.tree_map(resh, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(acc, mb):
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, l

            grads, losses = jax.lax.scan(mb_step, zeros, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            loss = jnp.mean(losses)
            metrics = {"loss": loss}

        new_state = dict(state)
        if tc.grad_compression == "int8_ef":
            grads, new_ef = ef_quantize_grads(grads, state["ef"])
            new_state["ef"] = new_ef

        new_params, new_opt, opt_metrics = adamw.update(
            grads, state["opt"], params, tc.adamw)
        metrics = {**metrics, **opt_metrics}

        if placements is not None:
            groups = {"params": new_params, "mu": new_opt["mu"],
                      "nu": new_opt["nu"]}
            faulted, uv_metrics = tc.undervolt.apply(
                groups, placements, voltage=uv_voltage,
                method=tc.undervolt_method)
            new_params = faulted["params"]
            new_opt = {**new_opt, "mu": faulted["mu"], "nu": faulted["nu"]}
            metrics = {**metrics, **uv_metrics}
            if governed_v is not None:
                metrics["governor_voltage"] = governed_v

        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, metrics

    return step


def make_eval_loss(bundle: ArchBundle, cfg: ArchConfig,
                   dist: Optional[DistContext] = None):
    def eval_loss(params, batch):
        loss, _ = bundle.module.forward_train(params, batch, cfg, dist)
        return loss
    return eval_loss
