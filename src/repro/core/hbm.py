"""HBM geometry model.

The paper's testbench (Xilinx VCU128) exposes 2 HBM2 stacks x 8 memory
channels x 2 pseudo-channels (PC) = 32 independently controllable PCs of
256 MB each.  We model the TPU v5e HBM2e the same way (32 PCs of 512 MB =
16 GB) -- stacked DRAM with independently addressable channels; only the
capacity per PC differs.  All higher layers (fault maps, the trade-off
solver, the placement engine) are geometry-parametric.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class HBMGeometry:
    """Physical organization of the HBM attached to one device."""

    name: str
    num_stacks: int
    channels_per_stack: int
    pcs_per_channel: int
    bytes_per_pc: int
    row_bytes: int = 1024  # DRAM row granularity used by the cluster model

    @property
    def num_pcs(self) -> int:
        return self.num_stacks * self.channels_per_stack * self.pcs_per_channel

    @property
    def total_bytes(self) -> int:
        return self.num_pcs * self.bytes_per_pc

    @property
    def bits_per_pc(self) -> int:
        return self.bytes_per_pc * 8

    def stack_of_pc(self, pc: int) -> int:
        """Stack index owning pseudo-channel ``pc`` (PCs numbered stack-major)."""
        if not 0 <= pc < self.num_pcs:
            raise ValueError(f"pc {pc} out of range [0, {self.num_pcs})")
        return pc // (self.channels_per_stack * self.pcs_per_channel)

    def pcs_of_stack(self, stack: int) -> Tuple[int, ...]:
        per = self.channels_per_stack * self.pcs_per_channel
        return tuple(range(stack * per, (stack + 1) * per))


_FLEET_SEED_STRIDE = 0x9E3779B9  # golden-ratio increment (splitmix-style)


def fleet_map_seeds(base_seed: int, num_shards: int) -> Tuple[int, ...]:
    """Per-shard fault-map seeds for a fleet of ``num_shards`` devices.

    Each device in a sharded serving fleet carries its *own* HBM stacks,
    so each shard's fault map must be an independent draw -- the
    per-part margin variation the undervolting literature documents.
    Seeds are derived deterministically from ``base_seed`` with a
    golden-ratio stride (reduced mod 2**32, the ``RandomState`` domain):
    shard 0 keeps ``base_seed`` exactly, so a 1-shard fleet reproduces
    the single-device fault map bit for bit, and distinct shards get
    well-separated seeds (collisions would need ~2**32 shards).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards={num_shards} must be >= 1")
    return tuple((int(base_seed) + k * _FLEET_SEED_STRIDE) & 0xFFFFFFFF
                 for k in range(num_shards))


# The paper's platform: 2 x 4 GB stacks, 32 x 256 MB PCs.
VCU128 = HBMGeometry(
    name="vcu128",
    num_stacks=2,
    channels_per_stack=8,
    pcs_per_channel=2,
    bytes_per_pc=256 * 1024 * 1024,
)

# TPU v5e: 16 GB HBM2e per chip, modeled as 32 x 512 MB PCs.
TPU_V5E = HBMGeometry(
    name="tpu_v5e",
    num_stacks=2,
    channels_per_stack=8,
    pcs_per_channel=2,
    bytes_per_pc=512 * 1024 * 1024,
)

GEOMETRIES = {g.name: g for g in (VCU128, TPU_V5E)}
