"""Online fault-map posterior: fold ECC telemetry into row beliefs.

The paper's fault map is measured *offline*; Voltron-style runtime
profiling and MoRS-style approximate models argue the loop should close
online.  This module maintains, per (pseudo-channel, DRAM row), the
posterior probability that the row behaves *weak* at the current
operating point, updated from the SECDED correction counters the fused
read path exports every step.

Model (MoRS-approximate on purpose -- two row classes, not per-cell):

  * prior: the static :class:`~repro.core.faultmap.FaultMap` draw.  A
    row the map marks weak starts near-certainly weak; a strong row
    carries a small "turned weak at runtime" prior (aging, sensing
    drift, voltage-regulator tolerance -- the effects an offline map
    cannot see).
  * likelihood: reading ``n`` SECDED(72,64) codewords from a row at
    voltage ``v`` yields ``c`` corrected events.  Corrections are
    ~Binomial(n, p_class(v)) with p_weak >> p_strong in the exponential
    regime, so each step adds a binomial log-likelihood ratio to the
    row's accumulated evidence.

The update is exact Bayes on the two-class model and costs O(observed
rows); the LLR arithmetic (:func:`binomial_llr`) is pure jnp and safe
to trace, though the scheduler folds counters host-side at the existing
token-gather sync, so no extra device round-trips are introduced.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.faultmap import FaultMap

# Floor rates so log-ratios stay finite in the guardband (both
# hypotheses predict ~zero corrections there -> LLR ~0, as it should).
_RATE_FLOOR = 1e-12
# A codeword correction needs >= 1 hit among 64 data bits + 8 parity
# bits; for small per-bit rates p the per-codeword probability is
# ~72 p (capped well below 1 to keep the binomial well-posed).
_CW_BITS = 72.0
_P_CAP = 0.5

# Default prior that a statically-strong row has drifted weak at
# runtime.  ~8 corrected codewords of weak-rate evidence overturn it.
TURN_WEAK_PRIOR = 1e-3
# Statically-weak rows: near-certain, but not literally 1.0 so the
# posterior stays invertible by contrary evidence.
STATIC_WEAK_PRIOR = 1.0 - 1e-4


def binomial_llr(corrected, codewords, p_weak, p_strong):
    """log P(c | weak) - log P(c | strong) for c ~ Binomial(n, p).

    Pure jnp (traceable); the binomial coefficient cancels in the
    ratio.  ``p_weak`` / ``p_strong`` are per-codeword correction
    probabilities, already floored/capped by the caller.
    """
    c = jnp.asarray(corrected, jnp.float32)
    n = jnp.asarray(codewords, jnp.float32)
    pw = jnp.asarray(p_weak, jnp.float32)
    ps = jnp.asarray(p_strong, jnp.float32)
    return (c * (jnp.log(pw) - jnp.log(ps))
            + (n - c) * (jnp.log1p(-pw) - jnp.log1p(-ps)))


@dataclasses.dataclass
class _RowBelief:
    llr: float = 0.0          # accumulated evidence (log-odds delta)
    corrected: int = 0        # lifetime corrected codewords observed
    uncorrectable: int = 0
    codewords: int = 0        # lifetime codewords read


class FaultMapPosterior:
    """Per-row weak-probability posterior over a static map prior.

    Sparse: only rows with observed telemetry are tracked (the pool
    places hot state on statically-strong rows, so the interesting set
    is small).  Deterministic in (map, observation stream).
    """

    def __init__(self, faultmap: FaultMap, *,
                 turn_weak_prior: float = TURN_WEAK_PRIOR,
                 static_weak_prior: float = STATIC_WEAK_PRIOR):
        self.faultmap = faultmap
        self.turn_weak_prior = float(turn_weak_prior)
        self.static_weak_prior = float(static_weak_prior)
        self._rows: Dict[Tuple[int, int], _RowBelief] = {}
        self.total_corrected = 0
        self.total_uncorrectable = 0

    # ---- priors ---------------------------------------------------------
    def _prior_logodds(self, pc: int, row: int) -> float:
        p = (self.static_weak_prior
             if bool(self.faultmap.weak_row_mask(pc)[row])
             else self.turn_weak_prior)
        return math.log(p / (1.0 - p))

    def _cw_probs(self, pc: int, voltage: float) -> Tuple[float, float]:
        """Per-codeword correction probability under (weak, strong)."""
        weak_r, strong_r = self.faultmap.row_rates(float(voltage))
        pw = min(_P_CAP, max(_RATE_FLOOR, _CW_BITS * float(weak_r[pc])))
        ps = min(_P_CAP, max(_RATE_FLOOR, _CW_BITS * float(strong_r[pc])))
        return pw, ps

    # ---- updates --------------------------------------------------------
    def observe(self, pc: int, row: int, *, corrected: int, codewords: int,
                voltage: float, uncorrectable: int = 0) -> None:
        """Fold one step's counters for one row into its belief.

        ``codewords``: how many SECDED codewords of this row the read
        path touched this step; ``corrected``: how many reported a
        (single-fault) correction.  Uncorrectable events are evidence
        too -- a multi-fault codeword implies at least the weak regime,
        so they count as corrections for the likelihood and are also
        tallied separately.
        """
        if codewords <= 0:
            return
        hits = int(corrected) + int(uncorrectable)
        b = self._rows.setdefault((int(pc), int(row)), _RowBelief())
        pw, ps = self._cw_probs(int(pc), voltage)
        b.llr += float(binomial_llr(min(hits, codewords), codewords, pw, ps))
        b.corrected += int(corrected)
        b.uncorrectable += int(uncorrectable)
        b.codewords += int(codewords)
        self.total_corrected += int(corrected)
        self.total_uncorrectable += int(uncorrectable)

    # ---- queries --------------------------------------------------------
    def p_weak(self, pc: int, row: int) -> float:
        b = self._rows.get((pc, row))
        logodds = self._prior_logodds(pc, row) + (b.llr if b else 0.0)
        # Stable sigmoid.
        if logodds >= 0:
            return 1.0 / (1.0 + math.exp(-logodds))
        e = math.exp(logodds)
        return e / (1.0 + e)

    def suspect_rows(self, setpoint: float,
                     threshold: float = 0.9) -> List[Tuple[int, int]]:
        """Rows believed weak where weakness *matters* at ``setpoint``.

        ``setpoint`` is the shard's operating voltage: in the guardband
        (or wherever weak and strong rates coincide) no row is suspect
        -- there is nothing to migrate away from.  Returns observed
        rows with posterior weak-probability >= ``threshold``, sorted.
        """
        weak_r, strong_r = self.faultmap.row_rates(float(setpoint))
        out = []
        for (pc, row) in self._rows:
            if weak_r[pc] <= strong_r[pc] + _RATE_FLOOR:
                continue
            if self.p_weak(pc, row) >= threshold:
                out.append((pc, row))
        return sorted(out)

    def predicted_rates(self, v: float) -> np.ndarray:
        """Per-PC expected stuck-cell rate under the posterior.

        The prior blend (:meth:`FaultMap.pc_total_rate`) plus, for each
        tracked row, the shift between its posterior and prior weak
        probability, weighted by the row's 1/rows_per_pc share of the
        channel -- the adaptive governor re-plans from this instead of
        the static map.
        """
        base = self.faultmap.pc_total_rate(float(v)).astype(np.float64)
        weak_r, strong_r = self.faultmap.row_rates(float(v))
        rpp = float(self.faultmap.rows_per_pc)
        for (pc, row) in self._rows:
            prior = (self.static_weak_prior
                     if bool(self.faultmap.weak_row_mask(pc)[row])
                     else self.turn_weak_prior)
            delta = self.p_weak(pc, row) - prior
            base[pc] += delta * (weak_r[pc] - strong_r[pc]) / rpp
        return np.clip(base, 0.0, 1.0)

    # ---- reporting ------------------------------------------------------
    @property
    def tracked_rows(self) -> Iterable[Tuple[int, int]]:
        return tuple(sorted(self._rows))

    def stats(self) -> Dict[str, int]:
        return {
            "tracked_rows": len(self._rows),
            "corrected": int(self.total_corrected),
            "uncorrectable": int(self.total_uncorrectable),
        }
