"""Calibrated HBM power model under voltage underscaling.

Reproduces the paper's power results (section III-A):

  * P = alpha * C_L * f * V^2  (eq. 1): total power scales with V^2 at
    fixed frequency; undervolting does not touch f, so bandwidth is
    preserved (the whole point of the technique).
  * 1.5x total power saving at V_min = 0.98 V, independent of bandwidth
    utilization (C2): (1.2/0.98)^2 = 1.4994.
  * 2.3x total saving at 0.85 V (C3): V^2 alone gives 1.99x; the extra
    0.3x comes from the ~14% active-capacitance drop as stuck bits stop
    toggling (Fig. 3), modeled by ``FaultModel.alpha_factor``.
  * Idle power is ~1/3 of full-utilization power (C10) and scales with
    V^2 as well (Fig. 2's bottom curve).

All powers are normalized to P(V_nom, util=1.0) = 1, exactly like Fig. 2.
``watts()`` scales by a per-chip nominal HBM power for absolute reports.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.faultmodel import DEFAULT_FAULT_MODEL, FaultModel, V_NOM

# Fraction of full-load power burned at zero bandwidth utilization (C10).
P_IDLE_FRAC = 1.0 / 3.0

# Nominal HBM power of one TPU v5e chip's stacks at full streaming load.
# Not publicly documented; assumption recorded in DESIGN.md and used only
# for absolute-watt reports, never for the validated ratios.
W_HBM_NOMINAL_V5E = 20.0


@dataclasses.dataclass(frozen=True)
class PowerModel:
    fault_model: FaultModel = DEFAULT_FAULT_MODEL
    p_idle_frac: float = P_IDLE_FRAC

    def power(self, v, util=1.0):
        """Normalized total HBM power at voltage ``v`` and bandwidth
        utilization ``util`` in [0, 1].  P(V_nom, 1.0) == 1."""
        v = np.asarray(v, dtype=np.float64)
        util = np.asarray(util, dtype=np.float64)
        v_sq = (v / V_NOM) ** 2
        # Fig. 3: the measured alpha*C_L*f (total power / V^2) drops below
        # the guardband because stuck bits stop toggling.
        alpha = self.fault_model.alpha_factor(v)
        load = self.p_idle_frac + (1.0 - self.p_idle_frac) * util
        return v_sq * load * alpha

    def savings(self, v, util=1.0):
        """Power-saving factor vs. nominal voltage at the same utilization
        (the paper's 1.5x / 2.3x numbers)."""
        return self.power(V_NOM, util) / self.power(v, util)

    def alpha_clf(self, v, util=1.0):
        """Measured-style alpha*C_L*f: power divided by V^2, normalized to
        its own value at V_nom for the same utilization (Fig. 3)."""
        p = self.power(v, util) / (np.asarray(v) / V_NOM) ** 2
        p_nom = self.power(V_NOM, util)
        return p / p_nom

    def watts(self, v, util=1.0, nominal_watts: float = W_HBM_NOMINAL_V5E):
        return nominal_watts * self.power(v, util)

    def energy_joules(self, step_seconds, v, util=1.0,
                      nominal_watts: float = W_HBM_NOMINAL_V5E):
        """HBM energy of one step.  Undervolting keeps f (and therefore
        step_seconds) constant, so energy scales exactly like power."""
        return step_seconds * self.watts(v, util, nominal_watts)


DEFAULT_POWER_MODEL = PowerModel()
