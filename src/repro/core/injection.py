"""Pytree-level fault injection driven by a placement + fault map.

This is the bridge between the paper's physical model and the training /
serving loops: every step, each tensor group living in an unsafe memory
domain is passed through the bitflip kernel segment-by-segment with its
own pseudo-channel's calibrated thresholds.  ECC domains route through
the fused ECC kernel instead (single-bit errors corrected, multi-bit
errors kept and counted).

Everything here is trace-friendly: the segment structure is static, so
the per-leaf Python loops unroll inside jit.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.domains import GroupPlacement
from repro.core.faultmap import FaultMap
from repro.core.faultmodel import V_MIN
from repro.kernels.bitflip import ops as bitflip_ops
from repro.kernels.ecc import ops as ecc_ops


def inject_leaf(x: jax.Array, placement, faultmap: FaultMap, voltage: float,
                *, ecc: bool = False, method: str = "auto",
                interpret=None, use_ref: bool = False):
    """Apply the domain's stuck-at faults to one tensor.

    Returns (faulted tensor, uncorrectable-fault count) -- the count is
    zero unless ``ecc`` is set (without ECC nothing is even detected).
    """
    u32, meta = bitflip_ops._to_u32(x)
    pieces = []
    uncorrectable = jnp.zeros((), jnp.int32)
    for seg in placement.segments:
        chunk = u32[seg.leaf_start_word:seg.leaf_start_word + seg.n_words]
        thr = faultmap.thresholds(voltage, seg.pc)
        if ecc:
            out, bad = ecc_ops.inject_and_correct_u32(
                chunk, thresholds=thr, seed=faultmap.seed,
                base_word=seg.phys_base_word, interpret=interpret,
                use_ref=use_ref)
            uncorrectable = uncorrectable + bad
        else:
            out = bitflip_ops.inject_u32(
                chunk, thresholds=thr, seed=faultmap.seed,
                base_word=seg.phys_base_word, method=method,
                interpret=interpret, use_ref=use_ref)
        pieces.append(out)
    faulted = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    return bitflip_ops._from_u32(faulted, meta), uncorrectable


def inject_group(tree, placement: GroupPlacement, faultmap: FaultMap,
                 *, method: str = "auto", interpret=None,
                 use_ref: bool = False):
    """Apply the domain's faults to a whole tensor group.

    Returns (faulted tree, total uncorrectable count).  A no-op (identity,
    zero count) when the domain sits in the guardband -- the paper finds
    zero faults at or above V_min = 0.98 V, and we hard-gate that.
    """
    domain = placement.domain
    if domain.voltage >= V_MIN - 1e-9:
        return tree, jnp.zeros((), jnp.int32)

    by_path: Dict[str, object] = {l.path: l for l in placement.leaves}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out_leaves = []
    total_bad = jnp.zeros((), jnp.int32)
    for path, leaf in flat:
        lp = by_path[jax.tree_util.keystr(path)]
        faulted, bad = inject_leaf(
            leaf, lp, faultmap, domain.voltage, ecc=domain.ecc,
            method=method, interpret=interpret, use_ref=use_ref)
        out_leaves.append(faulted)
        total_bad = total_bad + bad
    return (jax.tree_util.tree_unflatten(
        treedef, out_leaves), total_bad)


def clamp_nonfinite(tree, replacement: float = 0.0):
    """Optional mitigation: bit flips in exponent bits create Inf/NaN;
    fault-tolerant consumers can clamp them (EDEN-style preprocessing)."""
    def fix(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return jnp.where(jnp.isfinite(x), x,
                         jnp.asarray(replacement, x.dtype))
    return jax.tree_util.tree_map(fix, tree)
