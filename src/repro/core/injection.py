"""Pytree-level fault injection driven by a placement + fault map.

This is the bridge between the paper's physical model and the training /
serving loops.  The default path is the arena engine
(:mod:`repro.core.engine`): every step, each tensor group living in an
unsafe memory domain is packed into one block-indexed arena and injected
with a *single* fused Pallas pass per domain -- thresholds arrive as
runtime data derived from a (possibly traced) voltage, so voltage sweeps
never recompile.  ECC domains route through the fused inject+correct
kernel (single-bit errors corrected, multi-bit errors kept and counted).

The legacy per-segment path (one ``pallas_call`` per segment per leaf,
static thresholds) is kept as ``engine='segments'`` / ``inject_leaf`` --
it is the independent implementation the tests hold the arena engine
bit-exact against.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import engine as arena_engine
from repro.core.domains import GroupPlacement
from repro.core.faultmap import FaultMap
from repro.core.faultmodel import V_MIN
from repro.kernels.bitflip import ops as bitflip_ops
from repro.kernels.ecc import ops as ecc_ops


def inject_leaf(x: jax.Array, placement, faultmap: FaultMap, voltage: float,
                *, ecc: bool = False, method: str = "auto",
                interpret=None, use_ref: bool = False):
    """Legacy path: apply the domain's stuck-at faults to one tensor,
    segment by segment (one kernel launch per segment, static
    thresholds).

    Returns (faulted tensor, uncorrectable-fault count) -- the count is
    zero unless ``ecc`` is set (without ECC nothing is even detected).
    """
    u32, meta = bitflip_ops.to_u32(x)
    pieces = []
    uncorrectable = jnp.zeros((), jnp.int32)
    for seg in placement.segments:
        chunk = u32[seg.leaf_start_word:seg.leaf_start_word + seg.n_words]
        thr = faultmap.thresholds(voltage, seg.pc)
        if ecc:
            out, bad = ecc_ops.inject_and_correct_u32(
                chunk, thresholds=thr, seed=faultmap.seed,
                base_word=seg.phys_base_word, interpret=interpret,
                use_ref=use_ref)
            uncorrectable = uncorrectable + bad
        else:
            out = bitflip_ops.inject_u32(
                chunk, thresholds=thr, seed=faultmap.seed,
                base_word=seg.phys_base_word, method=method,
                interpret=interpret, use_ref=use_ref)
        pieces.append(out)
    faulted = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    return bitflip_ops.from_u32(faulted, meta), uncorrectable


def _inject_group_segments(tree, placement: GroupPlacement,
                           faultmap: FaultMap, *, method: str = "auto",
                           interpret=None, use_ref: bool = False):
    by_path: Dict[str, object] = {l.path: l for l in placement.leaves}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out_leaves = []
    total_bad = jnp.zeros((), jnp.int32)
    domain = placement.domain
    for path, leaf in flat:
        lp = by_path[jax.tree_util.keystr(path)]
        faulted, bad = inject_leaf(
            leaf, lp, faultmap, domain.voltage, ecc=domain.ecc,
            method=method, interpret=interpret, use_ref=use_ref)
        out_leaves.append(faulted)
        total_bad = total_bad + bad
    return (jax.tree_util.tree_unflatten(
        treedef, out_leaves), total_bad)


def inject_group(tree, placement: GroupPlacement, faultmap: FaultMap,
                 *, voltage=None, method: str = "auto", interpret=None,
                 use_ref: bool = False, engine: str = "arena"):
    """Apply the domain's faults to a whole tensor group.

    ``engine='arena'`` (default): one fused pass for the whole domain,
    ``voltage`` optionally overrides the domain voltage and may be a
    traced scalar.  ``engine='segments'``: the legacy per-segment path
    (no voltage override -- thresholds are static there by design).

    Returns (faulted tree, total uncorrectable count).  A no-op
    (identity, zero count) when the effective voltage sits in the
    guardband -- the paper finds zero faults at or above
    V_min = 0.98 V, and we hard-gate that for static voltages.
    """
    if engine == "arena":
        return arena_engine.inject_placement(
            tree, placement, faultmap, voltage=voltage, method=method,
            interpret=interpret, use_ref=use_ref)
    if engine != "segments":
        raise ValueError(f"unknown engine {engine!r}")
    if voltage is not None:
        raise ValueError("the segments engine has no voltage override")
    if placement.domain.voltage >= V_MIN - 1e-9:
        return tree, jnp.zeros((), jnp.int32)
    return _inject_group_segments(tree, placement, faultmap, method=method,
                                  interpret=interpret, use_ref=use_ref)


def clamp_nonfinite(tree, replacement: float = 0.0):
    """Optional mitigation: bit flips in exponent bits create Inf/NaN;
    fault-tolerant consumers can clamp them (EDEN-style preprocessing)."""
    def fix(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return jnp.where(jnp.isfinite(x), x,
                         jnp.asarray(replacement, x.dtype))
    return jax.tree_util.tree_map(fix, tree)
