"""The paper's three-factor trade-off: power x capacity x fault rate.

Section III-C: because pseudo-channels are independently controllable, an
application that tolerates fault rate T and needs capacity C can pick the
deepest voltage at which enough sufficiently-reliable PCs remain.  The
paper's worked examples (all re-asserted in benchmarks/fig6_tradeoff.py):

  * zero faults + full 8 GB      -> guardband only: 1.5x at 0.98 V
  * zero faults + 7 PCs          -> 1.6x at 0.95 V
  * 1e-6 rate  + half capacity   -> ~1.8x at 0.90 V
  * "2.3x savings is possible by sacrificing some memory space while the
     remaining memory space can work with 0% to 50% fault rate" (0.85 V)

The solver is *vectorized and jit-compatible*: :meth:`TradeoffSolver.
frontier` evaluates the whole (voltage, PC) grid in one traced jnp
computation -- per-voltage best PC subset, savings, capacity and rates as
stacked arrays -- so the runtime voltage governor can precompute it once
and walk it with traced setpoints.  The scalar :meth:`point` /
:meth:`solve` API is kept as a thin wrapper over the frontier and is
cross-checked against :func:`oracle_point`, the original float64 numpy
implementation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faultmap import FaultMap
from repro.core.faultmodel import ALPHA_DROP_MAX, V_CRITICAL, V_NOM
from repro.core.voltage import DEFAULT_POWER_MODEL, PowerModel


def voltage_grid(v_hi: float = V_NOM, v_lo: float = V_CRITICAL,
                 step: float = 0.01) -> np.ndarray:
    """The paper's sweep: V_nom down to V_critical in 10 mV steps."""
    n = int(round((v_hi - v_lo) / step))
    return np.round(v_hi - step * np.arange(n + 1), 4)


@dataclasses.dataclass(frozen=True)
class TradeoffPoint:
    voltage: float
    savings: float                 # power factor vs nominal, same util
    pc_ids: Tuple[int, ...]        # PCs kept powered/used
    capacity_bytes: int
    worst_pc_rate: float           # max stuck-cell rate among kept PCs
    mean_pc_rate: float


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("voltages", "savings", "power", "pc_rate",
                                "pc_order", "usable", "num_usable",
                                "worst_rate", "mean_rate"),
                   meta_fields=("bytes_per_pc",))
@dataclasses.dataclass(frozen=True)
class Frontier:
    """Stacked per-voltage solution of the three-factor trade-off.

    All arrays share leading axis V = len(voltages); ``pc_rate``,
    ``pc_order`` and ``usable`` have a trailing PC axis.  The "best PC
    subset" at voltage i is ``pc_order[i, :num_usable[i]]`` -- the usable
    PCs most-reliable-first; truncate it to meet a capacity requirement.
    Registered as a pytree so it can cross jit boundaries and live inside
    a compiled control loop (the runtime voltage governor).
    """

    voltages: jax.Array      # (V,) float32
    savings: jax.Array       # (V,) power-saving factor vs nominal
    power: jax.Array         # (V,) normalized power factor (util=1)
    pc_rate: jax.Array       # (V, P) per-PC total stuck-cell rate
    pc_order: jax.Array      # (V, P) int32, PCs by ascending rate (stable)
    usable: jax.Array        # (V, P) bool, rate meets the tolerance
    num_usable: jax.Array    # (V,) int32
    worst_rate: jax.Array    # (V,) max rate among usable PCs (0 if none)
    mean_rate: jax.Array     # (V,) mean rate among usable PCs (0 if none)
    bytes_per_pc: int

    @property
    def capacity_bytes(self) -> jax.Array:
        """(V,) usable capacity.  float32: PC sizes are powers of two, so
        every reachable value is exactly representable."""
        return self.num_usable.astype(jnp.float32) * float(self.bytes_per_pc)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _frontier_jit(fmap: FaultMap, pm: PowerModel, v_grid, tol) -> Frontier:
    """Whole-grid frontier in one traced computation.

    ``v_grid`` and ``tol`` are runtime data (may be traced); the fault
    map and power model are static.  float32 throughout -- the same
    precision as the kernel threshold synthesis -- and cross-checked
    against the float64 numpy oracle by the property tests.
    """
    mult = jnp.asarray(fmap.pc_multiplier, jnp.float32)
    bits = jnp.float32(fmap.geometry.bits_per_pc)

    def rates_at(v):
        e01, e10, s01, s10 = fmap.model.components_jnp(v, mult)
        r01 = jnp.clip(e01 + s01, 0.0, 1.0)
        r10 = jnp.clip(e10 + s10, 0.0, 1.0)
        # joint clip: a cell cannot be stuck both ways (matches
        # FaultModel.rates, which rescales so r01 + r10 <= 1)
        return jnp.minimum(r01 + r10, 1.0)

    v_grid = jnp.asarray(v_grid, jnp.float32)
    pc_rate = jax.vmap(rates_at)(v_grid)                      # (V, P)
    order = jnp.argsort(pc_rate, axis=1, stable=True)
    tol = jnp.asarray(tol, jnp.float32)
    # tol <= 0 means "provably fault-free in expectation": < 1 expected
    # faulty bit per PC (same rule as FaultMap.usable_pcs).
    usable = jnp.where(tol > 0.0, pc_rate <= tol, pc_rate * bits < 1.0)
    num_usable = jnp.sum(usable, axis=1).astype(jnp.int32)
    worst = jnp.max(jnp.where(usable, pc_rate, 0.0), axis=1)
    mean = (jnp.sum(jnp.where(usable, pc_rate, 0.0), axis=1)
            / jnp.maximum(num_usable, 1).astype(jnp.float32))

    # Power model, jnp port of PowerModel.power at util=1 (the load term
    # cancels in the savings ratio, so savings is utilization-independent).
    def stuck_at(v):
        e01, e10, s01, s10 = pm.fault_model.components_jnp(
            v, jnp.ones((1,), jnp.float32))
        r01 = jnp.clip(e01 + s01, 0.0, 1.0)[0]
        r10 = jnp.clip(e10 + s10, 0.0, 1.0)[0]
        return jnp.minimum(r01 + r10, 1.0)

    alpha = 1.0 - jnp.float32(ALPHA_DROP_MAX) * jax.vmap(stuck_at)(v_grid)
    power = (v_grid / jnp.float32(V_NOM)) ** 2 * alpha
    return Frontier(
        voltages=v_grid, savings=1.0 / power, power=power,
        pc_rate=pc_rate, pc_order=order.astype(jnp.int32), usable=usable,
        num_usable=num_usable, worst_rate=worst, mean_rate=mean,
        bytes_per_pc=int(fmap.geometry.bytes_per_pc))


def oracle_point(faultmap: FaultMap, v: float, tolerable_rate: float,
                 required_bytes: int,
                 power_model: PowerModel = DEFAULT_POWER_MODEL,
                 ) -> Optional[TradeoffPoint]:
    """Float64 numpy oracle: the original scalar best-subset search.

    Kept as an independent implementation of :meth:`TradeoffSolver.point`
    -- the property tests hold the vectorized float32 frontier to it on
    random fault maps.
    """
    geometry = faultmap.geometry
    usable = faultmap.usable_pcs(v, tolerable_rate)
    need = -(-required_bytes // geometry.bytes_per_pc)
    if len(usable) < max(need, 1):
        return None
    keep = usable[:max(need, 1)] if required_bytes > 0 else usable
    rates = faultmap.pc_total_rate(v)[keep]
    return TradeoffPoint(
        voltage=float(v),
        savings=float(power_model.savings(v)),
        pc_ids=tuple(int(p) for p in keep),
        capacity_bytes=int(len(keep) * geometry.bytes_per_pc),
        worst_pc_rate=float(rates.max()),
        mean_pc_rate=float(rates.mean()),
    )


class TradeoffSolver:
    """Searches the (voltage, PC-subset) space for maximum power savings
    subject to capacity and tolerable-fault-rate constraints."""

    def __init__(self, faultmap: FaultMap,
                 power_model: PowerModel = DEFAULT_POWER_MODEL):
        self.faultmap = faultmap
        self.power = power_model
        self.geometry = faultmap.geometry

    # ---- vectorized core -------------------------------------------------
    def frontier(self, v_grid: Optional[Sequence[float]] = None,
                 tolerable_rate: float = 0.0) -> Frontier:
        """Solve every voltage of ``v_grid`` at once (jit-compatible).

        ``v_grid`` defaults to the paper's 10 mV sweep; it and
        ``tolerable_rate`` may be traced.  Returns stacked arrays -- see
        :class:`Frontier`.
        """
        grid = np.asarray(voltage_grid()) if v_grid is None else v_grid
        return _frontier_jit(self.faultmap, self.power,
                             jnp.asarray(grid, jnp.float32),
                             jnp.asarray(tolerable_rate, jnp.float32))

    # ---- scalar wrappers -------------------------------------------------
    def point(self, v: float, tolerable_rate: float,
              required_bytes: int) -> Optional[TradeoffPoint]:
        """Best PC subset at a fixed voltage, or None if infeasible.

        Thin wrapper over a single-voltage :meth:`frontier` row.
        """
        f = self.frontier(np.asarray([v], np.float32), tolerable_rate)
        return self._point_from_row(f, 0, float(v), required_bytes)

    def _point_from_row(self, f: Frontier, i: int, v: float,
                        required_bytes: int) -> Optional[TradeoffPoint]:
        n_usable = int(f.num_usable[i])
        need = -(-required_bytes // self.geometry.bytes_per_pc)
        if n_usable < max(need, 1):
            return None
        keep_count = max(need, 1) if required_bytes > 0 else n_usable
        order = np.asarray(f.pc_order[i])
        rates = np.asarray(f.pc_rate[i])
        keep = order[:keep_count]
        kept_rates = rates[keep]
        return TradeoffPoint(
            voltage=float(v),
            savings=float(f.savings[i]),
            pc_ids=tuple(int(p) for p in keep),
            capacity_bytes=int(keep_count * self.geometry.bytes_per_pc),
            worst_pc_rate=float(kept_rates.max()),
            mean_pc_rate=float(kept_rates.mean()),
        )

    def solve(self, required_bytes: int, tolerable_rate: float,
              v_grid: Optional[Sequence[float]] = None) -> TradeoffPoint:
        """Deepest feasible voltage == maximum power savings (power is
        monotone in V).  One vectorized frontier solve over the grid."""
        grid = np.sort(np.asarray(
            v_grid if v_grid is not None else voltage_grid()))
        f = self.frontier(grid, tolerable_rate)
        need = max(-(-required_bytes // self.geometry.bytes_per_pc), 1)
        feasible = np.asarray(f.num_usable) >= need
        for i in np.flatnonzero(feasible):   # lowest voltage first
            p = self._point_from_row(f, int(i), float(grid[i]),
                                     required_bytes)
            if p is not None:
                return p
        raise ValueError(
            f"no feasible operating point: capacity {required_bytes} B, "
            f"tolerable rate {tolerable_rate}")

    def fig6_matrix(self, tolerable_rates: Sequence[float],
                    v_grid: Optional[Sequence[float]] = None,
                    ) -> Dict[float, List[int]]:
        """Fig. 6: usable PC count per (tolerable rate, voltage)."""
        grid = np.asarray(v_grid if v_grid is not None else voltage_grid())
        return {
            float(t): [int(n) for n in
                       np.asarray(self.frontier(grid, float(t)).num_usable)]
            for t in tolerable_rates
        }

    def pareto(self, tolerable_rate: float,
               v_grid: Optional[Sequence[float]] = None,
               ) -> List[TradeoffPoint]:
        """Capacity-vs-power frontier at one tolerable rate."""
        grid = np.sort(np.asarray(
            v_grid if v_grid is not None else voltage_grid()))[::-1]
        f = self.frontier(grid, tolerable_rate)
        num = np.asarray(f.num_usable)
        pts = []
        for i in range(len(grid)):           # nominal first
            if num[i] == 0:
                continue
            p = self._point_from_row(f, i, float(grid[i]), 0)
            if p is not None:
                pts.append(p)
        return pts
