"""The paper's three-factor trade-off: power x capacity x fault rate.

Section III-C: because pseudo-channels are independently controllable, an
application that tolerates fault rate T and needs capacity C can pick the
deepest voltage at which enough sufficiently-reliable PCs remain.  The
paper's worked examples (all re-asserted in benchmarks/fig6_tradeoff.py):

  * zero faults + full 8 GB      -> guardband only: 1.5x at 0.98 V
  * zero faults + 7 PCs          -> 1.6x at 0.95 V
  * 1e-6 rate  + half capacity   -> ~1.8x at 0.90 V
  * "2.3x savings is possible by sacrificing some memory space while the
     remaining memory space can work with 0% to 50% fault rate" (0.85 V)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faultmap import FaultMap
from repro.core.faultmodel import V_CRITICAL, V_NOM
from repro.core.voltage import DEFAULT_POWER_MODEL, PowerModel


def voltage_grid(v_hi: float = V_NOM, v_lo: float = V_CRITICAL,
                 step: float = 0.01) -> np.ndarray:
    """The paper's sweep: V_nom down to V_critical in 10 mV steps."""
    n = int(round((v_hi - v_lo) / step))
    return np.round(v_hi - step * np.arange(n + 1), 4)


@dataclasses.dataclass(frozen=True)
class TradeoffPoint:
    voltage: float
    savings: float                 # power factor vs nominal, same util
    pc_ids: Tuple[int, ...]        # PCs kept powered/used
    capacity_bytes: int
    worst_pc_rate: float           # max stuck-cell rate among kept PCs
    mean_pc_rate: float


class TradeoffSolver:
    """Searches the (voltage, PC-subset) space for maximum power savings
    subject to capacity and tolerable-fault-rate constraints."""

    def __init__(self, faultmap: FaultMap,
                 power_model: PowerModel = DEFAULT_POWER_MODEL):
        self.faultmap = faultmap
        self.power = power_model
        self.geometry = faultmap.geometry

    def point(self, v: float, tolerable_rate: float,
              required_bytes: int) -> Optional[TradeoffPoint]:
        """Best PC subset at a fixed voltage, or None if infeasible."""
        usable = self.faultmap.usable_pcs(v, tolerable_rate)
        need = -(-required_bytes // self.geometry.bytes_per_pc)
        if len(usable) < need or need == 0 and required_bytes > 0:
            return None
        keep = usable[:max(need, 1)] if required_bytes > 0 else usable
        rates = self.faultmap.pc_total_rate(v)[keep]
        return TradeoffPoint(
            voltage=float(v),
            savings=float(self.power.savings(v)),
            pc_ids=tuple(int(p) for p in keep),
            capacity_bytes=int(len(keep) * self.geometry.bytes_per_pc),
            worst_pc_rate=float(rates.max()),
            mean_pc_rate=float(rates.mean()),
        )

    def solve(self, required_bytes: int, tolerable_rate: float,
              v_grid: Optional[Sequence[float]] = None) -> TradeoffPoint:
        """Deepest feasible voltage == maximum power savings (power is
        monotone in V, so scan low-to-high and return the first fit)."""
        grid = np.asarray(v_grid if v_grid is not None else voltage_grid())
        for v in np.sort(grid):          # lowest voltage first
            p = self.point(float(v), tolerable_rate, required_bytes)
            if p is not None:
                return p
        raise ValueError(
            f"no feasible operating point: capacity {required_bytes} B, "
            f"tolerable rate {tolerable_rate}")

    def fig6_matrix(self, tolerable_rates: Sequence[float],
                    v_grid: Optional[Sequence[float]] = None,
                    ) -> Dict[float, List[int]]:
        """Fig. 6: usable PC count per (tolerable rate, voltage)."""
        grid = list(v_grid if v_grid is not None else voltage_grid())
        return {
            float(t): [self.faultmap.num_usable_pcs(float(v), float(t))
                       for v in grid]
            for t in tolerable_rates
        }

    def pareto(self, tolerable_rate: float,
               v_grid: Optional[Sequence[float]] = None,
               ) -> List[TradeoffPoint]:
        """Capacity-vs-power frontier at one tolerable rate."""
        grid = np.asarray(v_grid if v_grid is not None else voltage_grid())
        pts = []
        for v in np.sort(grid)[::-1]:    # nominal first
            usable = self.faultmap.usable_pcs(float(v), tolerable_rate)
            if len(usable) == 0:
                continue
            rates = self.faultmap.pc_total_rate(float(v))[usable]
            pts.append(TradeoffPoint(
                voltage=float(v), savings=float(self.power.savings(v)),
                pc_ids=tuple(int(p) for p in usable),
                capacity_bytes=int(len(usable) * self.geometry.bytes_per_pc),
                worst_pc_rate=float(rates.max()),
                mean_pc_rate=float(rates.mean())))
        return pts
