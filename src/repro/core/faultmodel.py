"""Calibrated voltage -> fault-rate model for undervolted HBM.

Every constant below is anchored to a measurement reported in the paper
(section III); the anchors are re-asserted by ``benchmarks/paper_figs.py``
and the unit tests.

  * V_nom = 1.2 V, V_min = 0.98 V  -> 19% guardband, zero faults inside (C1)
  * first 1->0 flips at 0.97 V, first 0->1 flips at 0.96 V (C4)
  * exponential fault growth from onset down to ~0.84 V, then all bits
    faulty until V_critical = 0.81 V, below which the part crashes (C5)
  * 0->1 flips are on average 1.21x more frequent than 1->0 flips (C6)

The exponential regime models per-cell timing-margin exhaustion; the
saturation (logistic) regime models the collapse of the whole array as the
sense amplifiers run out of headroom.  Process variation (per-PC and
per-stack multipliers, C7/C8) lives in :mod:`repro.core.faultmap` and acts
multiplicatively on the exponential regime only -- the paper observes that
both stacks share the same V_min and V_critical.
"""
from __future__ import annotations

import dataclasses

import numpy as np

V_NOM = 1.20
V_MIN = 0.98          # bottom of the guardband: last fault-free voltage
V_ONSET_10 = 0.97     # first 1->0 bit flips
V_ONSET_01 = 0.96     # first 0->1 bit flips
V_ALL_FAULTY = 0.84   # essentially every bit faulty at/below this
V_CRITICAL = 0.81     # lowest voltage at which the part still responds
STEP = 0.01           # the paper sweeps in 10 mV steps

# Exponential regime: log10(rate) is linear in voltage.
#   F0: 1->0 rate at onset: ~10 flipped bits across 8 GB (detection floor).
#   DECADES_PER_STEP: fitted so the *median PC's* total stuck rate at
#   0.90 V is ~1e-6 -- the Fig. 6 trade-off point (half the PCs usable at
#   a 1e-6 tolerable rate) -- while ~7 PCs remain fault-free at 0.95 V.
F0 = 1.2e-10
DECADES_PER_STEP = 0.52

# 0->1 flips are 21% more frequent than 1->0 (C6).
ASYMMETRY_01_OVER_10 = 1.21

# Saturation regime (array collapse) -- shared across stacks/PCs.
SAT_CENTER = 0.858
SAT_WIDTH = 0.002
# Of the saturated (collapsed) bits, the 0->1 : 1->0 split keeps the 1.21 ratio.
_W01 = ASYMMETRY_01_OVER_10 / (1.0 + ASYMMETRY_01_OVER_10)
_W10 = 1.0 / (1.0 + ASYMMETRY_01_OVER_10)

# Active-capacitance drop: stuck bits stop charging/discharging (C3).  The
# paper measures alpha*C_L*f 14% below nominal at 0.85 V, where the model's
# stuck fraction is ~0.98 -> max drop 0.1425.
ALPHA_DROP_MAX = 0.1425


def _exp_rate(v, onset, f0=F0, decades_per_step=DECADES_PER_STEP):
    """Exponential-regime fault fraction, gated to 0 above ``onset``.

    The curve itself is anchored at V_ONSET_10 for *both* directions so
    that the 1.21x asymmetry (C6) holds exactly wherever both directions
    are active; the per-direction ``onset`` only gates when the first
    flips of that direction appear (C4).
    """
    v = np.asarray(v, dtype=np.float64)
    steps_below = (V_ONSET_10 - v) / STEP
    rate = f0 * np.power(10.0, decades_per_step * steps_below)
    return np.where(v <= onset + 1e-9, rate, 0.0)


def _saturation(v):
    v = np.asarray(v, dtype=np.float64)
    return 1.0 / (1.0 + np.exp((v - SAT_CENTER) / SAT_WIDTH))


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Voltage -> per-bit stuck-at fault probabilities.

    ``multiplier`` scales the exponential (process-variation-sensitive)
    regime; the saturation regime is shared (C7).
    """

    f0: float = F0
    decades_per_step: float = DECADES_PER_STEP
    asymmetry: float = ASYMMETRY_01_OVER_10

    def components(self, v, multiplier=1.0):
        """(exp01, exp10, sat01, sat10) regime breakdown.

        The exponential regime carries process variation (multiplier) and
        spatial clustering; the saturation regime (array collapse) is
        uniform -- the paper observes shared V_min/V_critical across
        stacks and all-bits-faulty behavior below 0.84 V.
        """
        gate = np.asarray(v) < V_MIN - 1e-9  # C1: guardband is fault-free
        exp01 = (self.asymmetry
                 * _exp_rate(v, V_ONSET_01, self.f0, self.decades_per_step)
                 * multiplier)
        exp10 = (_exp_rate(v, V_ONSET_10, self.f0, self.decades_per_step)
                 * multiplier)
        sat = _saturation(v)
        z = np.zeros_like(sat)
        return (np.where(gate, exp01, z), np.where(gate, exp10, z),
                np.where(gate, _W01 * sat, z), np.where(gate, _W10 * sat, z))

    def components_jnp(self, v, multiplier):
        """Traced float32 port of :meth:`components`.

        ``v`` may be a traced jax scalar (runtime voltage); ``multiplier``
        is a float32 vector of per-PC sensitivities.  Same regime gating
        as the numpy path, evaluated with ``jnp.where`` so a single trace
        covers every voltage -- this is what lets the arena injection
        engine sweep voltages with zero recompiles.
        """
        import jax.numpy as jnp

        v = jnp.asarray(v, jnp.float32)
        m = jnp.asarray(multiplier, jnp.float32)
        gate = v < jnp.float32(V_MIN - 1e-9)
        steps_below = (jnp.float32(V_ONSET_10) - v) / jnp.float32(STEP)
        base = jnp.float32(self.f0) * jnp.power(
            jnp.float32(10.0), jnp.float32(self.decades_per_step) * steps_below)
        z = jnp.zeros_like(m)
        e01 = jnp.where(v <= jnp.float32(V_ONSET_01 + 1e-9),
                        jnp.float32(self.asymmetry) * base, 0.0) * m
        e10 = jnp.where(v <= jnp.float32(V_ONSET_10 + 1e-9), base, 0.0) * m
        sat = 1.0 / (1.0 + jnp.exp((v - jnp.float32(SAT_CENTER))
                                   / jnp.float32(SAT_WIDTH)))
        s01 = jnp.broadcast_to(jnp.float32(_W01) * sat, m.shape)
        s10 = jnp.broadcast_to(jnp.float32(_W10) * sat, m.shape)
        return (jnp.where(gate, e01, z), jnp.where(gate, e10, z),
                jnp.where(gate, s01, z), jnp.where(gate, s10, z))

    def rate_01(self, v, multiplier=1.0):
        """Fraction of bits stuck-at-1 (observed as 0->1 flips)."""
        e01, _, s01, _ = self.components(v, multiplier)
        return np.clip(e01 + s01, 0.0, 1.0)

    def rate_10(self, v, multiplier=1.0):
        """Fraction of bits stuck-at-0 (observed as 1->0 flips)."""
        _, e10, _, s10 = self.components(v, multiplier)
        return np.clip(e10 + s10, 0.0, 1.0)

    def rates(self, v, multiplier=1.0):
        """(stuck-at-1, stuck-at-0) fractions, jointly clipped to sum <= 1."""
        r01 = self.rate_01(v, multiplier)
        r10 = self.rate_10(v, multiplier)
        total = r01 + r10
        scale = np.where(total > 1.0, 1.0 / np.maximum(total, 1e-30), 1.0)
        return r01 * scale, r10 * scale

    def stuck_fraction(self, v, multiplier=1.0):
        r01, r10 = self.rates(v, multiplier)
        return np.clip(r01 + r10, 0.0, 1.0)

    def alpha_factor(self, v):
        """Relative active capacitance alpha(v)/alpha0 (C3, Fig. 3)."""
        return 1.0 - ALPHA_DROP_MAX * self.stuck_fraction(v)

    # ---- region classification (C1, C5) -------------------------------
    @staticmethod
    def region(v: float) -> str:
        if v > V_NOM + 1e-9:
            return "overvolted"
        if v >= V_MIN - 1e-9:
            return "guardband"      # zero faults, 1.5x savings at the bottom
        if v >= V_ALL_FAULTY - 1e-9:
            return "unsafe"         # exponential fault growth
        if v >= V_CRITICAL - 1e-9:
            return "all_faulty"     # every bit stuck
        return "crash"              # device stops responding; power-cycle

    @staticmethod
    def guardband_fraction() -> float:
        """The paper's headline 19% guardband: the voltage you can shed
        before the *first* faults appear, i.e. down to just above
        V_ONSET_10 = 0.97 V: (1.20 - 0.97) / 1.20 = 19.2%."""
        return (V_NOM - V_ONSET_10) / V_NOM


DEFAULT_FAULT_MODEL = FaultModel()
