"""Algorithm 1 of the paper: reliability assessment via sequential access.

Writes a data pattern (all-1s or all-0s) through a pseudo-channel's
address space, reads it back under the undervolt fault model, and counts
mismatched bits.  The physical HBM is simulated (CPU-only container), but
the tester itself is the paper's exact procedure -- including the voltage
sweep from V_nom to V_critical in 10 mV steps, the per-PC scope, and the
batch repetition (our stuck-at faults are deterministic per map seed, so
batches validate consistency; an optional transient rate models run-to-
run noise).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing as H
from repro.core.faultmap import FaultMap
from repro.core.tradeoff import voltage_grid
from repro.kernels.bitflip import ops as bitflip_ops

ALL_ONES = 0xFFFFFFFF
ALL_ZEROS = 0x00000000

STREAM_TRANSIENT = 0x68E31DA4


@dataclasses.dataclass(frozen=True)
class TestResult:
    voltage: float
    pc: int
    pattern: int
    mem_words: int
    fault_counts: tuple  # one entry per batch


def _count_flips(written: jax.Array, read: jax.Array) -> int:
    return int(jnp.sum(jax.lax.population_count(written ^ read)))


def run_pc_test(faultmap: FaultMap, voltage: float, pc: int, *,
                mem_words: int, pattern: int = ALL_ZEROS,
                batch_size: int = 1, method: str = "bitwise",
                transient_rate: float = 0.0, seed: int = 0,
                use_ref: bool = False) -> TestResult:
    """Algorithm 1 on one pseudo-channel (scaled-down memSize)."""
    thr = faultmap.thresholds(voltage, pc)
    base = pc * (faultmap.geometry.bytes_per_pc // 4)
    written = jnp.full((mem_words,), np.uint32(pattern), jnp.uint32)
    counts: List[int] = []
    for b in range(batch_size):
        read = bitflip_ops.inject_u32(
            written, thresholds=thr, seed=faultmap.seed, base_word=base,
            method=method, use_ref=use_ref)
        if transient_rate > 0.0:
            # Per-batch transient upsets on top of the stuck-at faults.
            q = np.uint32(H.rate_to_u32_threshold(
                min(1.0, 32.0 * transient_rate)))
            wid = jnp.arange(mem_words, dtype=jnp.uint32) + np.uint32(base)
            u = H.hash_stream(seed + b + 1, STREAM_TRANSIENT, wid)
            pos = H.hash_stream(seed ^ 0x5bd1e995, STREAM_TRANSIENT,
                                wid) & np.uint32(31)
            flip = jnp.where(u < q, np.uint32(1) << pos, np.uint32(0))
            read = read ^ flip
        counts.append(_count_flips(written, read))
    return TestResult(voltage=float(voltage), pc=pc, pattern=pattern,
                      mem_words=mem_words, fault_counts=tuple(counts))


def sweep(faultmap: FaultMap, *, pcs: Sequence[int], mem_words: int,
          patterns: Sequence[int] = (ALL_ZEROS, ALL_ONES),
          v_grid: Optional[Sequence[float]] = None,
          batch_size: int = 1, method: str = "bitwise",
          use_ref: bool = False) -> Dict[float, List[TestResult]]:
    """The paper's full sweep: V_nom -> V_critical, 10 mV steps."""
    grid = list(v_grid if v_grid is not None else voltage_grid())
    out: Dict[float, List[TestResult]] = {}
    for v in grid:
        out[float(v)] = [
            run_pc_test(faultmap, float(v), pc, mem_words=mem_words,
                        pattern=p, batch_size=batch_size, method=method,
                        use_ref=use_ref)
            for pc in pcs for p in patterns
        ]
    return out


def observed_rate(result: TestResult) -> float:
    """Observed per-bit flip rate for one test."""
    mean = float(np.mean(result.fault_counts))
    return mean / (result.mem_words * 32)
