"""Fault-map synthesis: process variation + spatial clustering.

The paper's fault characterization (section III-B, Figs. 4/5) shows three
levels of variation, all reproduced here:

  * per-stack: HBM1's fault rate is 13% above HBM0's on average, with the
    same V_min / V_critical (C7) -> a fixed multiplicative skew on the
    exponential regime, geometric-mean 1.
  * per-PC: some pseudo-channels (PC4/PC5 of HBM0, PC18/19/20 of HBM1) are
    roughly an order of magnitude more sensitive (C8) -> lognormal per-PC
    multipliers, plus the paper's named hot PCs boosted explicitly in the
    calibrated default map.
  * spatial clustering: most faults concentrate in small regions (C9) ->
    a two-level row model: a small fraction of "weak" rows (in contiguous
    runs) carries most of the fault mass.

A FaultMap is deterministic in (geometry, seed) and is the single source
of truth for: analytic rates (trade-off solver, power model), kernel
thresholds (fault injection), and the reliability tester.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.faultmodel import DEFAULT_FAULT_MODEL, FaultModel
from repro.core.hbm import HBMGeometry, VCU128

# Paper-calibrated hot pseudo-channels (Fig. 5): extra sensitivity factors.
PAPER_HOT_PCS: Dict[int, float] = {4: 8.0, 5: 6.0, 18: 9.0, 19: 7.0, 20: 6.0}

STACK_SKEW = 1.13          # HBM1 / HBM0 average fault-rate ratio (C7)
# Lognormal spread of per-PC sensitivity.  0.8 decades reproduces Fig. 5's
# dynamic range (some PCs "NF" while others show percent-level rates at
# the same voltage) and Fig. 6's fault-free PC counts.
PC_SIGMA_DECADES = 0.80

# Default map seed: selected by scanning seeds so the calibrated map
# reproduces the paper's Fig. 6 worked examples on VCU128 geometry:
# 7 fault-free PCs at 0.95 V, ~half the PCs usable at a 1e-6 tolerable
# rate at 0.90 V, and HBM1's mean unsafe-region fault rate above HBM0's.
PAPER_MAP_SEED = 469

# Spatial clustering (C9): WEAK_ROW_FRAC of rows carry WEAK_ROW_SHARE of
# the fault mass, in contiguous runs of WEAK_RUN_ROWS rows.
WEAK_ROW_FRAC = 0.05
WEAK_ROW_SHARE = 0.90
WEAK_RUN_ROWS = 8


# Threshold-table column layout: one uint32 row per pseudo-channel.
# Word-path uint32 hit thresholds, weak-row selection threshold, bitwise
# PLANES-bit thresholds, and the fused-ECC parity-hit thresholds -- i.e.
# everything voltage-dependent the kernels need, so a (num_pcs, NUM_COLS)
# table computed from a *traced* voltage scalar fully parameterizes one
# injection pass.
COL_Q01_WEAK = 0
COL_Q01_STRONG = 1
COL_Q10_WEAK = 2
COL_Q10_STRONG = 3
COL_WEAK_ROW_Q = 4
COL_T01_WEAK = 5
COL_T01_STRONG = 6
COL_T10_WEAK = 7
COL_T10_STRONG = 8
COL_PAR_Q_WEAK = 9
COL_PAR_Q_STRONG = 10
NUM_THR_COLS = 11


@dataclasses.dataclass(frozen=True)
class KernelThresholds:
    """Integer thresholds consumed by the bitflip kernel for one segment.

    Constructed by :meth:`FaultMap.thresholds` from one row of the
    vectorized threshold table, so the static per-segment path and the
    arena engine consume bit-identical integers.
    """

    q01_weak: int
    q01_strong: int
    q10_weak: int
    q10_strong: int
    weak_row_q: int        # uint32 threshold for weak-row selection
    words_per_row_log2: int
    p01_weak: float        # per-bit rates at PLANES-bit resolution
    p01_strong: float      # (p = t / 2**PLANES, so the bitwise path
    p10_weak: float        #  round-trips exactly through the table)
    p10_strong: float
    t01_weak: int          # bitwise-path PLANES-bit thresholds
    t01_strong: int
    t10_weak: int
    t10_strong: int
    par_q_weak: int        # ECC parity-bit word-hit thresholds
    par_q_strong: int


@dataclasses.dataclass(frozen=True)
class FaultMap:
    geometry: HBMGeometry
    seed: int
    model: FaultModel
    pc_multiplier: Tuple[float, ...]
    weak_row_frac: float = WEAK_ROW_FRAC
    weak_row_share: float = WEAK_ROW_SHARE
    weak_run_rows: int = WEAK_RUN_ROWS

    # ---- construction --------------------------------------------------
    @classmethod
    def from_seed(
        cls,
        geometry: HBMGeometry = VCU128,
        seed: int = 0,
        model: FaultModel = DEFAULT_FAULT_MODEL,
        stack_skew: float = STACK_SKEW,
        sigma_decades: float = PC_SIGMA_DECADES,
        hot_pcs: Optional[Dict[int, float]] = None,
    ) -> "FaultMap":
        rng = np.random.RandomState(seed)
        mult = 10.0 ** rng.normal(0.0, sigma_decades, geometry.num_pcs)
        skew = np.sqrt(stack_skew)
        for pc in range(geometry.num_pcs):
            mult[pc] *= skew if geometry.stack_of_pc(pc) == 1 else 1.0 / skew
        if hot_pcs is None:
            hot_pcs = PAPER_HOT_PCS if geometry.num_pcs == 32 else {}
        for pc, boost in hot_pcs.items():
            if pc < geometry.num_pcs:
                mult[pc] *= boost
        return cls(geometry=geometry, seed=seed, model=model,
                   pc_multiplier=tuple(float(m) for m in mult))

    # ---- analytic rates -------------------------------------------------
    def pc_rates(self, v: float) -> Tuple[np.ndarray, np.ndarray]:
        """(stuck-at-1, stuck-at-0) per-bit fractions for every PC."""
        r01 = np.empty(self.geometry.num_pcs)
        r10 = np.empty(self.geometry.num_pcs)
        for pc, m in enumerate(self.pc_multiplier):
            a, b = self.model.rates(v, m)
            r01[pc], r10[pc] = float(a), float(b)
        return r01, r10

    def pc_total_rate(self, v: float) -> np.ndarray:
        r01, r10 = self.pc_rates(v)
        return np.clip(r01 + r10, 0.0, 1.0)

    def stack_mean_rate(self, v: float, stack: int) -> float:
        pcs = self.geometry.pcs_of_stack(stack)
        return float(self.pc_total_rate(v)[list(pcs)].mean())

    def expected_faults(self, v: float, pc: int,
                        pattern: str = "both") -> float:
        """Expected faulty bits in one PC for a given test pattern.

        ``pattern``: 'zeros' observes only 0->1 flips, 'ones' only 1->0,
        'both' counts any stuck cell (capacity planning).
        """
        r01, r10 = self.pc_rates(v)
        bits = self.geometry.bits_per_pc
        if pattern == "zeros":
            return bits * r01[pc]
        if pattern == "ones":
            return bits * r10[pc]
        return bits * min(1.0, r01[pc] + r10[pc])

    def fault_free_prob(self, v: float, pc: int) -> float:
        """Poisson probability that a PC shows zero faulty cells at v."""
        lam = self.expected_faults(v, pc, "both")
        return float(np.exp(-min(lam, 700.0)))

    # ---- clustering ----------------------------------------------------
    def row_multipliers(self) -> Tuple[float, float]:
        """(weak, strong) within-PC rate multipliers; mass-preserving."""
        weak = self.weak_row_share / self.weak_row_frac
        strong = (1.0 - self.weak_row_share) / (1.0 - self.weak_row_frac)
        return weak, strong

    # ---- reliability scores (placement planner inputs) -------------------
    def row_rates(self, v: float) -> Tuple[np.ndarray, np.ndarray]:
        """Per-PC (weak-row, strong-row) total stuck-cell rates at ``v``.

        The row-level analogue of :meth:`pc_total_rate`, mirroring the
        threshold-table synthesis: clustering modulates the exponential
        regime only, the saturation collapse is spatially uniform.  The
        criticality-aware allocator uses the strong-row rate to predict
        the reliability of an extent that *avoids* weak rows.
        """
        wm, sm = self.row_multipliers()
        weak = np.empty(self.geometry.num_pcs)
        strong = np.empty(self.geometry.num_pcs)
        for pc, m in enumerate(self.pc_multiplier):
            e01, e10, s01, s10 = self.model.components(v, m)
            p01w = np.clip(e01 * wm + s01, 0.0, 1.0)
            p10w = np.clip(e10 * wm + s10, 0.0, 1.0)
            p01s = np.clip(e01 * sm + s01, 0.0, 1.0)
            p10s = np.clip(e10 * sm + s10, 0.0, 1.0)
            weak[pc] = min(float(p01w + p10w), 1.0)
            strong[pc] = min(float(p01s + p10s), 1.0)
        return weak, strong

    def predicted_rates(self, v: float,
                        avoid_weak_rows: bool = False) -> np.ndarray:
        """Per-PC predicted total stuck-cell rate of an extent at ``v``.

        With ``avoid_weak_rows`` the extent skips every weak row, so only
        the strong-row rate applies; otherwise the blended per-PC rate
        (:meth:`pc_total_rate`) is the right expectation.  The tiered
        placement planner scores candidate extents with this.
        """
        if avoid_weak_rows:
            return self.row_rates(v)[1]
        return self.pc_total_rate(v)

    def reliability_order(self, v: float) -> np.ndarray:
        """PC indices most-reliable-first at ``v`` (stable tie-break by
        index) -- the allocation order of the criticality-aware planner."""
        return np.argsort(self.pc_total_rate(v), kind="stable")

    @property
    def rows_per_pc(self) -> int:
        return self.geometry.bytes_per_pc // self.geometry.row_bytes

    def weak_row_mask(self, pc: int) -> np.ndarray:
        """(rows_per_pc,) bool: which DRAM rows of ``pc`` are weak.

        Bit-consistent with the kernels: a row is weak iff
        ``hash(seed, STREAM_ROW, global_row) < q(weak_row_frac)`` --
        exactly the draw :func:`repro.kernels.bitflip.ref._weak_rows`
        makes from physical word ids, so the planner's spare-row
        avoidance provably dodges the rows the injection kernels hit
        hardest.
        """
        return _weak_row_mask_np(self, pc)

    def weak_block_mask(self, pc: int, block_words: int) -> np.ndarray:
        """(blocks_per_pc,) bool: blocks of ``block_words`` words in ``pc``
        that contain at least one weak row (allocation granularity of the
        spare-row-avoiding planner)."""
        words_per_row = self.geometry.row_bytes // 4
        assert block_words % words_per_row == 0, (block_words, words_per_row)
        rows_per_block = block_words // words_per_row
        mask = self.weak_row_mask(pc)
        assert mask.shape[0] % rows_per_block == 0
        return mask.reshape(-1, rows_per_block).any(axis=1)

    # ---- kernel thresholds ----------------------------------------------
    @property
    def words_per_row_log2(self) -> int:
        words_per_row = self.geometry.row_bytes // 4
        assert words_per_row & (words_per_row - 1) == 0, "row must be pow2"
        return int(words_per_row.bit_length() - 1)

    def threshold_table(self, v) -> jax.Array:
        """(num_pcs, NUM_THR_COLS) uint32 kernel-threshold table at ``v``.

        ``v`` may be a traced scalar: the whole synthesis -- fault-model
        regimes, per-PC multipliers, weak/strong clustering, word-hit /
        bitwise / ECC-parity quantization -- is jnp float32, so a jitted
        voltage sweep retraces nothing.  Clustering (weak/strong rows)
        modulates only the exponential regime; the saturation collapse is
        spatially uniform.  The weak-row selection threshold is voltage-
        independent and broadcast as a constant column.

        ``v`` always crosses a jit boundary as a *runtime* scalar: XLA
        constant-folds transcendentals at a different precision than it
        evaluates them at runtime, and routing every caller (eager or
        traced) through the same compiled graph is what keeps the
        per-segment path, the arena engine, and the oracles
        bit-identical.
        """
        return _threshold_table_jit(self, jnp.asarray(v, jnp.float32))

    def thresholds(self, v: float, pc: int) -> KernelThresholds:
        """Integer thresholds for the injection kernel on one PC segment.

        One row of :meth:`threshold_table`, materialized -- the legacy
        per-segment path therefore stays bit-exact with the arena engine
        at any concrete voltage.
        """
        row = _threshold_table_np(self, float(v))[pc]
        inv = 1.0 / float(2 ** hashing.PLANES)
        return KernelThresholds(
            q01_weak=int(row[COL_Q01_WEAK]), q01_strong=int(row[COL_Q01_STRONG]),
            q10_weak=int(row[COL_Q10_WEAK]), q10_strong=int(row[COL_Q10_STRONG]),
            weak_row_q=int(row[COL_WEAK_ROW_Q]),
            words_per_row_log2=self.words_per_row_log2,
            p01_weak=int(row[COL_T01_WEAK]) * inv,
            p01_strong=int(row[COL_T01_STRONG]) * inv,
            p10_weak=int(row[COL_T10_WEAK]) * inv,
            p10_strong=int(row[COL_T10_STRONG]) * inv,
            t01_weak=int(row[COL_T01_WEAK]), t01_strong=int(row[COL_T01_STRONG]),
            t10_weak=int(row[COL_T10_WEAK]), t10_strong=int(row[COL_T10_STRONG]),
            par_q_weak=int(row[COL_PAR_Q_WEAK]),
            par_q_strong=int(row[COL_PAR_Q_STRONG]),
        )

    # ---- capacity planning ----------------------------------------------
    def usable_pcs(self, v: float, tolerable_rate: float) -> np.ndarray:
        """PC indices whose total stuck-cell rate is <= tolerable_rate,
        most reliable first.  tolerable_rate=0 means provably fault-free
        in expectation (< 1 expected faulty bit per PC)."""
        total = self.pc_total_rate(v)
        order = np.argsort(total, kind="stable")
        if tolerable_rate <= 0.0:
            keep = total[order] * self.geometry.bits_per_pc < 1.0
        else:
            keep = total[order] <= tolerable_rate
        return order[keep]

    def num_usable_pcs(self, v: float, tolerable_rate: float) -> int:
        return int(len(self.usable_pcs(v, tolerable_rate)))


@functools.partial(jax.jit, static_argnums=0)
def _threshold_table_jit(fmap: FaultMap, v) -> jax.Array:
    mult = jnp.asarray(fmap.pc_multiplier, jnp.float32)
    e01, e10, s01, s10 = fmap.model.components_jnp(v, mult)
    wm, sm = fmap.row_multipliers()
    p01w = jnp.clip(e01 * jnp.float32(wm) + s01, 0.0, 1.0)
    p01s = jnp.clip(e01 * jnp.float32(sm) + s01, 0.0, 1.0)
    p10w = jnp.clip(e10 * jnp.float32(wm) + s10, 0.0, 1.0)
    p10s = jnp.clip(e10 * jnp.float32(sm) + s10, 0.0, 1.0)

    def word_q(p):
        # Word-hit probability for the fast path: one stuck bit per hit
        # word; exact to O((32p)^2) for small p.
        return hashing.rate_to_u32_threshold_jnp(32.0 * p)

    def par_q(p01, p10):
        # 8 parity bits per SECDED(72,64) codeword, either direction.
        return hashing.rate_to_u32_threshold_jnp(8.0 * (p01 + p10))

    weak_row_q = jnp.full(
        mult.shape,
        np.uint32(hashing.rate_to_u32_threshold(fmap.weak_row_frac)))
    return jnp.stack(
        [word_q(p01w), word_q(p01s), word_q(p10w), word_q(p10s),
         weak_row_q,
         hashing.rate_to_plane_threshold_jnp(p01w),
         hashing.rate_to_plane_threshold_jnp(p01s),
         hashing.rate_to_plane_threshold_jnp(p10w),
         hashing.rate_to_plane_threshold_jnp(p10s),
         par_q(p01w, p10w), par_q(p01s, p10s)],
        axis=1)


@functools.lru_cache(maxsize=128)
def _weak_row_mask_np(fmap: FaultMap, pc: int) -> np.ndarray:
    """Numpy mirror of the kernels' weak-row draw for one PC, memoized on
    the frozen map.  Rows are indexed by *global* physical word id >>
    words_per_row_log2, so the mask matches injection bit-for-bit."""
    rows_per_pc = fmap.rows_per_pc
    row0 = pc * rows_per_pc
    rows = (np.uint32(row0)
            + np.arange(rows_per_pc, dtype=np.uint32))
    q = np.uint32(hashing.rate_to_u32_threshold(fmap.weak_row_frac))
    with np.errstate(over="ignore"):
        u = hashing.hash_stream(fmap.seed, hashing.STREAM_ROW, rows)
    return np.asarray(u < q)


@functools.lru_cache(maxsize=512)
def _threshold_table_np(fmap: FaultMap, v: float) -> np.ndarray:
    """Materialized threshold table for a concrete voltage, memoized on
    the (frozen, hashable) map so repeated per-segment calls are free.

    Evaluated outside any ambient trace (the inputs are concrete Python
    values even when a caller asks for static thresholds mid-trace, e.g.
    method dispatch inside a jitted train step)."""
    with jax.ensure_compile_time_eval():
        return np.asarray(fmap.threshold_table(v))
