"""Fault-map synthesis: process variation + spatial clustering.

The paper's fault characterization (section III-B, Figs. 4/5) shows three
levels of variation, all reproduced here:

  * per-stack: HBM1's fault rate is 13% above HBM0's on average, with the
    same V_min / V_critical (C7) -> a fixed multiplicative skew on the
    exponential regime, geometric-mean 1.
  * per-PC: some pseudo-channels (PC4/PC5 of HBM0, PC18/19/20 of HBM1) are
    roughly an order of magnitude more sensitive (C8) -> lognormal per-PC
    multipliers, plus the paper's named hot PCs boosted explicitly in the
    calibrated default map.
  * spatial clustering: most faults concentrate in small regions (C9) ->
    a two-level row model: a small fraction of "weak" rows (in contiguous
    runs) carries most of the fault mass.

A FaultMap is deterministic in (geometry, seed) and is the single source
of truth for: analytic rates (trade-off solver, power model), kernel
thresholds (fault injection), and the reliability tester.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import hashing
from repro.core.faultmodel import DEFAULT_FAULT_MODEL, FaultModel
from repro.core.hbm import HBMGeometry, VCU128

# Paper-calibrated hot pseudo-channels (Fig. 5): extra sensitivity factors.
PAPER_HOT_PCS: Dict[int, float] = {4: 8.0, 5: 6.0, 18: 9.0, 19: 7.0, 20: 6.0}

STACK_SKEW = 1.13          # HBM1 / HBM0 average fault-rate ratio (C7)
# Lognormal spread of per-PC sensitivity.  0.8 decades reproduces Fig. 5's
# dynamic range (some PCs "NF" while others show percent-level rates at
# the same voltage) and Fig. 6's fault-free PC counts.
PC_SIGMA_DECADES = 0.80

# Default map seed: selected by scanning seeds so the calibrated map
# reproduces the paper's Fig. 6 worked examples on VCU128 geometry:
# 7 fault-free PCs at 0.95 V, ~half the PCs usable at a 1e-6 tolerable
# rate at 0.90 V, and HBM1's mean unsafe-region fault rate above HBM0's.
PAPER_MAP_SEED = 469

# Spatial clustering (C9): WEAK_ROW_FRAC of rows carry WEAK_ROW_SHARE of
# the fault mass, in contiguous runs of WEAK_RUN_ROWS rows.
WEAK_ROW_FRAC = 0.05
WEAK_ROW_SHARE = 0.90
WEAK_RUN_ROWS = 8


@dataclasses.dataclass(frozen=True)
class KernelThresholds:
    """Integer thresholds consumed by the bitflip kernel for one segment."""

    q01_weak: int
    q01_strong: int
    q10_weak: int
    q10_strong: int
    weak_row_q: int        # uint32 threshold for weak-row selection
    words_per_row_log2: int
    p01_weak: float        # raw per-bit rates (bitwise path uses these)
    p01_strong: float
    p10_weak: float
    p10_strong: float


@dataclasses.dataclass(frozen=True)
class FaultMap:
    geometry: HBMGeometry
    seed: int
    model: FaultModel
    pc_multiplier: Tuple[float, ...]
    weak_row_frac: float = WEAK_ROW_FRAC
    weak_row_share: float = WEAK_ROW_SHARE
    weak_run_rows: int = WEAK_RUN_ROWS

    # ---- construction --------------------------------------------------
    @classmethod
    def from_seed(
        cls,
        geometry: HBMGeometry = VCU128,
        seed: int = 0,
        model: FaultModel = DEFAULT_FAULT_MODEL,
        stack_skew: float = STACK_SKEW,
        sigma_decades: float = PC_SIGMA_DECADES,
        hot_pcs: Optional[Dict[int, float]] = None,
    ) -> "FaultMap":
        rng = np.random.RandomState(seed)
        mult = 10.0 ** rng.normal(0.0, sigma_decades, geometry.num_pcs)
        skew = np.sqrt(stack_skew)
        for pc in range(geometry.num_pcs):
            mult[pc] *= skew if geometry.stack_of_pc(pc) == 1 else 1.0 / skew
        if hot_pcs is None:
            hot_pcs = PAPER_HOT_PCS if geometry.num_pcs == 32 else {}
        for pc, boost in hot_pcs.items():
            if pc < geometry.num_pcs:
                mult[pc] *= boost
        return cls(geometry=geometry, seed=seed, model=model,
                   pc_multiplier=tuple(float(m) for m in mult))

    # ---- analytic rates -------------------------------------------------
    def pc_rates(self, v: float) -> Tuple[np.ndarray, np.ndarray]:
        """(stuck-at-1, stuck-at-0) per-bit fractions for every PC."""
        r01 = np.empty(self.geometry.num_pcs)
        r10 = np.empty(self.geometry.num_pcs)
        for pc, m in enumerate(self.pc_multiplier):
            a, b = self.model.rates(v, m)
            r01[pc], r10[pc] = float(a), float(b)
        return r01, r10

    def pc_total_rate(self, v: float) -> np.ndarray:
        r01, r10 = self.pc_rates(v)
        return np.clip(r01 + r10, 0.0, 1.0)

    def stack_mean_rate(self, v: float, stack: int) -> float:
        pcs = self.geometry.pcs_of_stack(stack)
        return float(self.pc_total_rate(v)[list(pcs)].mean())

    def expected_faults(self, v: float, pc: int,
                        pattern: str = "both") -> float:
        """Expected faulty bits in one PC for a given test pattern.

        ``pattern``: 'zeros' observes only 0->1 flips, 'ones' only 1->0,
        'both' counts any stuck cell (capacity planning).
        """
        r01, r10 = self.pc_rates(v)
        bits = self.geometry.bits_per_pc
        if pattern == "zeros":
            return bits * r01[pc]
        if pattern == "ones":
            return bits * r10[pc]
        return bits * min(1.0, r01[pc] + r10[pc])

    def fault_free_prob(self, v: float, pc: int) -> float:
        """Poisson probability that a PC shows zero faulty cells at v."""
        lam = self.expected_faults(v, pc, "both")
        return float(np.exp(-min(lam, 700.0)))

    # ---- clustering ----------------------------------------------------
    def row_multipliers(self) -> Tuple[float, float]:
        """(weak, strong) within-PC rate multipliers; mass-preserving."""
        weak = self.weak_row_share / self.weak_row_frac
        strong = (1.0 - self.weak_row_share) / (1.0 - self.weak_row_frac)
        return weak, strong

    # ---- kernel thresholds ----------------------------------------------
    def thresholds(self, v: float, pc: int) -> KernelThresholds:
        """Integer thresholds for the injection kernel on one PC segment.

        Clustering (weak/strong rows) modulates only the exponential
        regime; the saturation collapse is spatially uniform.
        """
        e01, e10, s01, s10 = (float(x) for x in self.model.components(
            v, self.pc_multiplier[pc]))
        wm, sm = self.row_multipliers()
        words_per_row = self.geometry.row_bytes // 4
        assert words_per_row & (words_per_row - 1) == 0, "row must be pow2"

        def word_q(p: float) -> int:
            # Word-hit probability for the fast path: one stuck bit per
            # hit word; exact to O((32p)^2) for small p.
            return hashing.rate_to_u32_threshold(min(1.0, 32.0 * p))

        p01w = min(1.0, e01 * wm + s01)
        p01s = min(1.0, e01 * sm + s01)
        p10w = min(1.0, e10 * wm + s10)
        p10s = min(1.0, e10 * sm + s10)
        return KernelThresholds(
            q01_weak=word_q(p01w), q01_strong=word_q(p01s),
            q10_weak=word_q(p10w), q10_strong=word_q(p10s),
            weak_row_q=hashing.rate_to_u32_threshold(self.weak_row_frac),
            words_per_row_log2=int(np.log2(words_per_row)),
            p01_weak=p01w, p01_strong=p01s,
            p10_weak=p10w, p10_strong=p10s,
        )

    # ---- capacity planning ----------------------------------------------
    def usable_pcs(self, v: float, tolerable_rate: float) -> np.ndarray:
        """PC indices whose total stuck-cell rate is <= tolerable_rate,
        most reliable first.  tolerable_rate=0 means provably fault-free
        in expectation (< 1 expected faulty bit per PC)."""
        total = self.pc_total_rate(v)
        order = np.argsort(total, kind="stable")
        if tolerable_rate <= 0.0:
            keep = total[order] * self.geometry.bits_per_pc < 1.0
        else:
            keep = total[order] <= tolerable_rate
        return order[keep]

    def num_usable_pcs(self, v: float, tolerable_rate: float) -> int:
        return int(len(self.usable_pcs(v, tolerable_rate)))
