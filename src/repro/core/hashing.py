"""Counter-based integer hashing shared by the fault-injection paths.

Stuck-at faults are a property of *physical bit locations*: the same bit
must be stuck across steps, and the fault set at voltage v' < v must be a
superset of the one at v (lower voltage strictly removes timing margin).
We get both properties by assigning every location a deterministic uniform
value u = hash(seed, location) and declaring it stuck iff u < q(v), with
q monotone in v.  The hash is a murmur3-style finalizer -- cheap enough to
run per word inside the Pallas kernel, and bit-exact between the kernel
and the pure-jnp reference.

Seeds and stream ids are always Python ints (folded at trace time); only
the counter is a traced uint32 array, so nothing here captures array
constants inside a Pallas kernel body.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Distinct stream constants so each use-site draws independent values.
STREAM_WORD_01 = 0x9E3779B1   # word-level stuck-at-1 draw
STREAM_WORD_10 = 0x85EBCA77   # word-level stuck-at-0 draw
STREAM_BITPOS_01 = 0xC2B2AE3D
STREAM_BITPOS_10 = 0x27D4EB2F
STREAM_ROW = 0x165667B1       # weak-row selection
STREAM_BITPLANE = 0xD3A2646C  # bitwise-path plane seeds

_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_MASK = 0xFFFFFFFF

# Bit-planes in the bitwise injection path: probability resolution
# 2**-PLANES.  Lives here (not in the kernel package) so the fault-map's
# threshold-table synthesis needs no kernel import.
PLANES = 20


def mix32(x):
    """Murmur3/splitmix-style 32-bit finalizer on a traced uint32 array."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(_M1)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(_M2)
    x = x ^ (x >> np.uint32(16))
    return x


def mix32_int(x: int) -> int:
    """Pure-Python mix32 for trace-time seed folding."""
    x &= _MASK
    x ^= x >> 16
    x = (x * _M1) & _MASK
    x ^= x >> 15
    x = (x * _M2) & _MASK
    x ^= x >> 16
    return x


def hash_stream(seed: int, stream: int, counter):
    """Deterministic uniform uint32 per (seed, stream, counter).

    ``seed``/``stream`` are Python ints (compile-time); ``counter`` is a
    traced uint32 array.
    """
    inner = np.uint32(mix32_int(int(seed) ^ int(stream)))
    return mix32(counter ^ inner)


def rate_to_u32_threshold(rate: float) -> int:
    """Probability in [0,1] -> uint32 compare threshold (u < t <=> hit)."""
    rate = min(1.0, max(0.0, float(rate)))
    return min(0xFFFFFFFF, int(np.floor(rate * 4294967296.0)))


def rate_to_u32_threshold_jnp(rate):
    """Traced counterpart of :func:`rate_to_u32_threshold`.

    Accepts a float32 array of probabilities (possibly traced, e.g. a
    function of a runtime voltage) and returns uint32 thresholds.  A rate
    that rounds to 1.0 in float32 saturates to 0xFFFFFFFF, so the hit is
    certain up to one part in 2**32.
    """
    t = jnp.floor(jnp.clip(jnp.asarray(rate, jnp.float32), 0.0, 1.0)
                  * jnp.float32(4294967296.0))
    return jnp.where(t >= jnp.float32(4294967296.0),
                     jnp.uint32(0xFFFFFFFF), t.astype(jnp.uint32))


def rate_to_plane_threshold_jnp(rate):
    """Probability -> PLANES-bit integer threshold for the bitwise path,
    matching round(p * 2**PLANES) clipped to 2**PLANES - 1."""
    t = jnp.round(jnp.asarray(rate, jnp.float32) * jnp.float32(2 ** PLANES))
    return jnp.clip(t, 0.0, float(2 ** PLANES - 1)).astype(jnp.uint32)
