"""Arena-based fault-injection engine: one fused pass per memory domain.

The legacy path (:mod:`repro.core.injection`) walks a placement segment
by segment, launching one Pallas call per segment per leaf with the
thresholds baked in as static jit arguments -- O(segments) launches plus
a full retrace for every distinct (voltage, PC) pair.  This module
replaces that hot path:

  * every leaf of a group is packed (block-aligned) into one flat
    *arena* buffer, using the placement's exported
    :class:`~repro.core.domains.BlockTable`;
  * the fault map's voltage->threshold table is evaluated *inside the
    trace* -- voltage may be a traced scalar -- and gathered into
    per-block threshold rows;
  * a single ``pallas_call`` with a grid over all arena blocks performs
    the read-modify-write for the entire domain, reading each block's
    physical base word and threshold row from scalar-prefetch operands.

Consequences: injecting a multi-leaf, multi-PC group costs one kernel
launch instead of hundreds, and a jitted voltage sweep (the paper's
10 mV-step methodology, online V_min search, per-request voltage
schedules) compiles exactly once.

The ``use_ref`` path is the table-driven oracle: identical mask math on
the same arena/threshold operands, pure jnp -- bit-exact with the kernel
by construction and asserted by the tests.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains import ALIGN_WORDS, GroupPlacement
from repro.core.faultmap import NUM_THR_COLS, FaultMap
from repro.core.faultmodel import V_MIN
from repro.kernels.bitflip import ops as bitflip_ops
from repro.kernels.bitflip.bitflip import (BLOCK_LANES, BLOCK_WORDS,
                                           BLOCK_WORDS_LOG2, apply_masks,
                                           arena_bitflip_pallas, arena_masks)
from repro.kernels.ecc.ecc import (arena_ecc_codewords, arena_ecc_events,
                                   arena_ecc_pallas)

assert BLOCK_WORDS == ALIGN_WORDS, "arena blocks must match allocation slots"


@functools.lru_cache(maxsize=256)
def _block_arrays(placement: GroupPlacement):
    """Numpy block tables for a placement (bounded cache: a long-lived
    server may build placements for many distinct batch/length shapes).
    Kept as numpy -- they embed as constants both eagerly and under jit;
    caching device arrays here would leak tracers out of whatever trace
    first touched them."""
    table = placement.block_table()
    return (np.asarray(table.block_pc, np.int32),
            np.asarray(table.block_base, np.uint32))


def _static_value(v):
    """``float(v)`` for any concrete scalar (python, numpy, or a
    non-traced jax array), ``None`` for traced values.  Concrete jax
    scalars drive static decisions (method dispatch, guardband
    early-out) exactly like python floats."""
    try:
        return float(v)
    except (TypeError, jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return None


def resolve_method(faultmap: FaultMap, placement: GroupPlacement,
                   voltage=None) -> str:
    """Static word/bitwise dispatch for a whole domain.

    The fast word path is exact to O((32p)^2), so it is chosen only if
    *every* PC of the domain is inside its validity regime.  A traced
    voltage override cannot drive dispatch (method selects a trace);
    it falls back to the domain's configured voltage (with a trace-time
    warning) -- callers sweeping into the collapse regime should pass
    ``method='bitwise'`` explicitly.
    """
    v = _static_value(voltage)
    if v is None:
        v = placement.domain.voltage
        warnings.warn(
            f"method='auto' with a traced voltage dispatches from domain "
            f"{placement.domain.name!r}'s configured {v:.2f} V; sweeps "
            "crossing per-bit rates ~1e-3 should pass method='bitwise' "
            "explicitly", stacklevel=3)
    if any(bitflip_ops.pick_method(faultmap.thresholds(v, pc)) ==
           "bitwise" for pc in placement.domain.pc_ids):
        return "bitwise"
    return "word"


def pack_arena(tree, placement: GroupPlacement):
    """Pack a group's leaves into one (num_blocks * 8, 512) uint32 arena.

    Returns (arena2d, pack_meta).  Leaves are matched to placements by
    pytree path, exactly like the legacy path.  ``pack_meta`` records,
    in the *tree's flatten order* (placement order is keystr-sorted and
    may differ -- e.g. list index 10 sorts before 2), each leaf's arena
    slot and dtype-recovery metadata for :func:`unpack_arena`.
    """
    table = placement.block_table()
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaf_by_path = {jax.tree_util.keystr(p): leaf for p, leaf in flat}
    pieces = []
    slot_by_path = {}
    for lp, (start, n_blocks, n_words) in zip(placement.leaves,
                                              table.leaf_blocks):
        u32, meta = bitflip_ops.to_u32(leaf_by_path[lp.path])
        assert u32.shape[0] == n_words == lp.n_words, (
            lp.path, u32.shape, n_words, lp.n_words)
        pad = n_blocks * BLOCK_WORDS - n_words
        if pad:
            u32 = jnp.concatenate([u32, jnp.zeros((pad,), jnp.uint32)])
        pieces.append(u32)
        slot_by_path[lp.path] = (meta, start, n_words)
    arena = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    slots = tuple(slot_by_path[jax.tree_util.keystr(p)] for p, _ in flat)
    return arena.reshape(-1, BLOCK_LANES), (treedef, slots)


def unpack_arena(arena2d, pack_meta):
    """Inverse of :func:`pack_arena`: arena -> original pytree."""
    treedef, slots = pack_meta
    flat_arena = arena2d.reshape(-1)
    leaves = [
        bitflip_ops.from_u32(
            flat_arena[start * BLOCK_WORDS:start * BLOCK_WORDS + n_words],
            meta)
        for meta, start, n_words in slots]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _arena_oracle(arena2d, block_base, block_thr, *, seed: int, method: str,
                  words_per_row_log2: int, ecc: bool):
    """Table-driven pure-jnp oracle: same operands, same mask math.

    Returns (out, uncorrectable count, corrected count) -- counts are
    zero without ECC.
    """
    num_blocks = block_base.shape[0]
    x = arena2d.reshape(num_blocks, BLOCK_WORDS)
    wid = (block_base[:, None]
           + jnp.arange(BLOCK_WORDS, dtype=jnp.uint32)[None, :])
    thr_row = tuple(block_thr[:, c][:, None] for c in range(NUM_THR_COLS))
    if ecc:
        out, corr, bad = arena_ecc_events(
            x, wid, thr_row, seed=seed,
            words_per_row_log2=words_per_row_log2)
        return (out.reshape(arena2d.shape),
                jnp.sum(bad.astype(jnp.int32)),
                jnp.sum(corr.astype(jnp.int32)))
    mask01, mask10 = arena_masks(wid, thr_row, seed=seed, method=method,
                                 words_per_row_log2=words_per_row_log2)
    mask10 = mask10 & ~mask01
    out = (x | mask01) & ~mask10
    return (out.reshape(arena2d.shape), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32))


def inject_placement(tree, placement: GroupPlacement, faultmap: FaultMap,
                     *, voltage=None, method: str = "auto",
                     interpret: Optional[bool] = None,
                     use_ref: bool = False, with_corrected: bool = False):
    """Inject a whole group through one fused arena pass.

    ``voltage``: optional override of the domain's configured voltage.
    May be a *traced* scalar -- thresholds are computed inside the trace,
    so sweeping voltages re-executes, never recompiles.  When the
    effective voltage is static and inside the guardband the call is an
    exact no-op (identity tree); a traced voltage in the guardband is a
    numerical no-op (the threshold table gates itself to zero).

    Returns (faulted tree, uncorrectable-fault count) -- the count is
    zero unless the domain has ECC.  With ``with_corrected`` a third
    value is appended: the corrected-codeword count (ECC telemetry),
    computed from outputs the fused kernel already produces, so the
    launch budget is unchanged.
    """
    domain = placement.domain
    zero = jnp.zeros((), jnp.int32)
    if not placement.leaves:  # empty group: nothing placed, nothing to do
        return (tree, zero, zero) if with_corrected else (tree, zero)
    if voltage is None:
        voltage = domain.voltage
    sv = _static_value(voltage)
    if sv is not None and sv >= V_MIN - 1e-9:
        return (tree, zero, zero) if with_corrected else (tree, zero)
    if method == "auto":
        # ECC is word-path-only by design; don't resolve (or warn).
        method = "word" if domain.ecc else resolve_method(
            faultmap, placement, voltage)
    if interpret is None:
        interpret = bitflip_ops.default_interpret()

    block_pc, block_base = _block_arrays(placement)
    block_base = jnp.asarray(block_base)
    arena2d, pack_meta = pack_arena(tree, placement)
    block_thr = faultmap.threshold_table(voltage)[jnp.asarray(block_pc)]
    wprl2 = faultmap.words_per_row_log2

    if use_ref:
        out2d, bad, corr = _arena_oracle(
            arena2d, block_base, block_thr, seed=faultmap.seed,
            method=method, words_per_row_log2=wprl2, ecc=domain.ecc)
    elif domain.ecc:
        out2d, bad_blocks, corr_blocks = arena_ecc_pallas(
            arena2d, block_base, block_thr, seed=faultmap.seed,
            words_per_row_log2=wprl2, interpret=bool(interpret))
        bad = jnp.sum(bad_blocks)
        corr = jnp.sum(corr_blocks)
    else:
        out2d = arena_bitflip_pallas(
            arena2d, block_base, block_thr, seed=faultmap.seed,
            method=method, words_per_row_log2=wprl2,
            interpret=bool(interpret))
        bad = corr = zero
    out = unpack_arena(out2d, pack_meta)
    return (out, bad, corr) if with_corrected else (out, bad)


@functools.lru_cache(maxsize=256)
def leaf_block_tables(placement: GroupPlacement):
    """Per-leaf ``(block_base, block_pc)`` numpy arrays, in placement
    (keystr-sorted) order -- the arena engine's block tables sliced to
    one leaf, so the read path and the incremental write path can
    address a single cache buffer without packing the whole domain."""
    table = placement.block_table()
    bb = np.asarray(table.block_base, np.uint32)
    bp = np.asarray(table.block_pc, np.int32)
    return tuple((bb[s:s + n], bp[s:s + n])
                 for s, n, _ in table.leaf_blocks)


def refine_tables(block_base, block_pc, page_words: int):
    """Refine one leaf's arena block tables to page granularity.

    ``page_words`` must divide BLOCK_WORDS, so every page sits inside
    exactly one arena block and inherits that block's pseudo-channel
    (threshold row); its physical base is the block's base plus the
    page's offset inside the block.  The page tables are therefore a
    pure index transform of the block tables -- the paged KV cache
    costs zero extra placement bookkeeping.

    Returns ``(page_base, page_pc)`` numpy arrays with
    ``BLOCK_WORDS // page_words`` entries per block.
    """
    if page_words <= 0 or BLOCK_WORDS % page_words:
        raise ValueError(
            f"page_words={page_words} must positively divide the arena "
            f"block size ({BLOCK_WORDS} words)")
    per = BLOCK_WORDS // page_words
    base = (np.repeat(np.asarray(block_base, np.uint32), per)
            + np.tile(np.arange(per, dtype=np.uint32) * page_words,
                      len(block_base)))
    return base, np.repeat(np.asarray(block_pc, np.int32), per)


def leaf_addr_tables(placement):
    """Per-leaf ``(base, pc, words_log2)`` physical addressing tables.

    For an arena-backed :class:`~repro.core.domains.GroupPlacement`
    these are the block tables at BLOCK_WORDS granularity.  Placements
    whose leaves carry their own page tables (the paged serving cache's
    per-request placements, duck-typed on a ``page_base`` attribute)
    return those instead, with each leaf's page granularity.
    """
    leaves = placement.leaves
    if leaves and hasattr(leaves[0], "page_base"):
        out = []
        for lp in leaves:
            lg2 = int(lp.page_words).bit_length() - 1
            assert (1 << lg2) == lp.page_words, lp.page_words
            out.append((np.asarray(lp.page_base, np.uint32),
                        np.asarray(lp.page_pc, np.int32), lg2))
        return tuple(out)
    return tuple((bb, bp, BLOCK_WORDS_LOG2)
                 for bb, bp in leaf_block_tables(placement))


def corrupt_words(u32, off, block_base, block_thr, *, seed: int,
                  method: str, words_per_row_log2: int, ecc: bool,
                  words_log2: int = BLOCK_WORDS_LOG2):
    """Corrupt arbitrary leaf words through their arena block tables.

    The pure-jnp twin of the kernels' candidate-select addressing:
    ``off`` holds leaf word offsets (any shape matching ``u32``), the
    per-word physical id and threshold row are gathered with
    ``jnp.take`` from the leaf's ``block_base`` / per-block threshold
    rows (``block_thr``, possibly derived from a traced voltage), and
    the shared tile-level mask math is applied.  For ECC the last axis
    must hold leaf-adjacent words in even count (codeword pairs).
    ``words_log2``: granularity of the tables (arena blocks by default,
    pages for the paged serving cache).

    Returns (corrupted u32, uncorrectable count).
    """
    off = off.astype(jnp.uint32)
    jvec = (off >> np.uint32(words_log2)).astype(jnp.int32)
    wid = (jnp.take(jnp.asarray(block_base), jvec)
           + (off & np.uint32((1 << words_log2) - 1)))
    rows = jnp.take(jnp.asarray(block_thr), jvec, axis=0)
    thr = tuple(rows[..., c] for c in range(NUM_THR_COLS))
    if ecc:
        out, bad = arena_ecc_codewords(
            u32, wid, thr, seed=seed,
            words_per_row_log2=words_per_row_log2)
        return out, jnp.sum(bad.astype(jnp.int32))
    out = apply_masks(u32, wid, thr, seed=seed, method=method,
                      words_per_row_log2=words_per_row_log2)
    return out, jnp.zeros((), jnp.int32)


def ecc_event_counts(u32, off, block_base, block_thr, *, seed: int,
                     words_per_row_log2: int,
                     words_log2: int = BLOCK_WORDS_LOG2):
    """Per-codeword ECC event flags for arbitrary leaf words.

    The telemetry twin of :func:`corrupt_words`: identical table-driven
    addressing and mask math, but instead of mutating data it returns
    ``(corrected_bool, uncorrectable_bool)`` per codeword (last axis of
    ``u32`` halved).  Because stuck-at masks are deterministic in the
    physical word id, evaluating this on *clean* stored data yields
    exactly the events the fused read-path kernel observed when it
    loaded the same words this step -- a zero-extra-launch scrub.
    """
    off = off.astype(jnp.uint32)
    jvec = (off >> np.uint32(words_log2)).astype(jnp.int32)
    wid = (jnp.take(jnp.asarray(block_base), jvec)
           + (off & np.uint32((1 << words_log2) - 1)))
    rows = jnp.take(jnp.asarray(block_thr), jvec, axis=0)
    thr = tuple(rows[..., c] for c in range(NUM_THR_COLS))
    _, corrected, uncorrectable = arena_ecc_events(
        u32, wid, thr, seed=seed, words_per_row_log2=words_per_row_log2)
    return corrected, uncorrectable


def _corrupt_full_leaf(leaf, block_base, block_thr, *, seed, method,
                       wprl2, ecc, words_log2=BLOCK_WORDS_LOG2):
    u32, meta = bitflip_ops.to_u32(leaf)
    n = u32.shape[0]
    pad = (-n) % 2 if ecc else 0
    if pad:
        u32 = jnp.concatenate([u32, jnp.zeros((pad,), jnp.uint32)])
    off = jnp.arange(n + pad, dtype=jnp.uint32)
    out, bad = corrupt_words(u32, off, block_base, block_thr, seed=seed,
                             method=method, words_per_row_log2=wprl2,
                             ecc=ecc, words_log2=words_log2)
    return bitflip_ops.from_u32(out[:n], meta), bad


def _corrupt_leaf_slice(leaf, slot_axis, pos, block_base, block_thr, *,
                        seed, method, wprl2, ecc,
                        words_log2=BLOCK_WORDS_LOG2):
    """Corrupt only the slot written at absolute position ``pos``."""
    shape = leaf.shape
    ln = shape[slot_axis]
    outer = int(np.prod(shape[:slot_axis], dtype=np.int64))
    inner = int(np.prod(shape[slot_axis + 1:], dtype=np.int64))
    wpi = inner * jnp.dtype(leaf.dtype).itemsize // 4
    slot = (pos % ln).astype(jnp.int32)
    sl = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=slot_axis)
    u32, meta = bitflip_ops.to_u32(sl.reshape(outer, inner))
    u32 = u32.reshape(outer, wpi)
    off = (jnp.arange(outer, dtype=jnp.uint32)[:, None] * np.uint32(ln * wpi)
           + slot.astype(jnp.uint32) * np.uint32(wpi)
           + jnp.arange(wpi, dtype=jnp.uint32)[None, :])
    out, bad = corrupt_words(u32, off, block_base, block_thr, seed=seed,
                             method=method, words_per_row_log2=wprl2,
                             ecc=ecc, words_log2=words_log2)
    out = bitflip_ops.from_u32(out.reshape(-1), meta).reshape(sl.shape)
    return (jax.lax.dynamic_update_slice_in_dim(leaf, out, slot,
                                                axis=slot_axis), bad)


def _sliceable(leaf, slot_axis, ecc) -> bool:
    if slot_axis is None or slot_axis < 0:
        return False
    inner_bytes = (int(np.prod(leaf.shape[slot_axis + 1:], dtype=np.int64))
                   * jnp.dtype(leaf.dtype).itemsize)
    if inner_bytes % 4:
        return False                   # slot not word-aligned
    if ecc and (inner_bytes // 4) % 2:
        return False                   # slot splits an ECC codeword
    return True


def inject_placement_slice(tree, placement: GroupPlacement,
                           faultmap: FaultMap, *, slot_axes=None, pos=None,
                           voltage=None, method: str = "auto",
                           skip_paths=()):
    """Incremental write-path injection: O(touched-words), pure jnp.

    With ``pos`` a (traced) absolute position, only the ring slot
    ``pos % L`` of each leaf is corrupted -- the slice a decode step just
    wrote -- which is bit-identical to re-injecting the whole cache
    (stuck-at masks are deterministic per physical word and idempotent)
    at O(new-token) cost instead of O(cache).  Leaves without a slot
    axis (``slot_axes`` leaf < 0), with non-word-aligned slots, or whose
    slots split ECC codewords are corrupted whole (they are the small
    recurrent/bookkeeping states).  With ``pos=None`` every included
    leaf is corrupted whole (the post-prefill initialization).

    Whole-leaf corruption of carried state is the PERSISTENT-fault
    semantic of the model zoo's ``state``-layout leaves (RG-LRU
    h/conv, mLSTM matrix memories): the state is rewritten on every
    decode step, so the same deterministic per-word stuck-at masks
    re-apply to each new value -- a cell that faults on write stays
    faulted for the request's lifetime (corrupt-once-on-write), while
    a ring K/V row, written once, is only ever re-masked to the value
    it already has.

    ``skip_paths``: keystr paths handled elsewhere (e.g. K/V leaves
    corrupted on the read path by the fused attention kernel).

    Returns (tree, uncorrectable count).
    """
    domain = placement.domain
    if not placement.leaves:
        return tree, jnp.zeros((), jnp.int32)
    if voltage is None:
        voltage = domain.voltage
    sv = _static_value(voltage)
    if sv is not None and sv >= V_MIN - 1e-9:
        return tree, jnp.zeros((), jnp.int32)
    if method == "auto":
        method = "word" if domain.ecc else resolve_method(
            faultmap, placement, voltage)
    wprl2 = faultmap.words_per_row_log2
    table = faultmap.threshold_table(voltage)
    tables = {lp.path: (bb, table[jnp.asarray(bp)], lg2)
              for lp, (bb, bp, lg2) in zip(placement.leaves,
                                           leaf_addr_tables(placement))}

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    if slot_axes is None:
        ax_leaves = [-1] * len(flat)
    else:
        ax_leaves = jax.tree_util.tree_leaves(slot_axes)
        assert len(ax_leaves) == len(flat), "slot_axes must match the tree"
    out_leaves = []
    total_bad = jnp.zeros((), jnp.int32)
    skip = set(skip_paths)
    for (path, leaf), axis in zip(flat, ax_leaves):
        key = jax.tree_util.keystr(path)
        if key in skip:
            out_leaves.append(leaf)
            continue
        bb, bt, lg2 = tables[key]
        kw = dict(seed=faultmap.seed, method=method, wprl2=wprl2,
                  ecc=domain.ecc, words_log2=lg2)
        if pos is not None and _sliceable(leaf, axis, domain.ecc):
            faulted, bad = _corrupt_leaf_slice(leaf, axis, pos, bb, bt,
                                               **kw)
        else:
            faulted, bad = _corrupt_full_leaf(leaf, bb, bt, **kw)
        out_leaves.append(faulted)
        total_bad = total_bad + bad
    return jax.tree_util.tree_unflatten(treedef, out_leaves), total_bad


def _subjaxprs(params):
    """Jaxprs nested in an eqn's params (duck-typed: no jax.core
    internals, which get pruned across jax releases)."""
    stack = list(params.values())
    while stack:
        v = stack.pop()
        if hasattr(v, "eqns"):          # Jaxpr
            yield v
        elif hasattr(v, "jaxpr"):       # ClosedJaxpr
            yield v.jaxpr
        elif isinstance(v, (list, tuple)):
            stack.extend(v)


def count_pallas_calls(jaxpr) -> int:
    """Number of ``pallas_call`` equations in a jaxpr (recursive).

    The arena engine's structural contract -- one launch per domain --
    is asserted with this in the tests and reported by the benchmarks.
    """
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in _subjaxprs(eqn.params):
            n += count_pallas_calls(sub)
    return n


def inject_groups(groups: Dict[str, object],
                  placements: Dict[str, GroupPlacement],
                  faultmap: FaultMap, *, voltage=None, method: str = "auto",
                  interpret: Optional[bool] = None, use_ref: bool = False,
                  with_corrected: bool = False):
    """Arena-inject every group: one fused pass per domain.

    ``voltage`` as a scalar (possibly traced) overrides only domains
    *configured below the guardband* -- domains placed at or above
    V_MIN hold state the plan promises to keep safe (master params,
    optimizer moments) and are never dragged down by a sweep.  Pass a
    ``{domain name: scalar}`` dict to target domains explicitly,
    including safe ones.

    Returns (faulted groups dict, total uncorrectable count); with
    ``with_corrected`` also the total corrected-codeword count (ECC
    telemetry for the training hot path -- same launches either way).
    """
    if isinstance(voltage, dict):
        # Validate against every provided placement (callers sharing one
        # schedule dict across calls can pass their full placements map
        # with a subset of groups).
        known = {p.domain.name for p in placements.values()}
        unknown = set(voltage) - known
        if unknown:
            raise ValueError(
                f"voltage override names unknown domains {sorted(unknown)}; "
                f"placements cover {sorted(known)}")
    out: Dict[str, object] = {}
    total_bad = jnp.zeros((), jnp.int32)
    total_corr = jnp.zeros((), jnp.int32)
    for name, tree in groups.items():
        placement = placements[name]
        if isinstance(voltage, dict):
            v = voltage.get(placement.domain.name)
        elif (voltage is not None
              and placement.domain.voltage < V_MIN - 1e-9):
            v = voltage
        else:
            v = None
        faulted, bad, corr = inject_placement(
            tree, placement, faultmap, voltage=v,
            method=method, interpret=interpret, use_ref=use_ref,
            with_corrected=True)
        out[name] = faulted
        total_bad = total_bad + bad
        total_corr = total_corr + corr
    if with_corrected:
        return out, total_bad, total_corr
    return out, total_bad
