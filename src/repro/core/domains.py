"""Memory domains and physical placement.

The paper's central systems observation is that HBM pseudo-channels are
*independently controllable*, so an application can trade capacity for
power by keeping only reliable-enough PCs at a reduced voltage (Fig. 6).
This module operationalizes that:

  * A :class:`MemoryDomain` is a named (voltage, PC subset, ECC flag)
    region -- e.g. ``SAFE`` at 0.98 V holding optimizer state, ``CHEAP``
    at 0.91 V holding fault-tolerant KV cache.
  * A :class:`DomainAllocator` allocates tensor groups into the domain's
    PCs at aligned-block granularity, producing physical segments; the
    fault-injection kernel consumes physical word addresses so stuck
    bits are stable properties of locations, not tensors.  Given a fault
    map it hands out pseudo-channels most-reliable-first and can skip
    blocks containing *weak rows* (the paper's C9 spatial clustering) --
    spare-row avoidance at allocation time.
  * A :class:`CriticalityTier` is a tensor group's declared fault
    tolerance (e.g. optimizer state = ``safe``, KV cache = ``cheap``);
    :func:`place_groups_tiered` routes each group into the most
    power-saving domain whose predicted stuck-cell rate -- over the
    exact PC/row extent the group would occupy -- meets the tier.

Placement works on avals (ShapeDtypeStruct) as well as concrete arrays,
so capacity planning for full-scale models never allocates memory.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import weakref
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.faultmap import FaultMap
from repro.core.faultmodel import V_CRITICAL, V_NOM
from repro.core.hbm import HBMGeometry

# Allocation alignment: the injection kernel processes 4096-word blocks,
# so placements are aligned to 16 KiB to keep padded tails from aliasing.
# This is also the arena-engine block size: because every leaf starts on
# an aligned slot and PC extents are aligned multiples, each 4096-word
# block of a packed leaf lands in exactly one segment (one PC, one
# contiguous physical run) -- the invariant that lets a placement export
# a flat block-indexed table.
ALIGN_WORDS = 4096


class DeviceCrashError(RuntimeError):
    """Raised when a domain is driven below V_critical: the paper observes
    the part stops responding and needs a power cycle (section III-B)."""


class CapacityError(MemoryError):
    """Typed allocation-overflow error: names the domain, the requested
    bytes and the remaining extent (subclasses :class:`MemoryError` for
    backwards compatibility with callers catching the old bare error)."""

    def __init__(self, domain: str, requested_bytes: int, free_bytes: int,
                 note: str = "", shard=None):
        self.domain = domain
        self.requested_bytes = int(requested_bytes)
        self.free_bytes = int(free_bytes)
        self.shard = shard
        msg = (f"domain {domain!r} out of capacity: requested "
               f"{self.requested_bytes} B, remaining extent "
               f"{self.free_bytes} B")
        if shard is not None:
            msg += f" on shard {shard}"
        if note:
            msg += f" ({note})"
        super().__init__(msg)


@dataclasses.dataclass(frozen=True)
class CriticalityTier:
    """A tensor group's declared fault tolerance.

    ``max_rate`` is the tolerable total stuck-cell rate of the extent the
    group occupies; ``max_rate <= 0`` means "provably fault-free in
    expectation" (< 1 expected faulty bit per PC, the same rule as the
    trade-off solver).  ``avoid_weak_rows`` additionally skips allocation
    blocks containing weak rows, so the extent sees only the strong-row
    rate -- spare-row avoidance of the worst rows.
    """

    name: str
    max_rate: float
    avoid_weak_rows: bool = False

    def admits(self, rate: float, bits_per_pc: int) -> bool:
        if self.max_rate <= 0.0:
            return rate * bits_per_pc < 1.0
        return rate <= self.max_rate


# The default tier ladder, strictest first.  ``shared_prefix`` is the
# serving pool's tier for copy-on-write shared prompt pages: one
# corrupted shared page poisons every tenant mapping it, so shared
# data gets the strictest placement there is (weak-row-free extents,
# handed out most-reliable-first).  ``critical`` additionally dodges
# weak rows so it stays clean deeper than ``safe``; ``hedged``
# tolerates ppm-level faults on weak-row-free extents; ``cheap`` is for
# fault-tolerant bulk data (KV cache, activations); ``disposable``
# matches the paper's "0% to 50% fault rate" deep-undervolt example.
TIERS: Dict[str, CriticalityTier] = {
    t.name: t for t in (
        CriticalityTier("shared_prefix", 0.0, avoid_weak_rows=True),
        CriticalityTier("critical", 0.0, avoid_weak_rows=True),
        CriticalityTier("safe", 0.0),
        CriticalityTier("hedged", 1e-6, avoid_weak_rows=True),
        CriticalityTier("cheap", 1e-3),
        CriticalityTier("disposable", 0.5),
    )
}


def resolve_tier(tier) -> CriticalityTier:
    if isinstance(tier, CriticalityTier):
        return tier
    if isinstance(tier, str):
        try:
            return TIERS[tier]
        except KeyError:
            raise ValueError(
                f"unknown criticality tier {tier!r}; known: "
                f"{sorted(TIERS)}") from None
    raise TypeError(f"tier must be a name or CriticalityTier, got {tier!r}")


@dataclasses.dataclass(frozen=True)
class MemoryDomain:
    """A voltage/PC-subset region of one device's HBM."""

    name: str
    voltage: float
    pc_ids: Tuple[int, ...]
    ecc: bool = False

    def validate(self, geometry: HBMGeometry) -> None:
        if not self.pc_ids:
            raise ValueError(f"domain {self.name!r} has no PCs")
        if len(set(self.pc_ids)) != len(self.pc_ids):
            raise ValueError(f"domain {self.name!r} repeats PCs")
        for pc in self.pc_ids:
            if not 0 <= pc < geometry.num_pcs:
                raise ValueError(f"domain {self.name!r}: pc {pc} out of range")
        if self.voltage > V_NOM + 1e-9:
            raise ValueError(f"domain {self.name!r}: overvolting not modeled")
        if self.voltage < V_CRITICAL - 1e-9:
            raise DeviceCrashError(
                f"domain {self.name!r} at {self.voltage:.2f} V is below "
                f"V_critical={V_CRITICAL} V: HBM stops responding and "
                "requires a power cycle")

    def capacity_bytes(self, geometry: HBMGeometry) -> int:
        return len(self.pc_ids) * geometry.bytes_per_pc


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous physical run backing part of one leaf."""

    leaf_start_word: int   # offset within the flattened leaf (u32 words)
    n_words: int
    pc: int
    phys_base_word: int    # global physical word address


@dataclasses.dataclass(frozen=True)
class LeafPlacement:
    path: str
    n_words: int
    segments: Tuple[Segment, ...]


@dataclasses.dataclass(frozen=True)
class BlockTable:
    """Flat block-indexed export of a :class:`GroupPlacement`.

    The arena engine packs all leaves of a group (each padded to a
    multiple of ALIGN_WORDS) into one buffer; entry ``i`` describes
    arena block ``i``:

      * ``block_pc[i]``: pseudo-channel owning the block (indexes the
        fault map's threshold table),
      * ``block_base[i]``: physical base word of the block's first word,
      * ``leaf_blocks``: per leaf (in placement order) the
        ``(start_block, n_blocks, n_words)`` triple used to pack and
        unpack the arena.
    """

    block_pc: Tuple[int, ...]
    block_base: Tuple[int, ...]
    leaf_blocks: Tuple[Tuple[int, int, int], ...]

    @property
    def num_blocks(self) -> int:
        return len(self.block_pc)


@functools.lru_cache(maxsize=256)
def _block_table(placement: "GroupPlacement") -> BlockTable:
    block_pc: List[int] = []
    block_base: List[int] = []
    leaf_blocks: List[Tuple[int, int, int]] = []
    for leaf in placement.leaves:
        start_block = len(block_pc)
        for si, seg in enumerate(leaf.segments):
            assert seg.leaf_start_word % ALIGN_WORDS == 0, (
                "segment not block-aligned within its leaf")
            assert seg.phys_base_word % ALIGN_WORDS == 0, (
                "segment not block-aligned physically")
            last = si == len(leaf.segments) - 1
            assert last or seg.n_words % ALIGN_WORDS == 0, (
                "non-final segment with a partial block")
            n_blocks = -(-seg.n_words // ALIGN_WORDS)
            for b in range(n_blocks):
                block_pc.append(seg.pc)
                block_base.append(seg.phys_base_word + b * ALIGN_WORDS)
        leaf_blocks.append((start_block, len(block_pc) - start_block,
                            leaf.n_words))
    return BlockTable(block_pc=tuple(block_pc), block_base=tuple(block_base),
                      leaf_blocks=tuple(leaf_blocks))


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    group: str
    domain: MemoryDomain
    leaves: Tuple[LeafPlacement, ...]

    @property
    def total_words(self) -> int:
        return sum(l.n_words for l in self.leaves)

    def block_table(self) -> BlockTable:
        """Block-indexed segment table for the arena engine (cached --
        placements are frozen)."""
        return _block_table(self)


def _leaf_words(leaf) -> int:
    size = 1
    for d in leaf.shape:
        size *= d
    nbytes = size * jax.numpy.dtype(leaf.dtype).itemsize
    return (nbytes + 3) // 4


class DomainAllocator:
    """Block-granular bump allocator over a domain's pseudo-channels.

    Without a fault map this behaves exactly like the original bump
    allocator: PCs in the domain's declared order, every block eligible.
    With a fault map, PCs are handed out most-reliable-first (at the
    domain's configured voltage), and allocations may request *weak-row
    avoidance*: blocks containing weak rows are skipped and kept as
    spares for later tolerance-insensitive allocations, so avoidance
    costs no capacity overall.

    :meth:`free` returns blocks for recycling: freed blocks are kept in
    reliability order and re-issued before the bump cursor advances, so
    a free-then-realloc of the same footprint lands on the same
    reliability-ordered blocks -- the invariant a long-lived serving
    allocator (requests arriving and retiring forever) depends on.

    After a :class:`CapacityError` the allocator state is undefined; the
    placement that triggered it must be rebuilt from scratch.
    """

    def __init__(self, geometry: HBMGeometry, domain: MemoryDomain,
                 faultmap: Optional[FaultMap] = None,
                 order_by_reliability: Optional[bool] = None):
        domain.validate(geometry)
        self.geometry = geometry
        self.domain = domain
        self.faultmap = faultmap
        self.words_per_pc = geometry.bytes_per_pc // 4
        assert self.words_per_pc % ALIGN_WORDS == 0, "PC must be block-aligned"
        self.blocks_per_pc = self.words_per_pc // ALIGN_WORDS
        self.capacity_words = len(domain.pc_ids) * self.words_per_pc
        if order_by_reliability is None:
            order_by_reliability = faultmap is not None
        if order_by_reliability:
            if faultmap is None:
                raise ValueError("reliability ordering needs a fault map")
            rank = {int(pc): i for i, pc in
                    enumerate(faultmap.reliability_order(domain.voltage))}
            self.pc_order: Tuple[int, ...] = tuple(sorted(
                domain.pc_ids, key=lambda pc: rank[int(pc)]))
        else:
            self.pc_order = tuple(domain.pc_ids)
        self._rank = {pc: i for i, pc in enumerate(self.pc_order)}
        self._total_blocks = len(self.pc_order) * self.blocks_per_pc
        self._cursor = 0                 # blocks handed past, in pc_order
        self._spares: List[Tuple[int, int]] = []   # skipped weak blocks
        self._freed: List[Tuple[int, int, int]] = []  # (rank, blk, pc)
        self._owned: set = set()         # (pc, blk) currently allocated
        self._free_blocks = self._total_blocks
        self._weak_cache: Dict[int, object] = {}
        self._quarantined: set = set()   # (pc, blk) retired for good
        self._pools: List[object] = []   # live-page guards (weakrefs)

    @property
    def free_words(self) -> int:
        return self._free_blocks * ALIGN_WORDS

    def _block_at(self, i: int) -> Tuple[int, int]:
        return self.pc_order[i // self.blocks_per_pc], i % self.blocks_per_pc

    def _is_weak(self, pc: int, block: int) -> bool:
        if self.faultmap is None:
            return False
        mask = self._weak_cache.get(pc)
        if mask is None:
            mask = self.faultmap.weak_block_mask(pc, ALIGN_WORDS)
            self._weak_cache[pc] = mask
        return bool(mask[block])

    def _take(self, n_blocks: int, avoid_weak_rows: bool):
        """The next ``n_blocks`` (pc, block) pairs under the avoidance
        policy, plus the post-take cursor/spares/freed state -- or None
        if the domain cannot supply them.  Freed blocks (already in
        reliability order) are recycled before the cursor advances."""
        cursor, spares = self._cursor, list(self._spares)
        freed = list(self._freed)
        taken: List[Tuple[int, int]] = []
        if avoid_weak_rows:
            i = 0
            while i < len(freed) and len(taken) < n_blocks:
                _, blk, pc = freed[i]
                if self._is_weak(pc, blk):
                    i += 1
                    continue
                taken.append((pc, blk))
                freed.pop(i)
        else:
            while freed and len(taken) < n_blocks:
                _, blk, pc = freed.pop(0)
                taken.append((pc, blk))
            while spares and len(taken) < n_blocks:
                taken.append(spares.pop(0))
        while len(taken) < n_blocks and cursor < self._total_blocks:
            pc, blk = self._block_at(cursor)
            cursor += 1
            if (pc, blk) in self._quarantined:
                continue                 # retired: never re-issued
            if avoid_weak_rows and self._is_weak(pc, blk):
                spares.append((pc, blk))
                continue
            taken.append((pc, blk))
        if len(taken) < n_blocks:
            return None
        return taken, cursor, spares, freed

    def peek_pcs(self, n_words: int,
                 avoid_weak_rows: bool = False) -> Optional[Tuple[int, ...]]:
        """PCs the next ``n_words`` allocation would occupy (no commit),
        or None if it cannot be satisfied."""
        got = self._take(-(-n_words // ALIGN_WORDS), avoid_weak_rows)
        if got is None:
            return None
        return tuple(sorted({pc for pc, _ in got[0]}))

    def alloc(self, n_words: int,
              avoid_weak_rows: bool = False) -> Tuple[Segment, ...]:
        n_blocks = -(-n_words // ALIGN_WORDS)
        got = self._take(n_blocks, avoid_weak_rows)
        if got is None:
            note = (f"{len(self.domain.pc_ids)} PCs x "
                    f"{self.geometry.bytes_per_pc} B")
            if avoid_weak_rows:
                note += "; weak-row-avoiding allocation"
            raise CapacityError(self.domain.name, n_blocks * ALIGN_WORDS * 4,
                                self.free_words * 4, note)
        taken, self._cursor, self._spares, self._freed = got
        self._owned.update(taken)
        self._free_blocks -= n_blocks
        segments: List[Segment] = []
        for i, (pc, blk) in enumerate(taken):
            base = pc * self.words_per_pc + blk * ALIGN_WORDS
            words = min(ALIGN_WORDS, n_words - i * ALIGN_WORDS)
            prev = segments[-1] if segments else None
            if (prev is not None and prev.pc == pc
                    and prev.phys_base_word + prev.n_words == base):
                segments[-1] = dataclasses.replace(
                    prev, n_words=prev.n_words + words)
            else:
                segments.append(Segment(
                    leaf_start_word=i * ALIGN_WORDS, n_words=words, pc=pc,
                    phys_base_word=base))
        return tuple(segments)

    def _segment_blocks(self, segments) -> List[Tuple[int, int]]:
        """Validated (pc, block) pairs backing ``segments``."""
        blocks: List[Tuple[int, int]] = []
        for seg in segments:
            if seg.pc not in self._rank:
                raise ValueError(
                    f"segment pc {seg.pc} not in domain "
                    f"{self.domain.name!r} (PCs {sorted(self._rank)})")
            rel = seg.phys_base_word - seg.pc * self.words_per_pc
            if rel % ALIGN_WORDS or not (
                    0 <= rel < self.words_per_pc):
                raise ValueError(
                    f"segment base {seg.phys_base_word} is not a block "
                    f"of pc {seg.pc} in domain {self.domain.name!r}")
            blk0 = rel // ALIGN_WORDS
            for b in range(blk0, blk0 + -(-seg.n_words // ALIGN_WORDS)):
                blocks.append((seg.pc, b))
        return blocks

    def _check_owned(self, blocks: List[Tuple[int, int]], verb: str):
        dup = sorted(set(b for b in blocks if b not in self._owned))
        if len(set(blocks)) != len(blocks):
            dup = sorted(set(b for b in blocks if blocks.count(b) > 1))
        if dup:
            raise ValueError(
                f"double {verb} in domain {self.domain.name!r}: "
                f"(pc, block) {dup[:4]} not currently allocated "
                "(freed twice, or never handed out by this allocator)")

    def register_pool(self, pool) -> None:
        """Attach a :class:`~repro.serving.paged.PagePool` whose live
        pages guard :meth:`free`: freeing a block that still backs a
        live page in any registered pool is rejected (it would silently
        alias two tenants onto one physical block)."""
        self._pools.append(weakref.ref(pool))

    def _live_guard(self, blocks: List[Tuple[int, int]], verb: str):
        for ref in self._pools:
            pool = ref()
            if pool is None:
                continue
            live = pool.live_blocks() & set(blocks)
            if live:
                raise ValueError(
                    f"cannot {verb} (pc, block) {sorted(live)[:4]} in "
                    f"domain {self.domain.name!r}: still backing live "
                    "pages of a registered PagePool (retire or migrate "
                    "the pages first, or two tenants would alias one "
                    "physical block)")

    def free(self, segments: Tuple[Segment, ...]) -> None:
        """Return the blocks backing ``segments`` to the allocator.

        Blocks must have been handed out by :meth:`alloc` and not freed
        since; anything else (double-free, a foreign segment, a block
        outside this domain, a block still backing live pages of a
        registered pool) raises a ``ValueError`` before any state
        changes.  Freed blocks go back into the reliability-ordered
        recycling list, so reallocating the same footprint reproduces
        the same physical blocks in the same order.
        """
        blocks = self._segment_blocks(segments)
        self._check_owned(blocks, "free")
        self._live_guard(blocks, "free")
        for pc, blk in blocks:
            self._owned.discard((pc, blk))
            bisect.insort(self._freed, (self._rank[pc], blk, pc))
            self._free_blocks += 1

    def quarantine(self, segments: Tuple[Segment, ...]) -> None:
        """Permanently retire the blocks backing ``segments``.

        The self-healing path: a block whose row turned weak is pulled
        out of circulation -- removed from the owned set but *not*
        returned to the recycling list, so reliability-ordered recycling
        can never re-issue it.  Blocks must be currently allocated and
        page-free (same guards as :meth:`free`).  Irreversible by
        design; capacity shrinks accordingly.
        """
        blocks = self._segment_blocks(segments)
        self._check_owned(blocks, "quarantine")
        self._live_guard(blocks, "quarantine")
        for pc, blk in blocks:
            self._owned.discard((pc, blk))
            self._quarantined.add((pc, blk))

    @property
    def quarantined_blocks(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted(self._quarantined))

    def adopt(self, placement: "GroupPlacement") -> None:
        """Take ownership of an existing placement's blocks.

        ``place_groups`` / ``place_groups_tiered`` build their
        allocators internally and discard them; a long-lived owner (the
        serving scheduler retiring and recycling page blocks online)
        reconstructs ownership here: the placement's blocks become
        owned, everything else in the domain is recycling-eligible in
        reliability order, and the bump cursor is exhausted so
        :meth:`free` / :meth:`quarantine` / re-:meth:`alloc` behave as
        if this allocator had handed the placement out itself.  Only
        valid on a fresh allocator.
        """
        if self._owned or self._cursor or self._freed or self._spares:
            raise ValueError("adopt() requires a fresh allocator")
        blocks: List[Tuple[int, int]] = []
        for leaf in placement.leaves:
            blocks.extend(self._segment_blocks(leaf.segments))
        owned = set(blocks)
        if len(owned) != len(blocks):
            raise ValueError("placement maps one block twice")
        self._owned = owned
        for i in range(self._total_blocks):
            pc, blk = self._block_at(i)
            if (pc, blk) not in owned:
                bisect.insort(self._freed, (self._rank[pc], blk, pc))
        self._cursor = self._total_blocks
        self._free_blocks = self._total_blocks - len(owned)


def _sorted_leaves(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return sorted(paths, key=lambda kv: jax.tree_util.keystr(kv[0]))


def place_groups(
    groups: Dict[str, object],           # group name -> pytree (arrays/avals)
    policy: Dict[str, str],              # group name -> domain name
    domains: Dict[str, MemoryDomain],
    geometry: HBMGeometry,
) -> Dict[str, GroupPlacement]:
    """Assign every leaf of every group a physical placement."""
    allocators = {name: DomainAllocator(geometry, d)
                  for name, d in domains.items()}
    out: Dict[str, GroupPlacement] = {}
    for group_name in sorted(groups):
        domain_name = policy[group_name]
        alloc = allocators[domain_name]
        leaves = []
        for path, leaf in _sorted_leaves(groups[group_name]):
            n_words = _leaf_words(leaf)
            leaves.append(LeafPlacement(
                path=jax.tree_util.keystr(path), n_words=n_words,
                segments=alloc.alloc(n_words)))
        out[group_name] = GroupPlacement(
            group=group_name, domain=domains[domain_name],
            leaves=tuple(leaves))
    return out


def place_groups_tiered(
    groups: Dict[str, object],           # group name -> pytree (arrays/avals)
    tiers: Dict[str, object],            # group name -> tier name or object
    domains: Dict[str, MemoryDomain],
    geometry: HBMGeometry,
    faultmap: FaultMap,
) -> Dict[str, GroupPlacement]:
    """Criticality-aware placement: route each group to the most
    power-saving domain whose predicted rate meets the group's tier.

    Domains are tried deepest-voltage-first (maximum savings); a domain
    is admissible for a group iff (a) it has capacity for the group's
    aligned footprint under the tier's weak-row policy and (b) the
    predicted stuck-cell rate of the *exact PC extent* the group would
    occupy -- strong-row rate when the tier avoids weak rows -- meets
    ``tier.max_rate``.  Groups are placed strictest-tier-first so the
    most reliable PCs (allocators hand PCs out most-reliable-first) go
    to the least fault-tolerant data.

    Raises :class:`CapacityError` when no domain admits a group.
    """
    resolved = {g: resolve_tier(tiers[g]) for g in groups}
    allocators = {name: DomainAllocator(geometry, d, faultmap=faultmap)
                  for name, d in domains.items()}
    # deepest voltage first = most power-saving first; name tie-break
    dom_order = sorted(domains.values(), key=lambda d: (d.voltage, d.name))
    out: Dict[str, GroupPlacement] = {}
    for group_name in sorted(groups,
                             key=lambda g: (resolved[g].max_rate, g)):
        tier = resolved[group_name]
        leaf_list = _sorted_leaves(groups[group_name])
        footprint = sum(-(-_leaf_words(leaf) // ALIGN_WORDS) * ALIGN_WORDS
                        for _, leaf in leaf_list)
        placed = None
        for d in dom_order:
            alloc = allocators[d.name]
            pcs = alloc.peek_pcs(footprint, tier.avoid_weak_rows)
            if pcs is None:
                continue                     # no capacity in this domain
            # one rate sweep per (domain, tier) probe, not one per PC
            rates = faultmap.predicted_rates(d.voltage,
                                             tier.avoid_weak_rows)
            worst = float(max(rates[pc] for pc in pcs))
            if not tier.admits(worst, geometry.bits_per_pc):
                continue                     # too unreliable for the tier
            leaves = []
            for path, leaf in leaf_list:
                n_words = _leaf_words(leaf)
                leaves.append(LeafPlacement(
                    path=jax.tree_util.keystr(path), n_words=n_words,
                    segments=alloc.alloc(
                        n_words, avoid_weak_rows=tier.avoid_weak_rows)))
            placed = GroupPlacement(group=group_name, domain=d,
                                    leaves=tuple(leaves))
            break
        if placed is None:
            free = max((allocators[d.name].free_words * 4
                        for d in dom_order), default=0)
            raise CapacityError(
                "|".join(d.name for d in dom_order), footprint * 4, free,
                f"no domain admits group {group_name!r} at tier "
                f"{tier.name!r} (max_rate={tier.max_rate:g}, "
                f"avoid_weak_rows={tier.avoid_weak_rows})")
        out[group_name] = placed
    return out
