"""Memory domains and physical placement.

The paper's central systems observation is that HBM pseudo-channels are
*independently controllable*, so an application can trade capacity for
power by keeping only reliable-enough PCs at a reduced voltage (Fig. 6).
This module operationalizes that:

  * A :class:`MemoryDomain` is a named (voltage, PC subset, ECC flag)
    region -- e.g. ``SAFE`` at 0.98 V holding optimizer state, ``CHEAP``
    at 0.91 V holding fault-tolerant KV cache.
  * A :class:`DomainAllocator` bump-allocates tensor groups into the
    domain's PCs at DRAM-row granularity, producing physical segments;
    the fault-injection kernel consumes physical word addresses so stuck
    bits are stable properties of locations, not tensors.

Placement works on avals (ShapeDtypeStruct) as well as concrete arrays,
so capacity planning for full-scale models never allocates memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax

from repro.core.faultmodel import V_CRITICAL, V_NOM
from repro.core.hbm import HBMGeometry

# Allocation alignment: the injection kernel processes 4096-word blocks,
# so placements are aligned to 16 KiB to keep padded tails from aliasing.
# This is also the arena-engine block size: because every leaf starts on
# an aligned slot and PC extents are aligned multiples, each 4096-word
# block of a packed leaf lands in exactly one segment (one PC, one
# contiguous physical run) -- the invariant that lets a placement export
# a flat block-indexed table.
ALIGN_WORDS = 4096


class DeviceCrashError(RuntimeError):
    """Raised when a domain is driven below V_critical: the paper observes
    the part stops responding and needs a power cycle (section III-B)."""


@dataclasses.dataclass(frozen=True)
class MemoryDomain:
    """A voltage/PC-subset region of one device's HBM."""

    name: str
    voltage: float
    pc_ids: Tuple[int, ...]
    ecc: bool = False

    def validate(self, geometry: HBMGeometry) -> None:
        if not self.pc_ids:
            raise ValueError(f"domain {self.name!r} has no PCs")
        if len(set(self.pc_ids)) != len(self.pc_ids):
            raise ValueError(f"domain {self.name!r} repeats PCs")
        for pc in self.pc_ids:
            if not 0 <= pc < geometry.num_pcs:
                raise ValueError(f"domain {self.name!r}: pc {pc} out of range")
        if self.voltage > V_NOM + 1e-9:
            raise ValueError(f"domain {self.name!r}: overvolting not modeled")
        if self.voltage < V_CRITICAL - 1e-9:
            raise DeviceCrashError(
                f"domain {self.name!r} at {self.voltage:.2f} V is below "
                f"V_critical={V_CRITICAL} V: HBM stops responding and "
                "requires a power cycle")

    def capacity_bytes(self, geometry: HBMGeometry) -> int:
        return len(self.pc_ids) * geometry.bytes_per_pc


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous physical run backing part of one leaf."""

    leaf_start_word: int   # offset within the flattened leaf (u32 words)
    n_words: int
    pc: int
    phys_base_word: int    # global physical word address


@dataclasses.dataclass(frozen=True)
class LeafPlacement:
    path: str
    n_words: int
    segments: Tuple[Segment, ...]


@dataclasses.dataclass(frozen=True)
class BlockTable:
    """Flat block-indexed export of a :class:`GroupPlacement`.

    The arena engine packs all leaves of a group (each padded to a
    multiple of ALIGN_WORDS) into one buffer; entry ``i`` describes
    arena block ``i``:

      * ``block_pc[i]``: pseudo-channel owning the block (indexes the
        fault map's threshold table),
      * ``block_base[i]``: physical base word of the block's first word,
      * ``leaf_blocks``: per leaf (in placement order) the
        ``(start_block, n_blocks, n_words)`` triple used to pack and
        unpack the arena.
    """

    block_pc: Tuple[int, ...]
    block_base: Tuple[int, ...]
    leaf_blocks: Tuple[Tuple[int, int, int], ...]

    @property
    def num_blocks(self) -> int:
        return len(self.block_pc)


@functools.lru_cache(maxsize=256)
def _block_table(placement: "GroupPlacement") -> BlockTable:
    block_pc: List[int] = []
    block_base: List[int] = []
    leaf_blocks: List[Tuple[int, int, int]] = []
    for leaf in placement.leaves:
        start_block = len(block_pc)
        for si, seg in enumerate(leaf.segments):
            assert seg.leaf_start_word % ALIGN_WORDS == 0, (
                "segment not block-aligned within its leaf")
            assert seg.phys_base_word % ALIGN_WORDS == 0, (
                "segment not block-aligned physically")
            last = si == len(leaf.segments) - 1
            assert last or seg.n_words % ALIGN_WORDS == 0, (
                "non-final segment with a partial block")
            n_blocks = -(-seg.n_words // ALIGN_WORDS)
            for b in range(n_blocks):
                block_pc.append(seg.pc)
                block_base.append(seg.phys_base_word + b * ALIGN_WORDS)
        leaf_blocks.append((start_block, len(block_pc) - start_block,
                            leaf.n_words))
    return BlockTable(block_pc=tuple(block_pc), block_base=tuple(block_base),
                      leaf_blocks=tuple(leaf_blocks))


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    group: str
    domain: MemoryDomain
    leaves: Tuple[LeafPlacement, ...]

    @property
    def total_words(self) -> int:
        return sum(l.n_words for l in self.leaves)

    def block_table(self) -> BlockTable:
        """Block-indexed segment table for the arena engine (cached --
        placements are frozen)."""
        return _block_table(self)


def _leaf_words(leaf) -> int:
    size = 1
    for d in leaf.shape:
        size *= d
    nbytes = size * jax.numpy.dtype(leaf.dtype).itemsize
    return (nbytes + 3) // 4


class DomainAllocator:
    """Bump allocator over the concatenated extents of a domain's PCs."""

    def __init__(self, geometry: HBMGeometry, domain: MemoryDomain):
        domain.validate(geometry)
        self.geometry = geometry
        self.domain = domain
        self.words_per_pc = geometry.bytes_per_pc // 4
        self.capacity_words = len(domain.pc_ids) * self.words_per_pc
        self.cursor = 0

    @property
    def free_words(self) -> int:
        return self.capacity_words - self.cursor

    def alloc(self, n_words: int) -> Tuple[Segment, ...]:
        aligned = -(-n_words // ALIGN_WORDS) * ALIGN_WORDS
        if aligned > self.free_words:
            raise MemoryError(
                f"domain {self.domain.name!r} out of capacity: need "
                f"{aligned * 4} B, free {self.free_words * 4} B "
                f"({len(self.domain.pc_ids)} PCs x "
                f"{self.geometry.bytes_per_pc} B)")
        segments: List[Segment] = []
        leaf_off, remaining = 0, n_words
        while remaining > 0:
            pc_slot = self.cursor // self.words_per_pc
            in_pc = self.cursor % self.words_per_pc
            pc = self.domain.pc_ids[pc_slot]
            take = min(remaining, self.words_per_pc - in_pc)
            segments.append(Segment(
                leaf_start_word=leaf_off, n_words=take, pc=pc,
                phys_base_word=pc * self.words_per_pc + in_pc))
            self.cursor += take
            leaf_off += take
            remaining -= take
        # advance to the next aligned slot
        self.cursor = min(self.capacity_words,
                          -(-self.cursor // ALIGN_WORDS) * ALIGN_WORDS)
        return tuple(segments)


def place_groups(
    groups: Dict[str, object],           # group name -> pytree (arrays/avals)
    policy: Dict[str, str],              # group name -> domain name
    domains: Dict[str, MemoryDomain],
    geometry: HBMGeometry,
) -> Dict[str, GroupPlacement]:
    """Assign every leaf of every group a physical placement."""
    allocators = {name: DomainAllocator(geometry, d)
                  for name, d in domains.items()}
    out: Dict[str, GroupPlacement] = {}
    for group_name in sorted(groups):
        domain_name = policy[group_name]
        alloc = allocators[domain_name]
        leaves, paths = [], jax.tree_util.tree_flatten_with_path(
            groups[group_name])[0]
        for path, leaf in sorted(paths, key=lambda kv: jax.tree_util.keystr(kv[0])):
            n_words = _leaf_words(leaf)
            leaves.append(LeafPlacement(
                path=jax.tree_util.keystr(path), n_words=n_words,
                segments=alloc.alloc(n_words)))
        out[group_name] = GroupPlacement(
            group=group_name, domain=domains[domain_name],
            leaves=tuple(leaves))
    return out
