"""Gradient compression: per-tensor int8 quantization with error
feedback (EF-SGD style).

At 1000-node scale the DP gradient all-reduce is the dominant inter-pod
collective; int8 cuts its bytes 4x vs f32 (2x vs bf16).  Under GSPMD the
reduction is implicit, so the compression is applied as a
quantize-dequantize transform with a persistent error-feedback buffer --
numerically exactly what the compressed collective computes when the
reduction is performed on dequantized values.  The roofline model in
benchmarks/roofline.py exposes the corresponding collective-byte what-if
(§Perf); the EF buffer guarantees the quantization error stays bounded
instead of accumulating (unit-tested convergence property).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize_grads(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Quantize each gradient leaf with error feedback.

    Returns (dequantized grads used by the optimizer, new EF buffers).
    """
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_int8(g)
        dq = dequantize_int8(q, s)
        return dq, g - dq

    out = jax.tree_util.tree_map(one, grads, ef)
    dq = jax.tree_util.tree_map(lambda t: t[0], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return dq, new_ef


def init_ef(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
