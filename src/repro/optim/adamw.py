"""AdamW with decoupled weight decay, cosine schedule, global grad-norm
clipping.  Optimizer moments are stored f32 regardless of param dtype;
their logical sharding axes mirror the parameters (ZeRO-1 style: the
mesh rules additionally shard the moments over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamSpec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def moment_specs(param_specs) -> Dict[str, Any]:
    """ParamSpecs for optimizer moments (f32, same logical axes, with the
    'zero1' marker prepended so mesh rules can shard them over data)."""
    def f32(s: ParamSpec) -> ParamSpec:
        return ParamSpec(shape=s.shape, axes=s.axes, dtype=jnp.float32,
                         init="zeros")
    m = jax.tree_util.tree_map(
        f32, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"mu": m, "nu": m,
            "step": ParamSpec((), (), jnp.int32, "zeros")}


def init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros, "nu": zeros, "step": jnp.zeros((), jnp.int32)}


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def update(grads, opt_state, params,
           cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        m_hat = mu / c1
        v_hat = nu / c2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["mu"],
                                 opt_state["nu"])
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
