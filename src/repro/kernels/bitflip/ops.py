"""Public jit'd wrapper around the bitflip Pallas kernel.

Handles dtype <-> uint32 views, padding to kernel-block multiples, method
dispatch (fast word-hit path vs. exact bitwise path), and interpret-mode
fallback on CPU.  The allocator aligns physical placements to BLOCK_WORDS
so the padded tail of one tensor never aliases the next tensor's physical
words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.faultmap import KernelThresholds
from repro.kernels.bitflip import ref as _ref
from repro.kernels.bitflip.bitflip import BLOCK_LANES, BLOCK_WORDS, bitflip_pallas

# Above this per-bit rate the one-stuck-bit-per-word approximation is off
# by more than ~1.6% and we switch to the exact bitwise path.
WORD_PATH_MAX_RATE = 1e-3


def default_interpret() -> bool:
    """Pallas interpret-mode default: interpret everywhere but TPU."""
    return jax.default_backend() != "tpu"


_default_interpret = default_interpret  # backwards-compatible alias


def pick_method(thresholds: KernelThresholds) -> str:
    worst = max(thresholds.p01_weak, thresholds.p10_weak,
                thresholds.p01_strong, thresholds.p10_strong)
    return "word" if worst <= WORD_PATH_MAX_RATE else "bitwise"


def to_u32(x: jax.Array):
    """Flatten any-dtype array to a uint32 view + recovery metadata."""
    flat = x.reshape(-1)
    itemsize = x.dtype.itemsize
    if itemsize == 4:
        u32 = jax.lax.bitcast_convert_type(flat, jnp.uint32)
        return u32, (x.shape, x.dtype, flat.shape[0], 1)
    if itemsize == 2:
        n = flat.shape[0]
        pad = (-n) % 2
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
        u16 = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        u32 = jax.lax.bitcast_convert_type(u16.reshape(-1, 2), jnp.uint32)
        return u32, (x.shape, x.dtype, n, 2)
    if itemsize == 1:
        n = flat.shape[0]
        pad = (-n) % 4
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
        u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8)
        u32 = jax.lax.bitcast_convert_type(u8.reshape(-1, 4), jnp.uint32)
        return u32, (x.shape, x.dtype, n, 4)
    raise NotImplementedError(f"itemsize {itemsize} for dtype {x.dtype}")


def from_u32(u32: jax.Array, meta):
    """Inverse of :func:`to_u32`: uint32 view -> original shape/dtype."""
    shape, dtype, n, packing = meta
    if packing == 1:
        return jax.lax.bitcast_convert_type(u32, dtype).reshape(shape)
    lanes = jax.lax.bitcast_convert_type(
        u32, jnp.uint16 if packing == 2 else jnp.uint8)  # (m, packing)
    flat = jax.lax.bitcast_convert_type(lanes.reshape(-1), dtype)
    return flat[:n].reshape(shape)


# Backwards-compatible aliases from when these were module-private.
_to_u32 = to_u32
_from_u32 = from_u32


@functools.partial(jax.jit, static_argnames=(
    "thresholds", "seed", "base_word", "method", "interpret", "use_ref"))
def _inject_u32_jit(data_u32, *, thresholds, seed, base_word, method,
                    interpret, use_ref):
    n = data_u32.shape[0]
    if use_ref:
        return _ref.inject_u32_ref(data_u32, thresholds=thresholds,
                                   seed=seed, base_word=base_word,
                                   method=method)
    pad = (-n) % BLOCK_WORDS
    padded = (jnp.concatenate([data_u32, jnp.zeros((pad,), jnp.uint32)])
              if pad else data_u32)
    out = bitflip_pallas(padded.reshape(-1, BLOCK_LANES),
                         thresholds=thresholds, seed=seed,
                         base_word=base_word, method=method,
                         interpret=interpret)
    return out.reshape(-1)[:n]


def inject_u32(data_u32: jax.Array, *, thresholds: KernelThresholds,
               seed: int, base_word: int = 0, method: str = "auto",
               interpret=None, use_ref: bool = False) -> jax.Array:
    """Apply stuck-at faults to a flat uint32 array (physical words
    [base_word, base_word + n))."""
    if method == "auto":
        method = pick_method(thresholds)
    if interpret is None:
        interpret = _default_interpret()
    return _inject_u32_jit(data_u32, thresholds=thresholds, seed=int(seed),
                           base_word=int(base_word), method=method,
                           interpret=bool(interpret), use_ref=bool(use_ref))


def inject(x: jax.Array, *, thresholds: KernelThresholds, seed: int,
           base_word: int = 0, method: str = "auto", interpret=None,
           use_ref: bool = False) -> jax.Array:
    """Apply stuck-at faults to an arbitrary-dtype tensor in place of its
    physical words.  Returns a tensor of the same shape/dtype."""
    u32, meta = to_u32(x)
    out = inject_u32(u32, thresholds=thresholds, seed=seed,
                     base_word=base_word, method=method,
                     interpret=interpret, use_ref=use_ref)
    return from_u32(out, meta)
