"""Pallas TPU kernel: stuck-at fault injection for undervolted HBM.

This is the framework's perf-critical hot path: every tensor group placed
in an unsafe memory domain is passed through this kernel each step, so it
must stream at HBM bandwidth with one read-modify-write.  The kernel is
tile-parallel over (8, 512)-word VMEM blocks (16 KiB -- MXU/VPU aligned:
8 sublanes x 512 = 4x128 lanes), computes a counter-based hash per word,
and ORs/ANDNs the resulting stuck-at masks into the data.

Two entry points:

  * :func:`bitflip_pallas` -- the legacy single-segment kernel: one
    contiguous physical run, thresholds folded in as static Python ints
    (a recompile per distinct (voltage, PC) pair).
  * :func:`arena_bitflip_pallas` -- the arena engine's kernel: a grid
    over *all* blocks of a memory domain, with each block's physical
    base word and threshold-table row delivered as scalar-prefetch
    operands (SMEM).  One launch injects a whole multi-leaf, multi-PC
    domain, and because thresholds are runtime data, a voltage sweep
    never retraces or recompiles.

The mask math is shared with :mod:`repro.kernels.bitflip.ref` (pure jnp
integer ops), so kernel and oracle are bit-exact by construction; the
tests assert exact equality over shape/dtype/method sweeps in interpret
mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import faultmap as fm
from repro.kernels.bitflip import ref as _ref

BLOCK_SUBLANES = 8
BLOCK_LANES = 512
BLOCK_WORDS = BLOCK_SUBLANES * BLOCK_LANES  # 4096 words = 16 KiB
BLOCK_WORDS_LOG2 = BLOCK_WORDS.bit_length() - 1
assert 1 << BLOCK_WORDS_LOG2 == BLOCK_WORDS


def _kernel(x_ref, o_ref, *, thresholds, seed, base_word, method):
    x = x_ref[...]
    # Physical word index of every element in this block.
    i = pl.program_id(0).astype(jnp.uint32)
    sub = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    wid = (np.uint32(base_word) + i * np.uint32(BLOCK_WORDS)
           + sub * np.uint32(x.shape[1]) + lane)
    if method == "word":
        mask01, mask10 = _ref._word_masks(wid, seed, thresholds)
    else:
        mask01, mask10 = _ref._bitwise_masks(wid, seed, thresholds)
    mask10 = mask10 & ~mask01
    o_ref[...] = (x | mask01) & ~mask10


def bitflip_pallas(data2d: jax.Array, *, thresholds, seed: int,
                   base_word: int, method: str, interpret: bool):
    """Apply stuck-at faults to a (M, 512) uint32 array, M % 8 == 0."""
    m, n = data2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    body = functools.partial(_kernel, thresholds=thresholds, seed=seed,
                             base_word=base_word, method=method)
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        grid=(m // BLOCK_SUBLANES,),
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0)),
        interpret=interpret,
    )(data2d)


def block_word_ids(base, shape):
    """Physical word index of every element of one (sublane, lane) block
    whose first word sits at physical address ``base`` (traced uint32)."""
    sub = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    return base + sub * np.uint32(shape[1]) + lane


def arena_masks(wid, thr_row, *, seed: int, method: str,
                words_per_row_log2: int):
    """Stuck-at masks from one traced threshold-table row.

    ``thr_row`` indexes like a (NUM_THR_COLS,) uint32 vector -- inside
    the kernel it is a row of the scalar-prefetch SMEM operand; in the
    oracle it is a row of the gathered per-block table.  Shared by the
    arena kernels and the arena oracle so both are bit-exact.
    """
    if method == "word":
        return _ref.word_masks(
            wid, seed,
            q01_weak=thr_row[fm.COL_Q01_WEAK],
            q01_strong=thr_row[fm.COL_Q01_STRONG],
            q10_weak=thr_row[fm.COL_Q10_WEAK],
            q10_strong=thr_row[fm.COL_Q10_STRONG],
            weak_row_q=thr_row[fm.COL_WEAK_ROW_Q],
            words_per_row_log2=words_per_row_log2)
    if method == "bitwise":
        return _ref.bitwise_masks(
            wid, seed,
            t01_weak=thr_row[fm.COL_T01_WEAK],
            t01_strong=thr_row[fm.COL_T01_STRONG],
            t10_weak=thr_row[fm.COL_T10_WEAK],
            t10_strong=thr_row[fm.COL_T10_STRONG],
            weak_row_q=thr_row[fm.COL_WEAK_ROW_Q],
            words_per_row_log2=words_per_row_log2)
    raise ValueError(f"unknown method {method!r}")


def apply_masks(x_u32, wid, thr_row, *, seed: int, method: str,
                words_per_row_log2: int):
    """Corrupt one uint32 tile in place of its physical words.

    The read-modify-write at the heart of every injection path, exposed
    as a tile-level function so other Pallas kernels (the fused
    flash-attention read path) can corrupt data already resident in
    VMEM.  ``thr_row`` entries may be scalars (one block) or per-word
    arrays (a tile straddling blocks).
    """
    mask01, mask10 = arena_masks(wid, thr_row, seed=seed, method=method,
                                 words_per_row_log2=words_per_row_log2)
    mask10 = mask10 & ~mask01
    return (x_u32 | mask01) & ~mask10


def select_block_tables(off, base_ref, thr_ref, *, j0, n_cand: int,
                        num_blocks: int,
                        words_log2: int = BLOCK_WORDS_LOG2):
    """Physical word ids + per-word threshold columns for a tile of leaf
    word offsets ``off`` that may straddle several arena blocks.

    TPUs cannot gather SMEM with a vector index, so the per-word lookup
    ``block_base[off >> 12]`` is rewritten as ``n_cand`` dynamic-scalar
    reads (the same access pattern the arena kernels use) followed by
    vector selects: ``j0`` (traced scalar) is the first arena block the
    tile can touch and ``n_cand`` (static) bounds how many consecutive
    blocks it can span.  Works identically on SMEM refs inside a Pallas
    kernel and on plain jnp arrays (the oracle / incremental paths).

    ``words_log2`` sets the table granularity: the default addresses
    whole arena blocks; the paged serving cache passes its (smaller,
    block-dividing) page size so the same candidate-select machinery
    resolves per-*page* physical bases and threshold rows.

    Returns ``(wid, thr_cols)`` with ``wid`` the per-word physical ids
    and ``thr_cols`` a NUM_THR_COLS tuple of per-word uint32 arrays.
    """
    off = off.astype(jnp.uint32)
    jvec = off >> np.uint32(words_log2)
    rem = off & np.uint32((1 << words_log2) - 1)
    base = jnp.zeros_like(off)
    thr = [jnp.zeros_like(off) for _ in range(fm.NUM_THR_COLS)]
    j0 = j0.astype(jnp.int32) if hasattr(j0, "astype") else jnp.int32(j0)
    for jj in range(n_cand):
        cand = j0 + jj                       # traced scalar block index
        idx = jnp.minimum(cand, num_blocks - 1)   # clamp the SMEM read
        hit = jvec == cand.astype(jnp.uint32)     # never true if cand OOB
        base = base + jnp.where(hit, base_ref[idx], np.uint32(0))
        for c in range(fm.NUM_THR_COLS):
            thr[c] = thr[c] + jnp.where(hit, thr_ref[idx, c], np.uint32(0))
    return base + rem, tuple(thr)


def _arena_kernel(base_ref, thr_ref, x_ref, o_ref, *, seed, method,
                  words_per_row_log2):
    i = pl.program_id(0)
    x = x_ref[...]
    wid = block_word_ids(base_ref[i], x.shape)
    # Individual scalar SMEM reads (dynamic row, static column) -- the
    # TPU-safe access pattern for prefetched scalars.
    thr_row = tuple(thr_ref[i, c] for c in range(fm.NUM_THR_COLS))
    o_ref[...] = apply_masks(x, wid, thr_row, seed=seed, method=method,
                             words_per_row_log2=words_per_row_log2)


def arena_bitflip_pallas(arena2d: jax.Array, block_base: jax.Array,
                         block_thr: jax.Array, *, seed: int, method: str,
                         words_per_row_log2: int, interpret: bool):
    """Inject a whole domain arena in one fused pass.

    ``arena2d``: (num_blocks * 8, 512) uint32 -- every leaf of the domain
    packed block-aligned.  ``block_base``: (num_blocks,) uint32 physical
    base word per block.  ``block_thr``: (num_blocks, NUM_THR_COLS)
    uint32 threshold-table rows (the per-block PC's row at the current,
    possibly traced, voltage).  One ``pallas_call``, grid over blocks.
    """
    m, n = arena2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    num_blocks = m // BLOCK_SUBLANES
    assert block_base.shape == (num_blocks,), block_base.shape
    assert block_thr.shape == (num_blocks, fm.NUM_THR_COLS), block_thr.shape
    body = functools.partial(_arena_kernel, seed=seed, method=method,
                             words_per_row_log2=words_per_row_log2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_base, block_thr, arena2d)
