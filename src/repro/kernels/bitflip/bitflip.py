"""Pallas TPU kernel: stuck-at fault injection for undervolted HBM.

This is the framework's perf-critical hot path: every tensor group placed
in an unsafe memory domain is passed through this kernel each step, so it
must stream at HBM bandwidth with one read-modify-write.  The kernel is
tile-parallel over (8, 512)-word VMEM blocks (16 KiB -- MXU/VPU aligned:
8 sublanes x 512 = 4x128 lanes), computes a counter-based hash per word,
and ORs/ANDNs the resulting stuck-at masks into the data.

The mask math is shared with :mod:`repro.kernels.bitflip.ref` (pure jnp
integer ops), so kernel and oracle are bit-exact by construction; the
tests assert exact equality over shape/dtype/method sweeps in interpret
mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.bitflip import ref as _ref

BLOCK_SUBLANES = 8
BLOCK_LANES = 512
BLOCK_WORDS = BLOCK_SUBLANES * BLOCK_LANES  # 4096 words = 16 KiB


def _kernel(x_ref, o_ref, *, thresholds, seed, base_word, method):
    x = x_ref[...]
    # Physical word index of every element in this block.
    i = pl.program_id(0).astype(jnp.uint32)
    sub = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    wid = (np.uint32(base_word) + i * np.uint32(BLOCK_WORDS)
           + sub * np.uint32(x.shape[1]) + lane)
    if method == "word":
        mask01, mask10 = _ref._word_masks(wid, seed, thresholds)
    else:
        mask01, mask10 = _ref._bitwise_masks(wid, seed, thresholds)
    mask10 = mask10 & ~mask01
    o_ref[...] = (x | mask01) & ~mask10


def bitflip_pallas(data2d: jax.Array, *, thresholds, seed: int,
                   base_word: int, method: str, interpret: bool):
    """Apply stuck-at faults to a (M, 512) uint32 array, M % 8 == 0."""
    m, n = data2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    body = functools.partial(_kernel, thresholds=thresholds, seed=seed,
                             base_word=base_word, method=method)
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        grid=(m // BLOCK_SUBLANES,),
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0)),
        interpret=interpret,
    )(data2d)
