from repro.kernels.bitflip.ops import inject, inject_u32  # noqa: F401
