"""Pure-jnp oracle for the undervolt fault-injection kernel.

Two methods, bit-exact with the Pallas kernel (integer math only):

  * ``word``: fast path for low fault rates.  Each 32-bit word is "hit"
    with probability min(1, 32 p) and a hit word gets one stuck bit at a
    hashed position.  Exact to O((32 p)^2) -- used for the training-loop
    regime (p <= ~1e-3).
  * ``bitwise``: exact per-bit Bernoulli via 20 bit-sliced random planes
    (probability resolution 2^-20, so even strong-row rates just above
    the word-path dispatch boundary stay within ~2% relative error).
    Used near the collapse voltages where nearly every bit is stuck.

Both derive stuck bits from hash(seed, physical word index), so the fault
set is persistent across steps and monotone in voltage within a method.

The mask builders come in two flavors sharing one code path:

  * value-based (:func:`word_masks` / :func:`bitwise_masks`): thresholds
    are passed as uint32 scalars or per-word arrays, which may be static
    numpy constants *or traced values* -- rows of the fault map's
    voltage-indexed threshold table.  This is what the arena engine's
    fused kernels consume (thresholds arrive through scalar prefetch) and
    what makes a jitted voltage sweep recompile-free.
  * :class:`~repro.core.faultmap.KernelThresholds`-based wrappers
    (:func:`_word_masks` / :func:`_bitwise_masks`): the legacy static
    path; it folds the same integers at trace time, so both flavors are
    bit-exact with each other by construction.

All helpers take ``seed`` as a Python int and use numpy scalar constants
only, so they can be called from inside the Pallas kernel body without
capturing array constants.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashing as H

_U0 = np.uint32(0)
_U1 = np.uint32(1)
_U31 = np.uint32(31)
_FULL = np.uint32(0xFFFFFFFF)

# Bit-planes in the bitwise path: probability resolution 2**-PLANES.
# (Canonical definition lives in repro.core.hashing; re-exported here for
# backwards compatibility.)
PLANES = H.PLANES


def _weak_rows(wid, seed: int, weak_row_q, words_per_row_log2: int):
    row = wid >> np.uint32(words_per_row_log2)
    return H.hash_stream(seed, H.STREAM_ROW, row) < weak_row_q


def word_masks(wid, seed: int, *, q01_weak, q01_strong, q10_weak,
               q10_strong, weak_row_q, words_per_row_log2: int):
    """Stuck-at masks for the word-hit fast path.

    Threshold operands are uint32 scalars or arrays broadcastable against
    ``wid`` -- static numpy values and traced table rows behave
    identically.  ``words_per_row_log2`` is always static (geometry).
    """
    weak = _weak_rows(wid, seed, weak_row_q, words_per_row_log2)

    q01 = jnp.where(weak, q01_weak, q01_strong)
    q10 = jnp.where(weak, q10_weak, q10_strong)

    hit01 = H.hash_stream(seed, H.STREAM_WORD_01, wid) < q01
    hit10 = H.hash_stream(seed, H.STREAM_WORD_10, wid) < q10
    pos01 = H.hash_stream(seed, H.STREAM_BITPOS_01, wid) & _U31
    pos10 = H.hash_stream(seed, H.STREAM_BITPOS_10, wid) & _U31

    mask01 = jnp.where(hit01, _U1 << pos01, _U0)
    mask10 = jnp.where(hit10, _U1 << pos10, _U0)
    return mask01, mask10


def _word_masks(wid, seed: int, thr):
    """KernelThresholds wrapper around :func:`word_masks`."""
    return word_masks(
        wid, seed,
        q01_weak=np.uint32(thr.q01_weak), q01_strong=np.uint32(thr.q01_strong),
        q10_weak=np.uint32(thr.q10_weak), q10_strong=np.uint32(thr.q10_strong),
        weak_row_q=np.uint32(thr.weak_row_q),
        words_per_row_log2=thr.words_per_row_log2)


def _plane(seed: int, j: int, direction: int, wid):
    """Random 32-lane bit plane j for one flip direction."""
    plane_seed = H.mix32_int(int(seed) ^ (2 * j + direction + 1))
    return H.hash_stream(plane_seed, H.STREAM_BITPLANE, wid)


def _bitwise_lt(planes, t):
    """Bit-sliced per-lane compare: lane's PLANES-bit uniform < t (vector).

    planes[j] holds bit j of every lane's uniform; t is a per-word uint32
    holding a PLANES-bit threshold broadcast across its 32 lanes.
    """
    lt = jnp.zeros_like(t)
    eq = jnp.full_like(t, _FULL)
    for j in range(PLANES - 1, -1, -1):
        tmask = _U0 - ((t >> np.uint32(j)) & _U1)  # all-ones if bit set
        b = planes[j]
        lt = lt | (eq & ~b & tmask)
        eq = eq & (b ^ ~tmask)
    return lt


def bitwise_masks(wid, seed: int, *, t01_weak, t01_strong, t10_weak,
                  t10_strong, weak_row_q, words_per_row_log2: int):
    """Exact per-bit stuck-at masks (PLANES-bit probability resolution).

    ``t*`` are PLANES-bit thresholds as uint32 scalars or arrays; like
    :func:`word_masks` they may be static or traced.
    """
    weak = _weak_rows(wid, seed, weak_row_q, words_per_row_log2)

    planes01 = [_plane(seed, j, 0, wid) for j in range(PLANES)]
    planes10 = [_plane(seed, j, 1, wid) for j in range(PLANES)]
    mask01 = _bitwise_lt(planes01, jnp.where(weak, t01_weak, t01_strong))
    mask10 = _bitwise_lt(planes10, jnp.where(weak, t10_weak, t10_strong))
    return mask01, mask10


def _bitwise_masks(wid, seed: int, thr):
    """KernelThresholds wrapper around :func:`bitwise_masks`."""
    return bitwise_masks(
        wid, seed,
        t01_weak=np.uint32(thr.t01_weak), t01_strong=np.uint32(thr.t01_strong),
        t10_weak=np.uint32(thr.t10_weak), t10_strong=np.uint32(thr.t10_strong),
        weak_row_q=np.uint32(thr.weak_row_q),
        words_per_row_log2=thr.words_per_row_log2)


def inject_u32_ref(data_u32, *, thresholds, seed: int, base_word: int,
                   method: str = "word"):
    """Apply stuck-at faults to a flat uint32 array (reference)."""
    data_u32 = jnp.asarray(data_u32, dtype=jnp.uint32)
    n = data_u32.shape[0]
    wid = np.uint32(base_word) + jnp.arange(n, dtype=jnp.uint32)
    if method == "word":
        mask01, mask10 = _word_masks(wid, seed, thresholds)
    elif method == "bitwise":
        mask01, mask10 = _bitwise_masks(wid, seed, thresholds)
    else:
        raise ValueError(f"unknown method {method!r}")
    mask10 = mask10 & ~mask01  # a doubly-selected bit sticks at 1
    return (data_u32 | mask01) & ~mask10
