"""Pallas TPU kernel: chunked RG-LRU linear-recurrence scan.

Grid (B_tiles, n_chunks) with the chunk dimension sequential: the
carried state h lives in VMEM scratch and flows across chunk steps.
Inside a chunk the recurrence is evaluated with a log-depth associative
scan over the (CHUNK, R) tile -- VPU-friendly elementwise ops on
(8, 128)-aligned registers, one HBM read per input element and one
write per output element (the recurrence is strictly memory-bound, so
this kernel runs at HBM roofline by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
DEFAULT_BTILE = 8


def _kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, h_ref, *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)        # (BT, C, R)
    b = b_ref[...].astype(jnp.float32)
    # fold carried state into the first step
    b = b.at[:, 0].add(a[:, 0] * h_ref[...])

    def combine(prev, nxt):
        a1, b1 = prev
        a2, b2 = nxt
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    o_ref[...] = h.astype(o_ref.dtype)
    h_ref[...] = h[:, -1]

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hlast_ref[...] = h_ref[...].astype(hlast_ref.dtype)


def rglru_pallas(a, b, h0, *, chunk: int = DEFAULT_CHUNK,
                 btile: int = DEFAULT_BTILE, interpret: bool = True):
    """a, b: (B, S, R); h0: (B, R).  S % chunk == 0, B % btile == 0."""
    bsz, s, r = a.shape
    btile = min(btile, bsz)
    n_chunks = s // chunk
    nb = bsz // btile
    body = functools.partial(_kernel, n_chunks=n_chunks)
    out, hlast = pl.pallas_call(
        body,
        out_shape=(jax.ShapeDtypeStruct((bsz, s, r), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, r), jnp.float32)),
        grid=(nb, n_chunks),
        in_specs=[
            pl.BlockSpec((btile, chunk, r), lambda i, j: (i, j, 0)),
            pl.BlockSpec((btile, chunk, r), lambda i, j: (i, j, 0)),
            pl.BlockSpec((btile, r), lambda i, j: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((btile, chunk, r), lambda i, j: (i, j, 0)),
                   pl.BlockSpec((btile, r), lambda i, j: (i, 0))),
        scratch_shapes=[pltpu.VMEM((btile, r), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out, hlast
