"""Pure-jnp oracle for the RG-LRU chunked-scan kernel: the seeded linear
recurrence h_t = a_t * h_{t-1} + b_t via associative scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, bb, h0):
    """a, bb: (B, S, R) f32; h0: (B, R) f32 -> (h_seq, h_last)."""
    a = a.astype(jnp.float32)
    bb = bb.astype(jnp.float32)
    bb = bb.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(prev, nxt):
        a1, b1 = prev
        a2, b2 = nxt
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, bb), axis=1)
    return h, h[:, -1]
