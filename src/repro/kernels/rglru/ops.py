"""Public wrapper for the RG-LRU scan kernel: padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru.ref import rglru_scan_ref
from repro.kernels.rglru.rglru import (DEFAULT_BTILE, DEFAULT_CHUNK,
                                       rglru_pallas)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_ref"))
def rglru_scan(a, b, h0, *, chunk: int = DEFAULT_CHUNK, interpret=None,
               use_ref: bool = False):
    """Seeded linear recurrence h_t = a_t h_{t-1} + b_t over axis 1.

    a, b: (B, S, R); h0: (B, R).  Returns (h_seq f32, h_last f32).
    """
    if use_ref:
        return rglru_scan_ref(a, b, h0)
    if interpret is None:
        interpret = _default_interpret()
    bsz, s, r = a.shape
    chunk_ = min(chunk, s)
    pad_s = (-s) % chunk_
    btile = min(DEFAULT_BTILE, bsz)
    pad_b = (-bsz) % btile
    if pad_s or pad_b:
        pads3 = ((0, pad_b), (0, pad_s), (0, 0))
        a = jnp.pad(a, pads3)
        b = jnp.pad(b, pads3)
        h0 = jnp.pad(h0, ((0, pad_b), (0, 0)))
    out, hlast = rglru_pallas(a, b, h0, chunk=chunk_, btile=btile,
                              interpret=bool(interpret))
    # padded time steps have a=0,b=0 => h stays 0 after them only if...
    # they sit at the END, so the true h_last is at index s-1.
    hlast_true = out[:bsz, s - 1, :]
    return out[:bsz, :s], hlast_true
