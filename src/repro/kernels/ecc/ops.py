"""Public jit'd wrapper around the fused inject+ECC kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitflip.bitflip import BLOCK_LANES, BLOCK_WORDS
from repro.kernels.bitflip.ops import default_interpret as _default_interpret
from repro.kernels.ecc import ref as _ref
from repro.kernels.ecc.ecc import ecc_pallas


@functools.partial(jax.jit, static_argnames=(
    "thresholds", "seed", "base_word", "interpret", "use_ref"))
def _ecc_jit(data_u32, *, thresholds, seed, base_word, interpret, use_ref):
    n = data_u32.shape[0]
    if use_ref:
        pad2 = (-n) % 2
        padded = (jnp.concatenate([data_u32, jnp.zeros((pad2,), jnp.uint32)])
                  if pad2 else data_u32)
        out, bad = _ref.inject_and_correct_u32_ref(
            padded, thresholds=thresholds, seed=seed, base_word=base_word)
        return out[:n], bad
    pad = (-n) % BLOCK_WORDS
    padded = (jnp.concatenate([data_u32, jnp.zeros((pad,), jnp.uint32)])
              if pad else data_u32)
    out, bad = ecc_pallas(padded.reshape(-1, BLOCK_LANES),
                          thresholds=thresholds, seed=seed,
                          base_word=base_word, interpret=interpret)
    # Padded (zero) words can only contribute stuck-at-1 hits; their
    # codewords are beyond the tensor and their corrections are sliced
    # off, but their counts must not be: restrict by recomputing? No --
    # padding lives in the tensor's aligned allocation slot, so counting
    # its uncorrectable events is consistent with physical reality.
    return out.reshape(-1)[:n], jnp.sum(bad)


def inject_and_correct_u32(data_u32: jax.Array, *, thresholds, seed: int,
                           base_word: int = 0, interpret=None,
                           use_ref: bool = False):
    """Apply stuck-at faults + SECDED correction to a flat uint32 array.

    Returns (corrected array, uncorrectable codeword count).
    """
    if interpret is None:
        interpret = _default_interpret()
    return _ecc_jit(data_u32, thresholds=thresholds, seed=int(seed),
                    base_word=int(base_word), interpret=bool(interpret),
                    use_ref=bool(use_ref))
