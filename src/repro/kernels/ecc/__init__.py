from repro.kernels.ecc.ops import inject_and_correct_u32  # noqa: F401
