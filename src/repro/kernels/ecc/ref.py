"""Pure-jnp oracle for the fused inject+ECC kernel.

Emulates SECDED(72,64): each 64-bit codeword (two consecutive u32 words)
carries 8 parity bits.  Under undervolting the parity bits are as
vulnerable as data bits.  Behavioral emulation (we hold the pre-fault
data, so no syndrome algebra is needed):

  * 0 faults in the codeword  -> data unchanged
  * 1 fault (data or parity)  -> corrected, i.e. data restored
  * >=2 faults                -> uncorrectable: faulted data passes
                                 through and the event is counted

Word-path injection only: ECC is useful exactly in the low-rate regime
(p <= ~1e-3); near array collapse every codeword is multi-fault and ECC
buys nothing (the paper's all-faulty region).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashing as H
from repro.kernels.bitflip.ref import _word_masks

STREAM_PARITY = 0x94D049BB

_U0 = np.uint32(0)
_U1 = np.uint32(1)


def popcount32(v):
    """SWAR popcount on uint32 lanes (portable into Pallas)."""
    v = v - ((v >> _U1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> np.uint32(2)) & np.uint32(0x33333333))
    v = (v + (v >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> np.uint32(24)


def parity_q(thr) -> tuple[int, int]:
    """(weak, strong) word-hit thresholds for the 8 parity bits."""
    qw = H.rate_to_u32_threshold(min(1.0, 8.0 * (thr.p01_weak + thr.p10_weak)))
    qs = H.rate_to_u32_threshold(min(1.0, 8.0 * (thr.p01_strong + thr.p10_strong)))
    return qw, qs


def ecc_codewords(data_u32, wid, seed: int, thr):
    """Returns (corrected_u32, uncorrectable_bool_per_codeword).

    ``data_u32``/``wid`` must have an even number of elements along the
    last axis (codewords are adjacent word pairs).
    """
    mask01, mask10 = _word_masks(wid, seed, thr)
    mask10 = mask10 & ~mask01
    faulted = (data_u32 | mask01) & ~mask10
    fault_bits = faulted ^ data_u32

    shape = data_u32.shape
    pair = shape[:-1] + (shape[-1] // 2, 2)
    fb = fault_bits.reshape(pair)
    counts = popcount32(fb[..., 0]) + popcount32(fb[..., 1])

    # Parity-bit faults: one draw per codeword, weak-row aware.
    cw_id = wid.reshape(pair)[..., 0] >> _U1
    row = wid.reshape(pair)[..., 0] >> np.uint32(thr.words_per_row_log2)
    weak = H.hash_stream(seed, H.STREAM_ROW, row) < np.uint32(thr.weak_row_q)
    qw, qs = parity_q(thr)
    q = jnp.where(weak, np.uint32(qw), np.uint32(qs))
    par_hit = H.hash_stream(seed, STREAM_PARITY, cw_id) < q
    counts = counts + par_hit.astype(jnp.uint32)

    uncorrectable = counts >= 2
    keep_faulty = jnp.repeat(uncorrectable[..., None], 2, axis=-1).reshape(shape)
    out = jnp.where(keep_faulty, faulted, data_u32)
    return out, uncorrectable


def inject_and_correct_u32_ref(data_u32, *, thresholds, seed: int,
                               base_word: int):
    data_u32 = jnp.asarray(data_u32, dtype=jnp.uint32)
    n = data_u32.shape[0]
    assert n % 2 == 0, "ECC reference needs an even word count"
    wid = np.uint32(base_word) + jnp.arange(n, dtype=jnp.uint32)
    out, bad = ecc_codewords(data_u32, wid, seed, thresholds)
    return out, jnp.sum(bad.astype(jnp.int32))
