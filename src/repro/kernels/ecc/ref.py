"""Pure-jnp oracle for the fused inject+ECC kernel.

Emulates SECDED(72,64): each 64-bit codeword (two consecutive u32 words)
carries 8 parity bits.  Under undervolting the parity bits are as
vulnerable as data bits.  Behavioral emulation (we hold the pre-fault
data, so no syndrome algebra is needed):

  * 0 faults in the codeword  -> data unchanged
  * 1 fault (data or parity)  -> corrected, i.e. data restored
  * >=2 faults                -> uncorrectable: faulted data passes
                                 through and the event is counted

Word-path injection only: ECC is useful exactly in the low-rate regime
(p <= ~1e-3); near array collapse every codeword is multi-fault and ECC
buys nothing (the paper's all-faulty region).

Like the bitflip oracle, the codeword emulation comes in a value-based
flavor (:func:`ecc_codewords_vals`, thresholds as uint32 scalars/arrays,
static or traced) used by the arena engine, and a
KernelThresholds-based wrapper (:func:`ecc_codewords`) for the legacy
per-segment path.  Both fold to the same integer math.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashing as H
from repro.kernels.bitflip.ref import _weak_rows, word_masks

STREAM_PARITY = 0x94D049BB

_U0 = np.uint32(0)
_U1 = np.uint32(1)


def popcount32(v):
    """SWAR popcount on uint32 lanes (portable into Pallas)."""
    v = v - ((v >> _U1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> np.uint32(2)) & np.uint32(0x33333333))
    v = (v + (v >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> np.uint32(24)


def ecc_codeword_events(data_u32, wid, seed: int, *, q01_weak, q01_strong,
                        q10_weak, q10_strong, weak_row_q,
                        par_q_weak, par_q_strong, words_per_row_log2: int):
    """Returns (corrected_u32, corrected_bool, uncorrectable_bool).

    Per-codeword event flags: ``corrected`` marks single-fault codewords
    the SECDED logic silently repaired (the telemetry signal -- these
    cost nothing today but witness a row drifting weak), ``uncorrectable``
    marks multi-fault codewords whose faulted data passes through.

    ``data_u32``/``wid`` must have an even number of elements along the
    last axis (codewords are adjacent word pairs).  Threshold operands
    are uint32 scalars or per-word arrays, static or traced.
    """
    mask01, mask10 = word_masks(
        wid, seed,
        q01_weak=q01_weak, q01_strong=q01_strong,
        q10_weak=q10_weak, q10_strong=q10_strong,
        weak_row_q=weak_row_q, words_per_row_log2=words_per_row_log2)
    mask10 = mask10 & ~mask01
    faulted = (data_u32 | mask01) & ~mask10
    fault_bits = faulted ^ data_u32

    shape = data_u32.shape
    pair = shape[:-1] + (shape[-1] // 2, 2)
    fb = fault_bits.reshape(pair)
    counts = popcount32(fb[..., 0]) + popcount32(fb[..., 1])

    # Parity-bit faults: one draw per codeword, weak-row aware.
    cw_wid = wid.reshape(pair)[..., 0]
    cw_id = cw_wid >> _U1
    weak = _weak_rows(cw_wid, seed, _cw_vals(weak_row_q, pair),
                      words_per_row_log2)
    q = jnp.where(weak, _cw_vals(par_q_weak, pair), _cw_vals(par_q_strong, pair))
    par_hit = H.hash_stream(seed, STREAM_PARITY, cw_id) < q
    counts = counts + par_hit.astype(jnp.uint32)

    corrected = counts == 1
    uncorrectable = counts >= 2
    keep_faulty = jnp.repeat(uncorrectable[..., None], 2, axis=-1).reshape(shape)
    out = jnp.where(keep_faulty, faulted, data_u32)
    return out, corrected, uncorrectable


def ecc_codewords_vals(data_u32, wid, seed: int, *, q01_weak, q01_strong,
                       q10_weak, q10_strong, weak_row_q,
                       par_q_weak, par_q_strong, words_per_row_log2: int):
    """Returns (corrected_u32, uncorrectable_bool_per_codeword)."""
    out, _, uncorrectable = ecc_codeword_events(
        data_u32, wid, seed,
        q01_weak=q01_weak, q01_strong=q01_strong,
        q10_weak=q10_weak, q10_strong=q10_strong,
        weak_row_q=weak_row_q, par_q_weak=par_q_weak,
        par_q_strong=par_q_strong, words_per_row_log2=words_per_row_log2)
    return out, uncorrectable


def _cw_vals(v, pair_shape):
    """Reduce a per-word threshold operand to per-codeword (scalars pass
    through; arrays take the first word of each pair)."""
    v = jnp.asarray(v)
    if v.ndim == 0:
        return v
    return jnp.broadcast_to(v, pair_shape[:-2] + (pair_shape[-2] * 2,)) \
        .reshape(pair_shape)[..., 0]


def ecc_codewords(data_u32, wid, seed: int, thr):
    """KernelThresholds wrapper around :func:`ecc_codewords_vals`."""
    return ecc_codewords_vals(
        data_u32, wid, seed,
        q01_weak=np.uint32(thr.q01_weak), q01_strong=np.uint32(thr.q01_strong),
        q10_weak=np.uint32(thr.q10_weak), q10_strong=np.uint32(thr.q10_strong),
        weak_row_q=np.uint32(thr.weak_row_q),
        par_q_weak=np.uint32(thr.par_q_weak),
        par_q_strong=np.uint32(thr.par_q_strong),
        words_per_row_log2=thr.words_per_row_log2)


def inject_and_correct_u32_ref(data_u32, *, thresholds, seed: int,
                               base_word: int):
    data_u32 = jnp.asarray(data_u32, dtype=jnp.uint32)
    n = data_u32.shape[0]
    assert n % 2 == 0, "ECC reference needs an even word count"
    wid = np.uint32(base_word) + jnp.arange(n, dtype=jnp.uint32)
    out, bad = ecc_codewords(data_u32, wid, seed, thresholds)
    return out, jnp.sum(bad.astype(jnp.int32))
