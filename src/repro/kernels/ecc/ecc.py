"""Pallas TPU kernel: fused fault-injection + SECDED(72,64) correction.

Fusing the undervolt fault model with the ECC behavioral model keeps the
mitigation path at one HBM read-modify-write per step -- the same budget
as unprotected injection (a beyond-paper optimization; the paper treats
ECC as future mitigation work and cites [57]).

Block layout matches the bitflip kernel: (8, 512) uint32 VMEM tiles,
grid-parallel over blocks.  Each block additionally reduces its
uncorrectable-codeword count into a (1, 1) int32 output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.bitflip.bitflip import (BLOCK_LANES, BLOCK_SUBLANES,
                                           BLOCK_WORDS)
from repro.kernels.ecc import ref as _ref


def _kernel(x_ref, o_ref, bad_ref, *, thresholds, seed, base_word):
    x = x_ref[...]
    i = pl.program_id(0).astype(jnp.uint32)
    sub = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    wid = (np.uint32(base_word) + i * np.uint32(BLOCK_WORDS)
           + sub * np.uint32(x.shape[1]) + lane)
    out, bad = _ref.ecc_codewords(x, wid, seed, thresholds)
    o_ref[...] = out
    bad_ref[0, 0] = jnp.sum(bad.astype(jnp.int32))


def ecc_pallas(data2d: jax.Array, *, thresholds, seed: int, base_word: int,
               interpret: bool):
    """(M, 512) uint32, M % 8 == 0 -> (corrected, per-block bad counts)."""
    m, n = data2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    grid = (m // BLOCK_SUBLANES,)
    body = functools.partial(_kernel, thresholds=thresholds, seed=seed,
                             base_word=base_word)
    return pl.pallas_call(
        body,
        out_shape=(jax.ShapeDtypeStruct((m, n), jnp.uint32),
                   jax.ShapeDtypeStruct((grid[0], 1), jnp.int32)),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                                lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(data2d)
