"""Pallas TPU kernel: fused fault-injection + SECDED(72,64) correction.

Fusing the undervolt fault model with the ECC behavioral model keeps the
mitigation path at one HBM read-modify-write per step -- the same budget
as unprotected injection (a beyond-paper optimization; the paper treats
ECC as future mitigation work and cites [57]).

Block layout matches the bitflip kernel: (8, 512) uint32 VMEM tiles,
grid-parallel over blocks.  Each block additionally reduces its
uncorrectable-codeword count into a (1, 1) int32 output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import faultmap as fm
from repro.kernels.bitflip.bitflip import (BLOCK_LANES, BLOCK_SUBLANES,
                                           BLOCK_WORDS, block_word_ids)
from repro.kernels.ecc import ref as _ref


def _kernel(x_ref, o_ref, bad_ref, *, thresholds, seed, base_word):
    x = x_ref[...]
    i = pl.program_id(0).astype(jnp.uint32)
    sub = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    wid = (np.uint32(base_word) + i * np.uint32(BLOCK_WORDS)
           + sub * np.uint32(x.shape[1]) + lane)
    out, bad = _ref.ecc_codewords(x, wid, seed, thresholds)
    o_ref[...] = out
    bad_ref[0, 0] = jnp.sum(bad.astype(jnp.int32))


def ecc_pallas(data2d: jax.Array, *, thresholds, seed: int, base_word: int,
               interpret: bool):
    """(M, 512) uint32, M % 8 == 0 -> (corrected, per-block bad counts)."""
    m, n = data2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    grid = (m // BLOCK_SUBLANES,)
    body = functools.partial(_kernel, thresholds=thresholds, seed=seed,
                             base_word=base_word)
    return pl.pallas_call(
        body,
        out_shape=(jax.ShapeDtypeStruct((m, n), jnp.uint32),
                   jax.ShapeDtypeStruct((grid[0], 1), jnp.int32)),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                                lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(data2d)


def arena_ecc_events(x, wid, thr_row, *, seed: int,
                     words_per_row_log2: int):
    """Fused inject+correct+telemetry for one block from a traced
    threshold row: returns (out, corrected_bool, uncorrectable_bool)
    per codeword.  Shared by the arena ECC kernel, the paged decode
    kernel's telemetry path, and the scrub oracle."""
    return _ref.ecc_codeword_events(
        x, wid, seed,
        q01_weak=thr_row[fm.COL_Q01_WEAK],
        q01_strong=thr_row[fm.COL_Q01_STRONG],
        q10_weak=thr_row[fm.COL_Q10_WEAK],
        q10_strong=thr_row[fm.COL_Q10_STRONG],
        weak_row_q=thr_row[fm.COL_WEAK_ROW_Q],
        par_q_weak=thr_row[fm.COL_PAR_Q_WEAK],
        par_q_strong=thr_row[fm.COL_PAR_Q_STRONG],
        words_per_row_log2=words_per_row_log2)


def arena_ecc_codewords(x, wid, thr_row, *, seed: int,
                        words_per_row_log2: int):
    """Fused inject+correct for one block from a traced threshold row.

    Shared by the arena ECC kernel and the arena oracle (same contract
    as :func:`repro.kernels.bitflip.bitflip.arena_masks`).
    """
    out, _, uncorrectable = arena_ecc_events(
        x, wid, thr_row, seed=seed, words_per_row_log2=words_per_row_log2)
    return out, uncorrectable


def _arena_kernel(base_ref, thr_ref, x_ref, o_ref, bad_ref, corr_ref, *,
                  seed, words_per_row_log2):
    i = pl.program_id(0)
    x = x_ref[...]
    wid = block_word_ids(base_ref[i], x.shape)
    thr_row = tuple(thr_ref[i, c] for c in range(fm.NUM_THR_COLS))
    out, corr, bad = arena_ecc_events(x, wid, thr_row, seed=seed,
                                      words_per_row_log2=words_per_row_log2)
    o_ref[...] = out
    bad_ref[0, 0] = jnp.sum(bad.astype(jnp.int32))
    corr_ref[0, 0] = jnp.sum(corr.astype(jnp.int32))


def arena_ecc_pallas(arena2d: jax.Array, block_base: jax.Array,
                     block_thr: jax.Array, *, seed: int,
                     words_per_row_log2: int, interpret: bool):
    """Fused inject+SECDED over a whole domain arena in one pass.

    Same operand contract as ``arena_bitflip_pallas`` plus per-block
    uncorrectable- and corrected-codeword count outputs (the corrected
    counts are the telemetry stream the self-healing loop consumes).
    """
    m, n = arena2d.shape
    assert n == BLOCK_LANES and m % BLOCK_SUBLANES == 0, (m, n)
    num_blocks = m // BLOCK_SUBLANES
    assert block_base.shape == (num_blocks,), block_base.shape
    assert block_thr.shape == (num_blocks, fm.NUM_THR_COLS), block_thr.shape
    body = functools.partial(_arena_kernel, seed=seed,
                             words_per_row_log2=words_per_row_log2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                               lambda i, *_: (i, 0))],
        out_specs=(pl.BlockSpec((BLOCK_SUBLANES, BLOCK_LANES),
                                lambda i, *_: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, *_: (i, 0))),
    )
    return pl.pallas_call(
        body,
        out_shape=(jax.ShapeDtypeStruct((m, n), jnp.uint32),
                   jax.ShapeDtypeStruct((num_blocks, 1), jnp.int32),
                   jax.ShapeDtypeStruct((num_blocks, 1), jnp.int32)),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_base, block_thr, arena2d)
