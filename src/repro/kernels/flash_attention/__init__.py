from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attention.faulty import (  # noqa: F401
    faulty_decode_attention)
