"""Pure-jnp oracle for the flash-attention kernel: materialized-softmax
attention with causal/window masks and GQA, f32 internals."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale=None):
    """q: (B, H, Sq, D); k, v: (B, K, Sk, D); returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    g = h // kh
    scale = d ** -0.5 if scale is None else scale
    k_rep = jnp.repeat(k, g, axis=1)
    v_rep = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k_rep.astype(jnp.float32))
    qi = np.arange(sq)[:, None]
    ki = np.arange(sk)[None, :]
    delta = qi - ki
    mask = np.zeros((sq, sk), np.float32)
    if causal:
        mask = np.where(delta < 0, NEG_INF, mask)
    if window > 0:
        mask = np.where(delta >= window, NEG_INF, mask)
    s = s + mask
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v_rep.astype(jnp.float32)).astype(v.dtype)
