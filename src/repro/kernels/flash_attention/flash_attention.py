"""Pallas TPU kernel: flash attention forward (causal/window, GQA).

Grid (B, H, num_q_blocks, num_kv_blocks): the kv dimension is innermost
and sequential; the running (acc, m, l) streaming-softmax state lives in
VMEM scratch and survives across kv steps of the same q block.  Block
shapes are MXU-aligned ((BQ, D) x (BKV, D) contractions with D a
multiple of 128 for full-speed MXU issue).  GQA is expressed in the
BlockSpec index maps: the kv operands map head h -> h // group, so no
repeated K/V ever materializes.

This is the serving/prefill hot path; training uses the XLA chunked
path (models/layers.py) whose custom VJP implements the same algorithm.
The p-block tensors here never leave VMEM -- on the XLA path they round-
trip HBM, which is exactly the memory-term gap the §Perf log quantifies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BKV = 256


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, bq, bkv, sk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (BKV, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BKV)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    delta = q_pos - k_pos
    mask = jnp.zeros((bq, bkv), jnp.float32)
    if causal:
        mask = jnp.where(delta < 0, NEG_INF, mask)
    if window > 0:
        mask = jnp.where(delta >= window, NEG_INF, mask)
    # mask kv padding beyond the true sequence length
    mask = jnp.where(k_pos >= sk, NEG_INF, mask)
    s = s + mask

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool, window: int,
                           scale: float, bq: int = DEFAULT_BQ,
                           bkv: int = DEFAULT_BKV, interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, K, Sk, D), Sq % bq == Sk % bkv == 0."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    g = h // kh
    nq = sq // bq
    nkv = sk // bkv
    body = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bkv=bkv, sk=sk)
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), v.dtype),
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, q_, k_, g_=g: (b_, h_ // g_, k_, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, q_, k_, g_=g: (b_, h_ // g_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        scratch_shapes=[
            # VMEM scratch: streaming-softmax state per q block
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
