"""Pallas TPU kernel: decode attention with read-path fault injection.

The paper's reduced-voltage faults manifest when undervolted HBM is
*read*.  The write-path model (corrupt the stored cache, then attend)
pays an extra O(cache) HBM read-modify-write per decoded token; this
kernel moves injection onto the read path: K/V tiles are corrupted *in
VMEM, as they are loaded* by the decode attention kernel, so injection
costs zero extra HBM passes and rides the bandwidth the attention read
already spends.

Mechanics:

  * the serving placement exports, per cache leaf, the arena engine's
    ``block -> (physical base word, threshold row)`` tables; they arrive
    as scalar-prefetch operands (SMEM), with threshold rows derived from
    a possibly *traced* voltage -- traced KV-voltage sweeps compile once;
  * each K/V tile is a contiguous run of leaf words (the tile spans all
    KV heads of ``bkv`` cache slots), so its per-word physical ids and
    threshold rows come from :func:`select_block_tables` -- a handful of
    dynamic-scalar SMEM reads plus vector selects, never a gather;
  * the mask math is the exact tile-level functions the arena engine
    runs (:func:`apply_masks` / :func:`arena_ecc_codewords`), so
    read-path corruption is bit-identical to corrupt-then-attend on the
    same operands (asserted in tests/test_readpath.py);
  * the slot written *this* step is exempt (``clean_slot``): the freshly
    computed K/V is still in the store buffer, not yet a round-trip
    through undervolted HBM -- which also makes the scanned decode
    token-for-token identical to the legacy corrupt-after-step loop.

With ``inject=False`` the kernel is plain decode flash attention over
the stored cache -- the write-path modes use the same kernel so every
injection mode shares one set of attention numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import faultmap as fm
from repro.kernels.bitflip.bitflip import (BLOCK_WORDS, BLOCK_WORDS_LOG2,
                                           apply_masks, select_block_tables)
from repro.kernels.ecc.ecc import arena_ecc_codewords, arena_ecc_events

NEG_INF = -1e30

# Per-tile word cap: bounds the candidate-block selects (SMEM reads) a
# tile needs to resolve its physical addresses.
TILE_WORD_CAP = 16 * BLOCK_WORDS


def packing(dtype) -> int:
    """Elements per uint32 word for a cache dtype."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize > 4:
        raise NotImplementedError(f"itemsize {itemsize} for {dtype}")
    return 4 // itemsize


def kv_words_per_slot(kh: int, d: int, dtype) -> int:
    """uint32 words one cache slot (all KV heads) occupies; the unit of
    the cache-position -> arena-word mapping."""
    p = packing(dtype)
    if (kh * d) % p:
        raise ValueError(
            f"KV slot of {kh}x{d} {jnp.dtype(dtype).name} elements is not "
            "word-aligned; the read path needs whole uint32 words per slot")
    return kh * d // p


def pick_bkv(length: int, words_per_slot: int,
             cap: int = TILE_WORD_CAP) -> int:
    """Largest divisor of the cache length whose tile fits the word cap."""
    best = 1
    for c in range(1, length + 1):
        if length % c == 0 and c * words_per_slot <= cap:
            best = c
    return best


def _tile_to_u32(x):
    """(rows, elems) any-dtype tile -> (rows, words) uint32 view, word
    pairing identical to ``bitflip.ops.to_u32`` on the flattened leaf."""
    p = packing(x.dtype)
    if p == 1:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    lane = jax.lax.bitcast_convert_type(
        x, jnp.uint16 if p == 2 else jnp.uint8)
    return jax.lax.bitcast_convert_type(
        lane.reshape(x.shape[0], -1, p), jnp.uint32)


def _tile_from_u32(u32, dtype, shape):
    p = packing(dtype)
    if p == 1:
        return jax.lax.bitcast_convert_type(u32, dtype).reshape(shape)
    lane = jax.lax.bitcast_convert_type(
        u32, jnp.uint16 if p == 2 else jnp.uint8)
    return jax.lax.bitcast_convert_type(
        lane.reshape(shape[0], -1), dtype).reshape(shape)


def corrupt_kv_tile(x, word0, base_ref, thr_ref, *, num_blocks: int,
                    n_cand: int, seed: int, method: str,
                    words_per_row_log2: int, ecc: bool, slot_ids=None,
                    clean_slot=None, words_log2: int = BLOCK_WORDS_LOG2):
    """Read-path corruption of one (rows, elems) K/V tile.

    ``word0`` (traced scalar): leaf word offset of the tile's first
    element; rows are leaf-contiguous.  ``base_ref``/``thr_ref``: the
    leaf's arena block tables (SMEM refs inside a kernel, arrays in the
    oracle) at ``words_log2`` granularity -- whole arena blocks by
    default, single KV pages for a page-granular placement.
    ``clean_slot``: optional traced slot index whose row keeps its
    stored (store-buffer) value.
    """
    u = _tile_to_u32(x)
    word0 = word0.astype(jnp.uint32)
    off = (word0
           + jax.lax.broadcasted_iota(jnp.uint32, u.shape, 0)
           * np.uint32(u.shape[1])
           + jax.lax.broadcasted_iota(jnp.uint32, u.shape, 1))
    j0 = (word0 >> np.uint32(words_log2)).astype(jnp.int32)
    wid, thr = select_block_tables(off, base_ref, thr_ref, j0=j0,
                                   n_cand=n_cand, num_blocks=num_blocks,
                                   words_log2=words_log2)
    if ecc:
        assert u.shape[1] % 2 == 0, "ECC tiles need an even word count"
        out, _ = arena_ecc_codewords(u, wid, thr, seed=seed,
                                     words_per_row_log2=words_per_row_log2)
    else:
        out = apply_masks(u, wid, thr, seed=seed, method=method,
                          words_per_row_log2=words_per_row_log2)
    if clean_slot is not None:
        keep = (slot_ids == clean_slot)[:, None]
        out = jnp.where(keep, u, out)
    return _tile_from_u32(out, x.dtype, x.shape)


def _flash_tile_update(q_ref, k_t, v_t, pos_t, q_pos, acc_ref, m_ref,
                       l_ref, *, scale, causal, window, kh, g, d, bkv):
    """One flash-decode accumulator update over a (bkv, KH, D) tile.

    Shared op-for-op by the contiguous and the paged decode kernels, so
    both emit bit-identical outputs on the same tile sequence -- the
    contract that makes a paged serving cache token-equivalent to the
    contiguous per-request cache.  Returns ``(acc, l_new)`` for the
    caller's final normalization.
    """
    q = q_ref[0, 0].astype(jnp.float32) * scale      # (H, D)
    qr = q.reshape(kh, g, d)
    kf = k_t.astype(jnp.float32)
    vf = v_t.astype(jnp.float32)
    # (KH, G, D) x (bkv, KH, D) -> (KH, G, bkv), KH batched
    s = jax.lax.dot_general(qr, kf, (((2,), (2,)), ((0,), (1,))))

    delta = q_pos - pos_t
    mask = jnp.zeros((bkv,), jnp.float32)
    if causal:
        mask = jnp.where(delta < 0, NEG_INF, mask)
    if window > 0:
        mask = jnp.where(delta >= window, NEG_INF, mask)
    mask = jnp.where(pos_t < 0, NEG_INF, mask)       # empty ring slots
    s = s + mask[None, None, :]

    m_prev = m_ref[...].reshape(kh, g)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    acc = acc_ref[...].reshape(kh, g, d) * corr[..., None]
    # (KH, G, bkv) x (bkv, KH, D) -> (KH, G, D), KH batched
    acc = acc + jax.lax.dot_general(p, vf, (((2,), (0,)), ((0,), (1,))))
    l_new = l_ref[...].reshape(kh, g) * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc.reshape(acc_ref.shape)
    m_ref[...] = m_new.reshape(m_ref.shape)
    l_ref[...] = l_new.reshape(l_ref.shape)
    return acc, l_new


def _decode_kernel(kbase_ref, kthr_ref, vbase_ref, vthr_ref, offs_ref,
                   misc_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale, causal, window, bkv,
                   kh, g, d, seed, method, words_per_row_log2, ecc,
                   inject, k_wps, v_wps, k_cand, v_cand, k_blocks,
                   v_blocks, length, words_log2):
    b = pl.program_id(0)
    ki = pl.program_id(1)
    nkv = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_t = k_ref[0]                       # (bkv, KH, D)
    v_t = v_ref[0]
    pos_t = pos_ref[0]                   # (bkv,) int32, may carry faults
    slot_ids = (ki * bkv
                + jax.lax.broadcasted_iota(jnp.int32, (bkv,), 0))

    if inject:
        # Leaf word offset of this tile: the layer's slice offset
        # (prefetched: period-stacked leaves shift per scan index) plus
        # (b * L + ki * bkv) slots into the (B, L, KH, D) buffer.
        slot0 = (b * length + ki * bkv).astype(jnp.uint32)
        clean = misc_ref[0]
        k_t = corrupt_kv_tile(
            k_t.reshape(bkv, kh * d), offs_ref[0] + slot0 * np.uint32(k_wps),
            kbase_ref, kthr_ref, num_blocks=k_blocks, n_cand=k_cand,
            seed=seed, method=method, words_per_row_log2=words_per_row_log2,
            ecc=ecc, slot_ids=slot_ids, clean_slot=clean,
            words_log2=words_log2,
        ).reshape(bkv, kh, d)
        v_t = corrupt_kv_tile(
            v_t.reshape(bkv, kh * d), offs_ref[1] + slot0 * np.uint32(v_wps),
            vbase_ref, vthr_ref, num_blocks=v_blocks, n_cand=v_cand,
            seed=seed, method=method, words_per_row_log2=words_per_row_log2,
            ecc=ecc, slot_ids=slot_ids, clean_slot=clean,
            words_log2=words_log2,
        ).reshape(bkv, kh, d)

    acc, l_new = _flash_tile_update(
        q_ref, k_t, v_t, pos_t, misc_ref[1], acc_ref, m_ref, l_ref,
        scale=scale, causal=causal, window=window, kh=kh, g=g, d=d,
        bkv=bkv)

    @pl.when(ki == nkv - 1)
    def _finish():
        out = acc / jnp.maximum(l_new[..., None], 1e-30)
        o_ref[0, 0] = out.reshape(kh * g, d).astype(o_ref.dtype)


def faulty_decode_attention(q, k, v, pos, *, q_pos, k_tables, v_tables,
                            k_word0, v_word0, causal: bool = True,
                            window: int = 0, scale=None, seed: int,
                            method: str, words_per_row_log2: int,
                            ecc: bool, inject: bool, clean_slot=None,
                            bkv=None, interpret=None,
                            words_log2: int = BLOCK_WORDS_LOG2):
    """Decode attention over a ring cache with read-path injection.

    q: (B, 1, H, D) -- the decode token's query in model layout.
    k, v: (B, L, KH, D) -- the cache buffers in their *stored* layout.
    pos: (B, L) int32 -- absolute position per slot (-1 = empty).
    q_pos: traced scalar, the decode token's absolute position.
    k_tables / v_tables: (block_base, block_thr) arena tables for the
    cache leaf (thresholds already gathered at the current, possibly
    traced, voltage), at ``words_log2`` granularity -- arena blocks by
    default, single KV pages when the request's cache is physically
    paged.  k_word0 / v_word0: traced word offset of this (B, L, KH, D)
    slice within its leaf (stacked-layer leaves).
    clean_slot: traced slot index exempt from corruption (the slot the
    current token was just written to), or None.

    Returns (B, 1, H, D) in v.dtype.
    """
    b, sq, h, d = q.shape
    _, length, kh, _ = k.shape
    assert sq == 1, "read-path kernel is decode-specialized (S == 1)"
    g = h // kh
    scale = float(d ** -0.5 if scale is None else scale)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    k_wps = kv_words_per_slot(kh, d, k.dtype)
    v_wps = kv_words_per_slot(kh, d, v.dtype)
    if bkv is None:
        bkv = pick_bkv(length, max(k_wps, v_wps))
    assert length % bkv == 0, (length, bkv)
    nkv = length // bkv

    k_base, k_thr = k_tables
    v_base, v_thr = v_tables
    gran = 1 << words_log2
    k_cand = -(-bkv * k_wps // gran) + 1
    v_cand = -(-bkv * v_wps // gran) + 1
    offs = jnp.stack([jnp.asarray(k_word0), jnp.asarray(v_word0)]
                     ).astype(jnp.uint32)
    clean = jnp.int32(-1) if clean_slot is None else clean_slot
    misc = jnp.stack([jnp.asarray(clean, jnp.int32),
                      jnp.asarray(q_pos, jnp.int32)])

    body = functools.partial(
        _decode_kernel, scale=scale, causal=causal, window=window, bkv=bkv,
        kh=kh, g=g, d=d, seed=seed, method=method,
        words_per_row_log2=words_per_row_log2, ecc=ecc, inject=inject,
        k_wps=k_wps, v_wps=v_wps, k_cand=k_cand, v_cand=v_cand,
        k_blocks=int(k_base.shape[0]), v_blocks=int(v_base.shape[0]),
        length=length, words_log2=words_log2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(b, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda b_, k_, *_: (b_, 0, 0, 0)),
            pl.BlockSpec((1, bkv, kh, d),
                         lambda b_, k_, *_: (b_, k_, 0, 0)),
            pl.BlockSpec((1, bkv, kh, d),
                         lambda b_, k_, *_: (b_, k_, 0, 0)),
            pl.BlockSpec((1, bkv), lambda b_, k_, *_: (b_, k_)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, d),
                               lambda b_, k_, *_: (b_, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), v.dtype),
        grid_spec=grid_spec,
        interpret=bool(interpret),
    )(k_base, k_thr, v_base, v_thr, offs, misc, q, k, v, pos)


# ---------------------------------------------------------------------------
# Paged variant: batched decode over a page-pool cache
# ---------------------------------------------------------------------------


def corrupt_page_tile(x, base, thr_row, *, seed: int, method: str,
                      words_per_row_log2: int, ecc: bool, slot_ids=None,
                      clean_slot=None, with_counts: bool = False):
    """Read-path corruption of one (rows, elems) K/V tile that is a
    single physical page: every word shares one threshold row and the
    physical ids are ``base`` plus the word's offset inside the page.

    Same mask math as :func:`corrupt_kv_tile` (which resolves the same
    base/row through the candidate selects), so a paged tile corrupts
    bit-identically to the contiguous kernel reading the same physical
    words.

    ``with_counts`` (ECC only) additionally returns the tile's
    corrected-codeword count -- the SECDED events the hardware would
    report for free while the read happens; the clean slot's codewords
    are excluded exactly like its corruption is.
    """
    u = _tile_to_u32(x)
    wid = (jnp.asarray(base, jnp.uint32)
           + jax.lax.broadcasted_iota(jnp.uint32, u.shape, 0)
           * np.uint32(u.shape[1])
           + jax.lax.broadcasted_iota(jnp.uint32, u.shape, 1))
    corr_count = None
    if ecc:
        assert u.shape[1] % 2 == 0, "ECC tiles need an even word count"
        out, corr, _ = arena_ecc_events(
            u, wid, thr_row, seed=seed,
            words_per_row_log2=words_per_row_log2)
        if with_counts:
            corr = corr.astype(jnp.int32)
            if clean_slot is not None:
                corr = jnp.where((slot_ids == clean_slot)[:, None], 0, corr)
            corr_count = jnp.sum(corr)
    else:
        assert not with_counts, "telemetry counts require ECC"
        out = apply_masks(u, wid, thr_row, seed=seed, method=method,
                          words_per_row_log2=words_per_row_log2)
    if clean_slot is not None:
        keep = (slot_ids == clean_slot)[:, None]
        out = jnp.where(keep, u, out)
    tile = _tile_from_u32(out, x.dtype, x.shape)
    if with_counts:
        return tile, corr_count
    return tile


def _paged_kernel(ptab_ref, qpos_ref, kbase_ref, kthr_ref, vbase_ref,
                  vthr_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                  *rest, scale, causal, window, ps, kh, g, d, seed,
                  method, words_per_row_log2, ecc, inject, length,
                  telemetry):
    if telemetry:
        telem_ref, acc_ref, m_ref, l_ref = rest
    else:
        telem_ref, (acc_ref, m_ref, l_ref) = None, rest
    si = pl.program_id(0)
    pi = pl.program_id(1)
    npg = pl.num_programs(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_t = k_ref[0]                       # (ps, KH, D): one physical page
    v_t = v_ref[0]
    pos_t = pos_ref[0]                   # (ps,) int32, may carry faults
    q_pos = qpos_ref[si]

    if inject:
        pid = ptab_ref[si, pi]
        # The freshly written slot never round-tripped through
        # undervolted HBM this step (store-buffer exemption).
        clean = q_pos % length
        slot_ids = (pi * ps
                    + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0))
        k_thr = tuple(kthr_ref[pid, c] for c in range(fm.NUM_THR_COLS))
        v_thr = tuple(vthr_ref[pid, c] for c in range(fm.NUM_THR_COLS))
        kw = dict(seed=seed, method=method,
                  words_per_row_log2=words_per_row_log2, ecc=ecc,
                  slot_ids=slot_ids, clean_slot=clean,
                  with_counts=telemetry)
        k_t = corrupt_page_tile(k_t.reshape(ps, kh * d), kbase_ref[pid],
                                k_thr, **kw)
        v_t = corrupt_page_tile(v_t.reshape(ps, kh * d), vbase_ref[pid],
                                v_thr, **kw)
        if telemetry:
            (k_t, k_corr), (v_t, v_corr) = k_t, v_t
            telem_ref[0, 0] = k_corr + v_corr
        k_t = k_t.reshape(ps, kh, d)
        v_t = v_t.reshape(ps, kh, d)
    elif telemetry:
        telem_ref[0, 0] = jnp.zeros((), jnp.int32)

    acc, l_new = _flash_tile_update(
        q_ref, k_t, v_t, pos_t, q_pos, acc_ref, m_ref, l_ref,
        scale=scale, causal=causal, window=window, kh=kh, g=g, d=d,
        bkv=ps)

    @pl.when(pi == npg - 1)
    def _finish():
        out = acc / jnp.maximum(l_new[..., None], 1e-30)
        o_ref[0, 0] = out.reshape(kh * g, d).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, pos_pool, page_table, *,
                           q_pos, k_tables, v_tables, causal: bool = True,
                           window: int = 0, scale=None, seed: int,
                           method: str, words_per_row_log2: int,
                           ecc: bool, inject: bool, telemetry: bool = False,
                           interpret=None):
    """Batched decode attention over a *paged* ring cache.

    The continuous-batching scheduler's kernel: every serving slot
    attends over its own logical ring cache whose tiles live in pool
    pages.  Page tables arrive as scalar-prefetch operands and drive
    the BlockSpec index maps, so K/V tiles are gathered page-by-page
    straight from the pool buffer -- and corrupted in VMEM as they
    load, addressed by the page's physical base word and threshold row
    (one dynamic-scalar SMEM read each; a page never straddles arena
    blocks, so no candidate selects are needed at all).

    q: (S, 1, H, D) -- one decode query per serving slot.
    k_pool, v_pool: (num_pages, PS, KH, D) -- this layer's page pool.
    pos_pool: (num_pages, PS) int32 -- paged absolute positions.
    page_table: (S, n_lp) int32 -- physical page of each slot's
    logical page (inactive slots point at the pool's scratch page).
    The logical ring length is *derived* from the table width
    (``n_lp * PS``), which is what makes the kernel window-modular:
    a sliding-window leaf hands in the leading ``window // PS`` table
    entries and the ring arithmetic (slot = pos % length, store-buffer
    clean-slot exemption included) lands on the window ring, while
    full-length leaves pass their whole table.  One kernel, both
    layouts.
    q_pos: (S,) int32 -- per-slot absolute decode position.
    k_tables / v_tables: (page_base, page_thr) for this layer's leaf
    slice, thresholds gathered at the current (possibly traced)
    voltage.

    ``telemetry`` (ECC read path only) appends an (S, n_lp) int32
    output: corrected-codeword counts per (slot, logical page) -- the
    SECDED correction events the memory controller reports for free on
    real hardware.  Still one launch: the counts are a second output
    tile of the same kernel, never an extra pass.

    Returns (S, 1, H, D) in v.dtype; with ``telemetry`` a tuple of
    (out, counts).
    """
    if telemetry and not (ecc and inject):
        raise ValueError("telemetry output requires ecc=True, inject=True")
    s, sq, h, d = q.shape
    n, ps, kh, _ = k_pool.shape
    assert sq == 1, "paged kernel is decode-specialized (S == 1)"
    if isinstance(seed, jax.core.Tracer):
        raise TypeError(
            "paged_decode_attention seed must be a static Python int: "
            "the hash-stream draws are folded into the kernel body at "
            "trace time (per-plane seeds included).  Per-shard fault "
            "maps get distinct seeds by specializing one branch per "
            "shard (lax.switch over shard index), never by tracing the "
            "seed")
    n_lp = page_table.shape[1]
    length = n_lp * ps
    g = h // kh
    scale = float(d ** -0.5 if scale is None else scale)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    k_base, k_thr = k_tables
    v_base, v_thr = v_tables
    body = functools.partial(
        _paged_kernel, scale=scale, causal=causal, window=window, ps=ps,
        kh=kh, g=g, d=d, seed=seed, method=method,
        words_per_row_log2=words_per_row_log2, ecc=ecc, inject=inject,
        length=length, telemetry=telemetry)
    out_specs = pl.BlockSpec((1, 1, h, d), lambda s_, p_, *_: (s_, 0, 0, 0))
    out_shape = jax.ShapeDtypeStruct((s, 1, h, d), v_pool.dtype)
    if telemetry:
        out_specs = (out_specs,
                     pl.BlockSpec((1, 1), lambda s_, p_, *_: (s_, p_)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((s, n_lp), jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(s, n_lp),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda s_, p_, *_: (s_, 0, 0, 0)),
            pl.BlockSpec((1, ps, kh, d),
                         lambda s_, p_, ptab, *_: (ptab[s_, p_], 0, 0, 0)),
            pl.BlockSpec((1, ps, kh, d),
                         lambda s_, p_, ptab, *_: (ptab[s_, p_], 0, 0, 0)),
            pl.BlockSpec((1, ps),
                         lambda s_, p_, ptab, *_: (ptab[s_, p_], 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        body,
        out_shape=out_shape,
        grid_spec=grid_spec,
        interpret=bool(interpret),
    )(page_table, jnp.asarray(q_pos, jnp.int32), k_base, k_thr,
      v_base, v_thr, q, k_pool, v_pool, pos_pool)
