"""Public wrapper: layout conversion, padding, GQA plumbing, interpret
fallback for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BKV, DEFAULT_BQ, flash_attention_pallas)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "interpret", "use_ref", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, bq: int = DEFAULT_BQ,
                    bkv: int = DEFAULT_BKV, interpret=None,
                    use_ref: bool = False):
    """q: (B, H, S, D); k, v: (B, K, S, D).  Returns (B, H, S, D)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = float(d ** -0.5 if scale is None else scale)
    if use_ref:
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)
    if interpret is None:
        interpret = _default_interpret()
    bq_ = min(bq, sq)
    bkv_ = min(bkv, sk)
    pad_q = (-sq) % bq_
    pad_k = (-sk) % bkv_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 scale=scale, bq=bq_, bkv=bkv_,
                                 interpret=bool(interpret))
    return out[:, :, :sq]
