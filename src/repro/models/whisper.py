"""Whisper family: bidirectional audio encoder + causal text decoder.

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, enc_len=1500, d_model) -- the output of
whisper's two conv layers.  The encoder is enc_layers bidirectional
blocks; the decoder stacks self-attention (cached), cross-attention to
the encoder output (K/V cached at prefill), and plain-GELU MLPs
(cfg.mlp_gated=False).  Deviation noted in DESIGN.md: rotary positions
instead of whisper's learned/sinusoidal embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax.numpy as jnp

from repro.models import cache as C
from repro.models import dense as D
from repro.models import layers as L
from repro.models import stack as S
from repro.models.base import ArchConfig, ParamSpec


def dec_specs(cfg: ArchConfig, kind: str) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    out = dict(D.attn_mlp_specs(cfg, "global"))
    out.update({
        "ln_x": ParamSpec((d,), (None,), dt, "zeros"),
        "xq": ParamSpec((d, cfg.q_dim), ("embed", "heads"), dt),
        "xk": ParamSpec((d, cfg.kv_dim), ("embed", "kv"), dt),
        "xv": ParamSpec((d, cfg.kv_dim), ("embed", "kv"), dt),
        "xo": ParamSpec((cfg.q_dim, d), ("heads", "embed"), dt),
    })
    return out


def dec_cache_specs(cfg: ArchConfig, batch: int,
                    max_len: int) -> Dict[str, ParamSpec]:
    out = dict(D.attn_cache_specs(cfg, "global", batch, max_len))
    cross = (batch, cfg.enc_len, cfg.n_kv_heads, cfg.head_dim)
    # layout="cross": written once at prefill from the encoder output,
    # read-only afterwards -- shareable across requests with identical
    # audio (see CACHE_LAYOUTS in models/base.py).
    out["ck"] = ParamSpec(cross, ("batch", None, "kv_heads", "head_dim"),
                          cfg.dtype, "zeros", layout="cross")
    out["cv"] = ParamSpec(cross, ("batch", None, "kv_heads", "head_dim"),
                          cfg.dtype, "zeros", layout="cross")
    return out


def _cross_attend(cfg, p, x, ck, cv):
    b, s, _ = x.shape
    h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, p["xq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    enc_pos = jnp.broadcast_to(
        jnp.arange(ck.shape[1], dtype=jnp.int32), (b, ck.shape[1]))
    out = L.attention(q, ck, cv,
                      q_positions=jnp.zeros((b, s), jnp.int32),
                      k_positions=enc_pos, causal=False)
    return x + jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["xo"])


def dec_apply(cfg, p, x, cache, positions, mode, pos, enc_out):
    """Decoder block: cached self-attn + cross-attn + GELU MLP."""
    # --- causal self attention (ring cached) ---
    window = 0
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = D._qkv(cfg, p, h, positions)
    if mode == "train":
        out = L.attention(q, k, v, q_positions=positions,
                          k_positions=positions, causal=True, window=window)
        new_cache = cache
    elif mode == "prefill":
        self_cache = {k_: cache[k_] for k_ in ("k", "v", "pos")}
        new_self = C.ring_fill(self_cache, {"k": k, "v": v}, positions)
        out = L.attention(q, k, v, q_positions=positions,
                          k_positions=positions, causal=True, window=window)
        new_cache = dict(new_self)
    else:
        self_cache = {k_: cache[k_] for k_ in ("k", "v", "pos")}
        new_self = C.ring_write(self_cache, {"k": k, "v": v}, pos)
        out = L.attention(q, new_self["k"], new_self["v"],
                          q_positions=positions,
                          k_positions=new_self["pos"], causal=True,
                          kv_valid=new_self["pos"] >= 0)
        new_cache = dict(new_self)
    b, s, _, _ = out.shape
    x = x + jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["wo"])

    # --- cross attention ---
    if mode == "train":
        ck = jnp.einsum("bed,dq->beq", enc_out, p["xk"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        cv = jnp.einsum("bed,dq->beq", enc_out, p["xv"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
    elif mode == "prefill":
        ck = jnp.einsum("bed,dq->beq", enc_out, p["xk"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        cv = jnp.einsum("bed,dq->beq", enc_out, p["xv"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        new_cache["ck"], new_cache["cv"] = ck, cv
    else:
        ck, cv = cache["ck"], cache["cv"]
        new_cache["ck"], new_cache["cv"] = ck, cv
    x = _cross_attend(cfg, p, x, ck, cv)

    # --- MLP ---
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + D.mlp_apply(cfg, p, h2)
    return x, (new_cache if mode != "train" else cache)


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def enc_layout(cfg):
    return S.layout_from_kinds(("enc",) * cfg.enc_layers, 1)


def dec_layout(cfg):
    return S.layout_from_kinds(("dec",) * cfg.n_layers, 1)


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "enc_stack": S.stack_specs(
            enc_layout(cfg), lambda kind: D.attn_mlp_specs(cfg, "enc")),
        "ln_enc": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
        "embed": ParamSpec((cfg.vocab, cfg.d_model), (None, "embed"),
                           cfg.dtype),
        "unembed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.dtype),
        "dec_stack": S.stack_specs(
            dec_layout(cfg), functools.partial(dec_specs, cfg)),
        "ln_f": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return S.stack_cache_specs(
        dec_layout(cfg), lambda kind: dec_cache_specs(cfg, batch, max_len))


def encode(params, frames, cfg: ArchConfig):
    b, e, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32), (b, e))
    apply_slot = lambda kind, p, xx, c: D.attn_mlp_apply(
        cfg, "enc", p, xx, c, positions, "train")
    x, _ = S.apply_stack(params["enc_stack"], frames.astype(cfg.dtype),
                         enc_layout(cfg), apply_slot, cache=None,
                         remat=(cfg.remat == "block"))
    return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _run_decoder(cfg, params, x, positions, cache, mode, pos, enc_out):
    apply_slot = lambda kind, p, xx, c: dec_apply(
        cfg, p, xx, c, positions, mode, pos, enc_out)
    x, new_cache = S.apply_stack(params["dec_stack"], x, dec_layout(cfg),
                                 apply_slot, cache=cache,
                                 remat=(cfg.remat == "block"))
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = L.embed(tokens, params["embed"])
    x, _ = _run_decoder(cfg, params, x, positions, None, "train", None,
                        enc_out)
    loss = L.lm_head_loss(x[:, :-1], params["unembed"], tokens[:, 1:],
                          batch.get("loss_mask", None), dist)
    return loss, {"loss": loss}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None):
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x = L.embed(tokens, params["embed"])
    x, cache = _run_decoder(cfg, params, x, positions, cache, "prefill",
                            None, enc_out)
    logits = L.unembed(x[:, -1:], params["unembed"])
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    positions = C.decode_positions(pos, b, 1)
    x = L.embed(tokens, params["embed"])
    x, cache = _run_decoder(cfg, params, x, positions, cache, "decode",
                            pos, None)
    logits = L.unembed(x, params["unembed"])
    return logits[:, 0], cache
