"""DeepSeek-V2 family: MLA attention + fine-grained MoE with shared experts.

MLA (multi-head latent attention) caches only the compressed latent
(kv_lora_rank) plus the decoupled rope key -- 576 values/token for V2 --
and decodes in the *absorbed* form (queries projected into latent space),
so decode reads the small cache instead of materialized per-head K/V.

The routed FFN uses sort-based capacity dispatch inside shard_map:
activations are replicated across the model axis (they already are,
post-TP-all-reduce), every shard selects the tokens routed to its local
experts, computes them, and the combine is a single psum over the model
axis -- expert parallelism with *zero* all-to-all (a TPU-friendly
re-mapping of the usual GPU all-to-all EP; see DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import cache as C
from repro.models import layers as L
from repro.models import stack as S
from repro.models.base import ArchConfig, ParamSpec
from repro.models.dist import DistContext, ensure

ROUTER_AUX_COEF = 1e-3


# ---------------------------------------------------------------------------
# MLA attention
# ---------------------------------------------------------------------------


def mla_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    h, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    kvl = cfg.kv_lora_rank
    out = {
        "ln1": ParamSpec((d,), (None,), dt, "zeros"),
        "w_dkv": ParamSpec((d, kvl), ("embed", None), dt),
        "ln_kv": ParamSpec((kvl,), (None,), dt, "zeros"),
        "w_kpe": ParamSpec((d, dr), ("embed", None), dt),
        "w_uk": ParamSpec((kvl, h, dn), (None, "heads", None), dt),
        "w_uv": ParamSpec((kvl, h, dn), (None, "heads", None), dt),
        "w_o": ParamSpec((h, dn, d), ("heads", None, "embed"), dt),
    }
    if cfg.q_lora_rank:
        out["w_dq"] = ParamSpec((d, cfg.q_lora_rank), ("embed", None), dt)
        out["ln_q"] = ParamSpec((cfg.q_lora_rank,), (None,), dt, "zeros")
        out["w_uq"] = ParamSpec((cfg.q_lora_rank, h, dn + dr),
                                (None, "heads", None), dt)
    else:
        out["w_q"] = ParamSpec((d, h, dn + dr), ("embed", "heads", None), dt)
    return out


def mla_cache_specs(cfg: ArchConfig, batch: int,
                    max_len: int) -> Dict[str, ParamSpec]:
    return {
        "ckv": ParamSpec((batch, max_len, cfg.kv_lora_rank),
                         ("batch", "cache_seq", "kv_lora"), cfg.dtype,
                         "zeros"),
        "kpe": ParamSpec((batch, max_len, cfg.rope_head_dim),
                         ("batch", "cache_seq", None), cfg.dtype, "zeros"),
        "pos": ParamSpec((batch, max_len), ("batch", "cache_seq"),
                         jnp.int32, "zeros"),
    }


def mla_attn(cfg: ArchConfig, p, x, cache, positions, mode, pos=None):
    b, s, _ = x.shape
    h_, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    scale = (dn + dr) ** -0.5
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)

    if cfg.q_lora_rank:
        cq = L.rms_norm(jnp.einsum("bsd,dq->bsq", h, p["w_dq"]), p["ln_q"],
                        cfg.norm_eps)
        q = jnp.einsum("bsq,qhe->bshe", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", h, p["w_q"])
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = L.rope(q_pe, positions, cfg.rope_theta)

    ckv = L.rms_norm(jnp.einsum("bsd,dk->bsk", h, p["w_dkv"]), p["ln_kv"],
                     cfg.norm_eps)
    kpe = L.rope(jnp.einsum("bsd,dr->bsr", h, p["w_kpe"])[:, :, None, :],
                 positions, cfg.rope_theta)[:, :, 0, :]

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsk,khd->bshd", ckv, p["w_uk"])
        vv = jnp.einsum("bsk,khd->bshd", ckv, p["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None, :], (b, s, h_, dr))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = L.attention(q_full, k_full, vv, q_positions=positions,
                          k_positions=positions, causal=True,
                          softmax_scale=scale)
        new_cache = cache
        if mode == "prefill":
            new_cache = C.ring_fill(cache, {"ckv": ckv, "kpe": kpe},
                                    positions)
    else:  # absorbed decode
        new_cache = C.ring_write(cache, {"ckv": ckv, "kpe": kpe}, pos)
        q_c = jnp.einsum("bshd,khd->bshk", q_nope, p["w_uk"])
        q_cat = jnp.concatenate([q_c, q_pe], axis=-1)       # (B,1,H,kvl+dr)
        k_cat = jnp.concatenate([new_cache["ckv"], new_cache["kpe"]],
                                axis=-1)[:, :, None, :]     # (B,L,1,kvl+dr)
        v_lat = new_cache["ckv"][:, :, None, :]             # (B,L,1,kvl)
        ctx = L.attention(q_cat, k_cat, v_lat, q_positions=positions,
                          k_positions=new_cache["pos"], causal=True,
                          kv_valid=new_cache["pos"] >= 0,
                          softmax_scale=scale)              # (B,1,H,kvl)
        out = jnp.einsum("bshk,khd->bshd", ctx, p["w_uv"])

    return x + jnp.einsum("bshd,hdo->bso", out, p["w_o"]), new_cache


# ---------------------------------------------------------------------------
# Routed MoE FFN (shard_map expert parallelism)
# ---------------------------------------------------------------------------


def moe_ffn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt, e, f = cfg.d_model, cfg.dtype, cfg.n_experts, cfg.d_ff
    shared_f = cfg.n_shared_experts * cfg.d_ff
    return {
        "ln2": ParamSpec((d,), (None,), dt, "zeros"),
        "w_router": ParamSpec((d, e), ("embed", None), jnp.float32),
        "we_g": ParamSpec((e, d, f), ("experts", "embed", None), dt),
        "we_u": ParamSpec((e, d, f), ("experts", "embed", None), dt),
        "we_d": ParamSpec((e, f, d), ("experts", None, "embed"), dt),
        "ws_g": ParamSpec((d, shared_f), ("embed", "mlp"), dt),
        "ws_u": ParamSpec((d, shared_f), ("embed", "mlp"), dt),
        "ws_d": ParamSpec((shared_f, d), ("mlp", "embed"), dt),
    }


def moe_ffn(cfg: ArchConfig, p, x, dist: DistContext):
    """Routed experts + shared experts; returns (y, aux_loss)."""
    e, k = cfg.n_experts, cfg.top_k
    b, s, d = x.shape
    e_loc = e // dist.model_size
    assert e_loc * dist.model_size == e, (e, dist.model_size)

    # Router (replicated over the model axis; tokens sharded over batch).
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style load-balance loss.
    frac = jnp.mean(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1, 2))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    t_loc = (b // int(np.prod([dist.mesh.shape[a]
                               for a in dist.batch_axes]))) * s
    cap = max(1, int(np.ceil(cfg.capacity_factor * t_loc * k / e)))
    if s == 1:
        # Decode capacity must never drop a token: serving batches many
        # requests into one step, and their tokens compete for
        # within-expert rank -- a drop the solo (b=1) replay of the
        # same request wouldn't take breaks bit-exact replay.  Each
        # token's top_k experts are distinct, so t_loc bounds the
        # per-expert load; per-token outputs are independent of cap.
        cap = max(cap, t_loc)

    def local_fn(xl, wl, el, wg, wu, wd):
        j = jax.lax.axis_index(dist.model_axis)
        bl = xl.shape[0]
        t = bl * s
        x2 = xl.reshape(t, d)
        fe = el.reshape(t * k)
        fw = wl.reshape(t * k).astype(x2.dtype)
        e0 = j * e_loc
        loc = jnp.where((fe >= e0) & (fe < e0 + e_loc), fe - e0, e_loc)
        order = jnp.argsort(loc)                      # stable
        se = loc[order]
        rank = jnp.arange(t * k) - jnp.searchsorted(se, se, side="left")
        slot = jnp.where((se < e_loc) & (rank < cap), se * cap + rank,
                         e_loc * cap)
        tok = order // k
        buf = jnp.zeros((e_loc * cap + 1, d), x2.dtype).at[slot].set(x2[tok])
        eb = buf[: e_loc * cap].reshape(e_loc, cap, d)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, wg,
                                      preferred_element_type=jnp.float32))
        up = jnp.einsum("ecd,edf->ecf", eb, wu,
                        preferred_element_type=jnp.float32)
        ob = jnp.einsum("ecf,efd->ecd", (gate * up).astype(x2.dtype), wd)
        of = jnp.concatenate(
            [ob.reshape(e_loc * cap, d), jnp.zeros((1, d), x2.dtype)])
        contrib = of[slot] * fw[order][:, None]
        y = jnp.zeros((t, d), x2.dtype).at[tok].add(contrib)
        y = jax.lax.psum(y, dist.model_axis)
        return y.reshape(bl, s, d)

    y = shard_map(
        local_fn, mesh=dist.mesh,
        in_specs=(P(dist.batch_axes, None, None),
                  P(dist.batch_axes, None, None),
                  P(dist.batch_axes, None, None),
                  P(dist.model_axis, None, None),
                  P(dist.model_axis, None, None),
                  P(dist.model_axis, None, None)),
        out_specs=P(dist.batch_axes, None, None),
        check_rep=False,
    )(x, top_w, top_e, p["we_g"], p["we_u"], p["we_d"])
    return y, aux


def dense_ffn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    f = cfg.d_ff_dense or 4 * d
    return {
        "ln2": ParamSpec((d,), (None,), dt, "zeros"),
        "wg": ParamSpec((d, f), ("embed", "mlp"), dt),
        "wu": ParamSpec((d, f), ("embed", "mlp"), dt),
        "wd": ParamSpec((f, d), ("mlp", "embed"), dt),
    }


# ---------------------------------------------------------------------------
# whole-model functions
# ---------------------------------------------------------------------------


def slot_specs(cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    out = dict(mla_specs(cfg))
    if kind == "moe":
        out.update(moe_ffn_specs(cfg))
    else:  # densemlp: deepseek's first layer
        out.update(dense_ffn_specs(cfg))
    return out


def layout(cfg: ArchConfig) -> S.PeriodLayout:
    kinds = ("densemlp",) + ("moe",) * (cfg.n_layers - 1)
    return S.layout_from_kinds(kinds, 1, prefix_len=1)


def slot_apply(cfg, dist, kind, p, x, cache, positions, mode, pos,
               aux_acc=None):
    x, new_cache = mla_attn(cfg, p, x, cache, positions, mode, pos)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        routed, aux = moe_ffn(cfg, p, h2, dist)
        shared = L.gated_mlp(h2, p["ws_g"], p["ws_u"], p["ws_d"])
        x = x + routed + shared
    else:
        aux = jnp.zeros((), jnp.float32)
        x = x + L.gated_mlp(h2, p["wg"], p["wu"], p["wd"])
    return x, new_cache, aux


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), (None, "embed"),
                           cfg.dtype),
        "unembed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.dtype),
        "stack": S.stack_specs(layout(cfg),
                               functools.partial(slot_specs, cfg)),
        "ln_f": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return S.stack_cache_specs(
        layout(cfg), lambda kind: mla_cache_specs(cfg, batch, max_len))


def _run_stack(cfg, dist, params, x, positions, cache, mode, pos=None):
    """The scan carry is (activations, aux-loss accumulator)."""

    def apply_slot(kind, p, carry, c):
        xx, aux_sum = carry
        xx, c_new, aux = slot_apply(cfg, dist, kind, p, xx, c, positions,
                                    mode, pos)
        return (xx, aux_sum + aux), c_new

    (x, aux_total), new_cache = S.apply_stack(
        params["stack"], (x, jnp.zeros((), jnp.float32)), layout(cfg),
        apply_slot, cache=cache, remat=(cfg.remat == "block"))
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), aux_total, new_cache


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    dist = ensure(dist)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = L.embed(tokens, params["embed"])
    x, aux, _ = _run_stack(cfg, dist, params, x, positions, None, "train")
    xent = L.lm_head_loss(x[:, :-1], params["unembed"], tokens[:, 1:],
                          batch.get("loss_mask", None), dist)
    loss = xent + ROUTER_AUX_COEF * aux
    return loss, {"loss": loss, "xent": xent, "router_aux": aux}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None):
    dist = ensure(dist)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x = L.embed(tokens, params["embed"])
    x, _, cache = _run_stack(cfg, dist, params, x, positions, cache,
                             "prefill")
    logits = L.unembed(x[:, -1:], params["unembed"])
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None):
    dist = ensure(dist)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    positions = C.decode_positions(pos, b, 1)
    x = L.embed(tokens, params["embed"])
    x, _, cache = _run_stack(cfg, dist, params, x, positions, cache,
                             "decode", pos=pos)
    logits = L.unembed(x, params["unembed"])
    return logits[:, 0], cache


def routing_frequency(params, tokens, cfg: ArchConfig) -> np.ndarray:
    """Per-expert routing frequency over a probe token batch.

    Cheap criticality probe for expert-weight placement: embeds the
    tokens and runs every MoE layer's router on the *embeddings* (the
    true router input is the post-attention residual; the embedding
    approximation keeps the probe O(tokens * d * e) with no cache or
    attention).  Returns a float64 (n_experts,) vector summing to 1 --
    frequently-routed experts are criticality-tiered into shallower
    (safer) arena tiers, rare experts ride the deep cheap tiers.
    """
    x = L.embed(jnp.asarray(tokens, jnp.int32), params["embed"])
    counts = np.zeros(cfg.n_experts, np.float64)
    groups = [g for c in ("prefix", "periods", "rest")
              for g in params["stack"].get(c, {}).values()]
    for grp in groups:
        if "w_router" not in grp:
            continue
        wr = grp["w_router"]  # (layers, d, e) stacked periods or (d, e)
        if wr.ndim == 2:
            wr = wr[None]
        logits = jnp.einsum("bsd,lde->lbse", x.astype(jnp.float32), wr)
        _, top_e = jax.lax.top_k(logits, cfg.top_k)
        hot = np.asarray(top_e).reshape(-1)
        counts += np.bincount(hot, minlength=cfg.n_experts)
    total = counts.sum()
    return counts / total if total else counts + 1.0 / cfg.n_experts
