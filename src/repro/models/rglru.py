"""RecurrentGemma / Griffin family: RG-LRU recurrent blocks + local attention.

Pattern: (rec, rec, local-attn) repeating -- period-scanned with
heterogeneous slot caches: recurrent slots carry a constant-size state
(B, lru) + conv tail, attention slots a window-sized ring cache.  Decode
cost and state are O(1) in context length, which is why this arch runs
the long_500k cell.

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t*x_t)
is evaluated with jax.lax.associative_scan (log-depth) for train/prefill;
the Pallas kernel in kernels/rglru implements the same contraction with
chunked VMEM tiles for the TPU runtime.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import cache as C
from repro.models import dense as D
from repro.models import layers as L
from repro.models import stack as S
from repro.models.base import ArchConfig, ParamSpec

RGLRU_C = 8.0  # the Griffin paper's fixed recurrence sharpness constant


def rec_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt, r = cfg.d_model, cfg.dtype, cfg.lru_width
    return {
        "ln1": ParamSpec((d,), (None,), dt, "zeros"),
        "w_a": ParamSpec((d, r), ("embed", "mlp"), dt),     # gelu branch
        "w_b": ParamSpec((d, r), ("embed", "mlp"), dt),     # recurrent branch
        "conv_w": ParamSpec((cfg.conv_width, r), (None, "mlp"), dt),
        "conv_b": ParamSpec((r,), ("mlp",), dt, "zeros"),
        "w_rg": ParamSpec((r, r), ("mlp", None), dt),       # recurrence gate
        "b_rg": ParamSpec((r,), (None,), dt, "zeros"),
        "w_ig": ParamSpec((r, r), ("mlp", None), dt),       # input gate
        "b_ig": ParamSpec((r,), (None,), dt, "zeros"),
        # Lambda init => a ~ 0.95 at r_g ~ 0.5 (Griffin's stable-decay init)
        "lam": ParamSpec((r,), (None,), jnp.float32, "const", scale=-4.38),
        "w_out": ParamSpec((r, d), ("mlp", "embed"), dt),
        "ln2": ParamSpec((d,), (None,), dt, "zeros"),
        "wg": ParamSpec((d, cfg.d_ff), ("embed", "mlp"), dt),
        "wu": ParamSpec((d, cfg.d_ff), ("embed", "mlp"), dt),
        "wd": ParamSpec((cfg.d_ff, d), ("mlp", "embed"), dt),
    }


def rec_cache_specs(cfg: ArchConfig, batch: int) -> Dict[str, ParamSpec]:
    r = cfg.lru_width
    return {
        "h": ParamSpec((batch, r), ("batch", "mlp"), jnp.float32, "zeros"),
        "conv": ParamSpec((batch, cfg.conv_width - 1, r),
                          ("batch", None, "mlp"), cfg.dtype, "zeros"),
    }


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv along time.  x: (B,S,R); w: (W,R).

    tail: (B, W-1, R) previous inputs (decode/prefill continuation)."""
    wdt = x.dtype
    width = w.shape[0]
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), wdt)
           if tail is None else tail.astype(wdt))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b, xp[:, -(width - 1):]  # (B,S,R), new tail


def _rglru(y, p, h0):
    """RG-LRU over a sequence.  y: (B,S,R); h0: (B,R) f32."""
    yf = y.astype(jnp.float32)
    r_g = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", yf,
                                    p["w_rg"].astype(jnp.float32))
                         + p["b_rg"].astype(jnp.float32))
    i_g = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", yf,
                                    p["w_ig"].astype(jnp.float32))
                         + p["b_ig"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r_g     # (B,S,R)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_g * yf)

    # h_t = a_t h_{t-1} + gated_t  via associative scan, seeded with h0
    # by folding h0 into the first element.
    gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(prev, nxt):
        a1, b1 = prev
        a2, b2 = nxt
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1]  # (B,S,R) f32, final state


def rec_apply(cfg: ArchConfig, p, x, cache, mode):
    hpre = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    branch_a = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", hpre, p["w_a"]))
    yb = jnp.einsum("bsd,dr->bsr", hpre, p["w_b"])
    tail = cache["conv"] if cache is not None else None
    yb, new_tail = _causal_conv(yb, p["conv_w"], p["conv_b"], tail)
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((x.shape[0], cfg.lru_width), jnp.float32))
    hseq, h_last = _rglru(yb, p, h0)
    merged = branch_a * hseq.astype(x.dtype)
    x = x + jnp.einsum("bsr,rd->bsd", merged, p["w_out"])
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.gated_mlp(h2, p["wg"], p["wu"], p["wd"], act="gelu")
    new_cache = (None if cache is None
                 else {"h": h_last, "conv": new_tail.astype(cfg.dtype)})
    return x, new_cache


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def slot_specs(cfg: ArchConfig, kind: str):
    if kind == "rec":
        return rec_specs(cfg)
    return D.attn_mlp_specs(cfg, kind)   # "local"


def slot_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    if kind == "rec":
        return rec_cache_specs(cfg, batch)
    return D.attn_cache_specs(cfg, kind, batch, max_len)


def layout(cfg: ArchConfig) -> S.PeriodLayout:
    return S.layout_from_kinds(cfg.layer_kinds(), len(cfg.pattern))


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), (None, "embed"),
                           cfg.dtype),
        "unembed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.dtype),
        "stack": S.stack_specs(layout(cfg),
                               functools.partial(slot_specs, cfg)),
        "ln_f": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return S.stack_cache_specs(
        layout(cfg), lambda kind: slot_cache(cfg, kind, batch, max_len))


def _run_stack(cfg, params, x, positions, cache, mode, pos=None):
    def apply_slot(kind, p, xx, c):
        if kind == "rec":
            return rec_apply(cfg, p, xx, c, mode)
        return D.attn_mlp_apply(cfg, kind, p, xx, c, positions, mode, pos)

    x, new_cache = S.apply_stack(params["stack"], x, layout(cfg), apply_slot,
                                 cache=cache, remat=(cfg.remat == "block"))
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = L.embed(tokens, params["embed"]) * jnp.sqrt(float(cfg.d_model)
                                                    ).astype(cfg.dtype)
    x, _ = _run_stack(cfg, params, x, positions, None, "train")
    loss = L.lm_head_loss(x[:, :-1], params["unembed"], tokens[:, 1:],
                          batch.get("loss_mask", None), dist)
    return loss, {"loss": loss}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x = L.embed(tokens, params["embed"]) * jnp.sqrt(float(cfg.d_model)
                                                    ).astype(cfg.dtype)
    x, cache = _run_stack(cfg, params, x, positions, cache, "prefill")
    logits = L.unembed(x[:, -1:], params["unembed"])
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    positions = C.decode_positions(pos, b, 1)
    x = L.embed(tokens, params["embed"]) * jnp.sqrt(float(cfg.d_model)
                                                    ).astype(cfg.dtype)
    x, cache = _run_stack(cfg, params, x, positions, cache, "decode",
                          pos=pos)
    logits = L.unembed(x, params["unembed"])
    return logits[:, 0], cache
