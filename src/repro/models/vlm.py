"""InternVL2 family: ViT frontend (stub) + InternLM2-style dense decoder.

Per the assignment spec the modality frontend is a STUB: ``input_specs``
provides precomputed patch embeddings (B, enc_len, frontend_dim); this
module owns only the projector (ViT width -> d_model) and the language
model.  Image tokens occupy positions [0, enc_len); text follows; loss
is computed on text positions only.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models import cache as C
from repro.models import dense as D
from repro.models import layers as L
from repro.models.base import ArchConfig, ParamSpec


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "lm": D.param_specs(cfg),
        "proj_w": ParamSpec((cfg.frontend_dim, cfg.d_model),
                            ("frontend", "embed"), cfg.dtype),
        "proj_b": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return D.cache_specs(cfg, batch, max_len)


def _embed_multimodal(params, batch, cfg):
    patches = batch["patches"]                       # (B, enc_len, vit_dim)
    tokens = batch["tokens"]                         # (B, S_text)
    img = jnp.einsum("bpv,vd->bpd", patches.astype(cfg.dtype),
                     params["proj_w"]) + params["proj_b"]
    txt = L.embed(tokens, params["lm"]["embed"])
    return jnp.concatenate([img, txt], axis=1)


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    x = _embed_multimodal(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = D._run_stack(cfg, params["lm"], x, positions, None, "train")
    n_img = cfg.enc_len
    tokens = batch["tokens"]
    # hidden at position n_img-1+t predicts text token t
    loss = L.lm_head_loss(x[:, n_img - 1:-1], params["lm"]["unembed"],
                          tokens, batch.get("loss_mask", None), dist)
    return loss, {"loss": loss}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None):
    x = _embed_multimodal(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x, cache = D._run_stack(cfg, params["lm"], x, positions, cache,
                            "prefill")
    logits = L.unembed(x[:, -1:], params["lm"]["unembed"])
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None):
    return D.decode_step(params["lm"], cache, batch, pos, cfg, dist)
