"""Distribution context threaded into model families that use explicit
collectives (shard_map expert parallelism) and, via a trace-time context
variable, into layers that need activation sharding constraints (the
attention core pins q/k/v to a batch-sharded, head-replicated layout so
GSPMD never inserts per-block collectives inside the chunk loops)."""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    rules: Any = None            # launch.sharding.ShardingRules | None

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def activation_sharding(self, shape, leading_batch: bool = True):
        """NamedSharding for an activation tensor (batch-leading).

        Rank-4 tensors are attention activations (B, S, H, D): the
        'attn_act_heads' rule (default: replicate) can shard the head
        dim over the model axis when divisible -- the §Perf lever that
        recovers TP attention for head-rich archs (deepseek's 128 MLA
        heads, llama3-8b's 32)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.sharding import ShardingRules, resolve_spec
        from repro.models.base import ParamSpec
        rules = self.rules if self.rules is not None \
            else ShardingRules.default()
        lead = ("batch",) if leading_batch else (None,)
        if len(shape) == 4:
            axes = lead + (None, "attn_act_heads", None)
        else:
            axes = lead + (None,) * (len(shape) - 1)
        spec = resolve_spec(
            ParamSpec(shape=tuple(shape), axes=axes, dtype=jax.numpy.int32),
            rules, self.mesh)
        return NamedSharding(self.mesh, spec)


_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist", default=None)


@contextlib.contextmanager
def use(dist: Optional["DistContext"]):
    """Make ``dist`` visible to layer internals for the trace duration."""
    token = _CURRENT.set(dist)
    try:
        yield
    finally:
        _CURRENT.reset(token)


def current() -> Optional["DistContext"]:
    return _CURRENT.get()


@functools.lru_cache(maxsize=1)
def local_dist() -> DistContext:
    """1-device mesh for smoke tests / CPU examples."""
    from repro.launch.mesh import make_mesh_auto  # lazy: no models->launch
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    return DistContext(mesh=mesh, batch_axes=("data",), model_axis="model")


def ensure(dist: Optional[DistContext]) -> DistContext:
    return dist if dist is not None else local_dist()
