"""Model substrate: configs, parameter specs, logical-axis sharding.

Flax-free functional modules: every architecture family exposes

    param_specs(cfg)                  -> pytree of ParamSpec
    forward_train(params, batch, cfg) -> (loss, metrics)
    cache_specs(cfg, batch, seq)      -> pytree of ParamSpec (decode state)
    decode_step(params, cache, batch, cfg) -> (logits, cache)

ParamSpec carries *logical axes* (MaxText-style); launch/mesh.py resolves
them to PartitionSpecs through per-arch rule tables, with divisibility
checking and fallbacks.  Dry-runs materialize nothing: specs become
ShapeDtypeStructs and the whole step is lowered AOT.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# Cache-leaf layout kinds (the CacheLayout descriptor).  Every decode-state
# leaf is one of:
#   full    -- ring buffer covering the whole max_len sequence (classic KV)
#   window  -- ring buffer shorter than max_len (sliding-window attention);
#              pages window-modularly: slot(p) = p % window
#   cross   -- written once at prefill, read-only afterwards (encoder K/V
#              of enc-dec models); shareable copy-on-write across requests
#   state   -- slotless carried state (recurrent h/conv, mLSTM matrix
#              memory); faults here are persistent, not per-read
CACHE_LAYOUTS = ("full", "window", "cross", "state")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype/logical-axes/init description of one parameter."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (or None)
    dtype: Any = jnp.bfloat16
    init: str = "normal"                 # normal | zeros | ones | scaled
    scale: float = 1.0
    # cache-leaf layout override (see leaf_layout); None = infer from axes
    layout: Optional[str] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        assert self.layout in (None,) + CACHE_LAYOUTS, self.layout

    @property
    def aval(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def spec_avals(specs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: s.aval, specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# The logical axis naming the ring-buffer slot dimension of decode-state
# leaves.  Injection uses it to map a cache position to the arena words
# a decode step actually wrote (incremental write-path injection) and to
# the K/V rows the fused read-path attention kernel corrupts on load.
CACHE_SLOT_AXIS = "cache_seq"


def cache_slot_axes(specs) -> Any:
    """Per-leaf index of the ring-buffer slot axis, -1 for slotless
    decode state (recurrent/conv states, bookkeeping scalars).  Stacked
    period leaves (leading 'layers' axis) shift automatically because
    the axis is located by name."""
    def ax(s: ParamSpec) -> int:
        return (s.axes.index(CACHE_SLOT_AXIS)
                if CACHE_SLOT_AXIS in s.axes else -1)
    return jax.tree_util.tree_map(
        ax, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def cache_batch_axes(specs) -> Any:
    """Per-leaf index of the serving-batch axis, located by name
    ('batch'), -1 for batch-free bookkeeping leaves.  Stacked period
    leaves (leading 'layers' axis) shift automatically.  The state-
    arena scheduler scatters/slices per-request cache rows along this
    axis -- it is NOT always dim 0 (period-stacked leaves carry the
    layer stack in front)."""
    def ax(s: ParamSpec) -> int:
        return s.axes.index("batch") if "batch" in s.axes else -1
    return jax.tree_util.tree_map(
        ax, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def leaf_layout(spec: ParamSpec, max_len: int) -> str:
    """Layout kind of one cache leaf (see CACHE_LAYOUTS).

    Families may pin a kind explicitly via ParamSpec.layout (whisper's
    encoder K/V is ``cross``); otherwise leaves with a ring-slot axis
    classify as ``full``/``window`` by comparing the ring length against
    ``max_len``, and slotless leaves are carried ``state``.
    """
    if spec.layout is not None:
        return spec.layout
    if CACHE_SLOT_AXIS in spec.axes:
        ln = spec.shape[spec.axes.index(CACHE_SLOT_AXIS)]
        return "full" if ln >= max_len else "window"
    return "state"


def cache_layouts(specs, max_len: int) -> Any:
    """Per-leaf layout kind for a cache-spec tree."""
    return jax.tree_util.tree_map(
        lambda s: leaf_layout(s, max_len), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def ring_lengths(specs) -> Any:
    """Per-leaf ring length (slots along CACHE_SLOT_AXIS), 0 if slotless."""
    def ln(s: ParamSpec) -> int:
        return (s.shape[s.axes.index(CACHE_SLOT_AXIS)]
                if CACHE_SLOT_AXIS in s.axes else 0)
    return jax.tree_util.tree_map(
        ln, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(specs, key) -> Any:
    """Materialize parameters (smoke tests / examples only)."""
    flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for s, k in zip(flat, keys):
        if s.init == "zeros":
            leaves.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            leaves.append(jnp.ones(s.shape, s.dtype))
        elif s.init == "const":
            leaves.append(jnp.full(s.shape, s.scale, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(fan_in, 1))
            leaves.append(
                (jax.random.normal(k, s.shape, jnp.float32) * std
                 ).astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params(specs) -> int:
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) for s in flat)


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact numbers from the public pool)."""

    arch_id: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16

    # layer pattern, e.g. gemma3 5 local : 1 global, recurrentgemma 2 rec :
    # 1 local-attention.  None means all layers identical.
    pattern: Optional[Tuple[str, ...]] = None
    window: int = 0              # sliding-window size for local attention

    # MoE / MLA (deepseek family)
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 2.0
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0       # MLA decoupled rope dims
    d_ff_dense: int = 0          # deepseek layer-0 dense MLP width

    # ssm / hybrid
    conv_width: int = 4
    lru_width: int = 0

    # enc-dec / vlm frontends (stubs provide precomputed embeddings)
    enc_layers: int = 0
    enc_len: int = 0             # whisper: 1500 frames; vlm: image tokens
    frontend_dim: int = 0        # vlm: ViT output width fed to the projector
    mlp_gated: bool = True       # whisper uses plain GELU MLPs

    # training
    remat: str = "block"         # none | block
    seq_len_default: int = 4096

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind strings, honoring the repeating pattern."""
        if self.pattern is None:
            return ("global",) * self.n_layers
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], "ArchBundle"]] = {}


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    """Everything the launcher needs for one architecture."""

    cfg: ArchConfig
    module: Any                       # the family module (dense, moe, ...)
    reduced: Optional[ArchConfig] = None   # smoke-test configuration
    # shape-cell applicability: long_500k only for sub-quadratic families
    skip_cells: Tuple[str, ...] = ()
    skip_reasons: Dict[str, str] = dataclasses.field(default_factory=dict)


def register(arch_id: str, fn: Callable[[], ArchBundle]) -> None:
    _REGISTRY[arch_id] = fn


def get_arch(arch_id: str) -> ArchBundle:
    if arch_id not in _REGISTRY:
        # configs register lazily on import
        import importlib
        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]()


def list_archs() -> Tuple[str, ...]:
    from repro import configs  # noqa: F401  (triggers registration)
    return tuple(sorted(_REGISTRY))
