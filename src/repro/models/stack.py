"""Period-scan layer stacking.

Every assigned arch is a repetition of a short *period* of layer kinds
(uniform transformers: period = 1 global-attention layer; gemma3:
5 local + 1 global; recurrentgemma: 2 recurrent + 1 local-attention;
xlstm: mLSTM + sLSTM).  We scan over full periods -- each slot in the
period has its own parameter stack with a leading ``n_periods`` dim --
and unroll the remainder layers.  This keeps the HLO compact (one scan
body per arch regardless of depth: tractable 512-device compiles) while
letting heterogeneous slots carry *differently shaped* params and caches
(e.g. window-sized KV caches for local slots, full-length for global).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamSpec


@dataclasses.dataclass(frozen=True)
class PeriodLayout:
    slots: Tuple[str, ...]        # layer kind per slot within the period
    n_periods: int
    remainder: Tuple[str, ...]    # trailing layers that don't fill a period
    prefix: Tuple[str, ...] = ()  # leading layers before the periodic part
    # (e.g. deepseek-v2's dense-MLP first layer)

    @property
    def n_layers(self) -> int:
        return (len(self.prefix) + len(self.slots) * self.n_periods
                + len(self.remainder))


def layout_from_kinds(kinds: Tuple[str, ...], period_len: int,
                      prefix_len: int = 0) -> PeriodLayout:
    prefix = tuple(kinds[:prefix_len])
    body = kinds[prefix_len:]
    n_periods = len(body) // period_len
    return PeriodLayout(slots=tuple(body[:period_len]),
                        n_periods=n_periods,
                        remainder=tuple(body[period_len * n_periods:]),
                        prefix=prefix)


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec(shape=(n,) + spec.shape, axes=("layers",) + spec.axes,
                     dtype=spec.dtype, init=spec.init, scale=spec.scale,
                     layout=spec.layout)


def stack_specs(layout: PeriodLayout,
                slot_specs: Callable[[str], Any]) -> Dict[str, Any]:
    """Parameter specs for the whole stack.

    slot_specs(kind) -> pytree[ParamSpec] for one layer of that kind.
    """
    periods = {
        f"s{i}_{kind}": jax.tree_util.tree_map(
            lambda s: _stack_spec(s, layout.n_periods), slot_specs(kind),
            is_leaf=lambda x: isinstance(x, ParamSpec))
        for i, kind in enumerate(layout.slots)
    }
    rest = {f"r{i}_{kind}": slot_specs(kind)
            for i, kind in enumerate(layout.remainder)}
    pre = {f"p{i}_{kind}": slot_specs(kind)
           for i, kind in enumerate(layout.prefix)}
    return {"prefix": pre, "periods": periods, "rest": rest}


def stack_cache_specs(layout: PeriodLayout,
                      slot_cache: Callable[[str], Any]) -> Dict[str, Any]:
    """Decode-state specs mirroring the parameter layout."""
    periods = {
        f"s{i}_{kind}": jax.tree_util.tree_map(
            lambda s: _stack_spec(s, layout.n_periods), slot_cache(kind),
            is_leaf=lambda x: isinstance(x, ParamSpec))
        for i, kind in enumerate(layout.slots)
    }
    rest = {f"r{i}_{kind}": slot_cache(kind)
            for i, kind in enumerate(layout.remainder)}
    pre = {f"p{i}_{kind}": slot_cache(kind)
           for i, kind in enumerate(layout.prefix)}
    return {"prefix": pre, "periods": periods, "rest": rest}


def apply_stack(
    params: Dict[str, Any],
    x: jax.Array,
    layout: PeriodLayout,
    apply_slot: Callable[..., Any],   # (kind, params, x, cache) -> (x, cache)
    cache: Optional[Dict[str, Any]] = None,
    remat: bool = True,
    with_slot_ref: bool = False,
):
    """Run the full layer stack; threads per-layer caches if given.

    apply_slot(kind, slot_params, x, slot_cache) must return
    (new_x, new_slot_cache); slot_cache is None when cache is None.

    ``with_slot_ref``: apply_slot additionally receives ``(key, idx)``
    -- its slot key (e.g. ``"s0_global"``) and, for periodic slots, the
    traced period index of the layer being applied (None for
    prefix/remainder layers).  Consumers that address per-layer slices
    of the period-stacked cache leaves (the read-path injection context)
    need both to locate a layer inside its stacked leaf.

    ``remat`` only applies where gradients can flow: threading a cache
    means prefill/decode, where checkpointing would just insert
    materialization barriers into the inference path -- it is ignored
    there for every family.
    """
    remat = remat and cache is None
    slots = layout.slots

    def _call(kind, key, idx, p, x, c):
        if with_slot_ref:
            return apply_slot(kind, p, x, c, (key, idx))
        return apply_slot(kind, p, x, c)

    def period_body(x, period_params, period_cache, pidx=None):
        new_cache = {}
        for i, kind in enumerate(slots):
            key = f"s{i}_{kind}"
            c = period_cache[key] if period_cache is not None else None
            x, c_new = _call(kind, key, pidx, period_params[key], x, c)
            new_cache[key] = c_new
        return x, (new_cache if period_cache is not None else None)

    if remat:
        period_body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable)

    def apply_single(x, key, kind, params_d, cache_d):
        c = cache_d[key] if cache_d is not None else None
        body = functools.partial(_call, kind, key, None)
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return body(params_d[key], x, c)

    new_prefix = {}
    for i, kind in enumerate(layout.prefix):
        key = f"p{i}_{kind}"
        x, c_new = apply_single(x, key, kind, params["prefix"],
                                cache["prefix"] if cache is not None
                                else None)
        new_prefix[key] = c_new

    if layout.n_periods > 0:
        pidx = jnp.arange(layout.n_periods, dtype=jnp.int32)
        if cache is None:
            x, _ = jax.lax.scan(
                lambda x, xs: (period_body(x, xs[0], None, xs[1])[0], None),
                x, (params["periods"], pidx))
            new_period_cache = None
        else:
            def scan_fn(x, xs):
                p, c, i = xs
                return period_body(x, p, c, i)
            x, new_period_cache = jax.lax.scan(
                scan_fn, x, (params["periods"], cache["periods"], pidx))
    else:
        new_period_cache = {} if cache is not None else None

    new_rest = {}
    for i, kind in enumerate(layout.remainder):
        key = f"r{i}_{kind}"
        x, c_new = apply_single(x, key, kind, params["rest"],
                                cache["rest"] if cache is not None else None)
        new_rest[key] = c_new

    if cache is None:
        return x, None
    return x, {"prefix": new_prefix, "periods": new_period_cache,
               "rest": new_rest}
