"""xLSTM family: alternating mLSTM (matrix memory) and sLSTM blocks.

mLSTM is evaluated in *chunked* form -- linear-attention math inside a
chunk, a (B, H, Dk, Dv) matrix-memory state carried between chunks -- so
train/prefill cost is O(S * chunk) and decode state is O(1) in context
(this arch runs the long_500k cell).  Gating follows the xLSTM design
with a simplification recorded in DESIGN.md: sigmoid input/forget gates
(GLA-style) instead of the paper's exponential-gate + stabilizer in the
chunked path; the sLSTM path keeps the exact stabilized exponential
gating since it is evaluated step-recurrently anyway.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import stack as S
from repro.models.base import ArchConfig, ParamSpec

CHUNK = 128


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    di = d                      # inner width (projection factor 2 -> 2*di up)
    h = cfg.n_heads
    return {
        "ln": ParamSpec((d,), (None,), dt, "zeros"),
        "w_up": ParamSpec((d, 2 * di), ("embed", "mlp"), dt),
        "conv_w": ParamSpec((cfg.conv_width, di), (None, "mlp"), dt),
        "conv_b": ParamSpec((di,), ("mlp",), dt, "zeros"),
        "w_q": ParamSpec((di, di), ("mlp", "heads"), dt),
        "w_k": ParamSpec((di, di), ("mlp", "heads"), dt),
        "w_v": ParamSpec((di, di), ("mlp", "heads"), dt),
        "w_ig": ParamSpec((di, h), ("mlp", None), dt),
        "b_ig": ParamSpec((h,), (None,), dt, "zeros"),
        "w_fg": ParamSpec((di, h), ("mlp", None), dt),
        # forget-gate bias init +3 => decay ~0.95: stable long memory
        "b_fg": ParamSpec((h,), (None,), dt, "const", scale=3.0),
        "w_down": ParamSpec((di, d), ("mlp", "embed"), dt),
    }


def mlstm_cache_specs(cfg: ArchConfig, batch: int) -> Dict[str, ParamSpec]:
    di = cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {
        "mem": ParamSpec((batch, h, dh, dh), ("batch", "heads", None, None),
                         jnp.float32, "zeros"),
        "norm": ParamSpec((batch, h, dh), ("batch", "heads", None),
                          jnp.float32, "zeros"),
        "conv": ParamSpec((batch, cfg.conv_width - 1, di),
                          ("batch", None, "mlp"), cfg.dtype, "zeros"),
    }


def _causal_conv(x, w, b, tail):
    width = w.shape[0]
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
           if tail is None else tail.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b, xp[:, -(width - 1):]


def _mlstm_chunked(q, k, v, i_g, f_g, mem0, n0):
    """Chunked gated linear attention.

    q/k/v: (B, S, H, Dh); i_g/f_g: (B, S, H) in (0,1);
    mem0: (B, H, Dh, Dh); n0: (B, H, Dh).  Returns (out, mem, n).
    """
    b, s, h, dh = q.shape
    nc = -(-s // CHUNK)
    pad = nc * CHUNK - s
    if pad:
        q, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for x in (q, k, v))
        i_g = jnp.pad(i_g, ((0, 0), (0, pad), (0, 0)))
        f_g = jnp.pad(f_g, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)

    def resh(x):
        return x.reshape(b, nc, CHUNK, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(resh, (q, k, v, i_g, f_g))

    def body(carry, xs):
        mem, n = carry                        # (B,H,Dh,Dh) f32, (B,H,Dh)
        qb, kb, vb, ib, fb = xs               # (B,C,H,*)
        fb = fb.astype(jnp.float32)
        ib = ib.astype(jnp.float32)
        logf = jnp.log(jnp.maximum(fb, 1e-6))
        acc = jnp.cumsum(logf, axis=1)        # (B,C,H) log prod f_1..f_t
        a_inc = jnp.exp(acc)                  # inclusive decay
        a_tot = jnp.exp(acc[:, -1])           # (B,H)
        qf = qb.astype(jnp.float32) * a_inc[..., None]
        kf = kb.astype(jnp.float32) * (ib / jnp.maximum(a_inc, 1e-30)
                                       )[..., None]
        vf = vb.astype(jnp.float32)
        # intra-chunk scores with causal mask
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        scores = jnp.where(mask, scores, 0.0)
        intra = jnp.einsum("bhqk,bkhd->bqhd", scores, vf)
        inter = jnp.einsum("bqhd,bhde->bqhe", qf, mem)
        # mLSTM normalizer: |q . n_t| with n_t = decay*n + cumulative k mass
        denom = jnp.sum(scores, axis=-1).swapaxes(1, 2)      # (B,C,H)
        denom = denom + jnp.einsum("bqhd,bhd->bqh", qf, n)
        out = (intra + inter) / jnp.maximum(
            jnp.abs(denom)[..., None], 1.0)
        # state update
        kw = kb.astype(jnp.float32) * (ib * (a_tot[:, None]
                                             / jnp.maximum(a_inc, 1e-30))
                                       )[..., None]
        mem_new = mem * a_tot[..., None, None] + jnp.einsum(
            "bkhd,bkhe->bhde", kw, vf)
        n_new = n * a_tot[..., None] + jnp.einsum("bkhd->bhd", kw)
        return (mem_new, n_new), out

    (mem, n), outs = jax.lax.scan(body, (mem0, n0), (qc, kc, vc, ic, fc))
    out = outs.swapaxes(0, 1).reshape(b, nc * CHUNK, h, dh)[:, :s]
    return out, mem, n


def mlstm_apply(cfg: ArchConfig, p, x, cache, mode):
    b, s, d = x.shape
    h = cfg.n_heads
    di = d
    dh = di // h
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn, p["w_up"])
    xm, z = up[..., :di], up[..., di:]
    tail = cache["conv"] if cache is not None else None
    xm, new_tail = _causal_conv(xm, p["conv_w"], p["conv_b"], tail)
    xm = jax.nn.silu(xm)

    q = jnp.einsum("bse,ef->bsf", xm, p["w_q"]).reshape(b, s, h, dh)
    k = jnp.einsum("bse,ef->bsf", xm, p["w_k"]).reshape(b, s, h, dh) \
        * (dh ** -0.5)
    v = jnp.einsum("bse,ef->bsf", xm, p["w_v"]).reshape(b, s, h, dh)
    i_g = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", xm, p["w_ig"])
                         + p["b_ig"])
    f_g = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", xm, p["w_fg"])
                         + p["b_fg"])

    mem0 = (cache["mem"] if cache is not None
            else jnp.zeros((b, h, dh, dh), jnp.float32))
    n0 = (cache["norm"] if cache is not None
          else jnp.zeros((b, h, dh), jnp.float32))
    out, mem, n = _mlstm_chunked(q, k, v, i_g, f_g, mem0, n0)

    out = out.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    x = x + jnp.einsum("bse,ed->bsd", out, p["w_down"])
    new_cache = (None if cache is None else
                 {"mem": mem, "norm": n, "conv": new_tail.astype(cfg.dtype)})
    return x, new_cache


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    h = cfg.n_heads
    dh = d // h
    f_mlp = int(4 * d / 3)
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = ParamSpec((d, d), ("embed", "mlp"), dt)
        gates[f"r_{g}"] = ParamSpec((h, dh, dh), ("heads", None, None), dt)
        gates[f"b_{g}"] = ParamSpec(
            (d,), (None,), dt, "const" if g == "f" else "zeros",
            scale=3.0 if g == "f" else 1.0)
    return {
        "ln": ParamSpec((d,), (None,), dt, "zeros"),
        **gates,
        "w_out": ParamSpec((d, d), ("mlp", "embed"), dt),
        "ln2": ParamSpec((d,), (None,), dt, "zeros"),
        "wg": ParamSpec((d, f_mlp), ("embed", "mlp"), dt),
        "wu": ParamSpec((d, f_mlp), ("embed", "mlp"), dt),
        "wd": ParamSpec((f_mlp, d), ("mlp", "embed"), dt),
    }


def slstm_cache_specs(cfg: ArchConfig, batch: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {k: ParamSpec((batch, d), ("batch", "mlp"), jnp.float32, "zeros")
            for k in ("h", "c", "n", "m")}


def _slstm_scan(xz, xi, xf, xo, p, state, n_heads):
    """Stabilized exponential-gating sLSTM recurrence over time.

    x?: (B, S, D) preactivations (input contributions); state: dict of
    (B, D) f32.  Block-diagonal recurrent weights per head.
    """
    b, s, d = xz.shape
    dh = d // n_heads

    def rmat(name):
        return p[name].astype(jnp.float32)

    def step(st, xs):
        z_x, i_x, f_x, o_x = xs               # (B, D) each
        h, c, n, m = st["h"], st["c"], st["n"], st["m"]
        hh = h.reshape(b, n_heads, dh)

        def rec(name):
            return jnp.einsum("bhd,hde->bhe", hh,
                              rmat(name)).reshape(b, d)

        z = jnp.tanh(z_x + rec("r_z"))
        o = jax.nn.sigmoid(o_x + rec("r_o"))
        i_t = i_x + rec("r_i")
        f_t = f_x + rec("r_f")
        # stabilizer (xLSTM eq. 15-17)
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * (c_new / jnp.maximum(jnp.abs(n_new), 1.0))
        return ({"h": h_new, "c": c_new, "n": n_new, "m": m_new}, h_new)

    xs = tuple(x.astype(jnp.float32).swapaxes(0, 1) for x in (xz, xi, xf, xo))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.swapaxes(0, 1), state           # (B,S,D) f32


def slstm_apply(cfg: ArchConfig, p, x, cache, mode):
    b, s, d = x.shape
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    pre = {g: jnp.einsum("bsd,de->bse", xn, p[f"w_{g}"]) + p[f"b_{g}"]
           for g in ("z", "i", "f", "o")}
    state = (cache if cache is not None else
             {k: jnp.zeros((b, d), jnp.float32) for k in
              ("h", "c", "n", "m")})
    state = {k: state[k] for k in ("h", "c", "n", "m")}
    hs, new_state = _slstm_scan(pre["z"], pre["i"], pre["f"], pre["o"],
                                p, state, cfg.n_heads)
    x = x + jnp.einsum("bsd,de->bse", hs.astype(x.dtype), p["w_out"])
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.gated_mlp(h2, p["wg"], p["wu"], p["wd"], act="gelu")
    return x, (new_state if cache is not None else None)


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def slot_specs(cfg: ArchConfig, kind: str):
    return mlstm_specs(cfg) if kind == "mlstm" else slstm_specs(cfg)


def slot_cache(cfg: ArchConfig, kind: str, batch: int):
    return (mlstm_cache_specs(cfg, batch) if kind == "mlstm"
            else slstm_cache_specs(cfg, batch))


def layout(cfg: ArchConfig) -> S.PeriodLayout:
    return S.layout_from_kinds(cfg.layer_kinds(), len(cfg.pattern))


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), (None, "embed"),
                           cfg.dtype),
        "unembed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.dtype),
        "stack": S.stack_specs(layout(cfg),
                               functools.partial(slot_specs, cfg)),
        "ln_f": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    del max_len  # state size is context-independent (the ssm advantage)
    return S.stack_cache_specs(
        layout(cfg), lambda kind: slot_cache(cfg, kind, batch))


def _run_stack(cfg, params, x, cache, mode):
    def apply_slot(kind, p, xx, c):
        if kind == "mlstm":
            return mlstm_apply(cfg, p, xx, c, mode)
        return slstm_apply(cfg, p, xx, c, mode)

    x, new_cache = S.apply_stack(params["stack"], x, layout(cfg), apply_slot,
                                 cache=cache, remat=(cfg.remat == "block"))
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"])
    x, _ = _run_stack(cfg, params, x, None, "train")
    loss = L.lm_head_loss(x[:, :-1], params["unembed"], tokens[:, 1:],
                          batch.get("loss_mask", None), dist)
    return loss, {"loss": loss}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None):
    from repro.models import cache as C
    tokens = batch["tokens"]
    b = tokens.shape[0]
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x = L.embed(tokens, params["embed"])
    x, cache = _run_stack(cfg, params, x, cache, "prefill")
    logits = L.unembed(x[:, -1:], params["unembed"])
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"])
    x, cache = _run_stack(cfg, params, x, cache, "decode")
    logits = L.unembed(x, params["unembed"])
    return logits[:, 0], cache
