"""Generic ring-buffer cache helpers (KV caches, MLA compressed caches).

A cache is a dict with a ``pos`` int32 array (B, L) recording the
absolute position stored in each slot (-1 = empty) plus any number of
value arrays with the slot axis at dim 1.  Slot for position p is
p % L, so full-length caches (L = max_len) behave like plain caches and
window caches (L = window) roll over -- one code path for both.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ParamSpec


def init_cache(specs) -> Any:
    def mk(s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree_util.tree_map(
        mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def ring_fill(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
              positions: jax.Array) -> Dict[str, jax.Array]:
    """Prefill: write a full sequence; keeps the last L entries."""
    ln = cache["pos"].shape[1]
    s = positions.shape[1]
    out = {}
    if s >= ln:
        # slots for the kept tail are a static rotation of 0..L-1
        slots = np.arange(s - ln, s) % ln
        inv = np.argsort(slots)
        for k, arr in new.items():
            out[k] = arr[:, -ln:][:, inv]
        out["pos"] = positions[:, -ln:][:, inv]
    else:
        for k, arr in new.items():
            start = (0,) * arr.ndim
            out[k] = jax.lax.dynamic_update_slice(cache[k], arr, start)
        out["pos"] = jax.lax.dynamic_update_slice(cache["pos"], positions,
                                                  (0, 0))
    return out


def ring_update(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
                pos: jax.Array) -> Dict[str, jax.Array]:
    """Decode: write one token at slot pos % L."""
    ln = cache["pos"].shape[1]
    slot = pos % ln
    out = {}
    for k, arr in new.items():
        start = (0, slot) + (0,) * (arr.ndim - 2)
        out[k] = jax.lax.dynamic_update_slice(cache[k], arr, start)
    b = cache["pos"].shape[0]
    out["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"],
        jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32), (0, slot))
    return out


def ring_update_rows(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
                     pos: jax.Array) -> Dict[str, jax.Array]:
    """Per-row twin of :func:`ring_update` for serving-slot batches.

    ``pos`` is an (B,) int32 vector -- each batch row writes its own
    ring slot ``pos[b] % L``.  Rows with a negative position (inactive
    serving slots, chunk padding) are left untouched, so one traced
    program serves lanes at heterogeneous positions.  ``new`` entries
    are (B, 1, ...) single-token chunks like :func:`ring_update`.
    """
    ln = cache["pos"].shape[1]
    qp = jnp.reshape(pos, (-1,)).astype(jnp.int32)
    valid = qp >= 0
    slot = jnp.where(valid, qp, 0) % ln
    b = jnp.arange(qp.shape[0])
    out = {}
    for k, arr in new.items():
        row = arr[:, 0]
        keep = cache[k][b, slot]
        vmask = jnp.reshape(valid, (-1,) + (1,) * (row.ndim - 1))
        out[k] = cache[k].at[b, slot].set(jnp.where(vmask, row, keep))
    out["pos"] = cache["pos"].at[b, slot].set(
        jnp.where(valid, qp, cache["pos"][b, slot]))
    return out


def ring_write(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
               pos: jax.Array) -> Dict[str, jax.Array]:
    """Decode ring write that accepts either a scalar position (solo
    decode, every row at the same step) or a (B,) per-row vector
    (state-arena serving slots at heterogeneous positions)."""
    if jnp.ndim(pos) == 0:
        return ring_update(cache, new, pos)
    return ring_update_rows(cache, new, pos)


def decode_positions(pos: jax.Array, b: int, c: int) -> jax.Array:
    """(B, C) query-position grid for a decode step from a scalar or a
    (B,) per-row position.  ``broadcast_to(pos, (b, c))`` only handles
    the scalar case -- a (B,) vector must expand along a new token
    axis, not the batch axis."""
    qp = jnp.asarray(pos, jnp.int32)
    if qp.ndim == 0:
        return jnp.broadcast_to(qp, (b, c))
    if qp.ndim == 1:
        return jnp.broadcast_to(jnp.reshape(qp, (b, 1)), (b, c))
    return qp  # already a (B, C) per-token grid (mixed serving step)


def paged_update(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
                 pos: jax.Array, page_table: jax.Array, length: int,
                 page_slots: int, wstart: jax.Array = None,
                 scratch_id: int = None) -> Dict[str, jax.Array]:
    """Paged twin of :func:`ring_update`: a chunk of tokens per serving
    slot, scattered into a shared page pool.

    ``cache`` holds pool buffers with the *page* axis at dim 0 and the
    within-page slot axis at dim 1 (``pos``: (num_pages, page_slots);
    values: (num_pages, page_slots, ...)).  ``new`` entries are
    (S, C, ...) per-slot token chunks (decode steps use C=1), ``pos``
    is the (S,) or (S, C) absolute position per token, and
    ``page_table`` (S, length//page_slots) maps each slot's logical
    ring page to its physical pool page.  Slot for position p is
    p % length, exactly like the contiguous ring -- inactive serving
    slots' page-table rows point at the pool's scratch page, so their
    writes land in the sink.

    Tokens whose position is negative (chunk padding past the prompt)
    or below ``wstart`` (per-slot write floor: positions already held
    by copy-on-write shared prefix pages must never be rewritten) are
    redirected to the ``scratch_id`` sink page instead of written.
    """
    qp = pos.astype(jnp.int32)
    if qp.ndim == 1:
        qp = qp[:, None]
    valid = qp >= 0
    if wstart is not None:
        valid &= qp >= jnp.reshape(wstart, (-1, 1)).astype(jnp.int32)
    slot = jnp.where(valid, qp, 0) % length
    lp = slot // page_slots
    row = jnp.where(valid, slot % page_slots, 0)
    pid = jnp.take_along_axis(page_table, lp, axis=1)
    if scratch_id is not None:
        pid = jnp.where(valid, pid, scratch_id)
    out = {}
    for k, arr in new.items():
        out[k] = cache[k].at[pid, row].set(arr[:, :qp.shape[1]])
    out["pos"] = cache["pos"].at[pid, row].set(qp)
    return out
