"""Generic ring-buffer cache helpers (KV caches, MLA compressed caches).

A cache is a dict with a ``pos`` int32 array (B, L) recording the
absolute position stored in each slot (-1 = empty) plus any number of
value arrays with the slot axis at dim 1.  Slot for position p is
p % L, so full-length caches (L = max_len) behave like plain caches and
window caches (L = window) roll over -- one code path for both.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ParamSpec


def init_cache(specs) -> Any:
    def mk(s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree_util.tree_map(
        mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def ring_fill(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
              positions: jax.Array) -> Dict[str, jax.Array]:
    """Prefill: write a full sequence; keeps the last L entries."""
    ln = cache["pos"].shape[1]
    s = positions.shape[1]
    out = {}
    if s >= ln:
        # slots for the kept tail are a static rotation of 0..L-1
        slots = np.arange(s - ln, s) % ln
        inv = np.argsort(slots)
        for k, arr in new.items():
            out[k] = arr[:, -ln:][:, inv]
        out["pos"] = positions[:, -ln:][:, inv]
    else:
        for k, arr in new.items():
            start = (0,) * arr.ndim
            out[k] = jax.lax.dynamic_update_slice(cache[k], arr, start)
        out["pos"] = jax.lax.dynamic_update_slice(cache["pos"], positions,
                                                  (0, 0))
    return out


def ring_update(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
                pos: jax.Array) -> Dict[str, jax.Array]:
    """Decode: write one token at slot pos % L."""
    ln = cache["pos"].shape[1]
    slot = pos % ln
    out = {}
    for k, arr in new.items():
        start = (0, slot) + (0,) * (arr.ndim - 2)
        out[k] = jax.lax.dynamic_update_slice(cache[k], arr, start)
    b = cache["pos"].shape[0]
    out["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"],
        jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32), (0, slot))
    return out


def paged_update(cache: Dict[str, jax.Array], new: Dict[str, jax.Array],
                 pos: jax.Array, page_table: jax.Array, length: int,
                 page_slots: int) -> Dict[str, jax.Array]:
    """Paged twin of :func:`ring_update`: one decode token per serving
    slot, scattered into a shared page pool.

    ``cache`` holds pool buffers with the *page* axis at dim 0 and the
    within-page slot axis at dim 1 (``pos``: (num_pages, page_slots);
    values: (num_pages, page_slots, ...)).  ``new`` entries are
    (S, 1, ...) per-slot tokens, ``pos`` is the (S,) or (S, 1) absolute
    position per serving slot, and ``page_table`` (S, length//page_slots)
    maps each slot's logical ring page to its physical pool page.  Slot
    for position p is p % length, exactly like the contiguous ring --
    inactive serving slots' page-table rows point at the pool's scratch
    page, so their writes land in the sink.
    """
    qp = jnp.reshape(pos, (-1,)).astype(jnp.int32)
    slot = qp % length
    lp = slot // page_slots
    row = slot % page_slots
    pid = jnp.take_along_axis(page_table, lp[:, None], axis=1)[:, 0]
    out = {}
    for k, arr in new.items():
        out[k] = cache[k].at[pid, row].set(arr[:, 0])
    out["pos"] = cache["pos"].at[pid, row].set(qp)
    return out
