"""Shared neural layers: RMSNorm, RoPE, chunked attention, gated MLP.

Attention is *blockwise* (streaming softmax over KV chunks, optionally
over Q chunks too), so no O(S^2) score tensor is ever materialized --
this is what lets the 32k prefill and 500k decode cells lower with sane
memory footprints on the production mesh.  The Pallas flash-attention
kernel shares its reference math with this implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding; x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions (..., S) -> angles (..., S, 1, half), broadcast over heads
    angles = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _chunk_mask(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) additive mask from absolute positions."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                  jnp.float32)
    delta = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = jnp.where(delta < 0, NEG_INF, m)
    if window > 0:
        m = jnp.where(delta >= window, NEG_INF, m)
    return m


def attention(q, k, v, *, q_positions, k_positions, causal: bool = True,
              window: int = 0, kv_valid: Optional[jax.Array] = None,
              q_chunk: int = 1024, kv_chunk: int = 1024,
              softmax_scale: Optional[float] = None):
    """Blockwise multi-head attention with GQA and a flash-style VJP.

    q: (B, Sq, H, D); k, v: (B, Sk, K, Dk/Dv) with H % K == 0 (Dv may
    differ from Dk: MLA absorbed decode).
    q_positions: (B, Sq) absolute positions; k_positions: (B, Sk).
    kv_valid: optional (B, Sk) bool -- False entries are masked out
    (ring-buffer caches, padding).

    Streams KV in chunks with a running softmax; never forms (Sq, Sk).
    The custom VJP saves only (out, logsumexp) and *recomputes*
    probability blocks in the backward pass -- the memory-efficient
    (flash) attention algorithm, which is also what the Pallas kernel
    implements for the TPU runtime.
    """
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    if kv_valid is None:
        kv_valid = jnp.ones((b, sk), bool)

    # Pin attention activations to a batch-sharded, head-replicated
    # layout: GQA head counts rarely divide the model axis, and letting
    # GSPMD keep head_dim sharded makes it all-reduce every score block
    # inside the chunk loops (measured 5.8 TB/chip on llama3.2 train --
    # see EXPERIMENTS.md §Perf).  Head-replication costs redundant
    # attention FLOPs on the model axis instead; recovering them is a
    # hillclimb lever (head padding / ring attention).
    from repro.models import dist as _dist
    dctx = _dist.current()
    if dctx is not None:
        cons = jax.lax.with_sharding_constraint
        q = cons(q, dctx.activation_sharding(q.shape))
        k = cons(k, dctx.activation_sharding(k.shape))
        v = cons(v, dctx.activation_sharding(v.shape))

    cfg = (bool(causal), int(window), int(min(q_chunk, sq)),
           int(min(kv_chunk, sk)), float(scale), h // kh)
    out = _attention_cvjp(cfg, q, k, v, q_positions, k_positions, kv_valid)
    if dctx is not None:
        out = jax.lax.with_sharding_constraint(
            out, dctx.activation_sharding(out.shape))
    return out


def _pad_time(x, n, value=0):
    if n == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[1] = (0, n)
    return jnp.pad(x, pads, constant_values=value)


def _attention_fwd_impl(cfg, q, k, v, q_positions, k_positions, kv_valid):
    causal, window, q_chunk, kv_chunk, scale, g = cfg
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    dv = v.shape[-1]
    qs = (q.astype(jnp.float32) * scale).reshape(b, sq, kh, g, d)

    n_kv = -(-sk // kv_chunk)
    pad_k = n_kv * kv_chunk - sk
    k_ = _pad_time(k, pad_k)
    v_ = _pad_time(v, pad_k)
    kp = _pad_time(k_positions, pad_k, np.iinfo(np.int32).max)
    vm = _pad_time(kv_valid, pad_k, False)
    kc = k_.reshape(b, n_kv, kv_chunk, kh, d).swapaxes(0, 1)
    vc = v_.reshape(b, n_kv, kv_chunk, kh, dv).swapaxes(0, 1)
    kpc = kp.reshape(b, n_kv, kv_chunk).swapaxes(0, 1)
    vmc = vm.reshape(b, n_kv, kv_chunk).swapaxes(0, 1)

    def process_q_chunk(args):
        q_blk, qpos_blk = args              # (B, Cq, K, G, D), (B, Cq)
        cq = q_blk.shape[1]
        acc0 = jnp.zeros((b, cq, kh, g, dv), jnp.float32)
        m0 = jnp.full((b, cq, kh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cq, kh, g), jnp.float32)

        def body(carry, inputs):
            acc, m, l = carry
            k_blk, v_blk, kp_blk, vm_blk = inputs
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_blk,
                           k_blk.astype(jnp.float32))
            mask = _chunk_mask(qpos_blk, kp_blk, causal, window)
            s = s + mask[:, :, None, None, :]
            s = jnp.where(vm_blk[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, v_blk.astype(jnp.float32))
            l = l * corr + jnp.sum(p, axis=-1)
            return (acc, m_new, l), None

        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                      (kc, vc, kpc, vmc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # rows with no unmasked kv get lse=+big so the bwd recompute
        # yields p == 0 for them
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        return out, lse

    if sq <= q_chunk:
        out, lse = process_q_chunk((qs, q_positions))
    else:
        n_q = -(-sq // q_chunk)
        pad_q = n_q * q_chunk - sq
        qq = _pad_time(qs, pad_q).reshape(
            b, n_q, q_chunk, kh, g, d).swapaxes(0, 1)
        qp = _pad_time(q_positions, pad_q).reshape(
            b, n_q, q_chunk).swapaxes(0, 1)
        out, lse = jax.lax.map(process_q_chunk, (qq, qp))
        out = out.swapaxes(0, 1).reshape(b, -1, kh, g, dv)[:, :sq]
        lse = lse.swapaxes(0, 1).reshape(b, -1, kh, g)[:, :sq]

    return out.reshape(b, sq, h, dv).astype(v.dtype), lse


def _attn_fwd(cfg, q, k, v, q_positions, k_positions, kv_valid):
    out, lse = _attention_fwd_impl(cfg, q, k, v, q_positions, k_positions,
                                   kv_valid)
    return out, (q, k, v, q_positions, k_positions, kv_valid, out, lse)


def _attn_bwd(cfg, res, dout):
    causal, window, q_chunk, kv_chunk, scale, g = cfg
    q, k, v, q_positions, k_positions, kv_valid, out, lse = res
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    dv = v.shape[-1]

    dog = dout.reshape(b, sq, kh, g, dv).astype(jnp.float32)
    og = out.reshape(b, sq, kh, g, dv).astype(jnp.float32)
    dvec = jnp.sum(dog * og, axis=-1)              # (B, Sq, K, G)
    qs = (q.astype(jnp.float32) * scale).reshape(b, sq, kh, g, d)

    n_q = -(-sq // q_chunk)
    pad_q = n_q * q_chunk - sq
    qq = _pad_time(qs, pad_q).reshape(b, n_q, q_chunk, kh, g, d
                                      ).swapaxes(0, 1)
    qp = _pad_time(q_positions, pad_q).reshape(b, n_q, q_chunk
                                               ).swapaxes(0, 1)
    lsq = _pad_time(lse, pad_q, 1e30).reshape(b, n_q, q_chunk, kh, g
                                              ).swapaxes(0, 1)
    dvq = _pad_time(dvec, pad_q).reshape(b, n_q, q_chunk, kh, g
                                         ).swapaxes(0, 1)
    doq = _pad_time(dog, pad_q).reshape(b, n_q, q_chunk, kh, g, dv
                                        ).swapaxes(0, 1)

    n_kv = -(-sk // kv_chunk)
    pad_k = n_kv * kv_chunk - sk
    kc = _pad_time(k, pad_k).astype(jnp.float32).reshape(
        b, n_kv, kv_chunk, kh, d).swapaxes(0, 1)
    vc = _pad_time(v, pad_k).astype(jnp.float32).reshape(
        b, n_kv, kv_chunk, kh, dv).swapaxes(0, 1)
    kpc = _pad_time(k_positions, pad_k, np.iinfo(np.int32).max
                    ).reshape(b, n_kv, kv_chunk).swapaxes(0, 1)
    vmc = _pad_time(kv_valid, pad_k, False
                    ).reshape(b, n_kv, kv_chunk).swapaxes(0, 1)

    def kv_body(dq_acc, kv_in):
        k_c, v_c, kp_c, vm_c = kv_in

        def q_body(carry, q_in):
            dk_c, dv_c = carry
            q_blk, qp_blk, lse_blk, d_blk, do_blk = q_in
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_blk, k_c)
            mask = _chunk_mask(qp_blk, kp_c, causal, window)
            s = s + mask[:, :, None, None, :]
            s = jnp.where(vm_c[:, None, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])        # recomputed block
            dv_c = dv_c + jnp.einsum("bqkgc,bqkgd->bckd", p, do_blk)
            dp = jnp.einsum("bqkgd,bckd->bqkgc", do_blk, v_c)
            ds = p * (dp - d_blk[..., None])
            dq_blk = jnp.einsum("bqkgc,bckd->bqkgd", ds, k_c) * scale
            dk_c = dk_c + jnp.einsum("bqkgc,bqkgd->bckd", ds, q_blk)
            return (dk_c, dv_c), dq_blk

        zeros = (jnp.zeros((b, kv_chunk, kh, d), jnp.float32),
                 jnp.zeros((b, kv_chunk, kh, dv), jnp.float32))
        (dk_c, dv_c), dq_chunks = jax.lax.scan(
            q_body, zeros, (qq, qp, lsq, dvq, doq))
        return dq_acc + dq_chunks, (dk_c, dv_c)

    dq0 = jnp.zeros((n_q, b, q_chunk, kh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_body, dq0, (kc, vc, kpc, vmc))

    dq = dq.swapaxes(0, 1).reshape(b, -1, h, d)[:, :sq].astype(q.dtype)
    dk = dks.swapaxes(0, 1).reshape(b, -1, kh, d)[:, :sk].astype(k.dtype)
    dv_out = dvs.swapaxes(0, 1).reshape(b, -1, kh, dv)[:, :sk].astype(
        v.dtype)

    def f0(x):
        return np.zeros(x.shape, jax.dtypes.float0)

    return (dq, dk, dv_out, f0(q_positions), f0(k_positions), f0(kv_valid))


import functools as _functools  # noqa: E402

_attention_cvjp = jax.custom_vjp(
    lambda cfg, q, k, v, qp, kp, vm: _attention_fwd_impl(
        cfg, q, k, v, qp, kp, vm)[0],
    nondiff_argnums=(0,))
_attention_cvjp.defvjp(_attn_fwd, _attn_bwd)


def gated_mlp(x, w_gate, w_up, w_down, act: str = "silu"):
    """SwiGLU/GeGLU MLP: down(act(x@gate) * (x@up))."""
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("bsf,fd->bsd", (a * u).astype(x.dtype), w_down)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """Tied output head: (B, S, D) x (V, D)^T -> (B, S, V)."""
    return jnp.einsum("bsd,vd->bsv", x, table,
                      preferred_element_type=jnp.float32)


def softmax_xent(logits, labels, mask=None):
    """Token-mean cross-entropy; logits (B, S, V) f32, labels (B, S).

    The gold logit is extracted with an iota-compare masked reduction
    instead of take_along_axis: on a vocab-sharded logits tensor this
    fuses into the local reduction + one small all-reduce, where a
    gather would force SPMD to replicate the logits.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(viota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_head_loss(x, table, labels, mask=None, dist=None):
    """Fused unembed + cross-entropy with explicit logits sharding:
    batch over the DP axes, vocab over the model axis -- the (B, S, V)
    tensor is the biggest activation in small-vocab-dominated models and
    must never be replicated."""
    logits = unembed(x, table)
    if dist is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        spec = PartitionSpec(dist.batch_axes, None, "model")
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(dist.mesh, spec))
    return softmax_xent(logits, labels, mask)
