"""Dense GQA transformer family: llama3 / yi / gemma3 (5:1 local:global).

One scan-friendly layer kind ("global" / "local" differ only in the
sliding-window mask and cache length), period-stacked via models/stack.
Caches are ring buffers: local slots allocate only ``window`` entries --
for gemma3 that cuts decode-cache memory ~6x vs. a uniform cache (this
is also a §Perf lever).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as C
from repro.models import layers as L
from repro.models import stack as S
from repro.models.base import ArchConfig, ParamSpec

# ---------------------------------------------------------------------------
# attention + MLP slot (shared with hybrid/vlm/whisper families)
# ---------------------------------------------------------------------------


def attn_mlp_specs(cfg: ArchConfig, kind: str) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    out = {
        "ln1": ParamSpec((d,), (None,), dt, "zeros"),
        "wq": ParamSpec((d, cfg.q_dim), ("embed", "heads"), dt),
        "wk": ParamSpec((d, cfg.kv_dim), ("embed", "kv"), dt),
        "wv": ParamSpec((d, cfg.kv_dim), ("embed", "kv"), dt),
        "wo": ParamSpec((cfg.q_dim, d), ("heads", "embed"), dt),
        "ln2": ParamSpec((d,), (None,), dt, "zeros"),
    }
    if cfg.mlp_gated:
        out["wg"] = ParamSpec((d, cfg.d_ff), ("embed", "mlp"), dt)
        out["wu"] = ParamSpec((d, cfg.d_ff), ("embed", "mlp"), dt)
        out["wd"] = ParamSpec((cfg.d_ff, d), ("mlp", "embed"), dt)
    else:
        out["w1"] = ParamSpec((d, cfg.d_ff), ("embed", "mlp"), dt)
        out["w2"] = ParamSpec((cfg.d_ff, d), ("mlp", "embed"), dt)
    return out


def mlp_apply(cfg: ArchConfig, p, h):
    if cfg.mlp_gated:
        return L.gated_mlp(h, p["wg"], p["wu"], p["wd"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", h, p["w1"])).astype(h.dtype), p["w2"])


def cache_len(cfg: ArchConfig, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.window > 0:
        return min(cfg.window, max_len)
    return max_len


def attn_cache_specs(cfg: ArchConfig, kind: str, batch: int,
                     max_len: int) -> Dict[str, ParamSpec]:
    ln = cache_len(cfg, kind, max_len)
    kv = (batch, ln, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": ParamSpec(kv, ("batch", "cache_seq", "kv_heads", "head_dim"),
                       cfg.dtype, "zeros"),
        "v": ParamSpec(kv, ("batch", "cache_seq", "kv_heads", "head_dim"),
                       cfg.dtype, "zeros"),
        # positions written so far; -1 = empty (kv_valid mask)
        "pos": ParamSpec((batch, ln), ("batch", "cache_seq"), jnp.int32,
                         "zeros"),
    }


def _qkv(cfg, p, h, positions):
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,dq->bsq", h, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,dq->bsq", h, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_mlp_apply(cfg: ArchConfig, kind: str, p, x, cache,
                   positions, mode: str, pos=None, fault_ctx=None,
                   slot_ref=None):
    """One transformer block.  mode: train | prefill | decode.
    kind: global | local (sliding window) | enc (bidirectional).

    ``fault_ctx`` (decode only): a read-path injection context
    (:mod:`repro.serving.readpath`); when it covers this slot, decode
    attention runs through the fused kernel that corrupts K/V tiles as
    they are loaded from the undervolted cache domain.  ``slot_ref`` is
    the ``(slot key, period index)`` pair from the stack."""
    window = cfg.window if kind == "local" else 0
    causal = kind != "enc"
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, positions)

    if mode in ("train",) or kind == "enc":
        out = L.attention(q, k, v, q_positions=positions,
                          k_positions=positions, causal=causal,
                          window=window)
        new_cache = cache
    elif mode == "prefill":
        new_cache = C.ring_fill(cache, {"k": k, "v": v}, positions)
        out = L.attention(q, k, v, q_positions=positions,
                          k_positions=positions, causal=causal,
                          window=window)
    else:  # decode: S == 1
        covered = (fault_ctx is not None and slot_ref is not None
                   and fault_ctx.covers(slot_ref[0]))
        if covered:
            # The ctx owns both the ring write for its cache layout
            # (contiguous ring_update, or the paged pool scatter) and
            # the fused attention over it; under the paged scheduler
            # ``pos`` is the per-serving-slot position vector.
            new_cache = fault_ctx.update(slot_ref[0], cache,
                                         {"k": k, "v": v}, pos)
            out = fault_ctx.attend(slot_ref[0], slot_ref[1], q, new_cache,
                                   q_pos=pos, causal=causal, window=window)
        else:
            new_cache = C.ring_write(cache, {"k": k, "v": v}, pos)
            valid = new_cache["pos"] >= 0
            out = L.attention(q, new_cache["k"], new_cache["v"],
                              q_positions=positions,
                              k_positions=new_cache["pos"], causal=causal,
                              window=window, kv_valid=valid)

    b, s, _, _ = out.shape
    x = x + jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["wo"])
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(cfg, p, h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# whole-model functions
# ---------------------------------------------------------------------------


def layout(cfg: ArchConfig) -> S.PeriodLayout:
    period = len(cfg.pattern) if cfg.pattern else 1
    return S.layout_from_kinds(cfg.layer_kinds(), period)


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        # input table: replicated rows, embed-dim sharded => cheap gather
        # (a 2D-sharded table forces SPMD to all-gather it per lookup);
        # untied output head: (vocab->model, embed->data) => sharded logits
        "embed": ParamSpec((cfg.vocab, cfg.d_model), (None, "embed"),
                           cfg.dtype),
        "unembed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.dtype),
        "stack": S.stack_specs(layout(cfg),
                               functools.partial(attn_mlp_specs, cfg)),
        "ln_f": ParamSpec((cfg.d_model,), (None,), cfg.dtype, "zeros"),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return S.stack_cache_specs(
        layout(cfg),
        lambda kind: attn_cache_specs(cfg, kind, batch, max_len))


def _run_stack(cfg, params, x, positions, cache, mode, pos=None,
               fault_ctx=None):
    if fault_ctx is None:
        apply_slot = lambda kind, p, xx, c: attn_mlp_apply(
            cfg, kind, p, xx, c, positions, mode, pos)
        with_ref = False
    else:
        apply_slot = lambda kind, p, xx, c, ref: attn_mlp_apply(
            cfg, kind, p, xx, c, positions, mode, pos,
            fault_ctx=fault_ctx, slot_ref=ref)
        with_ref = True
    x, new_cache = S.apply_stack(params["stack"], x, layout(cfg), apply_slot,
                                 cache=cache, remat=(cfg.remat == "block"),
                                 with_slot_ref=with_ref)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def forward_train(params, batch, cfg: ArchConfig, dist=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = L.embed(tokens, params["embed"])
    x, _ = _run_stack(cfg, params, x, positions, None, "train")
    loss = L.lm_head_loss(x[:, :-1], params["unembed"], tokens[:, 1:],
                          batch.get("loss_mask", None), dist)
    return loss, {"loss": loss}


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None,
            prompt_len=None):
    """``prompt_len`` (traced int32, <= tokens.shape[1]): the real
    prompt length when ``tokens`` is padded to a length bucket.  Pad
    tokens sit at positions >= prompt_len, so real queries mask them
    causally and the real rows' numerics are bit-identical to an
    unpadded prefill; the cache rows the padding wrote are reset to
    the init state (k/v=0, pos=-1) and logits are taken at column
    prompt_len - 1 instead of -1.  Requires uniform full-length caches
    (see ``SUPPORTS_PADDED_PREFILL``)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = C.init_cache(cache_specs(cfg, b, max_len))
    x = L.embed(tokens, params["embed"])
    x, cache = _run_stack(cfg, params, x, positions, cache, "prefill")
    if prompt_len is None:
        logits = L.unembed(x[:, -1:], params["unembed"])
        return logits[:, 0], cache
    plen = jnp.asarray(prompt_len, jnp.int32)
    last = jax.lax.dynamic_index_in_dim(x, plen - 1, axis=1,
                                        keepdims=True)
    logits = L.unembed(last, params["unembed"])

    def scrub(leaf):
        ln = leaf["pos"].shape[-1]
        pad = jnp.arange(ln, dtype=jnp.int32) >= plen
        out = dict(leaf)
        out["pos"] = jnp.where(pad, -1, leaf["pos"])
        for k in ("k", "v"):
            mask = pad.reshape((1,) * (leaf[k].ndim - 3) + (ln, 1, 1))
            out[k] = jnp.where(mask, jnp.zeros((), leaf[k].dtype), leaf[k])
        return out

    cache = jax.tree_util.tree_map(
        scrub, cache,
        is_leaf=lambda t: isinstance(t, dict) and "pos" in t)
    return logits[:, 0], cache


def decode_step(params, cache, batch, pos, cfg: ArchConfig, dist=None,
                fault_ctx=None):
    """batch["tokens"]: (B, C); pos: scalar int32 absolute position
    (C=1, returns (B, vocab) logits), a (B,) per-row vector (state-arena
    serving slots at heterogeneous positions; rows with pos < 0 skip
    their ring write), or a (B, C) per-token position array (mixed
    prefill-chunk/decode serving step, returns full (B, C, vocab)
    logits -- the caller picks each slot's sample column).

    ``fault_ctx``: optional read-path injection context -- attention
    layers it covers corrupt their K/V tiles at load time instead of
    requiring the cache to be re-injected between steps."""
    tokens = batch["tokens"]
    b, c = tokens.shape
    positions = C.decode_positions(pos, b, c)
    x = L.embed(tokens, params["embed"])
    x, cache = _run_stack(cfg, params, x, positions, cache, "decode",
                          pos=pos, fault_ctx=fault_ctx)
    logits = L.unembed(x, params["unembed"])
    return (logits[:, 0] if c == 1 else logits), cache


# The serving engine's fused read-path injection understands this
# family's cache layout (ring k/v/pos leaves, slot axis "cache_seq").
SUPPORTS_READ_PATH = True
# The continuous-batching scheduler can page this family's cache: the
# decode step threads a paged ctx through attn_mlp_apply (per-slot
# position vectors, pool-page ring writes, batched paged attention).
SUPPORTS_PAGED = True
# prefill() accepts a traced ``prompt_len`` over padded token buckets
# (positions >= prompt_len are causally dead and scrubbed from the
# cache), letting the serving engine compile O(log max_len) prefill
# buckets instead of one program per distinct prompt length.
SUPPORTS_PADDED_PREFILL = True
