"""Atomic checkpoint/restore with async writing and elastic reshard.

Format: one ``step_<N>.npz`` per checkpoint (leaves keyed by pytree
keystr) + ``step_<N>.json`` metadata, written to a temp name and
atomically renamed -- a torn write can never shadow a good checkpoint.
Restore maps leaves back into a caller-provided template, casting to the
template's dtypes, so a checkpoint taken on one mesh restores onto any
other mesh/device count (elastic restart: the arrays are host numpy and
get resharded by the next jit invocation).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


# numpy's npz format can't round-trip ml_dtypes (bfloat16, fp8); store
# them as same-width uint views with the dtype encoded in the key.
_VIEW_BITS = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _encode(k: str, v: np.ndarray):
    if v.dtype.kind == "V" or v.dtype.name not in np.sctypeDict:
        view = _VIEW_BITS[v.dtype.itemsize]
        return f"{k}@{v.dtype.name}", v.view(view)
    return k, v


def _decode(k: str, v: np.ndarray):
    if "@" in k:
        import ml_dtypes
        k, name = k.rsplit("@", 1)
        return k, v.view(np.dtype(getattr(ml_dtypes, name)))
    return k, v


def _flatten(state) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def save(directory: str, step: int, state: Any,
         metadata: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = dict(_encode(k, v) for k, v in _flatten(state).items())
    final = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **leaves)
    os.replace(tmp, final)                      # atomic
    meta = {"step": step, **(metadata or {})}
    mtmp = final.replace(".npz", ".json") + ".tmp"
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, final.replace(".npz", ".json"))
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[len("step_"):-len(".npz")])
             for f in os.listdir(directory)
             if f.startswith("step_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(directory: str, template: Any,
            step: Optional[int] = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into ``template``'s structure/dtypes (elastic-safe)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        stored = dict(_decode(k, data[k]) for k in data.files)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = jax.tree_util.keystr(p)
            want = (leaf.dtype if hasattr(leaf, "dtype")
                    else np.asarray(leaf).dtype)
            leaves.append(stored[key].astype(want))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
    with open(path.replace(".npz", ".json")) as f:
        meta = json.load(f)
    return state, meta


class AsyncCheckpointer:
    """Background-thread writer: training never blocks on I/O."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def submit(self, step: int, state: Any,
               metadata: Optional[Dict[str, Any]] = None) -> None:
        # materialize on host before queuing so the device arrays are
        # free to be donated/overwritten by the next step
        host_state = jax.tree_util.tree_map(np.asarray, state)
        self._q.put((step, host_state, metadata))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state, metadata = item
            try:
                save(self.directory, step, state, metadata)
                self._gc()
            except Exception as e:          # noqa: BLE001
                self._errors.append(e)

    def _gc(self) -> None:
        steps = sorted(
            int(f[len("step_"):-len(".npz")])
            for f in os.listdir(self.directory)
            if f.startswith("step_") and f.endswith(".npz"))
        for s in steps[: -self.keep]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory,
                                           f"step_{s:08d}{ext}"))
                except OSError:
                    pass

    def finalize(self) -> None:
        self._q.put(None)
        self._worker.join(timeout=120)
        if self._errors:
            raise self._errors[0]
