"""Deterministic synthetic data pipeline.

Host-side numpy generation, seeded by (seed, step, host_shard) so every
host produces its own disjoint slice of the global batch with no
coordination -- the multi-host pattern -- and a restart at step k
regenerates exactly the same stream (checkpoint/resume bit-exactness is
unit-tested).

Sequences are Markov-structured (each token limits its successors to a
small seeded set), so language models can actually learn them: the
examples' loss curves are meaningful, not noise-fitting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.models.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # successors per token (entropy knob)
    host_count: int = 1
    host_index: int = 0


def _transition_table(cfg: DataConfig) -> np.ndarray:
    rng = np.random.RandomState(cfg.seed ^ 0xBEEF)
    return rng.randint(0, cfg.vocab,
                       size=(cfg.vocab, cfg.branching)).astype(np.int32)


def make_batch(cfg: DataConfig, step: int,
               arch: Optional[ArchConfig] = None) -> Dict[str, np.ndarray]:
    """The host's shard of global batch ``step``."""
    assert cfg.global_batch % cfg.host_count == 0
    local = cfg.global_batch // cfg.host_count
    rng = np.random.RandomState(
        (cfg.seed * 1_000_003 + step * 7919 + cfg.host_index) % (2**31))
    table = _transition_table(cfg)
    tokens = np.empty((local, cfg.seq_len), np.int32)
    tokens[:, 0] = rng.randint(0, cfg.vocab, local)
    choices = rng.randint(0, cfg.branching, size=(local, cfg.seq_len))
    for t in range(1, cfg.seq_len):
        tokens[:, t] = table[tokens[:, t - 1], choices[:, t]]
    out = {"tokens": tokens}
    if arch is not None and arch.family == "vlm":
        out["patches"] = rng.randn(
            local, arch.enc_len, arch.frontend_dim).astype(np.float32)
    if arch is not None and arch.family == "audio":
        out["frames"] = rng.randn(
            local, arch.enc_len, arch.d_model).astype(np.float32)
    return out


def batch_iterator(cfg: DataConfig, start_step: int = 0,
                   arch: Optional[ArchConfig] = None):
    step = start_step
    while True:
        yield step, make_batch(cfg, step, arch)
        step += 1
