"""Batched serving engine: prefill + greedy/temperature decode, with
optional undervolted KV-cache domains (the EDEN-style application-level
trade-off: KV bits ride cheap memory; the model's robustness to rare
flips buys the paper's deep power savings)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchBundle, ArchConfig, spec_avals
from repro.models.dist import DistContext
from repro.training.undervolt import UndervoltPlan


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    undervolt: Optional[UndervoltPlan] = None
    # Optional per-request KV-domain voltage override (may be traced):
    # the arena engine re-derives thresholds from it at run time, so a
    # serving fleet can walk cache voltage up and down under load
    # without ever recompiling the decode step.  Method dispatch is
    # static: 'auto' resolves from a *concrete* kv_voltage correctly; a
    # *traced* kv_voltage with kv_method='auto' is rejected up front
    # (generate raises ValueError) -- traced sweeps must pick the method
    # explicitly ('bitwise' once rates cross ~1e-3).
    kv_voltage: Optional[float] = None
    kv_method: str = "auto"
    # Frontier-walking admission governor (repro.training.governor),
    # built from ``undervolt``: at admission time the engine re-plans
    # the KV-cache voltage to the deepest point at which the governed
    # domain keeps enough *usable* capacity for this request's cache.
    # Mutually exclusive with kv_voltage.
    governor: Optional[object] = None


def _kv_placement(bundle, cfg, batch_size, sc):
    if sc.undervolt is None or not sc.undervolt.enabled:
        return None
    if not sc.undervolt.covers("kv_cache"):
        return None
    cache_avals = spec_avals(
        bundle.module.cache_specs(cfg, batch_size, sc.max_len))
    return sc.undervolt.place({"kv_cache": cache_avals})


def _static_kv_voltage(v):
    """float(v) for concrete scalars, None for traced values."""
    from repro.core.engine import _static_value
    return _static_value(v)


def generate(bundle: ArchBundle, cfg: ArchConfig, params, batch: Dict,
             sc: ServeConfig, dist: Optional[DistContext] = None,
             key=None) -> jnp.ndarray:
    """Prefill on batch['tokens'] then decode max_new_tokens greedily."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    placement = _kv_placement(bundle, cfg, b, sc)
    fmap = sc.undervolt.fault_map() if placement is not None else None

    kv_voltage = sc.kv_voltage
    if sc.governor is not None:
        if sc.kv_voltage is not None:
            raise ValueError(
                "ServeConfig.governor and kv_voltage are mutually "
                "exclusive voltage controls")
        if sc.undervolt is None or sc.governor.plan is not sc.undervolt:
            raise ValueError(
                "sc.governor must be built from sc.undervolt (its "
                "frontier/capacity tables belong to that plan's fault "
                "map and domains)")
        if placement is None:
            raise ValueError(
                "ServeConfig.governor is set but the undervolt plan "
                "does not place 'kv_cache' (or is disabled): admission "
                "governance would silently be a no-op")
        kv_domain = placement["kv_cache"].domain.name
        if sc.governor.config.domain != kv_domain:
            raise ValueError(
                f"sc.governor governs domain "
                f"{sc.governor.config.domain!r} but the KV cache is "
                f"placed in domain {kv_domain!r}")
        # Admission-time re-plan: deepest voltage at which the governed
        # domain keeps this request's cache bytes usable.
        kv_bytes = placement["kv_cache"].total_words * 4
        kv_voltage = sc.governor.admit(kv_bytes)
    if (kv_voltage is not None and sc.kv_method == "auto"
            and _static_kv_voltage(kv_voltage) is None):
        raise ValueError(
            "ServeConfig.kv_method='auto' cannot dispatch from a traced "
            "kv_voltage (method selection is static); pass "
            "kv_method='word' or 'bitwise' explicitly for traced "
            "voltage schedules")

    prefill = jax.jit(lambda p, bt: bundle.module.prefill(
        p, bt, cfg, sc.max_len, dist))
    step = jax.jit(lambda p, c, t, pos: bundle.module.decode_step(
        p, c, t, pos, cfg, dist))

    logits, cache = prefill(params, batch)
    pos0 = s + (cfg.enc_len if cfg.family == "vlm" else 0)

    def inject_cache(c):
        if placement is None:
            return c
        from repro.core.injection import inject_group
        faulted, _ = inject_group(c, placement["kv_cache"], fmap,
                                  voltage=kv_voltage,
                                  method=sc.kv_method)
        return faulted

    cache = inject_cache(cache)
    out = []
    if key is None:
        key = jax.random.PRNGKey(0)

    def sample(lg, k):
        if sc.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / sc.temperature).astype(
            jnp.int32)

    key, k0 = jax.random.split(key)
    tok = sample(logits, k0)[:, None]
    out.append(tok)
    for i in range(sc.max_new_tokens - 1):
        logits, cache = step(params, cache, {"tokens": tok},
                             jnp.int32(pos0 + i))
        cache = inject_cache(cache)
        key, ki = jax.random.split(key)
        tok = sample(logits, ki)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
