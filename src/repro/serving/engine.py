"""Batched serving engine: prefill + one scanned, donated decode.

Undervolted KV-cache domains (the EDEN-style application-level
trade-off: KV bits ride cheap memory; the model's robustness to rare
flips buys the paper's deep power savings) are modeled on the *read
path*: the paper's faults manifest when undervolted HBM is read, so the
fused decode-attention kernel corrupts K/V tiles as they are loaded --
zero extra HBM passes -- while the write path shrinks to the
O(new-token) slice each decode step actually writes.  The whole decode
phase is a single jitted ``lax.scan`` with the cache donated, so
per-token Python dispatch and cache-sized buffer copies are gone.

Injection modes (``ServeConfig.kv_injection``):

  * ``'read'``   -- fused read-path corruption (K/V tiles corrupted in
    VMEM at load); the write path covers only non-K/V bookkeeping
    (``pos``) incrementally.  Decode-step injection work no longer
    scales with cache size.
  * ``'write'``  -- incremental write-path: the slice written this step
    is corrupted in O(new-token) work; attention reads the stored
    (already-corrupt) cache.  Bit-identical tokens to ``'read'``
    (stuck-at masks are deterministic per physical word and
    idempotent); also the fallback for families without read-path
    support.
  * ``'rewrite'`` -- the legacy full-cache re-injection every token
    (one arena pass per step, O(cache) HBM traffic); kept as the slow
    cross-validation oracle, like ``engine='segments'`` in core.
  * ``'auto'``   -- ``'read'`` when the family/cache supports it, else
    ``'write'``.

All modes share one set of attention numerics: whenever injection is
active and the family supports it, attention routes through the fused
kernel (with corruption disabled in the write modes), so
``decode='scan'`` and the legacy ``decode='loop'`` emit token-for-token
identical output across modes -- asserted in tests/test_serving_scan.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.engine import _static_value, resolve_method
from repro.core.faultmodel import V_MIN
from repro.models.base import (ArchBundle, ArchConfig, cache_layouts,
                               cache_slot_axes, spec_avals)
from repro.models.dist import DistContext
from repro.serving import readpath
from repro.training.undervolt import UndervoltPlan


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0
    undervolt: Optional[UndervoltPlan] = None
    # Optional per-request KV-domain voltage override (may be traced):
    # thresholds are re-derived from it at run time, so a serving fleet
    # can walk cache voltage up and down under load without ever
    # recompiling the decode step.  Method dispatch is static: 'auto'
    # resolves from a *concrete* kv_voltage correctly; a *traced*
    # kv_voltage with kv_method='auto' is rejected up front (generate
    # raises ValueError) -- traced sweeps must pick the method
    # explicitly ('bitwise' once rates cross ~1e-3).
    kv_voltage: Optional[float] = None
    kv_method: str = "auto"
    # Frontier-walking admission governor (repro.training.governor),
    # built from ``undervolt``: at admission time the engine re-plans
    # the KV-cache voltage to the deepest point at which the governed
    # domain keeps enough *usable* capacity for this request's cache.
    # Mutually exclusive with kv_voltage.
    governor: Optional[object] = None
    # Decode driver: 'scan' (single jitted lax.scan, cache donated) or
    # 'loop' (per-token Python dispatch -- the legacy driver, kept for
    # cross-validation).
    decode: str = "scan"
    # Where faults are applied: see the module docstring.
    kv_injection: str = "auto"
    # Continuous-batching scheduler knobs (ignored by generate()):
    # prompt tokens consumed per mixed step for prefilling slots --
    # chunked prefill rides the ONE compiled donated step instead of a
    # per-prompt-length jitted prefill.
    prefill_chunk: int = 8
    # Reliability-pinned copy-on-write prefix sharing: tenants with a
    # common prompt prefix map the same physical pages read-only.
    share_prefix: bool = False
    # Observability plane (repro.obs.ObsConfig): in-step metric
    # counters on the donated state, host-side latency histograms,
    # energy accounting, and the structured event trace.  None means
    # the scheduler's default (enabled); pass ObsConfig(enabled=False)
    # to strip the counter leaf from the compiled step entirely.
    obs: Optional[object] = None


def _kv_placement(bundle, cfg, batch_size, sc):
    if sc.undervolt is None or not sc.undervolt.enabled:
        return None, None
    if not sc.undervolt.covers("kv_cache"):
        return None, None
    cache_avals = spec_avals(
        bundle.module.cache_specs(cfg, batch_size, sc.max_len))
    placement = sc.undervolt.place({"kv_cache": cache_avals})["kv_cache"]
    return placement, cache_avals


def _static_kv_voltage(v):
    """float(v) for concrete scalars, None for traced values."""
    return _static_value(v)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


class _BucketedPrefill:
    """Memoized jitted prefill over power-of-two prompt-length buckets.

    ``jax.jit`` re-specializes on every distinct prompt length, so a
    serving front door compiling prefill per request pays one XLA
    compile per length seen.  Families advertising
    ``SUPPORTS_PADDED_PREFILL`` take a traced ``prompt_len`` over a
    zero-padded token buffer instead: prompts are padded up to the next
    power of two (capped at ``max_len``), so the compile count is
    O(log max_len) while logits and cache stay bit-identical to the
    unpadded prefill (pad positions are causally dead and scrubbed).
    ``traces`` counts actual retraces -- asserted in
    tests/test_prefill_buckets.py.
    """

    def __init__(self, module, cfg, max_len: int, dist=None):
        self.module = module
        self.cfg = cfg
        self.max_len = int(max_len)
        self.dist = dist
        self.traces: list = []
        # Padding rewrites ring rows at positions >= prompt_len, which
        # is only sound for full-length rings: window caches rotate
        # once the padded length exceeds the window, and carried state
        # ("state") or one-shot encoder K/V ("cross") leaves would see
        # the pad tokens' writes.  Any non-"full" leaf layout routes
        # every prompt length to the exact (per-shape) prefill instead.
        specs = module.cache_specs(cfg, 1, max_len)
        self.uniform = all(
            lay == "full" for lay in jax.tree_util.tree_leaves(
                cache_layouts(specs, max_len)))
        self._padded = jax.jit(self._traced)
        self._exact = jax.jit(
            lambda p, bt: module.prefill(p, bt, cfg, max_len, dist))

    def _traced(self, params, batch, plen):
        self.traces.append(1)
        return self.module.prefill(params, batch, self.cfg, self.max_len,
                                   self.dist, prompt_len=plen)

    def __call__(self, params, batch):
        tokens = batch["tokens"]
        s = tokens.shape[1]
        if not self.uniform or s > self.max_len:
            return self._exact(params, batch)
        bucket = min(_next_pow2(s), self.max_len)
        padded = dict(batch)
        padded["tokens"] = jnp.pad(jnp.asarray(tokens),
                                   ((0, 0), (0, bucket - s)))
        return self._padded(params, padded, jnp.int32(s))


_PREFILL_BUCKETS: Dict[Any, Any] = {}


def bucketed_prefill(module, cfg, max_len: int, dist=None):
    """The process-wide bucketed-prefill entry for one (module, cfg,
    max_len) serving shape, or None when the family cannot pad.
    Sharing the instance across ``generate()`` calls is what bounds the
    legacy path's compile count."""
    if not getattr(module, "SUPPORTS_PADDED_PREFILL", False):
        return None
    key = (module, cfg, int(max_len),
           id(dist) if dist is not None else None)
    bp = _PREFILL_BUCKETS.get(key)
    if bp is None:
        bp = _PREFILL_BUCKETS[key] = _BucketedPrefill(module, cfg,
                                                      max_len, dist)
    return bp


def sample_tokens(logits, key, temperature: float):
    """Greedy / temperature sampling over (B, vocab) logits.

    The single sampling implementation shared by the one-shot engine
    and the continuous-batching scheduler: the scheduler's token-
    equivalence contract (scheduler slot == standalone request, bit for
    bit) depends on both using exactly these ops in this order.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(
        jnp.int32)


@dataclasses.dataclass
class DecodeEngine:
    """Everything static about one request shape's decode phase, plus
    the jitted scanned driver.  ``decode_all(params, cache, tok0, key,
    kv_voltage) -> (n_more, B, 1) tokens`` donates the cache buffer --
    XLA updates it in place instead of copying it every token."""

    mode: str                    # read | write | rewrite
    method: str
    active: bool                 # may this request inject at all
    use_fused: bool              # attention routed through faulty kernel
    n_more: int
    decode_all: Any              # jitted scanned decode
    step_core: Any               # (p, c, tok, pos, v) -> (logits, c)
    init_inject: Any             # (c, v) -> c
    sample: Any                  # (logits, key) -> tokens


def build_decode_engine(bundle: ArchBundle, cfg: ArchConfig,
                        sc: ServeConfig, batch_size: int, prompt_len: int,
                        dist: Optional[DistContext] = None,
                        static_voltage=None,
                        kv_placement=None) -> DecodeEngine:
    """Construct the decode-phase closures for one request shape.

    ``static_voltage``: the concrete effective KV voltage if known
    (None when the request will pass a traced voltage at run time --
    injection is then assumed live and method must already be
    concrete).  Used by :func:`generate` and directly by benchmarks /
    structural tests that lower ``decode_all`` without running prefill.

    ``kv_placement``: explicit physical placement of this request's
    cache, overriding the plan's own allocation -- in particular a
    page-granular :class:`repro.serving.paged.RequestPlacement`, which
    is how a scheduler request is replayed standalone on identical
    physical words (the token-equivalence contract).
    """
    module = bundle.module
    if kv_placement is not None:
        if sc.undervolt is None or not sc.undervolt.enabled:
            raise ValueError(
                "kv_placement override needs sc.undervolt (its fault "
                "map supplies the placement's threshold tables)")
        kvp = kv_placement
        cache_avals = spec_avals(
            module.cache_specs(cfg, batch_size, sc.max_len))
        flat, _ = jax.tree_util.tree_flatten_with_path(cache_avals)
        words = {jax.tree_util.keystr(p):
                 int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize // 4
                 for p, a in flat}
        for lp in kvp.leaves:
            if words.get(lp.path) != lp.n_words:
                raise ValueError(
                    f"kv_placement does not fit this request's cache: "
                    f"leaf {lp.path} places {lp.n_words} words but the "
                    f"(batch={batch_size}, max_len={sc.max_len}) cache "
                    f"holds {words.get(lp.path)} -- placements exported "
                    "by the paged pool describe a single request "
                    "(batch 1) at the pool's max_len")
    else:
        kvp, cache_avals = _kv_placement(bundle, cfg, batch_size, sc)
    fmap = sc.undervolt.fault_map() if kvp is not None else None
    paged_kvp = (kvp is not None and len(kvp.leaves) > 0
                 and hasattr(kvp.leaves[0], "page_base"))

    if sc.kv_injection not in ("auto", "read", "write", "rewrite"):
        raise ValueError(f"unknown kv_injection {sc.kv_injection!r}")
    if paged_kvp and sc.kv_injection == "rewrite":
        raise ValueError(
            "kv_injection='rewrite' (the legacy full-cache segment "
            "walker) cannot address a page-granular placement; use "
            "'read' (fused) or 'write' (incremental) with paged caches")
    sv = static_voltage
    active = kvp is not None and not (sv is not None
                                      and sv >= V_MIN - 1e-9)
    supports_read = (active and readpath.supports(module)
                     and readpath.cache_supported(kvp, cache_avals))
    mode = sc.kv_injection
    if mode == "auto":
        mode = "read" if supports_read else "write"
    if mode == "read" and active and not supports_read:
        raise ValueError(
            "kv_injection='read' needs a family with read-path support "
            "and word-aligned K/V slots; use 'write' (scanned "
            "incremental write-path) or 'rewrite' (full re-injection)")
    method = sc.kv_method
    if active and method == "auto":
        if sv is None:
            raise ValueError(
                "kv_method='auto' cannot dispatch from a traced "
                "kv_voltage (method selection is static); pass "
                "kv_method='word' or 'bitwise' explicitly for traced "
                "voltage schedules")
        method = "word" if kvp.domain.ecc else resolve_method(
            fmap, kvp, sv)
    # Fused attention whenever faults may flow, in *every* mode, so all
    # injection modes share bit-identical attention numerics.
    use_fused = active and supports_read
    slot_axes = (cache_slot_axes(
        module.cache_specs(cfg, batch_size, sc.max_len))
        if active else None)
    pos0 = prompt_len + (cfg.enc_len if cfg.family == "vlm" else 0)
    n_more = sc.max_new_tokens - 1

    def make_ctx(v):
        if not use_fused:
            return None
        return readpath.build_ctx(
            kvp, fmap, cache_avals, voltage=v, method=method,
            inject=(mode == "read"))

    def init_inject(c, v):
        """Post-prefill injection (the cache's first trip to HBM)."""
        if not active:
            return c
        if mode == "read":
            # K/V leaves stay clean in the buffer (the read path
            # corrupts them at load); bookkeeping leaves take their
            # write-path faults now.
            c, _ = arena.inject_placement_slice(
                c, kvp, fmap, voltage=v, method=method,
                skip_paths=readpath.kv_paths(kvp))
            return c
        if paged_kvp:
            # whole-tree write-path injection through the page tables
            # (bit-identical to the legacy segment walker, which cannot
            # address sub-block pages)
            c, _ = arena.inject_placement_slice(
                c, kvp, fmap, voltage=v, method=method)
            return c
        from repro.core.injection import inject_group
        c, _ = inject_group(c, kvp, fmap, voltage=v, method=method)
        return c

    def post_inject(c, pos, v):
        """Write-path injection after a decode step wrote slot pos%L."""
        if not active:
            return c
        if mode == "rewrite":
            from repro.core.injection import inject_group
            c, _ = inject_group(c, kvp, fmap, voltage=v, method=method)
            return c
        skip = readpath.kv_paths(kvp) if mode == "read" else ()
        c, _ = arena.inject_placement_slice(
            c, kvp, fmap, slot_axes=slot_axes, pos=pos, voltage=v,
            method=method, skip_paths=skip)
        return c

    def step_with_ctx(p, c, tok, pos, v, ctx):
        if ctx is not None:
            logits, c = module.decode_step(p, c, {"tokens": tok}, pos,
                                           cfg, dist, fault_ctx=ctx)
        else:
            logits, c = module.decode_step(p, c, {"tokens": tok}, pos,
                                           cfg, dist)
        return logits, post_inject(c, pos, v)

    def step_core(p, c, tok, pos, v):
        return step_with_ctx(p, c, tok, pos, v, make_ctx(v))

    def sample(lg, k):
        return sample_tokens(lg, k, sc.temperature)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def decode_all(p, c, tok, k, v):
        c = init_inject(c, v)
        ctx = make_ctx(v)      # hoisted: scan-invariant threshold tables

        def body(carry, _):
            c, tok, pos, k = carry
            logits, c = step_with_ctx(p, c, tok, pos, v, ctx)
            k, ki = jax.random.split(k)
            nt = sample(logits, ki)[:, None]
            return (c, nt, pos + 1, k), nt

        (c, _, _, _), toks = jax.lax.scan(
            body, (c, tok, jnp.int32(pos0), k), None, length=n_more)
        # The final cache is returned so the donated input aliases an
        # output of the same shape: XLA updates the cache in place
        # through the scan instead of copying it (asserted on the HLO
        # in tests); callers that are done with the request drop it.
        return toks, c                  # toks: (n_more, B, 1)

    return DecodeEngine(mode=mode, method=method, active=active,
                        use_fused=use_fused, n_more=n_more,
                        decode_all=decode_all, step_core=step_core,
                        init_inject=init_inject, sample=sample)


def generate(bundle: ArchBundle, cfg: ArchConfig, params, batch: Dict,
             sc: ServeConfig, dist: Optional[DistContext] = None,
             key=None, kv_placement=None) -> jnp.ndarray:
    """Prefill on batch['tokens'] then decode max_new_tokens greedily.

    ``kv_placement`` overrides the plan's own cache allocation with an
    explicit physical placement (see :func:`build_decode_engine`)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    if kv_placement is not None:
        if sc.governor is not None:
            raise ValueError(
                "kv_placement and ServeConfig.governor are mutually "
                "exclusive: the placement is already decided, so there "
                "is no admission to govern")
        placement = kv_placement
    else:
        placement, _ = _kv_placement(bundle, cfg, b, sc)
    module = bundle.module
    if sc.decode not in ("scan", "loop"):
        raise ValueError(f"unknown decode driver {sc.decode!r}")

    kv_voltage = sc.kv_voltage
    if sc.governor is not None:
        if sc.kv_voltage is not None:
            raise ValueError(
                "ServeConfig.governor and kv_voltage are mutually "
                "exclusive voltage controls")
        if sc.undervolt is None or sc.governor.plan is not sc.undervolt:
            raise ValueError(
                "sc.governor must be built from sc.undervolt (its "
                "frontier/capacity tables belong to that plan's fault "
                "map and domains)")
        if placement is None:
            raise ValueError(
                "ServeConfig.governor is set but the undervolt plan "
                "does not place 'kv_cache' (or is disabled): admission "
                "governance would silently be a no-op")
        kv_domain = placement.domain.name
        if sc.governor.config.domain != kv_domain:
            raise ValueError(
                f"sc.governor governs domain "
                f"{sc.governor.config.domain!r} but the KV cache is "
                f"placed in domain {kv_domain!r}")
        # Admission-time re-plan: deepest voltage at which the governed
        # domain keeps this request's cache bytes usable.
        kv_bytes = placement.total_words * 4
        kv_voltage = sc.governor.admit(kv_bytes)
    if (kv_voltage is not None and sc.kv_method == "auto"
            and _static_kv_voltage(kv_voltage) is None):
        raise ValueError(
            "ServeConfig.kv_method='auto' cannot dispatch from a traced "
            "kv_voltage (method selection is static); pass "
            "kv_method='word' or 'bitwise' explicitly for traced "
            "voltage schedules")

    eff_v = kv_voltage if kv_voltage is not None else (
        placement.domain.voltage if placement is not None else None)
    sv = _static_kv_voltage(eff_v) if eff_v is not None else None
    # sv None here means a traced voltage: injection must be assumed
    # live (build_decode_engine treats static_voltage=None that way).
    eng = build_decode_engine(
        bundle, cfg, dataclasses.replace(sc, kv_voltage=None,
                                         governor=None),
        b, s, dist,
        static_voltage=(sv if eff_v is not None else V_MIN),
        kv_placement=kv_placement)
    varr = (jnp.asarray(eff_v, jnp.float32) if eng.active
            else jnp.float32(0.0))

    prefill = bucketed_prefill(module, cfg, sc.max_len, dist)
    if prefill is None:
        prefill = jax.jit(lambda p, bt: module.prefill(
            p, bt, cfg, sc.max_len, dist))
    logits, cache = prefill(params, batch)
    pos0 = s + (cfg.enc_len if cfg.family == "vlm" else 0)

    if key is None:
        key = jax.random.PRNGKey(0)
    key, k0 = jax.random.split(key)
    tok0 = eng.sample(logits, k0)[:, None]

    if sc.decode == "loop":
        # Legacy per-token Python dispatch (cross-validation oracle).
        cache = jax.jit(eng.init_inject)(cache, varr)
        step = jax.jit(eng.step_core, donate_argnums=(1,))
        out = [tok0]
        tok = tok0
        for i in range(eng.n_more):
            logits, cache = step(params, cache, tok,
                                 jnp.int32(pos0 + i), varr)
            key, ki = jax.random.split(key)
            tok = eng.sample(logits, ki)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    if eng.n_more == 0:
        return tok0
    toks, _ = eng.decode_all(params, cache, tok0, key, varr)
    return jnp.concatenate(
        [tok0, jnp.moveaxis(toks, 0, 1)[:, :, 0]], axis=1)
