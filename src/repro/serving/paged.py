"""Paged KV cache: serving-cache pages carved out of HBM arena blocks.

The paper's three-factor trade-off (power x capacity x fault rate,
Fig. 6) only becomes a *serving* resource once the system can steer
which data lands on which reliability class of memory at runtime
(Voltron's observation).  This module makes the fault map that
steerable resource:

  * a :class:`PagePool` carves fixed-size KV pages out of
    ``DomainAllocator`` blocks.  Because a page size must divide the
    arena block size, every page sits inside exactly one block and
    inherits its pseudo-channel -- the per-page physical base / threshold
    tables are a pure index refinement of the arena engine's block
    tables (:func:`repro.core.engine.refine_tables`), zero extra
    bookkeeping.  Pages are handed out tier-aware: weak-block pages go
    to fault-tolerant requests first, weak-avoiding tiers get strong
    pages most-reliable-first, and exhaustion raises
    :class:`~repro.core.domains.CapacityError` for the scheduler to
    treat as queue backpressure, not a crash.
  * a :class:`PagedKVCache` owns the pooled device buffers (the pool is
    literally ``cache_specs(cfg, num_pages, page_slots)`` -- a ring
    cache whose "batch" rows are pages) and the serving-side data paths:
    scattering a prefilled request into its pages, the paged decode
    write, and the write-path fault injection of exactly the words a
    step touched.
  * a :class:`PagedServingCtx` is the decode-step hook (same protocol as
    :class:`repro.serving.readpath.ReadPathCtx`, plus the paged cache
    write): attention routes through
    :func:`repro.kernels.flash_attention.faulty.paged_decode_attention`,
    which gathers K/V tiles page-by-page via scalar-prefetched page
    tables and corrupts them in VMEM as they load.
  * :meth:`PagePool.request_placement` exports one request's pages as a
    page-granular placement of the *standalone* contiguous cache, so
    PR 3's ``generate()`` can replay the exact same physical fault map
    -- the scheduler's token-for-token acceptance contract.
"""
from __future__ import annotations

import bisect
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core import faultmap as fm
from repro.core.domains import (ALIGN_WORDS, CapacityError, MemoryDomain,
                                Segment, resolve_tier)
from repro.core.faultmap import NUM_THR_COLS, FaultMap
from repro.kernels.bitflip.bitflip import BLOCK_WORDS
from repro.kernels.ecc.ecc import arena_ecc_events
from repro.kernels.flash_attention import faulty
from repro.models.base import cache_layouts, cache_slot_axes, spec_avals

# Chaos-injection column remap: a "row went weak at runtime" fault is
# synthesized by overriding a page's *strong* thresholds with its weak
# ones (every voltage-dependent pair), so all rows under the page start
# drawing faults at the weak rate -- same compiled graph, the override
# is a jnp.where over gathered threshold rows.
_WEAKEN_COLS = np.asarray(
    [fm.COL_Q01_WEAK, fm.COL_Q01_WEAK, fm.COL_Q10_WEAK, fm.COL_Q10_WEAK,
     fm.COL_WEAK_ROW_Q, fm.COL_T01_WEAK, fm.COL_T01_WEAK, fm.COL_T10_WEAK,
     fm.COL_T10_WEAK, fm.COL_PAR_Q_WEAK, fm.COL_PAR_Q_WEAK], np.int32)
assert _WEAKEN_COLS.shape[0] == NUM_THR_COLS

# Pool-cache leaves: the shared attention-cache layout (stack containers
# x ring k/v/pos leaves).
_LEAF_RE = re.compile(
    r"^\['(prefix|periods|rest)'\]\['([^']+)'\]\['(k|v|pos)'\]$")


class PagedLayoutError(ValueError):
    """A cache layout that cannot be paged: page size not dividing the
    arena block size, non-uniform cache lengths, ECC-incompatible page
    geometry, ...  Subclasses ``ValueError`` so config-validation
    callers can catch it generically."""


class PageSharingError(ValueError):
    """A refcounted-page protocol violation: releasing a shared page a
    holder does not hold (double release), retaining or COW-forking a
    page that is not shared, re-sharing an already-shared page, or
    ``free()``-ing a page that still has holders.  Typed so the
    scheduler's copy-on-write bookkeeping fails loudly instead of
    silently corrupting a page another tenant still maps."""


@dataclasses.dataclass(frozen=True, eq=False)
class PagedLeafPlacement:
    """Page-granular placement of one leaf of a request's *standalone*
    (contiguous, B=1) cache: entry ``j`` of the tables describes leaf
    words ``[j * page_words, (j+1) * page_words)``.  Duck-typed against
    :class:`~repro.core.domains.LeafPlacement` via the ``page_base``
    attribute (see :func:`repro.core.engine.leaf_addr_tables`)."""

    path: str
    n_words: int
    page_words: int
    page_base: np.ndarray      # (n_pages,) uint32 physical base words
    page_pc: np.ndarray        # (n_pages,) int32 owning pseudo-channel


@dataclasses.dataclass(frozen=True, eq=False)
class RequestPlacement:
    """One request's cache placement assembled from its pool pages.

    Quacks like a :class:`~repro.core.domains.GroupPlacement` for the
    serving engine (``domain`` / ``leaves`` / ``total_words``) but
    addresses physical words through per-leaf *page* tables, which is
    what lets PR 3's contiguous ``generate()`` reproduce a scheduler
    request bit-for-bit.
    """

    group: str
    domain: MemoryDomain
    leaves: Tuple[PagedLeafPlacement, ...]
    # seed of the exporting pool's fault map: a sharded scheduler's
    # shards draw distinct maps, and a replay against any other map
    # would silently diverge -- readpath.build_ctx cross-checks it
    map_seed: Optional[int] = None

    @property
    def total_words(self) -> int:
        return sum(l.n_words for l in self.leaves)


@dataclasses.dataclass(frozen=True, eq=False)
class _PoolLeaf:
    """Static metadata of one pool-cache leaf."""

    path: str
    container: str             # prefix | periods | rest
    slot_key: str              # e.g. "s0_global"
    which: str                 # k | v | pos
    stacked: bool              # leading period axis
    n_layers: int              # 1 for unstacked leaves
    wps: int                   # uint32 words per cache slot
    page_words: int            # wps * page_slots
    layer_words: int           # words per layer slice of the pool leaf
    length: int                # logical ring length (max_len or window)
    n_pages: int               # length // page_slots (leaf's table width)
    layout: str                # "full" | "window" (see base.CACHE_LAYOUTS)
    # Physical tables (None when the pool is unplaced / clean):
    page_base: Optional[np.ndarray]   # (n_layers, total_pages) uint32
    page_pc: Optional[np.ndarray]     # (n_layers, total_pages) int32
    block_base: Optional[np.ndarray]  # arena block tables of the leaf
    block_pc: Optional[np.ndarray]


def _leaf_words_per_slot(shape, slot_axis, dtype) -> int:
    inner = int(np.prod(shape[slot_axis + 1:], dtype=np.int64))
    nbytes = inner * jnp.dtype(dtype).itemsize
    if nbytes % 4:
        raise PagedLayoutError(
            f"cache slot of {inner} x {jnp.dtype(dtype).name} elements "
            "is not word-aligned; the paged cache needs whole uint32 "
            "words per slot")
    return nbytes // 4


class PagePool:
    """Host-side page allocator over one serving cache pool.

    ``num_pages`` is the usable page count; one extra *scratch* page is
    appended (``scratch_id``) as the write sink for inactive serving
    slots -- it is never handed out.  A page id is valid across every
    leaf and layer of the cache simultaneously (vLLM-style): allocating
    ``n`` pages provisions K, V and position storage for ``n *
    page_slots`` cache slots of every layer.

    Tier routing: pages whose backing arena blocks contain weak rows
    (in any leaf/layer) are classed *weak*.  Weak-avoiding tiers
    allocate strong pages most-reliable-first; tolerant tiers consume
    weak pages first and then strong pages least-reliable-first, so
    the reliable end of the pool stays available for strict traffic.
    """

    def __init__(self, module, cfg, *, max_len: int, page_slots: int,
                 num_pages: int, plan=None, shard=None):
        if not getattr(module, "SUPPORTS_PAGED", False):
            raise ValueError(
                f"family module {getattr(module, '__name__', module)!r} "
                "does not support the paged serving cache (needs ring "
                "k/v/pos cache leaves and the paged decode-step hook)")
        if page_slots <= 0 or max_len % page_slots:
            raise PagedLayoutError(
                f"page_slots={page_slots} must positively divide "
                f"max_len={max_len} (ServeConfig.max_len): a request's "
                "logical cache is a whole number of pages -- pick "
                "page_slots from the divisors of max_len")
        self.module = module
        self.cfg = cfg
        self.max_len = int(max_len)
        self.page_slots = int(page_slots)
        self.num_pages = int(num_pages)
        self.total_pages = self.num_pages + 1
        self.scratch_id = self.num_pages      # trailing page, never issued
        self.plan = plan
        # Shard index of a mesh-sharded scheduler owning this pool (None
        # for single-device pools); CapacityErrors name it so fleet
        # backpressure is attributable to the exhausted device.
        self.shard = shard

        # The pool *is* a ring cache whose batch rows are pages.
        self.pool_specs = module.cache_specs(cfg, self.total_pages,
                                             self.page_slots)
        self.pool_avals = spec_avals(self.pool_specs)

        placed = (plan is not None and plan.enabled
                  and plan.covers("kv_cache"))
        if placed:
            self.placement = plan.place(
                {"kv_cache": self.pool_avals})["kv_cache"]
            self.domain = self.placement.domain
            self.faultmap: Optional[FaultMap] = plan.fault_map()
        else:
            self.placement = None
            self.domain = None
            self.faultmap = None
        self.leaves = self._build_leaves()
        self._by_path = {l.path: l for l in self.leaves}
        # A request's page-table width is set by its *longest* ring:
        # window leaves address only the first length//page_slots table
        # entries window-modularly, so a family whose rings are all
        # windows allocates fewer pages per request (rotated-out pages
        # are never held -- the pool-level eviction win).
        self.n_logical_pages = max(l.n_pages for l in self.leaves)
        # words one page id provisions across every leaf and layer
        self.page_set_words = sum(l.n_layers * l.page_words
                                  for l in self.leaves)
        self.request_words = self.n_logical_pages * self.page_set_words

        weak, rate = self._page_classes()
        order = sorted(range(self.num_pages), key=lambda p: (rate[p], p))
        self._strong: List[int] = [p for p in order if not weak[p]]
        self._weak: List[int] = [p for p in order if weak[p]]
        self._weak_set = set(self._weak)
        self._rate = rate
        self._owned: set = set()
        # Copy-on-write prefix sharing: refcounted holders per shared
        # page, plus the content-hash prefix cache (prompt-prefix bytes
        # -> the shared pages storing it, insertion-ordered for LRU
        # eviction under capacity pressure).
        self._shared: Dict[int, set] = {}
        self._prefix: Dict[bytes, np.ndarray] = {}
        # Self-healing: pages retired for good (suspect rows); never
        # reinserted into the free lists, monotonically growing.
        self._quarantined: set = set()
        # Observability hook: callable(kind, **data) the scheduler
        # installs to trace pool-side events (quarantine/prefix_evict).
        self.on_event = None

    # ---- static layout ---------------------------------------------------
    def _build_leaves(self) -> Tuple[_PoolLeaf, ...]:
        flat, _ = jax.tree_util.tree_flatten_with_path(self.pool_avals)
        axes = jax.tree_util.tree_leaves(cache_slot_axes(self.pool_specs))
        by_path = {}
        for (p, aval), ax in zip(flat, axes):
            by_path[jax.tree_util.keystr(p)] = (aval, ax)
        # standalone specs tell us the request-side ring length and
        # layout kind of every leaf (full / window); state and cross
        # leaves never reach the page pool -- the scheduler routes
        # families carrying them through the per-slot state arena
        req_specs = self.module.cache_specs(self.cfg, 1, self.max_len)
        req_axes = jax.tree_util.tree_leaves(cache_slot_axes(req_specs))
        req_layouts = jax.tree_util.tree_leaves(
            cache_layouts(req_specs, self.max_len))
        req_flat, _ = jax.tree_util.tree_flatten_with_path(
            spec_avals(req_specs))
        leaf_meta = {}
        for (p, aval), ax, lay in zip(req_flat, req_axes, req_layouts):
            path = jax.tree_util.keystr(p)
            if lay in ("state", "cross"):
                raise PagedLayoutError(
                    f"cache leaf {path} has layout {lay!r}: "
                    "slotless carried state / cross-attention leaves "
                    "cannot live in the page pool (accepted layouts: "
                    "'full', 'window').  Serve this family through the "
                    "scheduler's per-slot state arena instead")
            length = aval.shape[ax]
            if self.page_slots > length:
                raise PagedLayoutError(
                    f"cache leaf {path}: page_slots={self.page_slots} "
                    f"exceeds the {lay!r} ring length {length} "
                    "(cfg.window); a page must fit inside the ring -- "
                    f"pick page_slots <= {length}")
            if length % self.page_slots:
                field = ("cfg.window" if lay == "window"
                         else "ServeConfig.max_len")
                raise PagedLayoutError(
                    f"cache leaf {path}: page_slots={self.page_slots} "
                    f"does not divide the leaf's ring length {length} "
                    f"({field}); a {lay!r} ring pages window-modularly "
                    "only when page_slots divides it -- pick page_slots "
                    f"from the divisors of {length}")
            leaf_meta[path] = (length, lay)

        placed = self.placement is not None
        tabs = (arena.leaf_block_tables(self.placement) if placed else None)
        paths = ([lp.path for lp in self.placement.leaves] if placed
                 else None)
        ecc = placed and self.domain.ecc
        out = []
        for path in sorted(by_path):
            m = _LEAF_RE.match(path)
            if not m:
                raise PagedLayoutError(
                    f"cache leaf {path} is not a ring k/v/pos leaf of "
                    "the shared attention-cache layout (containers "
                    "'prefix'/'periods'/'rest', leaves 'k'/'v'/'pos'); "
                    "the page pool accepts 'full' and 'window' ring "
                    "layouts only -- carried-state and cross-attention "
                    "leaves serve through the per-slot state arena")
            aval, ax = by_path[path]
            stacked = m.group(1) == "periods"
            if (ax != (2 if stacked else 1)):
                raise PagedLayoutError(
                    f"cache leaf {path}: slot axis {ax} is not the ring "
                    "axis the paged layout expects (axis 2 for stacked "
                    "period leaves, axis 1 otherwise)")
            length, layout = leaf_meta[path]
            n_layers = aval.shape[0] if stacked else 1
            wps = _leaf_words_per_slot(aval.shape, ax, aval.dtype)
            page_words = wps * self.page_slots
            if BLOCK_WORDS % page_words:
                raise PagedLayoutError(
                    f"cache leaf {path}: page size {page_words} words "
                    f"({self.page_slots} slots x {wps} words) does not "
                    f"divide the arena block size ({BLOCK_WORDS} words); "
                    "pick page_slots so every page sits inside one "
                    "allocation block")
            if ecc and (page_words % 2 or
                        (m.group(3) in ("k", "v") and wps % 2)):
                raise PagedLayoutError(
                    f"cache leaf {path}: ECC domains need even page and "
                    f"slot word counts (codeword pairs), got page="
                    f"{page_words} / slot={wps} words; use a head_dim/"
                    "page_slots combination giving even word counts or "
                    "drop ecc=True from the domain")
            layer_words = self.total_pages * page_words
            pb = pc = bb = bp = None
            if placed:
                bb, bp = tabs[paths.index(path)]
                pb_flat, pc_flat = arena.refine_tables(bb, bp, page_words)
                n = n_layers * self.total_pages
                pb = pb_flat[:n].reshape(n_layers, self.total_pages)
                pc = pc_flat[:n].reshape(n_layers, self.total_pages)
            out.append(_PoolLeaf(
                path=path, container=m.group(1), slot_key=m.group(2),
                which=m.group(3), stacked=stacked, n_layers=n_layers,
                wps=wps, page_words=page_words, layer_words=layer_words,
                length=length, n_pages=length // self.page_slots,
                layout=layout,
                page_base=pb, page_pc=pc, block_base=bb, block_pc=bp))
        return tuple(out)

    def _page_classes(self):
        """(weak, worst-rate) per usable page, aggregated over every
        leaf/layer slice the page id provisions.

        A page is *weak* when any of its K/V payload slices overlaps a
        weak DRAM row (the paper's C9 spatial-clustering unit) -- row
        granularity, not allocation-block granularity, because pages
        are much smaller than blocks and block-level classing would
        condemn every page that merely shares a 16 KiB block with one
        weak row.  The ``pos`` bookkeeping sliver (one word per slot,
        packed so densely that a single weak row would condemn the
        whole pool) is not counted: weak-row avoidance targets the
        payload rows that dominate a request's fault exposure."""
        weak = np.zeros(self.num_pages, bool)
        rate = np.zeros(self.num_pages, np.float64)
        if self.placement is None:
            return weak, rate
        fmap = self.faultmap
        wpc = fmap.geometry.bytes_per_pc // 4
        wpr = 1 << fmap.words_per_row_log2
        rates = fmap.predicted_rates(self.domain.voltage)
        rmasks = {int(pc): fmap.weak_row_mask(int(pc))
                  for pc in self.domain.pc_ids}
        for leaf in self.leaves:
            base = leaf.page_base[:, :self.num_pages].astype(np.int64)
            pc = leaf.page_pc[:, :self.num_pages]
            for l in range(leaf.n_layers):
                rate = np.maximum(rate, rates[pc[l]])
                if leaf.which not in ("k", "v"):
                    continue
                in_pc = base[l] - pc[l].astype(np.int64) * wpc
                r0 = in_pc // wpr
                r1 = (in_pc + leaf.page_words - 1) // wpr
                w = np.array([rmasks[int(c)][int(a):int(b) + 1].any()
                              for c, a, b in zip(pc[l], r0, r1)])
                weak |= w
        return weak, rate

    @property
    def uniform(self) -> bool:
        """True when every ring leaf is full-length (no window leaves).
        Copy-on-write prefix sharing keys on page-aligned *position*
        prefixes, which only line up across requests for full rings --
        the scheduler disables sharing for non-uniform layouts."""
        return all(l.layout == "full" for l in self.leaves)

    # ---- allocation ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._strong) + len(self._weak)

    def alloc(self, n_pages: int, tier="cheap") -> np.ndarray:
        """Allocate ``n_pages`` page ids under ``tier``'s policy.

        Raises :class:`CapacityError` (the scheduler's backpressure
        signal) when the pool cannot supply them -- for weak-avoiding
        tiers, weak pages do not count as supply.
        """
        tier = resolve_tier(tier)
        name = self.domain.name if self.domain is not None else "page_pool"
        if tier.avoid_weak_rows:
            if len(self._strong) < n_pages:
                raise CapacityError(
                    name, n_pages * self.page_set_words * 4,
                    len(self._strong) * self.page_set_words * 4,
                    f"{n_pages} weak-free pages for tier {tier.name!r}; "
                    f"{len(self._weak)} weak pages held back",
                    shard=self.shard)
            taken = self._strong[:n_pages]
            del self._strong[:n_pages]
        else:
            if self.free_pages < n_pages:
                raise CapacityError(
                    name, n_pages * self.page_set_words * 4,
                    self.free_pages * self.page_set_words * 4,
                    f"{n_pages} pages for tier {tier.name!r}",
                    shard=self.shard)
            taken = self._weak[:n_pages]
            del self._weak[:n_pages]
            need = n_pages - len(taken)
            if need:
                # least-reliable strong pages first: keep the reliable
                # end of the pool for weak-avoiding tiers
                taken += self._strong[-need:][::-1]
                del self._strong[-need:]
        self._owned.update(taken)
        return np.asarray(taken, np.int32)

    def free(self, page_ids) -> None:
        """Return pages to the pool (double-free raises ValueError;
        freeing a page that still has sharing holders raises
        PageSharingError -- shared pages retire through release())."""
        ids = [int(p) for p in np.asarray(page_ids).reshape(-1)]
        held = [p for p in ids if p in self._shared]
        if held:
            raise PageSharingError(
                f"free() of shared pages {sorted(held)[:4]}: pages with "
                "live holders must be released per holder, not freed")
        bad = [p for p in ids if p not in self._owned]
        if bad or len(set(ids)) != len(ids):
            raise ValueError(
                f"double free of pool pages {sorted(set(bad) or set(ids))[:4]}: "
                "not currently allocated")
        for p in ids:
            self._reinsert(p)

    def _reinsert(self, p: int) -> None:
        self._owned.discard(p)
        lst = self._weak if p in self._weak_set else self._strong
        keys = [(self._rate[q], q) for q in lst]
        lst.insert(bisect.bisect_left(keys, (self._rate[p], p)), p)

    @property
    def num_weak_pages(self) -> int:
        """Pages whose backing arena blocks contain weak rows (a static
        property of this pool's fault map, not of allocation state)."""
        return len(self._weak_set)

    # ---- self-healing: quarantine + migration accounting ----------------
    @property
    def quarantined_pages(self) -> Tuple[int, ...]:
        return tuple(sorted(self._quarantined))

    def is_owned(self, pid) -> bool:
        return int(pid) in self._owned

    def is_quarantined(self, pid) -> bool:
        return int(pid) in self._quarantined

    def quarantine(self, page_ids) -> None:
        """Permanently retire pages whose backing rows turned suspect.

        Free pages leave the free lists; owned *private* pages leave the
        owned set (their tenant must already have been migrated off --
        the device-side copy is :meth:`PagedKVCache.migrate_pages`).
        Shared pages raise :class:`PageSharingError` (migrate the
        sharing holders first via :meth:`migrate`).  Already-quarantined
        pages are skipped, so quarantine grows monotonically and the
        call is idempotent under replayed suspect reports.
        """
        ids = sorted({int(q) for q in np.asarray(page_ids).reshape(-1)})
        held = [p for p in ids if p in self._shared]
        if held:
            raise PageSharingError(
                f"quarantine of shared pages {held[:4]}: pages with live "
                "holders must be migrated (migrate()) before retiring")
        fresh = []
        for p in ids:
            if p in self._quarantined:
                continue
            if not (0 <= p < self.num_pages):
                raise ValueError(f"quarantine of invalid page id {p}")
            if p in self._owned:
                self._owned.discard(p)
            elif p in self._strong:
                self._strong.remove(p)
            elif p in self._weak:
                self._weak.remove(p)
            self._quarantined.add(p)
            fresh.append(p)
        if fresh and self.on_event is not None:
            self.on_event("quarantine", pages=fresh)

    def migrate(self, src, dst) -> None:
        """Host accounting of one page migration: ``dst`` (freshly
        allocated, private) takes over ``src``'s role and ``src`` is
        quarantined.  A shared ``src`` hands its holder set and prefix-
        cache entries to ``dst``, so sharing tenants keep their pages
        without ever observing the move (their page tables were
        rewritten inside the step)."""
        src, dst = int(src), int(dst)
        if dst not in self._owned or dst in self._shared:
            raise PageSharingError(
                f"migrate target {dst} must be a freshly allocated "
                "private page")
        if src not in self._owned:
            raise ValueError(f"migrate source {src} is not allocated")
        if src in self._shared:
            self._shared[dst] = self._shared.pop(src)
            for pids in self._prefix.values():
                pids[pids == src] = dst
        self._owned.discard(src)
        self._quarantined.add(src)

    def page_rows(self, pid: int) -> Tuple[Tuple[int, int], ...]:
        """(pc, DRAM row) pairs the K/V payload of page ``pid`` overlaps
        -- the telemetry fold's page -> row map (same row math as
        :meth:`_page_classes`, ``pos`` excluded)."""
        if self.placement is None:
            return ()
        fmap = self.faultmap
        wpc = fmap.geometry.bytes_per_pc // 4
        wpr = 1 << fmap.words_per_row_log2
        out = set()
        for leaf in self.leaves:
            if leaf.which not in ("k", "v"):
                continue
            for l in range(leaf.n_layers):
                base = int(leaf.page_base[l, pid])
                pc = int(leaf.page_pc[l, pid])
                in_pc = base - pc * wpc
                for r in range(in_pc // wpr,
                               (in_pc + leaf.page_words - 1) // wpr + 1):
                    out.add((pc, r))
        return tuple(sorted(out))

    def pages_on_row(self, pc: int, row: int) -> np.ndarray:
        """Usable page ids whose K/V payload overlaps DRAM row ``row``
        of pseudo-channel ``pc`` -- the suspect-row -> victim-pages map
        the migration planner walks."""
        hits = np.zeros(self.num_pages, bool)
        if self.placement is None:
            return np.zeros((0,), np.int32)
        fmap = self.faultmap
        wpc = fmap.geometry.bytes_per_pc // 4
        wpr = 1 << fmap.words_per_row_log2
        for leaf in self.leaves:
            if leaf.which not in ("k", "v"):
                continue
            base = leaf.page_base[:, :self.num_pages].astype(np.int64)
            pcs = leaf.page_pc[:, :self.num_pages]
            for l in range(leaf.n_layers):
                in_pc = base[l] - pcs[l].astype(np.int64) * wpc
                r0 = in_pc // wpr
                r1 = (in_pc + leaf.page_words - 1) // wpr
                hits |= (pcs[l] == pc) & (r0 <= row) & (row <= r1)
        return np.flatnonzero(hits).astype(np.int32)

    def page_codewords(self) -> int:
        """SECDED codewords one page's K/V payload spans across every
        leaf and layer (the per-step observation size of a fully-read
        page, for the posterior's binomial update)."""
        return sum(l.n_layers * l.page_words // 2
                   for l in self.leaves if l.which in ("k", "v"))

    def page_blocks(self, page_ids) -> set:
        """(pc, arena block) pairs backing ``page_ids`` over every leaf
        and layer."""
        out: set = set()
        if self.placement is None:
            return out
        wpc = self.faultmap.geometry.bytes_per_pc // 4
        for leaf in self.leaves:
            for l in range(leaf.n_layers):
                for p in (int(q) for q in
                          np.asarray(page_ids).reshape(-1)):
                    base = int(leaf.page_base[l, p])
                    pc = int(leaf.page_pc[l, p])
                    out.add((pc, (base - pc * wpc) // ALIGN_WORDS))
        return out

    def live_blocks(self) -> set:
        """(pc, arena block) pairs that still back live (owned or
        shared) pages -- the :meth:`DomainAllocator.free` guard's view
        of this pool."""
        return self.page_blocks(sorted(self._owned))

    def retirable_blocks(self) -> Tuple[Segment, ...]:
        """Quarantined-page blocks with no live pages left on them, as
        block-aligned segments ready for ``DomainAllocator.quarantine``
        (a block only retires once every tenant sharing it is gone --
        pages are much smaller than allocation blocks)."""
        if self.placement is None or not self._quarantined:
            return ()
        dead = self.page_blocks(sorted(self._quarantined))
        live = self.live_blocks()
        free = self.page_blocks(self._strong + self._weak)
        wpc = self.faultmap.geometry.bytes_per_pc // 4
        return tuple(
            Segment(leaf_start_word=0, n_words=ALIGN_WORDS, pc=pc,
                    phys_base_word=pc * wpc + blk * ALIGN_WORDS)
            for pc, blk in sorted(dead - live - free))

    # ---- copy-on-write prefix sharing ------------------------------------
    @property
    def shared_pages(self) -> int:
        return len(self._shared)

    @property
    def prefix_entries(self) -> int:
        return len(self._prefix)

    def is_shared(self, pid) -> bool:
        return int(pid) in self._shared

    def share(self, page_ids, holder) -> None:
        """Convert privately-owned pages into shared pages held by
        ``holder`` (refcount 1).  Re-sharing raises PageSharingError."""
        for p in (int(q) for q in np.asarray(page_ids).reshape(-1)):
            if p not in self._owned:
                raise PageSharingError(
                    f"share of page {p}: not currently allocated")
            if p in self._shared:
                raise PageSharingError(
                    f"share of page {p}: already shared (holders="
                    f"{len(self._shared[p])}); use retain()")
            self._shared[p] = {holder}

    def retain(self, page_ids, holder) -> None:
        """Add ``holder`` to shared pages' holder sets."""
        pids = [int(q) for q in np.asarray(page_ids).reshape(-1)]
        for p in pids:
            if p not in self._shared:
                raise PageSharingError(
                    f"retain of page {p}: not a shared page")
            if holder in self._shared[p]:
                raise PageSharingError(
                    f"retain of page {p}: holder {holder!r} already "
                    "holds it")
        for p in pids:
            self._shared[p].add(holder)

    def release(self, page_ids, holder) -> None:
        """Drop ``holder``'s reference; a page whose holder set empties
        returns to the free lists (reliability-ordered recycling).
        Releasing a page the holder does not hold -- including a second
        release from the same request -- raises PageSharingError."""
        pids = [int(q) for q in np.asarray(page_ids).reshape(-1)]
        for p in pids:
            if p not in self._shared or holder not in self._shared[p]:
                raise PageSharingError(
                    f"double release of page {p} by holder {holder!r}: "
                    "not currently held")
        for p in pids:
            self._shared[p].discard(holder)
            if not self._shared[p]:
                del self._shared[p]
                self._reinsert(p)

    def cow_fork(self, src_pid, tier="cheap") -> int:
        """Allocate the private target page for copy-on-write-forking
        the shared page ``src_pid`` (first write to a partially-filled
        shared boundary page).  Forking an unshared page is a protocol
        violation and raises PageSharingError; the device-side row copy
        is :meth:`PagedKVCache.reset_and_fork`."""
        src = int(np.asarray(src_pid).reshape(()))
        if src not in self._shared:
            raise PageSharingError(
                f"cow_fork of page {src}: not a shared page (private "
                "pages are written in place, never forked)")
        return int(self.alloc(1, tier)[0])

    def match_prefix(self, tokens: np.ndarray) -> Tuple[int, np.ndarray]:
        """Longest cached prefix of ``tokens``: the full prompt first
        (partial boundary page -> COW fork), then page-aligned prefixes
        descending.  Returns (matched_len, shared page ids covering
        ceil(matched/page_slots) logical pages), or (0, empty)."""
        toks = np.ascontiguousarray(tokens, np.int32).reshape(-1)
        n = toks.shape[0]
        lengths = [n] + [k * self.page_slots
                         for k in range(n // self.page_slots, 0, -1)
                         if k * self.page_slots != n]
        for ln in lengths:
            pids = self._prefix.get(toks[:ln].tobytes())
            if pids is not None:
                return ln, pids.copy()
        return 0, np.zeros((0,), np.int32)

    def register_prefix(self, tokens: np.ndarray, page_ids) -> bool:
        """Publish ``page_ids`` as the shared storage of the prompt
        prefix ``tokens``; each page gains the entry's cache holder, so
        the prefix outlives its creating tenant until evicted.  Pages
        must already be shared (the scheduler share()s a creator's own
        pages at its prefill->decode transition).  Returns False when
        the key is already cached."""
        toks = np.ascontiguousarray(tokens, np.int32).reshape(-1)
        key = toks.tobytes()
        if key in self._prefix:
            return False
        pids = np.asarray(page_ids, np.int32).reshape(-1)
        self.retain(pids, ("__prefix__", key))
        self._prefix[key] = pids.copy()
        return True

    def evict_prefix(self) -> bool:
        """Drop the least-recently-registered prefix entry, releasing
        its cache holds (pages still mapped by live tenants survive via
        their holders).  Returns False when the cache is empty."""
        if not self._prefix:
            return False
        key = next(iter(self._prefix))
        pids = self._prefix.pop(key)
        self.release(pids, ("__prefix__", key))
        if self.on_event is not None:
            self.on_event("prefix_evict", pages=len(pids))
        return True

    # ---- exports ---------------------------------------------------------
    def request_placement(self, page_ids) -> Optional[RequestPlacement]:
        """The page-granular placement of one request's *standalone*
        (B=1, contiguous) cache: logical page ``j`` of layer ``l`` lives
        where pool page ``page_ids[j]``'s layer-``l`` slice lives.  Feed
        it to ``generate(..., kv_placement=...)`` to replay a scheduler
        request through PR 3's engine on identical physical words."""
        if self.placement is None:
            return None
        pids = np.asarray(page_ids, np.int64).reshape(-1)
        assert pids.shape[0] == self.n_logical_pages, pids.shape
        leaves = []
        for leaf in self.leaves:
            lp = pids[:leaf.n_pages]       # window leaves: leading slice
            base = leaf.page_base[:, lp].reshape(-1)       # (nl * n_lp,)
            pc = leaf.page_pc[:, lp].reshape(-1)
            leaves.append(PagedLeafPlacement(
                path=leaf.path,
                n_words=leaf.n_layers * leaf.length * leaf.wps,
                page_words=leaf.page_words,
                page_base=np.ascontiguousarray(base, np.uint32),
                page_pc=np.ascontiguousarray(pc, np.int32)))
        return RequestPlacement(
            group="kv_cache", domain=self.domain, leaves=tuple(leaves),
            map_seed=(self.faultmap.seed
                      if self.faultmap is not None else None))


# ---------------------------------------------------------------------------
# Device-side paged cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PagedLeafEntry:
    base: jax.Array            # (n_layers, total_pages) uint32
    thr: jax.Array             # (n_layers, total_pages, NUM_THR_COLS)


@dataclasses.dataclass(frozen=True)
class _PagedSlotEntry:
    k: _PagedLeafEntry
    v: _PagedLeafEntry
    length: int = 0            # this ring's logical length (<= max_len)
    n_pages: int = 0           # leading page-table entries it addresses


@dataclasses.dataclass
class PagedServingCtx:
    """Decode-step hook for the paged serving cache.

    Same ``covers``/``update``/``attend`` protocol as
    :class:`repro.serving.readpath.ReadPathCtx`, with the cache write
    overridden to the pool-page scatter and attention to the batched
    paged kernel.  Inactive serving slots' page-table rows point at the
    pool's scratch page and their positions are stale -- their lanes
    compute masked garbage that the scheduler discards.
    """

    entries: Dict[str, _PagedSlotEntry]
    page_table: jax.Array      # (S, n_logical_pages) int32
    length: int                # logical ring length (max_len)
    page_slots: int
    seed: int
    words_per_row_log2: int
    method: str
    ecc: bool
    inject: bool
    interpret: Optional[bool] = None

    def covers(self, slot_key: str) -> bool:
        return slot_key in self.entries

    def update(self, slot_key: str, cache, new, pos):
        """Paged ring write (see :func:`repro.models.cache.paged_update`)
        of one decode token per serving slot.  Window rings write
        window-modularly through the leading ``n_pages`` table entries."""
        from repro.models.cache import paged_update
        e = self.entries[slot_key]
        return paged_update(cache, new, pos,
                            self.page_table[:, :e.n_pages],
                            e.length, self.page_slots)

    def attend(self, slot_key: str, layer_idx, q, cache, *, q_pos,
               causal: bool, window: int, scale=None):
        e = self.entries[slot_key]
        idx = (jnp.uint32(0) if layer_idx is None
               else layer_idx.astype(jnp.uint32))
        kb = jax.lax.dynamic_index_in_dim(e.k.base, idx, keepdims=False)
        kt = jax.lax.dynamic_index_in_dim(e.k.thr, idx, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(e.v.base, idx, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(e.v.thr, idx, keepdims=False)
        # the kernel derives the ring length from the table width, so a
        # window leaf hands it the leading window//page_slots entries
        return faulty.paged_decode_attention(
            q, cache["k"], cache["v"], cache["pos"],
            self.page_table[:, :e.n_pages],
            q_pos=jnp.reshape(q_pos, (-1,)).astype(jnp.int32),
            k_tables=(kb, kt), v_tables=(vb, vt), causal=causal,
            window=window, scale=scale, seed=self.seed,
            method=self.method,
            words_per_row_log2=self.words_per_row_log2, ecc=self.ecc,
            inject=self.inject, interpret=self.interpret)


@dataclasses.dataclass
class MixedServingCtx(PagedServingCtx):
    """Mixed prefill-chunk/decode step hook: one compiled step serves
    slots in both phases.

    Decode lanes (``dec``) behave exactly like :class:`PagedServingCtx`
    on their first token column (fused paged kernel, read-path
    injection).  Prefill lanes instead run *clean* chunked prefill
    attention: K/V for positions < ``prefill_end`` are gathered from
    the slot's pages (stored clean until the prefill->decode transition
    injects them, so the numerics are bit-identical to standalone
    prefill) with arithmetic key positions -- never the stored ``pos``
    bookkeeping, which is write-path corrupt on shared prefix pages.
    Writes below ``wstart`` (positions held by copy-on-write shared
    pages) are redirected to the scratch sink.
    """

    dec: Optional[jax.Array] = None           # (S,) bool
    wstart: Optional[jax.Array] = None        # (S,) int32
    prefill_end: Optional[jax.Array] = None   # (S,) int32
    scratch_id: int = 0
    # per-slot_key pre-write window snapshot + fresh chunk K/V, stashed
    # by update() for attend() (see _stash_window)
    _window: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    def _stash_window(self, slot_key: str, e: _PagedSlotEntry, cache,
                      new, pos):
        """Window rings under chunked prefill: an in-chunk ring write at
        position p overwrites slot p % window, which may still hold a
        pre-chunk position an *earlier* chunk query needs (window=8,
        chunk=4: writing pos 12 evicts pos 4, which query 10 still
        attends).  So before writing, gather the last ``window``
        pre-chunk positions from the ring in ascending-position order
        (bit-identical summation order to standalone prefill) and stash
        them together with the fresh chunk K/V; prefill lanes attend
        over the concatenation instead of re-gathering the clobbered
        ring."""
        w = e.length
        qp = jnp.asarray(pos, jnp.int32)
        if qp.ndim == 1:
            qp = qp[:, None]
        c0 = qp[:, 0]                          # chunk-start per slot
        kpos = (c0[:, None] - w
                + jnp.arange(w, dtype=jnp.int32)[None, :])   # ascending
        slot = jnp.where(kpos >= 0, kpos, 0) % w
        lp = slot // self.page_slots
        row = slot % self.page_slots
        pid = jnp.take_along_axis(self.page_table[:, :e.n_pages], lp,
                                  axis=1)
        rk = cache["k"][pid, row]              # (S, w, KH, D)
        rv = cache["v"][pid, row]
        self._window[slot_key] = (rk, rv, kpos, new["k"], new["v"], qp)

    def update(self, slot_key: str, cache, new, pos):
        from repro.models.cache import paged_update
        e = self.entries[slot_key]
        if e.length < self.length:
            self._stash_window(slot_key, e, cache, new, pos)
        return paged_update(cache, new, pos,
                            self.page_table[:, :e.n_pages],
                            e.length, self.page_slots,
                            wstart=self.wstart, scratch_id=self.scratch_id)

    def attend(self, slot_key: str, layer_idx, q, cache, *, q_pos,
               causal: bool, window: int, scale=None):
        from repro.models import layers as mlayers
        e = self.entries[slot_key]
        qp = jnp.asarray(q_pos, jnp.int32)
        s = q.shape[0]
        qp = jnp.broadcast_to(qp.reshape(s, -1), q.shape[:2])
        dec_out = PagedServingCtx.attend(
            self, slot_key, layer_idx, q[:, :1], cache,
            q_pos=jnp.maximum(qp[:, 0], 0), causal=causal, window=window,
            scale=scale)
        if e.length < self.length:
            # window ring: pre-write snapshot + fresh chunk (stashed by
            # update), both in ascending position order
            rk, rv, rkpos, fk, fv, fqp = self._window[slot_key]
            gk = jnp.concatenate([rk, fk], axis=1)
            gv = jnp.concatenate([rv, fv], axis=1)
            kpos = jnp.concatenate([rkpos, fqp], axis=1)
            kv_valid = kpos >= 0
        else:
            gk = cache["k"][self.page_table]  # (S, n_lp, ps, KH, D)
            gv = cache["v"][self.page_table]
            gk = gk.reshape((s, self.length) + gk.shape[3:])
            gv = gv.reshape((s, self.length) + gv.shape[3:])
            kpos = jnp.broadcast_to(
                jnp.arange(self.length, dtype=jnp.int32),
                (s, self.length))
            kv_valid = kpos < self.prefill_end[:, None]
        pref = mlayers.attention(q, gk, gv, q_positions=qp,
                                 k_positions=kpos, causal=causal,
                                 window=window, kv_valid=kv_valid,
                                 softmax_scale=scale)
        col0 = jnp.where(self.dec[:, None, None, None], dec_out,
                         pref[:, :1])
        return jnp.concatenate([col0, pref[:, 1:]], axis=1)


class PagedKVCache:
    """Device-side data paths of one :class:`PagePool`.

    Pure functions over the pool tree (the scheduler jits and donates
    around them): init, prefill scatter, admission-time injection, the
    per-step write-path injection, and the decode-step context.
    """

    def __init__(self, pool: PagePool, interpret: Optional[bool] = None):
        self.pool = pool
        self.interpret = interpret
        self._tables = {}
        if pool.placement is not None:
            for leaf in pool.leaves:
                self._tables[leaf.path] = (
                    jnp.asarray(leaf.page_base),
                    jnp.asarray(leaf.page_pc),
                    jnp.asarray(leaf.block_base),
                    jnp.asarray(leaf.block_pc))

    def init_pool(self):
        from repro.models.cache import init_cache
        return init_cache(self.pool.pool_specs)

    def _leaf_arrays(self, tree, leaf: _PoolLeaf):
        arr = tree[leaf.container][leaf.slot_key][leaf.which]
        return arr if leaf.stacked else arr[None]

    def _store(self, tree, leaf: _PoolLeaf, arr_l):
        tree[leaf.container][leaf.slot_key][leaf.which] = (
            arr_l if leaf.stacked else arr_l[0])

    @staticmethod
    def _tree_copy(tree):
        return jax.tree_util.tree_map(lambda x: x, tree)

    # ---- context ---------------------------------------------------------
    def make_ctx(self, page_table, voltage, *, method: str,
                 inject: bool, dec=None, wstart=None,
                 prefill_end=None, chaos=None) -> PagedServingCtx:
        """Decode-step context; passing the per-slot phase arrays
        (``dec``/``wstart``/``prefill_end``) returns the mixed
        chunked-prefill/decode variant instead.

        ``chaos`` is the fault-injection hook for self-healing tests: a
        traced ``(total_pages,)`` bool mask of pages whose rows "went
        weak at runtime" -- their K/V *read* thresholds are overridden
        column-wise to the weak rates (:data:`_WEAKEN_COLS`), so the
        fused kernel starts drawing weak-rate faults (and ECC
        corrections) from them without retracing.  Only the read path is
        chaoticized: stored data stays governed by the static map, so a
        migrated page's payload remains bit-identical to what a clean
        replay on the final placement reads back.
        """
        p = self.pool
        entries: Dict[str, Dict[str, _PagedLeafEntry]] = {}
        if p.placement is not None:
            table = p.faultmap.threshold_table(voltage)
            seed, wprl2 = p.faultmap.seed, p.faultmap.words_per_row_log2
            ecc = p.domain.ecc
        else:
            table = seed = None
            wprl2, ecc, inject = 0, False, False
        wtab = (table[:, jnp.asarray(_WEAKEN_COLS)]
                if table is not None and chaos is not None else None)
        geom: Dict[str, Tuple[int, int]] = {}
        for leaf in p.leaves:
            if leaf.which not in ("k", "v"):
                continue
            geom[leaf.slot_key] = (leaf.length, leaf.n_pages)
            if table is not None:
                pb, pc, _, _ = self._tables[leaf.path]
                thr = table[pc]
                if wtab is not None:
                    thr = jnp.where(chaos[None, :, None], wtab[pc], thr)
                e = _PagedLeafEntry(base=pb, thr=thr)
            else:
                nl, tp = leaf.n_layers, p.total_pages
                e = _PagedLeafEntry(
                    base=jnp.zeros((nl, tp), jnp.uint32),
                    thr=jnp.zeros((nl, tp, NUM_THR_COLS), jnp.uint32))
            entries.setdefault(leaf.slot_key, {})[leaf.which] = e
        kw = dict(
            entries={k: _PagedSlotEntry(k=h["k"], v=h["v"],
                                        length=geom[k][0],
                                        n_pages=geom[k][1])
                     for k, h in entries.items()},
            page_table=page_table, length=p.max_len,
            page_slots=p.page_slots, seed=(seed if seed is not None else 0),
            words_per_row_log2=wprl2, method=method, ecc=ecc,
            inject=inject, interpret=self.interpret)
        if dec is not None:
            return MixedServingCtx(dec=dec, wstart=wstart,
                                   prefill_end=prefill_end,
                                   scratch_id=p.scratch_id, **kw)
        return PagedServingCtx(**kw)

    # ---- self-healing: in-step migration + telemetry scrub ---------------
    def migrate_pages(self, tree, mig_src, mig_dst):
        """Copy page ``mig_src[i]`` -> ``mig_dst[i]`` in every leaf and
        layer -- the device half of a page migration, run *inside* the
        donated step before the decode read.  Disabled migration slots
        carry the scratch id in both arrays: copying scratch onto
        itself (including several times -- identical values per
        duplicate index) is the traced-shape no-op.  In read mode the
        buffer holds clean data, so the copy lands the exact payload a
        replay on the destination placement prefills -- the
        bit-identity contract's load-bearing property."""
        p = self.pool
        tree = self._tree_copy(tree)
        src = jnp.asarray(mig_src, jnp.int32)
        dst = jnp.asarray(mig_dst, jnp.int32)
        for leaf in p.leaves:
            arr_l = self._leaf_arrays(tree, leaf)
            vals = arr_l[:, src]                  # (nl, M, ps, ...)
            self._store(tree, leaf, arr_l.at[:, dst].set(vals))
        return tree

    def scrub_telemetry(self, tree, page_table, voltage, *, chaos=None):
        """Per-page SECDED event counts over the K/V payload of every
        page ``page_table`` references: (corrected, uncorrectable),
        each ``(total_pages,)`` int32.

        Pure jnp on the stored (clean, read-mode) buffers using the
        same deterministic mask math as the fused kernel
        (:func:`repro.kernels.ecc.ecc.arena_ecc_events` on identical
        physical word ids and thresholds), so the counts match what the
        attention read path corrects without adding a single pallas
        launch.  Patrol-scrub semantics: the whole page is scanned,
        including ring slots no request has filled yet -- a fault on a
        still-clean slot counts (slightly over the tokens actually
        attended), which is fine for telemetry whose job is detecting
        weak rows, not billing exact reads.  Pages only reachable from
        the scratch sink report zero.  ``chaos`` applies the same
        weak-column threshold override as :meth:`make_ctx`, so the
        scrub sees the synthetic row-goes-weak fault the kernel sees.
        """
        p = self.pool
        zero = jnp.zeros((p.total_pages,), jnp.int32)
        if p.placement is None or not p.domain.ecc:
            return zero, zero
        table = p.faultmap.threshold_table(voltage)
        wtab = (table[:, jnp.asarray(_WEAKEN_COLS)]
                if chaos is not None else None)
        corrected, uncorrectable = zero, zero
        for leaf in p.leaves:
            if leaf.which not in ("k", "v"):
                continue
            pb, pc, _, _ = self._tables[leaf.path]
            thr = table[pc]                 # (nl, tp, NUM_THR_COLS)
            if wtab is not None:
                thr = jnp.where(chaos[None, :, None], wtab[pc], thr)
            arr_l = self._leaf_arrays(tree, leaf)
            u32 = faulty._tile_to_u32(
                arr_l.reshape(leaf.n_layers * p.total_pages, -1))
            u32 = u32.reshape(leaf.n_layers, p.total_pages,
                              leaf.page_words)
            wid = (pb[:, :, None]
                   + jnp.arange(leaf.page_words, dtype=jnp.uint32)[None,
                                                                   None, :])
            thr_row = tuple(thr[:, :, c][:, :, None]
                            for c in range(NUM_THR_COLS))
            _, corr, bad = arena_ecc_events(
                u32, wid, thr_row, seed=p.faultmap.seed,
                words_per_row_log2=p.faultmap.words_per_row_log2)
            corrected = corrected + jnp.sum(
                corr.astype(jnp.int32), axis=(0, 2))
            uncorrectable = uncorrectable + jnp.sum(
                bad.astype(jnp.int32), axis=(0, 2))
        read = jnp.zeros((p.total_pages,), bool)
        read = read.at[page_table.reshape(-1)].set(True)
        read = read.at[p.scratch_id].set(False)
        return (jnp.where(read, corrected, 0),
                jnp.where(read, uncorrectable, 0))

    # ---- admission -------------------------------------------------------
    def scatter_request(self, tree, cache, page_ids):
        """Write a standalone (B=1) post-prefill cache into the pages
        ``page_ids`` -- pure data movement, so a freshly admitted
        request's pages hold exactly the state standalone prefill
        produces (stale tenants are fully overwritten, empty ring slots
        reset to the init state)."""
        p = self.pool
        tree = self._tree_copy(tree)
        pids = jnp.asarray(page_ids, jnp.int32)
        for leaf in p.leaves:
            arr_l = self._leaf_arrays(tree, leaf)
            src = self._leaf_arrays(cache, leaf)             # (nl, 1, L, ...)
            tail = src.shape[3:]
            src = src.reshape((leaf.n_layers, leaf.n_pages,
                               p.page_slots) + tail)
            self._store(tree, leaf,
                        arr_l.at[:, pids[:leaf.n_pages]].set(src))
        return tree

    def reset_and_fork(self, tree, page_ids, fork_src, fork_dst,
                       fork_rows, fork_pos0):
        """Chunked-prefill admission: reset ``page_ids`` to the init
        state (stale-tenant scrub: pos -> -1, values -> 0), then
        copy-on-write-fork the partially-filled shared boundary page
        ``fork_src`` into the private page ``fork_dst``: rows below
        ``fork_rows`` copy the shared page's K/V (clean by the sharing
        protocol) with positions synthesized arithmetically from
        ``fork_pos0`` (the stored ``pos`` of a shared page is its
        creator's write-path corruption -- never copied), rows at or
        above reset to init.  Shared entries of an admission's page
        table are passed as the scratch id (resetting the scratch sink
        is harmless), which keeps the traced shapes fixed; a disabled
        fork points both ``fork_src`` and ``fork_dst`` at scratch."""
        p = self.pool
        tree = self._tree_copy(tree)
        pids = jnp.asarray(page_ids, jnp.int32)
        dst = jnp.asarray(fork_dst, jnp.int32)
        rows = jnp.arange(p.page_slots, dtype=jnp.int32)
        keep = rows < jnp.asarray(fork_rows, jnp.int32)
        for leaf in p.leaves:
            arr_l = self._leaf_arrays(tree, leaf)
            if leaf.which == "pos":
                arr_l = arr_l.at[:, pids].set(-1)
                fp = jnp.where(keep,
                               jnp.asarray(fork_pos0, jnp.int32) + rows, -1)
                fork = jnp.broadcast_to(fp, (leaf.n_layers, p.page_slots))
            else:
                arr_l = arr_l.at[:, pids].set(0)
                srcv = jax.lax.dynamic_index_in_dim(
                    arr_l, jnp.asarray(fork_src, jnp.int32), axis=1,
                    keepdims=False)                       # (nl, ps, ...)
                mask = keep.reshape((1, p.page_slots)
                                    + (1,) * (srcv.ndim - 2))
                fork = jnp.where(mask, srcv, 0)
            self._store(tree, leaf,
                        arr_l.at[:, dst].set(fork.astype(arr_l.dtype)))
        return tree

    def inject_pages(self, tree, page_ids, voltage, *, method: str,
                     skip_kv: bool):
        """Whole-page injection of one request's pages -- the paged twin
        of the engine's post-prefill ``init_inject`` (same physical
        words, same masks).  ``skip_kv``: in read mode the K/V leaves
        stay clean in the buffer (the read path corrupts them at load);
        only bookkeeping (``pos``) takes write-path faults."""
        p = self.pool
        if p.placement is None:
            return tree
        tree = self._tree_copy(tree)
        table = p.faultmap.threshold_table(voltage)
        pids = jnp.asarray(page_ids, jnp.int32)
        n_lp = pids.shape[0]
        for leaf in p.leaves:
            if skip_kv and leaf.which in ("k", "v"):
                continue
            _, _, bb, bp = self._tables[leaf.path]
            bt = table[bp]
            arr_l = self._leaf_arrays(tree, leaf)
            vals = arr_l[:, pids]                    # (nl, n_lp, ps, ...)
            shape = vals.shape
            u32 = faulty._tile_to_u32(
                vals.reshape(leaf.n_layers * n_lp, -1))
            u32 = u32.reshape(leaf.n_layers, n_lp, leaf.page_words)
            off = (jnp.arange(leaf.n_layers, dtype=jnp.uint32)[:, None, None]
                   * np.uint32(leaf.layer_words)
                   + pids.astype(jnp.uint32)[None, :, None]
                   * np.uint32(leaf.page_words)
                   + jnp.arange(leaf.page_words, dtype=jnp.uint32)[None,
                                                                   None, :])
            out, _ = arena.corrupt_words(
                u32, off, bb, bt, seed=p.faultmap.seed, method=method,
                words_per_row_log2=p.faultmap.words_per_row_log2,
                ecc=p.domain.ecc)
            out = faulty._tile_from_u32(
                out.reshape(leaf.n_layers * n_lp, -1), vals.dtype,
                (leaf.n_layers * n_lp,) + shape[2:]).reshape(shape)
            self._store(tree, leaf, arr_l.at[:, pids].set(out))
        return tree

    # ---- per-step write path ---------------------------------------------
    def post_step_inject(self, tree, page_table, q_pos, voltage, *,
                         mode: str, method: str):
        """Write-path injection of exactly the words a decode step
        wrote: the (pid, row) slot of every active serving slot, in
        every layer.  In read mode only the ``pos`` bookkeeping is
        covered (K/V corruption happens at load); ECC domains corrupt
        the whole ``pos`` pages instead (single positions split
        codewords), matching the standalone engine's fallback.
        """
        p = self.pool
        if p.placement is None:
            return tree
        tree = self._tree_copy(tree)
        table = p.faultmap.threshold_table(voltage)
        kw = dict(seed=p.faultmap.seed, method=method,
                  words_per_row_log2=p.faultmap.words_per_row_log2)
        qp = jnp.reshape(q_pos, (-1,)).astype(jnp.int32)
        n_s = qp.shape[0]
        for leaf in p.leaves:
            if mode == "read" and leaf.which in ("k", "v"):
                continue
            # window-modular: each leaf's ring slot for position p is
            # p % length, addressed through the leading length//ps
            # entries of the request's page table
            slot = qp % leaf.length
            lp = slot // p.page_slots
            row = slot % p.page_slots
            pid = jnp.take_along_axis(page_table, lp[:, None],
                                      axis=1)[:, 0]
            _, _, bb, bp = self._tables[leaf.path]
            bt = table[bp]
            arr_l = self._leaf_arrays(tree, leaf)
            if leaf.which == "pos" and p.domain.ecc:
                # single positions split ECC codewords: corrupt the
                # whole pos pages (cheap -- pos is 1 word per slot)
                ptab_l = page_table[:, :leaf.n_pages]
                vals = arr_l[:, ptab_l]          # (nl, S, n_lp, ps)
                u32 = jax.lax.bitcast_convert_type(vals, jnp.uint32)
                off = (jnp.arange(leaf.n_layers,
                                  dtype=jnp.uint32)[:, None, None, None]
                       * np.uint32(leaf.layer_words)
                       + ptab_l.astype(jnp.uint32)[None, :, :, None]
                       * np.uint32(leaf.page_words)
                       + jnp.arange(p.page_slots,
                                    dtype=jnp.uint32)[None, None, None, :])
                out, _ = arena.corrupt_words(u32, off, bb, bt, ecc=True,
                                             **kw)
                out = jax.lax.bitcast_convert_type(out, vals.dtype)
                self._store(tree, leaf,
                            arr_l.at[:, ptab_l].set(out))
                continue
            vals = arr_l[:, pid, row]            # (nl, S, ...)
            shape = vals.shape
            u32 = faulty._tile_to_u32(
                vals.reshape(leaf.n_layers * n_s, -1))
            u32 = u32.reshape(leaf.n_layers, n_s, leaf.wps)
            off = (jnp.arange(leaf.n_layers, dtype=jnp.uint32)[:, None, None]
                   * np.uint32(leaf.layer_words)
                   + (pid.astype(jnp.uint32) * np.uint32(p.page_slots)
                      + row.astype(jnp.uint32))[None, :, None]
                   * np.uint32(leaf.wps)
                   + jnp.arange(leaf.wps, dtype=jnp.uint32)[None, None, :])
            out, _ = arena.corrupt_words(u32, off, bb, bt,
                                         ecc=p.domain.ecc, **kw)
            out = faulty._tile_from_u32(
                out.reshape(leaf.n_layers * n_s, -1), vals.dtype,
                (leaf.n_layers * n_s,) + shape[2:]).reshape(shape)
            self._store(tree, leaf, arr_l.at[:, pid, row].set(out))
        return tree
