"""Read-path injection context: wiring the fused faulty-attention kernel
into a model's decode step.

A :class:`ReadPathCtx` is built once per (traced) KV voltage from the
serving placement: for every K/V cache leaf it carries the arena
engine's ``block -> (physical base word, threshold row)`` tables, with
threshold rows already gathered at the current voltage.  The model's
decode attention calls :meth:`ReadPathCtx.attend`, which routes the
stored cache buffers through
:func:`repro.kernels.flash_attention.faulty.faulty_decode_attention` --
faults are computed on the K/V tile already in VMEM, so injection costs
zero extra HBM passes and a traced voltage sweep compiles once.

With ``inject=False`` the context still routes attention through the
fused kernel but skips the mask math entirely: the write-path serving
modes use this so every injection mode shares bit-identical attention
numerics (the scanned decode's cross-mode equality tests rely on it).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.domains import GroupPlacement
from repro.core.faultmap import FaultMap
from repro.kernels.flash_attention import faulty

# Cache leaves the read path covers: the ring K/V buffers of the shared
# attention cache layout (models/stack.py containers x models/cache.py
# ring leaves).  Everything else (pos bookkeeping, recurrent states)
# stays on the (incremental) write path.
_KV_LEAF_RE = re.compile(
    r"^\['(prefix|periods|rest)'\]\['([^']+)'\]\['([kv])'\]$")


def supports(module) -> bool:
    """Whether a family module's decode step accepts a read-path ctx."""
    return bool(getattr(module, "SUPPORTS_READ_PATH", False))


def state_leaf_paths(specs, max_len: int) -> Tuple[str, ...]:
    """Keystr paths of the carried-``state`` cache leaves of a family.

    These are the leaves the read path can never cover: recurrent
    state (RG-LRU h/conv, mLSTM matrix memories) is rewritten whole on
    every decode step, so write-path injection re-applies the domain's
    stuck-at masks to each new value -- a fault acquired on write
    PERSISTS for the lifetime of the request (corrupt-once-on-write),
    unlike ring K/V rows which are written once and only re-masked
    idempotently.  The persistent-fault oracle test keys on this list
    to know which leaves to difference across steps.
    """
    from repro.models.base import cache_layouts
    flat, _ = jax.tree_util.tree_flatten_with_path(
        cache_layouts(specs, max_len))
    return tuple(jax.tree_util.keystr(p) for p, lay in flat
                 if lay == "state")


@dataclasses.dataclass(frozen=True)
class _LeafEntry:
    base: jax.Array           # (num_blocks,) uint32 physical block bases
    thr: jax.Array            # (num_blocks, NUM_THR_COLS) @ current voltage
    layer_words: int          # words per period index (0 = unstacked leaf)
    words_log2: int           # table granularity (arena blocks or pages)


@dataclasses.dataclass(frozen=True)
class _SlotEntry:
    k: _LeafEntry
    v: _LeafEntry


def _kv_leaves(placement: GroupPlacement, aval_by_path):
    """(slot key, 'k'|'v', placement leaf, aval) for every K/V leaf."""
    out = []
    for lp in placement.leaves:
        m = _KV_LEAF_RE.match(lp.path)
        if not m:
            continue
        out.append((m.group(2), m.group(3), lp, aval_by_path[lp.path],
                    m.group(1) == "periods"))
    return out


def _avals_by_path(cache_avals):
    flat, _ = jax.tree_util.tree_flatten_with_path(cache_avals)
    return {jax.tree_util.keystr(p): a for p, a in flat}


def cache_supported(placement: GroupPlacement, cache_avals) -> bool:
    """Whether every K/V leaf of this placement can ride the read path:
    word-aligned slots and (for ECC domains) codeword-aligned tiles."""
    by_path = _avals_by_path(cache_avals)
    matched = _kv_leaves(placement, by_path)
    if not matched:
        return False
    for _, _, lp, aval, stacked in matched:
        shape = aval.shape[1:] if stacked else aval.shape
        if len(shape) != 4:
            return False
        _, _, kh, d = shape
        try:
            wps = faulty.kv_words_per_slot(kh, d, aval.dtype)
        except ValueError:
            return False
        if placement.domain.ecc and wps % 2:
            return False
    return True


def kv_paths(placement: GroupPlacement) -> Tuple[str, ...]:
    """keystr paths of the leaves the read path corrupts (skipped by the
    incremental write-path injection)."""
    return tuple(lp.path for lp in placement.leaves
                 if _KV_LEAF_RE.match(lp.path))


@dataclasses.dataclass
class ReadPathCtx:
    entries: Dict[str, _SlotEntry]
    seed: int
    words_per_row_log2: int
    method: str
    ecc: bool
    inject: bool
    interpret: Optional[bool] = None
    # KV-tile size override: page-granular placements force the tile to
    # one page so the flash accumulation order (and hence the bits of
    # the output) matches the paged serving kernel over the same words.
    bkv: Optional[int] = None

    def covers(self, slot_key: str) -> bool:
        return slot_key in self.entries

    def update(self, slot_key: str, cache, new, pos):
        """Decode cache write for this ctx's layout: plain contiguous
        ring update here; the paged serving ctx overrides it with the
        pool-page scatter.  Owning the write on the ctx keeps the
        model's decode branch cache-layout-agnostic."""
        from repro.models.cache import ring_update
        return ring_update(cache, new, pos)

    def attend(self, slot_key: str, layer_idx, q, cache, *, q_pos,
               causal: bool, window: int, scale=None):
        """Fused decode attention over a slot's ring cache.

        ``layer_idx``: traced period index for stacked slots (None for
        prefix/remainder layers); ``q_pos``: the decode token's absolute
        position -- its ring slot is exempt from corruption (the value
        still sits in the store buffer, it never round-tripped through
        undervolted HBM this step).
        """
        e = self.entries[slot_key]
        k, v, pos = cache["k"], cache["v"], cache["pos"]
        idx = jnp.uint32(0) if layer_idx is None else layer_idx.astype(
            jnp.uint32)
        clean = (q_pos % k.shape[1]).astype(jnp.int32)
        assert e.k.words_log2 == e.v.words_log2, (e.k, e.v)
        return faulty.faulty_decode_attention(
            q, k, v, pos, q_pos=q_pos,
            k_tables=(e.k.base, e.k.thr), v_tables=(e.v.base, e.v.thr),
            k_word0=idx * np.uint32(e.k.layer_words),
            v_word0=idx * np.uint32(e.v.layer_words),
            causal=causal, window=window, scale=scale, seed=self.seed,
            method=self.method, words_per_row_log2=self.words_per_row_log2,
            ecc=self.ecc, inject=self.inject, clean_slot=clean,
            bkv=self.bkv, interpret=self.interpret,
            words_log2=e.k.words_log2)


def build_ctx(placement: GroupPlacement, faultmap: FaultMap, cache_avals,
              *, voltage, method: str, inject: bool,
              interpret=None) -> ReadPathCtx:
    """Build the per-voltage context (``voltage`` may be traced: the
    threshold gather happens inside the caller's trace, so per-request
    voltage schedules re-execute one compiled decode).

    ``placement`` may be an arena-backed GroupPlacement (block-granular
    tables) or a page-granular request placement exported by the paged
    serving cache (:mod:`repro.serving.paged`) -- the kernel addressing
    is table-driven either way; a paged placement additionally pins the
    KV tile to one page so the numerics match the paged batch kernel.
    """
    ms = getattr(placement, "map_seed", None)
    if ms is not None and ms != faultmap.seed:
        raise ValueError(
            f"kv_placement was exported from a pool whose fault map "
            f"has seed {ms}, but the replay plan's fault map has seed "
            f"{faultmap.seed}: a sharded scheduler's shards draw "
            "distinct maps, so replay a request against ITS shard's "
            "plan (sched.shard_plan(result.shard)) or the tokens "
            "would silently diverge")
    table = faultmap.threshold_table(voltage)
    tabs = arena.leaf_addr_tables(placement)
    by_path = _avals_by_path(cache_avals)
    halves: Dict[str, Dict[str, _LeafEntry]] = {}
    bkv = set()
    for i, lp in enumerate(placement.leaves):
        m = _KV_LEAF_RE.match(lp.path)
        if not m:
            continue
        slot_key, which, stacked = m.group(2), m.group(3), \
            m.group(1) == "periods"
        aval = by_path[lp.path]
        bb, bp, lg2 = tabs[i]
        shape = aval.shape[1:] if stacked else aval.shape
        _, length, kh, d = shape
        wps = faulty.kv_words_per_slot(kh, d, aval.dtype)
        layer_words = shape[0] * length * wps if stacked else 0
        if hasattr(lp, "page_words"):
            assert lp.page_words % wps == 0, (lp.path, lp.page_words, wps)
            bkv.add(lp.page_words // wps)
        halves.setdefault(slot_key, {})[which] = _LeafEntry(
            base=jnp.asarray(bb), thr=table[jnp.asarray(bp)],
            layer_words=int(layer_words), words_log2=lg2)
    entries = {key: _SlotEntry(k=h["k"], v=h["v"])
               for key, h in halves.items() if "k" in h and "v" in h}
    assert len(bkv) <= 1, f"inconsistent page slot counts {bkv}"
    return ReadPathCtx(
        entries=entries, seed=faultmap.seed,
        words_per_row_log2=faultmap.words_per_row_log2, method=method,
        ecc=placement.domain.ecc, inject=inject, interpret=interpret,
        bkv=(bkv.pop() if bkv else None))
