"""Continuous-batching serving scheduler over the reliability-aware
paged KV cache, optionally sharded across a 1-D ``serve`` device mesh.

PR 3's serving path decodes one fixed, contiguously placed batch at a
time: admission happens once, at ``generate()``, and capacity is
whatever that batch's placement grabbed.  This module replaces that
with an admission -> prefill -> decode -> retire loop over concurrent
requests:

  * requests wait in a FIFO queue; admission takes a free serving slot
    plus ``max_len / page_slots`` pool pages matching the request's
    criticality tier (weak-block pages go to tolerant requests first).
    :class:`~repro.core.domains.CapacityError` from the page pool -- or
    from the admission governor -- is *backpressure*: the request simply
    waits for pages to be retired, it never crashes the loop.
  * prefill is *chunked into the decode step*: each compiled step
    consumes up to ``ServeConfig.prefill_chunk`` prompt tokens for
    every prefilling slot (written through the paged path, attended
    with clean gathered attention) while decoding slots advance one
    token through the fused paged kernel.  There is no separate
    prefill program, so the compile count is flat in prompt length
    *and* traffic -- ONE jitted donated step serves any mix of phases,
    lengths and tiers, and the per-step KV voltage stays a traced
    scalar the admission governor can re-plan without a recompile.
  * prompt prefixes are shared copy-on-write: an admitted prompt is
    matched against the pool's content-hash prefix cache and maps the
    longest page-aligned cached prefix read-only (per-page refcounts);
    a partially-filled boundary page is forked onto a private page
    before first write.  Pages that may become shared are allocated
    under the strictest placement tier (``shared_prefix``: weak-free
    blocks, most-reliable pseudo-channels first), because one
    corrupted shared page would poison every tenant mapping it.
  * retirement releases per-page references; pages whose holder sets
    empty return to the pool (reliability-ordered recycling), turning
    capacity reclaimed by tolerating weak blocks -- and by not storing
    shared prefixes twice -- directly into extra concurrent traffic.

Mesh sharding (``mesh=`` + ``launch.mesh.make_serve_mesh``): the slot
array, page pool and page tables are partitioned over the mesh's
``serve`` axis.  Each shard owns its own arena blocks, its own
*independently seeded* :class:`~repro.core.faultmap.FaultMap` (the
per-part margin variation real fleets exhibit: distinct weak-row draws
AND distinct per-PC threshold calibrations), its own governor and its
own voltage setpoint -- heterogeneous fleets undervolt some stacks
deeper than others, aggregated by :func:`repro.training.governor.
fleet_report`.  The donated decode step stays ONE jitted program: a
``shard_map`` whose body switches on ``lax.axis_index('serve')`` into
the shard's seed-specialized branch.  Kernel seeds are folded into the
pallas bodies at trace time throughout the stack (hash streams,
per-plane mask seeds), so per-shard maps are obtained by branch
specialization, never by tracing a seed -- one trace
(``decode_traces == 1``), one pallas launch per shard, and the
compiled step contains **zero collectives**: prefill chunks, paged
decode attention and COW prefix sharing are shard-local by
construction; only the sampled token lanes return to the host.

Token-equivalence contract (asserted in tests/test_scheduler.py and
tests/test_sharded_scheduler.py): every request's tokens are
bit-identical to running it alone through PR 3's ``generate()`` with
the request's page placement (:meth:`PagePool.request_placement`) on
*its shard's* fault map -- greedy and sampled, read and write injection
modes, with and without ECC, shared prefix or not, at every shard
count.  The mechanism behind sharing-compatible injection: shared
pages store *clean* K/V in every mode and the decode kernel's
read-path masks are applied at load in every mode -- the stuck-at
masks and the ECC round are idempotent, so privately-stored-corrupt
pages re-mask to themselves while clean shared pages corrupt to
exactly the standalone stored values.  The one exclusion is a
*governor-driven* run whose voltage actually moves mid-request: the
domain rail is global per shard, so a re-plan triggered by a later
admission also retunes the in-flight requests' thresholds, and a
standalone replay (one constant ``kv_voltage``) cannot reproduce that
trajectory -- ``RequestResult.voltage`` records the admission-time
re-plan, not a promise that the whole lifetime ran there.
``kv_injection='rewrite'`` (the legacy full-cache oracle) cannot
address pages and is rejected up front.  Prompts longer than
``max_len`` are rejected at submit: chunked prefill writes the prompt
through the ring in place and cannot rotate it the way the standalone
prefill's tail-keep does.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains import ALIGN_WORDS, CapacityError, DomainAllocator
from repro.core.engine import _static_value, resolve_method
from repro.core.faultmodel import V_MIN, V_NOM
from repro.core.hbm import fleet_map_seeds
from repro.models.base import ArchBundle, ArchConfig, cache_layouts
from repro.obs.metrics import (MetricsRegistry, ObsConfig,
                               init_step_counters, step_counter_delta)
from repro.obs.trace import EventTrace
from repro.serving.engine import ServeConfig, sample_tokens
from repro.serving.paged import PagedKVCache, PagePool, RequestPlacement


class ShardLayoutError(ValueError):
    """A shard layout that cannot partition the scheduler cleanly:
    capacity or page pool not divisible by the shard count, a mesh
    without the serve axis, or colliding per-shard fault-map seeds."""


def validate_shard_layout(n_shards: int, num_slots: int, num_pages: int,
                          *, base_seed: int = 0,
                          seeds: Optional[Sequence[int]] = None,
                          setpoints: Optional[Sequence[float]] = None,
                          ) -> Tuple[Tuple[int, ...],
                                     Tuple[Optional[float], ...]]:
    """Check a serve-mesh layout and resolve per-shard seeds/setpoints.

    Pure host logic (unit-testable without devices): slots and pages
    must split evenly so every shard runs the same compiled shapes,
    and per-shard fault-map seeds must be distinct -- two shards
    sharing a seed would silently model one physical HBM part twice.
    """
    if n_shards < 1:
        raise ShardLayoutError(f"n_shards={n_shards} must be >= 1")
    if num_slots % n_shards:
        raise ShardLayoutError(
            f"ServeConfig capacity num_slots={num_slots} is not "
            f"divisible by the shard count {n_shards}: every shard owns "
            "an equal fixed-capacity slot range (pick num_slots = "
            f"{n_shards} * slots_per_shard)")
    if num_pages % n_shards:
        raise ShardLayoutError(
            f"num_pages={num_pages} is not divisible by the shard "
            f"count {n_shards}: the page pool is partitioned into "
            "equal per-shard arenas (pick num_pages = "
            f"{n_shards} * pages_per_shard)")
    if seeds is None:
        seeds = fleet_map_seeds(base_seed, n_shards)
    seeds = tuple(int(s) for s in seeds)
    if len(seeds) != n_shards:
        raise ShardLayoutError(
            f"shard_seeds has {len(seeds)} entries for {n_shards} "
            "shards: pass exactly one fault-map seed per shard")
    dup = [s for s, c in collections.Counter(seeds).items() if c > 1]
    if dup:
        raise ShardLayoutError(
            f"per-shard fault-map seed collision: seed(s) {sorted(dup)} "
            "appear on more than one shard; every shard models an "
            "independent physical HBM part and must draw its own map "
            "(use core.hbm.fleet_map_seeds or pass distinct seeds)")
    if setpoints is None:
        sp: Tuple[Optional[float], ...] = (None,) * n_shards
    else:
        if len(setpoints) != n_shards:
            raise ShardLayoutError(
                f"shard_setpoints has {len(setpoints)} entries for "
                f"{n_shards} shards: pass one governor setpoint per "
                "shard (or None)")
        sp = tuple(None if s is None else float(s) for s in setpoints)
    return seeds, sp


@dataclasses.dataclass
class Request:
    """One serving request.

    ``max_new_tokens`` defaults to the scheduler's ServeConfig value;
    ``tier`` routes page allocation (a name from
    ``repro.core.domains.TIERS`` or a CriticalityTier); ``key`` is the
    request's sampling PRNGKey (defaults to PRNGKey(0), exactly like
    ``generate``)."""

    rid: Any
    tokens: Any                       # prompt token ids, shape (prompt_len,)
    max_new_tokens: Optional[int] = None
    tier: Any = "cheap"
    key: Optional[jax.Array] = None
    # Modality inputs beyond tokens, UNBATCHED (whisper ``frames`` of
    # shape (enc_len, d_model), VLM ``patches`` of (enc_len,
    # frontend_dim)); the scheduler adds the batch axis at admission.
    # Only consumed on the state-arena route; the paged route serves
    # token-only families and rejects extras.
    extras: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class RequestResult:
    rid: Any
    tokens: np.ndarray                # (1, max_new_tokens), like generate()
    page_ids: np.ndarray
    placement: Optional[RequestPlacement]
    voltage: Optional[float]          # KV-domain voltage at admission
    ttft_steps: Optional[int] = None  # steps from admission to token 0
    pages_shared: int = 0             # prefix pages mapped read-only
    shard: int = 0                    # mesh shard that served the request


@dataclasses.dataclass(frozen=True)
class SelfHealConfig:
    """Policy of the self-healing loop: online ECC telemetry -> live
    fault-map posterior -> in-step page migration -> block quarantine.

    Requires an ECC'd KV domain in ``kv_injection='read'`` mode: the
    SECDED correction counters *are* the telemetry signal, and read-mode
    storage (clean buffers, corruption applied at load) is what makes a
    migrated page's payload bit-identical to a standalone replay on its
    final placement.

    ``max_migrations`` sizes the per-shard in-step migration slots (the
    donated step always carries that many src/dst lanes; idle lanes
    point at the scratch page).  ``migrate_tier`` places migration
    targets (strictest first -- a page is being moved *because* its row
    went bad); ``fallback_tier`` is tried when the strict tier is
    exhausted.  ``setpoint_cap`` bounds the graceful-degradation
    escalation: when admission fails under quarantine pressure, the
    shard's rate setpoint is raised x10 (up to the cap) instead of
    crashing the loop.
    """

    suspect_threshold: float = 0.9
    max_migrations: int = 4
    migrate_tier: Any = "shared_prefix"
    fallback_tier: Any = "cheap"
    setpoint_cap: float = 1.0


@dataclasses.dataclass
class _AdmitPlan:
    """Host-side page plan of one admission."""

    row: np.ndarray                   # (n_logical_pages,) page-table row
    retained: np.ndarray              # shared prefix pages mapped read-only
    eligible: bool                    # may register / extend the prefix cache
    matched: int                      # shared prefix length (tokens)
    fs: int                           # retained page count (full pages)
    cover: int                        # pages holding prompt rows
    fork_src: int                     # shared boundary page (scratch = none)
    fork_rows: int                    # clean rows to COW-copy
    cursor0: int                      # first prompt position to prefill
    wstart0: int                      # write floor (shared rows are r/o)


@dataclasses.dataclass
class _Shard:
    """Per-shard runtime: the shard's own arena-backed page pool (its
    fault map drawn from the shard's seed), paged-cache helper, voltage
    governor + setpoint, injection method resolved against the shard's
    map, and the donated admission-time jits specialized to the shard's
    slice of the stacked pool state."""

    index: int
    seed: Optional[int]
    plan: Any
    pool: PagePool
    kvc: PagedKVCache
    governor: Any
    setpoint: Optional[float]
    method: str
    voltage: float
    admit_reset: Any = None
    transition_pool: Any = None
    # Self-healing runtime (None unless SelfHealConfig is passed):
    posterior: Any = None             # FaultMapPosterior over this map
    allocator: Any = None             # adopted DomainAllocator (quarantine)
    suspects: Any = None              # current suspect (pc, row) set
    retired_blocks: Any = None        # (pc, blk) already quarantined
    migrations: int = 0
    migration_stalls: int = 0
    setpoint_escalations: int = 0


class ContinuousBatchingScheduler:
    """Serve overlapping requests through one compiled mixed
    prefill/decode step.

    ``num_slots`` bounds concurrent requests (the compiled step's batch
    width); ``num_pages`` x ``page_slots`` sizes the shared KV pool;
    ``max_active`` optionally throttles admissions below ``num_slots``
    (benchmarks use it to sweep concurrency on one compiled step).

    With ``mesh`` (a 1-D serve mesh from ``make_serve_mesh``), slots
    and pages are global totals split evenly across the mesh's shards;
    each shard draws its own fault map from ``shard_seeds`` (default:
    ``fleet_map_seeds`` of the plan's seed, so shard 0 reproduces the
    single-device map) and, under a governor, admits against its own
    ``shard_setpoints`` entry -- a heterogeneous-voltage fleet on one
    compiled step.

    This class is also the zoo's single serving front door: families
    whose cache does not page (no ``SUPPORTS_PAGED`` on the module --
    MoE/MLA, recurrent-state hybrids, xLSTM, whisper, VLM) are
    dispatched from ``__new__`` to the state-arena route
    (:class:`repro.serving.statearena.StateArenaScheduler`), which
    honors the same construction surface and the same contracts (one
    donated step, flat trace/launch budgets, bit-exact solo replay).
    """

    def __new__(cls, bundle: Optional[ArchBundle] = None, *args,
                **kwargs):
        if (cls is ContinuousBatchingScheduler and bundle is not None
                and not getattr(bundle.module, "SUPPORTS_PAGED", False)):
            from repro.serving.statearena import StateArenaScheduler
            return object.__new__(StateArenaScheduler)
        return object.__new__(cls)

    def __init__(self, bundle: ArchBundle, cfg: ArchConfig, params,
                 sc: ServeConfig, *, num_slots: int, num_pages: int,
                 page_slots: int, max_active: Optional[int] = None,
                 dist=None, interpret: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "serve",
                 shard_seeds: Optional[Sequence[int]] = None,
                 shard_setpoints: Optional[Sequence[float]] = None,
                 self_heal: Optional[SelfHealConfig] = None,
                 obs: Optional[ObsConfig] = None):
        if sc.kv_injection == "rewrite":
            raise ValueError(
                "kv_injection='rewrite' re-injects whole contiguous "
                "caches every token; the scheduler's caches are paged "
                "and the legacy segment walker cannot address pages. "
                "Use 'read' (fused, default via 'auto') or 'write' "
                "(incremental), or serve one-shot batches through "
                "generate() if you need the rewrite oracle")
        if sc.kv_injection not in ("auto", "read", "write"):
            raise ValueError(f"unknown kv_injection {sc.kv_injection!r}")
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.dist = dist
        self.mesh = mesh
        self._axis = mesh_axis
        if mesh is not None:
            if mesh_axis not in mesh.axis_names:
                raise ShardLayoutError(
                    f"mesh axis {mesh_axis!r} missing from mesh axes "
                    f"{tuple(mesh.axis_names)}: build the serving mesh "
                    "with launch.mesh.make_serve_mesh (1-D, axis "
                    "'serve') or pass mesh_axis=<your axis>")
            other = [a for a in mesh.axis_names
                     if a != mesh_axis and mesh.shape[a] > 1]
            if other:
                raise ShardLayoutError(
                    f"serve mesh must be 1-D: axes {other} have size > "
                    "1 besides the serve axis (model-parallel axes are "
                    "not supported by the sharded scheduler)")
            if dist is not None:
                raise ShardLayoutError(
                    "mesh and dist are mutually exclusive: the serve "
                    "mesh shards requests (data parallel); per-shard "
                    "model parallelism is not supported")
            self.n_shards = int(mesh.shape[mesh_axis])
        else:
            if shard_seeds is not None or shard_setpoints is not None:
                raise ShardLayoutError(
                    "shard_seeds/shard_setpoints require a serve mesh "
                    "(pass mesh=make_serve_mesh(n))")
            self.n_shards = 1
        self.num_slots = int(num_slots)
        self.max_active = int(num_slots if max_active is None
                              else max_active)
        if self.num_slots < 1 or not 1 <= self.max_active <= self.num_slots:
            raise ValueError(
                f"need 1 <= max_active ({self.max_active}) <= num_slots "
                f"({self.num_slots})")
        self.chunk = int(sc.prefill_chunk)
        if self.chunk < 1:
            raise ValueError(
                f"prefill_chunk={sc.prefill_chunk} must be >= 1: every "
                "step consumes at least one prompt token per prefilling "
                "slot")

        plan = (sc.undervolt
                if sc.undervolt is not None and sc.undervolt.enabled
                else None)
        base_seed = plan.map_seed if plan is not None else 0
        seeds, setpoints = validate_shard_layout(
            self.n_shards, self.num_slots, int(num_pages),
            base_seed=base_seed, seeds=shard_seeds,
            setpoints=shard_setpoints)
        self.shard_seeds = seeds
        self.slots_per_shard = self.num_slots // self.n_shards
        self.pages_per_shard = int(num_pages) // self.n_shards

        # ---- voltage control / injection mode (mirrors generate()) ----
        # Shard 0 carries the base plan exactly; the global checks below
        # run against it, then each shard re-resolves what depends on
        # its own fault map (method dispatch, governor frontier).
        self.governor = sc.governor
        pool0 = PagePool(bundle.module, cfg, max_len=sc.max_len,
                         page_slots=page_slots,
                         num_pages=self.pages_per_shard, plan=plan,
                         shard=(0 if mesh is not None else None))
        placed = pool0.placement is not None
        if self.governor is not None:
            if sc.kv_voltage is not None:
                raise ValueError(
                    "ServeConfig.governor and kv_voltage are mutually "
                    "exclusive voltage controls")
            if sc.undervolt is None or self.governor.plan is not sc.undervolt:
                raise ValueError(
                    "sc.governor must be built from sc.undervolt (its "
                    "frontier/capacity tables belong to that plan's "
                    "fault map and domains)")
            if not placed:
                raise ValueError(
                    "ServeConfig.governor is set but the undervolt plan "
                    "does not place 'kv_cache' (or is disabled): "
                    "admission governance would silently be a no-op")
            if self.governor.config.domain != pool0.domain.name:
                raise ValueError(
                    f"sc.governor governs domain "
                    f"{self.governor.config.domain!r} but the KV cache "
                    f"is placed in domain {pool0.domain.name!r}")
        if any(s is not None for s in setpoints) and self.governor is None:
            raise ShardLayoutError(
                "shard_setpoints need an admission governor "
                "(ServeConfig.governor): setpoints are per-shard "
                "governor walk targets")
        eff_v = sc.kv_voltage if sc.kv_voltage is not None else (
            pool0.domain.voltage if placed else None)
        sv = _static_value(eff_v) if eff_v is not None else None
        self.active = placed and (
            self.governor is not None
            or eff_v is None
            or sv is None                       # traced: assume live
            or sv < V_MIN - 1e-9)
        mode = sc.kv_injection
        if mode == "auto":
            mode = "read"
        self.mode = mode
        if self.active and sc.kv_method == "auto":
            if self.governor is not None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch under an admission "
                    "governor (the KV voltage is re-planned per "
                    "admission); pass kv_method='word' or 'bitwise' "
                    "explicitly")
            if sv is None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch from a traced "
                    "kv_voltage (method selection is static); pass "
                    "kv_method='word' or 'bitwise' explicitly for "
                    "traced voltage schedules")
        volt0 = float(sv) if sv is not None else (
            eff_v if eff_v is not None else 0.0)

        # ---- per-shard pools, fault maps, governors -------------------
        self._shards: List[_Shard] = []
        for k, seed in enumerate(seeds):
            if plan is None:
                plan_k = None
            elif int(seed) == int(plan.map_seed):
                plan_k = plan
            else:
                plan_k = dataclasses.replace(plan, map_seed=int(seed))
            pool_k = pool0 if (k == 0 and plan_k is plan) else PagePool(
                bundle.module, cfg, max_len=sc.max_len,
                page_slots=page_slots, num_pages=self.pages_per_shard,
                plan=plan_k, shard=(k if mesh is not None else None))
            gov_k = None
            if self.governor is not None:
                if plan_k is plan:
                    gov_k = self.governor
                else:
                    from repro.training.governor import VoltageGovernor
                    gov_k = VoltageGovernor(plan_k, self.governor.config)
            method_k = sc.kv_method
            if self.active and method_k == "auto":
                method_k = ("word" if pool_k.domain.ecc
                            else resolve_method(pool_k.faultmap,
                                                pool_k.placement, sv))
            self._shards.append(_Shard(
                index=k, seed=(int(seed) if plan is not None else None),
                plan=plan_k, pool=pool_k,
                kvc=PagedKVCache(pool_k, interpret=interpret),
                governor=gov_k, setpoint=setpoints[k], method=method_k,
                voltage=volt0))
        self.pool = self._shards[0].pool       # single-device back-compat
        self.kvc = self._shards[0].kvc
        self.method = self._shards[0].method

        # ---- self-healing loop (telemetry -> posterior -> migration) --
        self._heal = self_heal
        self._mig_slots = (self_heal.max_migrations
                           if self_heal is not None else 0)
        if self_heal is not None:
            if not placed or not pool0.domain.ecc:
                raise ValueError(
                    "self_heal needs an ECC'd KV-cache placement: the "
                    "SECDED correction counters are the telemetry "
                    "signal (place kv_cache in a domain with ecc=True)")
            if self.mode != "read":
                raise ValueError(
                    f"self_heal needs kv_injection='read' (got "
                    f"{self.mode!r}): read-mode pages store clean data, "
                    "which is what makes an in-step page copy land the "
                    "exact payload a replay on the final placement "
                    "prefills")
            if self_heal.max_migrations < 1:
                raise ValueError(
                    f"self_heal.max_migrations="
                    f"{self_heal.max_migrations} must be >= 1")
            from repro.core.faultmap_posterior import FaultMapPosterior
            for sh in self._shards:
                sh.posterior = FaultMapPosterior(sh.pool.faultmap)
                sh.suspects = set()
                sh.retired_blocks = set()
                # Long-lived ownership of the pool's arena blocks:
                # place() discards its internal allocators, so block
                # retirement adopts the placement into a fresh one and
                # registers the pool as the free()/quarantine() guard.
                alloc = DomainAllocator(sh.pool.faultmap.geometry,
                                        sh.pool.domain, sh.pool.faultmap)
                alloc.adopt(sh.pool.placement)
                alloc.register_pool(sh.pool)
                sh.allocator = alloc
        self._pending_mig: List[List[Tuple[int, int]]] = [
            [] for _ in range(self.n_shards)]
        self._telem_last = np.zeros(
            (self.n_shards, self._shards[0].pool.total_pages), np.int64)
        self._telem_u_last = self._telem_last.copy()

        # ---- bookkeeping (global slot id g = shard * S + slot) --------
        self.queue: collections.deque = collections.deque()
        self.results: Dict[Any, RequestResult] = {}
        s = self.num_slots
        self._slots: List[Optional[Any]] = [None] * s
        self._slot_priv: List[Optional[np.ndarray]] = [None] * s
        self._slot_shared: List[Optional[np.ndarray]] = [None] * s
        self._slot_plan: List[Optional[_AdmitPlan]] = [None] * s
        self._ptoks: List[Optional[np.ndarray]] = [None] * s
        self._dec_h = [True] * s
        self._cursor_h = [0] * s
        self._plen_h = [0] * s
        self._admit_step: Dict[Any, int] = {}
        self._out: Dict[Any, List[int]] = {}
        self._remaining: Dict[Any, int] = {}
        self._meta: Dict[Any, RequestResult] = {}
        self.steps = 0
        self.admitted = 0
        self.peak_active = 0
        self.traces: List[int] = []

        # ---- observability plane (metrics + event trace) --------------
        # Resolution order: explicit ctor kwarg > ServeConfig.obs >
        # default-on ObsConfig().  Counters ride the donated state as
        # one (n_shards, N_STEP_COUNTERS) int32 leaf -- accumulated
        # with pure jnp inside the compiled step (zero extra pallas
        # launches); events and latency are host-side only.
        self.obs = (obs if obs is not None
                    else sc.obs if sc.obs is not None else ObsConfig())
        self.layout_kinds = tuple(sorted(set(
            jax.tree_util.tree_leaves(cache_layouts(
                bundle.module.cache_specs(cfg, 1, sc.max_len),
                sc.max_len)))))
        self.metrics: Optional[MetricsRegistry] = None
        self.trace: Optional[EventTrace] = None
        if self.obs.enabled:
            self.metrics = MetricsRegistry(
                self.n_shards, self._shards[0].pool, config=self.obs,
                layouts=self.layout_kinds)
            self.trace = EventTrace(capacity=self.obs.trace_capacity)
            for sh in self._shards:
                sh.pool.on_event = functools.partial(
                    self._pool_event, sh.index)

        self.state = self._init_state()
        if mesh is not None:
            from repro.launch.sharding import serve_sharding
            self.state = jax.device_put(self.state,
                                        serve_sharding(mesh, self._axis))
            from jax.experimental.shard_map import shard_map
            spec = jax.sharding.PartitionSpec(self._axis)
            rep = jax.sharding.PartitionSpec()
            self._step = jax.jit(
                shard_map(self._shard_body, mesh=mesh,
                          in_specs=(rep, spec, spec),
                          out_specs=(spec, spec), check_rep=False),
                donate_argnums=(1,))
        else:
            self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        for k, sh in enumerate(self._shards):
            sh.admit_reset = jax.jit(
                functools.partial(self._admit_reset_fn, k),
                donate_argnums=(0,))
            sh.transition_pool = jax.jit(
                functools.partial(self._transition_pool_fn, k),
                donate_argnums=(0,))

    # ---- compiled pieces --------------------------------------------------
    def _init_state(self):
        n, s, c = self.n_shards, self.slots_per_shard, self.chunk
        pools = [sh.kvc.init_pool() for sh in self._shards]
        p = self._shards[0].pool
        out = {
            "pool": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *pools),
            "ptab": jnp.full((n, s, p.n_logical_pages),
                             p.scratch_id, jnp.int32),
            "qpos": jnp.zeros((n, s), jnp.int32),
            "tok": jnp.zeros((n, s, c), jnp.int32),
            "keys": jnp.zeros((n, s, 2), jnp.uint32),
            "active": jnp.zeros((n, s), bool),
            # per-slot phase: decoding (True) vs chunked-prefilling
            "dec": jnp.ones((n, s), bool),
            "cursor": jnp.zeros((n, s), jnp.int32),
            "plen": jnp.zeros((n, s), jnp.int32),
            "wstart": jnp.zeros((n, s), jnp.int32),
            # Self-healing lanes (all donated with the step; idle
            # migration slots carry the scratch sentinel).  "telem" /
            # "telem_u" accumulate per-page SECDED corrected /
            # uncorrectable counts; "chaos" is the row-goes-weak fault-
            # injection mask (per page, host-set, read-path only).
            "telem": jnp.zeros((n, p.total_pages), jnp.int32),
            "telem_u": jnp.zeros((n, p.total_pages), jnp.int32),
            "chaos": jnp.zeros((n, p.total_pages), bool),
            "mig_src": jnp.full((n, self._mig_slots), p.scratch_id,
                                jnp.int32),
            "mig_dst": jnp.full((n, self._mig_slots), p.scratch_id,
                                jnp.int32),
        }
        if self.obs.enabled:
            # In-step metric counters (see obs.metrics.STEP_COUNTERS):
            # donated with the rest of the state, diffed on host.
            out["mtr"] = init_step_counters(n)
        return out

    def _sample_one(self, logits, key):
        """Standalone-identical sampling on one (1, vocab) logits row
        (the engine's shared implementation, so the bit-equality
        contract has a single sampling code path)."""
        return sample_tokens(logits, key, self.sc.temperature)

    def _shard_step(self, k, params, state, v):
        """One shard's mixed prefill/decode step on its local state
        (leaves unstacked: pool (...), ptab (S, n_lp), ...).  Closes
        over the shard's kvc -- its fault map's seed and calibration
        constants fold into this branch at trace time, which is exactly
        how distinct shards get distinct weak-row draws and threshold
        tables inside ONE compiled program."""
        sh = self._shards[k]
        module = self.bundle.module
        c = self.chunk
        s = self.slots_per_shard
        act, dec = state["active"], state["dec"]
        cursor, plen = state["cursor"], state["plen"]
        ptab, pool_in = state["ptab"], state["pool"]
        chaos = state["chaos"] if self._heal is not None else None
        if self._heal is not None:
            # In-step page migration: copy suspect pages to their
            # healthy targets, then rewrite every page-table entry
            # naming a source -- BEFORE the decode read, so this step
            # already attends (and writes) through the new placement.
            # Idle lanes are scratch->scratch copies; the sentinel must
            # be excluded from the rewrite match because inactive page-
            # table rows legitimately hold the scratch id.
            src, dst = state["mig_src"], state["mig_dst"]
            pool_in = sh.kvc.migrate_pages(pool_in, src, dst)
            moving = (src != sh.pool.scratch_id)
            eq = ((ptab[:, :, None] == src[None, None, :])
                  & moving[None, None, :])
            repl = jnp.where(eq, dst[None, None, :], 0).sum(-1)
            ptab = jnp.where(eq.any(-1), repl.astype(ptab.dtype), ptab)
        cols = jnp.arange(c, dtype=jnp.int32)
        # Token-lane positions: decode lanes use column 0 only, prefill
        # lanes are this step's prompt chunk; -1 lanes are causally
        # dead and their cache writes are suppressed.
        pref_pos = cursor[:, None] + cols[None, :]
        pref_pos = jnp.where(pref_pos < plen[:, None], pref_pos, -1)
        dec_pos = jnp.where(cols[None, :] == 0, state["qpos"][:, None], -1)
        pos = jnp.where(dec[:, None], dec_pos, pref_pos)
        prefill_end = jnp.where(act & ~dec,
                                jnp.minimum(cursor + c, plen), 0)
        # Read-path masks run in EVERY mode: idempotent on privately
        # stored-corrupt pages, and the only way clean shared pages can
        # read as each tenant's standalone stored-corrupt values.
        ctx = sh.kvc.make_ctx(
            ptab, v, method=sh.method, inject=self.active,
            dec=dec, wstart=state["wstart"], prefill_end=prefill_end,
            chaos=chaos)
        ks = jax.vmap(jax.random.split)(state["keys"])
        new_keys, ki = ks[:, 0], ks[:, 1]
        logits, pool = module.decode_step(
            params, pool_in, {"tokens": state["tok"]}, pos,
            self.cfg, self.dist, fault_ctx=ctx)
        if self.active and self.mode in ("read", "write"):
            # write-path injection covers only decoding slots' writes;
            # prefill writes stay clean until the transition injection
            ptab_inj = jnp.where(dec[:, None], ptab,
                                 sh.pool.scratch_id)
            pool = sh.kvc.post_step_inject(
                pool, ptab_inj, state["qpos"], v, mode=self.mode,
                method=sh.method)
        # sample column: decode lanes at 0, a finishing prefill at its
        # last prompt lane (the standalone post-prefill logits row)
        fin = act & ~dec & (plen - cursor <= c)
        sampling = act & (dec | fin)
        if c == 1:
            lg = logits
        else:
            col = jnp.where(dec, 0, jnp.clip(plen - 1 - cursor, 0, c - 1))
            lg = jnp.take_along_axis(logits, col[:, None, None],
                                     axis=1)[:, 0]
        nt = jax.vmap(lambda l, kk: self._sample_one(l[None], kk)[0])(
            lg, ki)[:, None]
        pad = jnp.zeros((s, c - 1), jnp.int32)
        nt_row = jnp.concatenate([nt, pad], axis=1) if c > 1 else nt
        telem, telem_u = state["telem"], state["telem_u"]
        if self._heal is not None:
            # Telemetry scrub: per-page SECDED event counts over every
            # referenced page, accumulated into the donated counters
            # (pure jnp on the stored buffers -- same mask math as the
            # kernel, zero extra pallas launches, read on host at the
            # existing token gather).
            corr, bad = sh.kvc.scrub_telemetry(pool, ptab, v,
                                               chaos=chaos)
            telem = telem + corr
            telem_u = telem_u + bad
        new_state = {
            "pool": pool,
            "ptab": ptab,
            "qpos": state["qpos"] + (act & dec).astype(jnp.int32),
            "tok": jnp.where(sampling[:, None], nt_row, state["tok"]),
            # keys advance only where a token was sampled, so a
            # request's key trajectory matches standalone generate()
            "keys": jnp.where(sampling[:, None], new_keys, state["keys"]),
            "active": act,
            "dec": dec,       # the prefill->decode flip happens on host
            "cursor": jnp.where(act & ~dec,
                                jnp.minimum(cursor + c, plen), cursor),
            "plen": plen,
            "wstart": state["wstart"],
            "telem": telem,
            "telem_u": telem_u,
            "chaos": state["chaos"],
            "mig_src": state["mig_src"],
            "mig_dst": state["mig_dst"],
        }
        if self.obs.enabled:
            # In-step metrics: pure jnp over masks already live in this
            # trace (no extra launches, no host sync) -- computed from
            # the PRE-step phase/cursor values, matching what this step
            # actually did.
            new_state["mtr"] = state["mtr"] + step_counter_delta(
                act=act, dec=dec, cursor=cursor, plen=plen,
                wstart=state["wstart"], chunk=c,
                n_logical_pages=sh.pool.n_logical_pages,
                mig_src=state["mig_src"], scratch_id=sh.pool.scratch_id)
        return new_state, nt

    def _step_fn(self, params, state, v):
        """Reference all-shard step on the stacked state (the mesh-less
        execution path, and the jaxpr surface tests/benchmarks count
        pallas launches on -- one launch per shard).  ``v`` may be a
        scalar (broadcast: homogeneous fleet) or a (n_shards,) vector
        of per-shard voltages."""
        self.traces.append(1)
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32),
                             (self.n_shards,))
        outs = [self._shard_step(
                    k, params,
                    jax.tree_util.tree_map(lambda x: x[k], state), v[k])
                for k in range(self.n_shards)]
        new_state = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        return new_state, jnp.stack([o[1] for o in outs])

    def _shard_body(self, params, state, v):
        """shard_map body: every device runs its own shard's branch of
        one switch on the serve-axis index.  Each branch is
        seed-specialized (static fault-map constants), state slices are
        shard-local, and nothing crosses the mesh -- the compiled step
        has zero collectives."""
        self.traces.append(1)
        idx = jax.lax.axis_index(self._axis)
        local = jax.tree_util.tree_map(lambda x: x[0], state)
        branches = [functools.partial(self._shard_step, k)
                    for k in range(self.n_shards)]
        new_local, nt = jax.lax.switch(idx, branches, params, local, v[0])
        return (jax.tree_util.tree_map(lambda x: x[None], new_local),
                nt[None])

    def _admit_reset_fn(self, k, pool_tree, reset_ids, fork_src,
                        fork_dst, fork_rows, fork_pos0):
        sh = self._shards[k]
        sub = jax.tree_util.tree_map(lambda x: x[k], pool_tree)
        sub = sh.kvc.reset_and_fork(sub, reset_ids, fork_src, fork_dst,
                                    fork_rows, fork_pos0)
        return jax.tree_util.tree_map(lambda x, y: x.at[k].set(y),
                                      pool_tree, sub)

    def _transition_pool_fn(self, k, pool_tree, priv, shared, v):
        """Prefill->decode transition injection: the paged twin of the
        standalone engine's post-prefill ``init_inject`` over the whole
        cache.  Private pages take the mode's full treatment; pages
        that are (or just became) shared keep their K/V clean in every
        mode -- the kernel's always-on read-path masks reproduce the
        standalone stored corruption at load -- and only their ``pos``
        bookkeeping takes write-path faults (same physical words and
        values for every tenant, so replays agree)."""
        sh = self._shards[k]
        sub = jax.tree_util.tree_map(lambda x: x[k], pool_tree)
        sub = sh.kvc.inject_pages(
            sub, priv, v, method=sh.method,
            skip_kv=(self.mode == "read"))
        sub = sh.kvc.inject_pages(sub, shared, v, method=sh.method,
                                  skip_kv=True)
        return jax.tree_util.tree_map(lambda x, y: x.at[k].set(y),
                                      pool_tree, sub)

    # ---- host loop --------------------------------------------------------
    def submit(self, request: Request) -> None:
        n_new = (request.max_new_tokens
                 if request.max_new_tokens is not None
                 else self.sc.max_new_tokens)
        if int(n_new) < 1:
            raise ValueError(
                f"request {request.rid!r}: max_new_tokens={n_new} must "
                "be >= 1 (every admitted request samples at least the "
                "prefill token)")
        plen = int(np.asarray(request.tokens).reshape(-1).shape[0])
        if plen < 1:
            raise ValueError(
                f"request {request.rid!r}: empty prompt")
        if request.extras:
            raise ValueError(
                f"request {request.rid!r}: extras "
                f"{sorted(request.extras)} on the paged route; the "
                f"{self.cfg.family!r} family is token-only (modality "
                "extras belong to state-arena families: whisper frames, "
                "vlm patches)")
        if plen > self.sc.max_len:
            raise ValueError(
                f"request {request.rid!r}: prompt length {plen} exceeds "
                f"max_len={self.sc.max_len}; chunked prefill writes the "
                "prompt through the paged ring in place and cannot "
                "rotate it (serve long prompts through generate())")
        self.queue.append(request)

    @property
    def n_active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    def shard_active(self, k: int) -> int:
        s = self.slots_per_shard
        return sum(1 for r in self._slots[k * s:(k + 1) * s]
                   if r is not None)

    def shard_plan(self, k: int):
        """Shard ``k``'s undervolt plan (its own fault-map seed) -- the
        plan a standalone ``generate()`` replay of a request served on
        shard ``k`` must run against."""
        return self._shards[k].plan

    @property
    def _voltage(self) -> float:
        return self._shards[0].voltage

    @_voltage.setter
    def _voltage(self, v: float) -> None:
        # homogeneous override (benchmarks set a fleet-wide schedule)
        for sh in self._shards:
            sh.voltage = float(v)

    def _volt_vec(self):
        return jnp.asarray([sh.voltage for sh in self._shards],
                           jnp.float32)

    def _free_slot_in(self, k: int) -> Optional[int]:
        s = self.slots_per_shard
        for g in range(k * s, (k + 1) * s):
            if self._slots[g] is None:
                return g
        return None

    def _plan_pages(self, k: int, req: Request, prompt: np.ndarray,
                    n_new: int) -> _AdmitPlan:
        """Match the prompt against shard ``k``'s prefix cache, retain
        the shared pages, and allocate the rest: prospective-shared
        pages (those that will hold prompt rows and be published at the
        transition) under the strictest ``shared_prefix`` tier, the
        remainder under the request's own tier.  Raises CapacityError
        with every side effect rolled back."""
        p = self._shards[k].pool
        ps = p.page_slots
        plen = prompt.shape[0]
        holder = ("__req__", req.rid)
        # no sharing when generation would wrap the ring into the
        # read-only prefix pages, and none at all on non-uniform
        # (window) layouts: COW prefix matching keys on page-aligned
        # position prefixes, which only line up across requests when
        # every ring is full-length (window tables are position-modular)
        eligible = (bool(self.sc.share_prefix) and p.uniform
                    and plen + n_new <= p.max_len)
        if eligible:
            matched, spids = p.match_prefix(prompt)
        else:
            matched, spids = 0, np.zeros((0,), np.int32)
        fs, r = matched // ps, matched % ps
        # partial matches are page-aligned by construction; only a
        # full-prompt match can end inside a page (COW boundary fork)
        assert r == 0 or matched == plen
        cover = -(-plen // ps)
        retained = spids[:fs].astype(np.int32)
        if fs:
            p.retain(retained, holder)
        try:
            fork_dst = -1
            if r:
                fork_dst = p.cow_fork(int(spids[fs]), "shared_prefix")
            try:
                n_share = cover - fs - (1 if r else 0)
                share_new = (p.alloc(n_share, "shared_prefix")
                             if eligible and n_share else
                             np.zeros((0,), np.int32))
                try:
                    n_rest = (p.n_logical_pages - cover if eligible
                              else p.n_logical_pages)
                    rest = p.alloc(n_rest, req.tier)
                except CapacityError:
                    if len(share_new):
                        p.free(share_new)
                    raise
            except CapacityError:
                if fork_dst >= 0:
                    p.free([fork_dst])
                raise
        except CapacityError:
            if fs:
                p.release(retained, holder)
            raise
        fork = (np.array([fork_dst], np.int32) if r
                else np.zeros((0,), np.int32))
        row = np.concatenate([retained, fork, share_new, rest])
        assert row.shape[0] == p.n_logical_pages
        return _AdmitPlan(
            row=row, retained=retained, eligible=eligible,
            matched=matched, fs=fs, cover=(cover if eligible else 0),
            fork_src=(int(spids[fs]) if r else p.scratch_id),
            fork_rows=r,
            cursor0=(matched if matched < plen else plen - 1),
            wstart0=(matched if matched < plen else plen))

    def _rollback(self, k: int, plan: _AdmitPlan, rid) -> None:
        p = self._shards[k].pool
        if plan.fs:
            p.release(plan.retained, ("__req__", rid))
        p.free(plan.row[plan.fs:])

    def _shard_order(self) -> List[int]:
        """Admission routing: shards with a free slot, most free pages
        first (ties to the lowest index) -- page-level load balancing
        that also spreads tenants across fault maps."""
        order = [k for k in range(self.n_shards)
                 if self._free_slot_in(k) is not None]
        order.sort(key=lambda k: (-self._shards[k].pool.free_pages, k))
        return order

    def _try_admit_on(self, k: int, req: Request, prompt: np.ndarray,
                      n_new: int) -> bool:
        """One shard's full admission attempt (pages, then governor),
        rolled back and reported as False on backpressure."""
        g = self._free_slot_in(k)
        if g is None:
            return False
        plan = None
        while plan is None:
            try:
                plan = self._plan_pages(k, req, prompt, n_new)
            except CapacityError:
                if not self._shards[k].pool.evict_prefix():
                    return False               # backpressure on this shard
        sh = self._shards[k]
        if sh.governor is not None:
            # the governed domain must keep the WHOLE post-admission
            # working set of ITS shard usable (the scheduler's analog
            # of generate()'s whole-batch bytes), not just the new
            # request's cache
            need = (self.shard_active(k) + 1) * sh.pool.request_words * 4
            try:
                sh.voltage = sh.governor.admit(need,
                                               setpoint=sh.setpoint)
            except CapacityError:
                # Graceful degradation under quarantine pressure: relax
                # the shard's rate setpoint one decade and retry before
                # reporting backpressure.
                if not self._escalate_setpoint(k):
                    self._rollback(k, plan, req.rid)
                    return False
                try:
                    sh.voltage = sh.governor.admit(need,
                                                   setpoint=sh.setpoint)
                except CapacityError:
                    self._rollback(k, plan, req.rid)
                    return False
        self.queue.popleft()
        self._admit(req, g, plan, prompt, n_new)
        return True

    def admit_pending(self) -> int:
        """Admit queued requests FIFO until every shard's slots, page
        pool, or governor pushes back (evicting idle prefix-cache
        entries before giving up).  Returns the number admitted."""
        n = 0
        while self.queue and self.n_active < self.max_active:
            req = self.queue[0]
            prompt = np.asarray(req.tokens, np.int32).reshape(-1)
            n_new = int(req.max_new_tokens
                        if req.max_new_tokens is not None
                        else self.sc.max_new_tokens)
            if not any(self._try_admit_on(k, req, prompt, n_new)
                       for k in self._shard_order()):
                self._emit("backpressure", rid=req.rid,
                           queued=len(self.queue),
                           active=self.n_active)
                break                          # backpressure: wait
            n += 1
        return n

    def _admit(self, req: Request, g: int, plan: _AdmitPlan,
               prompt: np.ndarray, n_new: int) -> None:
        k, s = divmod(g, self.slots_per_shard)
        sh = self._shards[k]
        p = sh.pool
        plen = prompt.shape[0]
        # scrub the freshly allocated pages (stale-tenant data) and COW-
        # copy the shared boundary page's clean prompt rows; retained
        # shared entries are passed as scratch (reset there is a no-op)
        reset_row = plan.row.copy()
        reset_row[:plan.fs] = p.scratch_id
        st = self.state
        pool_tree = sh.admit_reset(
            st["pool"], jnp.asarray(reset_row),
            jnp.int32(plan.fork_src),
            jnp.int32(plan.row[plan.fs] if plan.fork_rows
                      else p.scratch_id),
            jnp.int32(plan.fork_rows), jnp.int32(plan.fs * p.page_slots))
        key = req.key if req.key is not None else jax.random.PRNGKey(0)
        self.state = {
            **st,
            "pool": pool_tree,
            "ptab": st["ptab"].at[k, s].set(jnp.asarray(plan.row)),
            "qpos": st["qpos"].at[k, s].set(plen),
            "keys": st["keys"].at[k, s].set(key),
            "active": st["active"].at[k, s].set(True),
            "dec": st["dec"].at[k, s].set(False),
            "cursor": st["cursor"].at[k, s].set(plan.cursor0),
            "plen": st["plen"].at[k, s].set(plen),
            "wstart": st["wstart"].at[k, s].set(plan.wstart0),
        }
        self._slots[g] = req.rid
        self._slot_shared[g] = plan.retained.copy()
        self._slot_priv[g] = plan.row[plan.fs:].copy()
        self._slot_plan[g] = plan
        self._ptoks[g] = prompt
        self._dec_h[g] = False
        self._cursor_h[g] = plan.cursor0
        self._plen_h[g] = plen
        self._admit_step[req.rid] = self.steps
        self._out[req.rid] = []
        self._remaining[req.rid] = n_new
        self._meta[req.rid] = RequestResult(
            rid=req.rid, tokens=None, page_ids=plan.row.copy(),
            placement=p.request_placement(plan.row),
            voltage=(sh.voltage if p.placement is not None else None),
            pages_shared=plan.fs, shard=k)
        self.admitted += 1
        self.peak_active = max(self.peak_active, self.n_active)
        self._emit("admission", shard=k, rid=req.rid, plen=int(plen),
                   n_new=int(n_new), pages_shared=int(plan.fs),
                   voltage=(float(sh.voltage)
                            if p.placement is not None else None))
        if plan.fork_rows:
            self._emit("cow_fork", shard=k, rid=req.rid,
                       src=int(plan.fork_src),
                       dst=int(plan.row[plan.fs]),
                       rows=int(plan.fork_rows))

    def _transition(self, g: int) -> None:
        """Prefill finished this step: publish shareable pages, inject
        the request's pages (the standalone ``init_inject`` twin), and
        flip the slot to the decode phase."""
        k, s = divmod(g, self.slots_per_shard)
        rid = self._slots[g]
        plan = self._slot_plan[g]
        sh = self._shards[k]
        p = sh.pool
        if plan.eligible:
            own = plan.row[plan.fs:plan.cover]
            if len(own):
                p.share(own, ("__req__", rid))
                self._slot_shared[g] = np.concatenate(
                    [self._slot_shared[g], own])
                self._slot_priv[g] = plan.row[plan.cover:].copy()
            prompt = self._ptoks[g]
            plen = prompt.shape[0]
            lengths = list(range(p.page_slots, plen, p.page_slots))
            for ln in lengths + [plen]:
                p.register_prefix(prompt[:ln],
                                  plan.row[:-(-ln // p.page_slots)])
        st = self.state
        new_state = {**st, "dec": st["dec"].at[k, s].set(True)}
        if self.active:
            pad = np.full(p.n_logical_pages, p.scratch_id, np.int32)
            priv = pad.copy()
            priv[:len(self._slot_priv[g])] = self._slot_priv[g]
            shared = pad.copy()
            nsh = plan.cover if plan.eligible else 0
            shared[:nsh] = plan.row[:nsh]
            new_state["pool"] = sh.transition_pool(
                st["pool"], jnp.asarray(priv), jnp.asarray(shared),
                jnp.float32(sh.voltage))
        self.state = new_state
        self._dec_h[g] = True

    def _collect(self, g: int, rid, token: int) -> None:
        out = self._out[rid]
        if not out:
            self._meta[rid].ttft_steps = (self.steps
                                          - self._admit_step[rid])
        out.append(int(token))
        self._remaining[rid] -= 1
        if self._remaining[rid] == 0:
            self._retire(g)

    def _retire(self, g: int) -> None:
        k, s = divmod(g, self.slots_per_shard)
        sh = self._shards[k]
        rid = self._slots[g]
        res = self._meta.pop(rid)
        res.tokens = np.asarray(self._out.pop(rid), np.int32)[None, :]
        self.results[rid] = res
        self._emit("retirement", shard=k, rid=rid,
                   tokens=int(res.tokens.shape[1]),
                   ttft_steps=res.ttft_steps)
        if len(self._slot_shared[g]):
            sh.pool.release(self._slot_shared[g], ("__req__", rid))
        if len(self._slot_priv[g]):
            sh.pool.free(self._slot_priv[g])
        del self._remaining[rid]
        del self._admit_step[rid]
        self._slots[g] = None
        self._slot_priv[g] = None
        self._slot_shared[g] = None
        self._slot_plan[g] = None
        self._ptoks[g] = None
        self._dec_h[g] = True
        st = self.state
        self.state = {
            **st,
            "ptab": st["ptab"].at[k, s].set(sh.pool.scratch_id),
            "active": st["active"].at[k, s].set(False),
            "dec": st["dec"].at[k, s].set(True),
        }

    def _feed_chunks(self) -> None:
        """Host -> device refresh of the prompt-chunk token lanes of
        every prefilling slot (decoding slots keep their sampled
        token in lane 0)."""
        idx = [g for g, r in enumerate(self._slots)
               if r is not None and not self._dec_h[g]]
        if not idx:
            return
        rows = np.zeros((len(idx), self.chunk), np.int32)
        for j, g in enumerate(idx):
            cur = self._cursor_h[g]
            t = self._ptoks[g][cur:cur + self.chunk]
            rows[j, :len(t)] = t
        ks = np.asarray([g // self.slots_per_shard for g in idx])
        ss = np.asarray([g % self.slots_per_shard for g in idx])
        self.state["tok"] = self.state["tok"].at[ks, ss].set(
            jnp.asarray(rows))

    # ---- self-healing loop ------------------------------------------------
    def _escalate_setpoint(self, k: int) -> bool:
        """Raise shard ``k``'s governor rate setpoint one decade (up to
        the configured cap) -- the graceful-degradation response to
        admission CapacityError once quarantine has eaten into the
        frontier.  Returns False when escalation does not apply (no
        self-healing, no setpoint, nothing quarantined, power-mode
        governor, or already at the cap)."""
        sh = self._shards[k]
        if (self._heal is None or sh.governor is None
                or sh.setpoint is None
                or sh.governor.config.mode not in ("rate", "adaptive",
                                                   "efficiency")
                or not sh.pool.quarantined_pages):
            return False
        cap = float(self._heal.setpoint_cap)
        if sh.setpoint >= cap:
            return False
        old = sh.setpoint
        sh.setpoint = min(sh.setpoint * 10.0, cap)
        sh.setpoint_escalations += 1
        self._emit("escalation", shard=k, setpoint_from=old,
                   setpoint_to=sh.setpoint)
        return True

    def weaken_row(self, k: int, pc: int, row: int) -> np.ndarray:
        """Chaos hook: make DRAM row ``row`` of shard ``k``'s pseudo-
        channel ``pc`` go weak *at runtime* -- every pool page whose
        K/V payload overlaps the row starts reading through weak-rate
        thresholds (read path only; stored data stays clean, so replay
        bit-identity is preserved).  Returns the affected page ids.
        The compiled step is untouched: the mask is a donated state
        leaf, not a trace-time constant."""
        if self._heal is None:
            raise ValueError(
                "weaken_row needs self_heal=SelfHealConfig(...): the "
                "chaos mask and telemetry lanes only exist under the "
                "self-healing loop")
        pids = self._shards[k].pool.pages_on_row(int(pc), int(row))
        if len(pids):
            self.state["chaos"] = self.state["chaos"].at[
                k, jnp.asarray(pids)].set(True)
        return pids

    def _plan_self_heal(self) -> None:
        """Host half, before the step: walk each shard's suspect rows,
        quarantine free victim pages outright, and stage up to
        ``max_migrations`` live-page migrations into the step's
        src/dst lanes (targets freshly allocated off suspect rows)."""
        heal = self._heal
        M = self._mig_slots
        for k, sh in enumerate(self._shards):
            if not sh.suspects or self._pending_mig[k]:
                continue
            p = sh.pool
            suspect_pages: List[int] = []
            seen = set()
            for (pc, row) in sorted(sh.suspects):
                for pid in p.pages_on_row(pc, row):
                    pid = int(pid)
                    if pid not in seen:
                        seen.add(pid)
                        suspect_pages.append(pid)
            free_victims = [pid for pid in suspect_pages
                            if not p.is_owned(pid)
                            and not p.is_quarantined(pid)]
            if free_victims:
                p.quarantine(free_victims)
            victims = [pid for pid in suspect_pages if p.is_owned(pid)]
            pairs: List[Tuple[int, int]] = []
            rejects: List[int] = []
            for src in victims[:M]:
                dst = None
                while dst is None:
                    try:
                        cand = int(p.alloc(1, heal.migrate_tier)[0])
                    except CapacityError:
                        try:
                            cand = int(p.alloc(1, heal.fallback_tier)[0])
                        except CapacityError:
                            # quarantine pressure: keep serving on the
                            # suspect page, retry next step
                            sh.migration_stalls += 1
                            break
                    if cand in seen:
                        rejects.append(cand)    # target itself suspect
                        continue
                    dst = cand
                if dst is None:
                    break
                pairs.append((src, dst))
            if rejects:
                p.quarantine(rejects)
            if not pairs:
                continue
            row_src = np.full(M, p.scratch_id, np.int32)
            row_dst = np.full(M, p.scratch_id, np.int32)
            for i, (s_, d_) in enumerate(pairs):
                row_src[i], row_dst[i] = s_, d_
            self.state["mig_src"] = self.state["mig_src"].at[k].set(
                jnp.asarray(row_src))
            self.state["mig_dst"] = self.state["mig_dst"].at[k].set(
                jnp.asarray(row_dst))
            self._pending_mig[k] = pairs

    def _finalize_self_heal(self) -> None:
        """Host half, after the step: the staged migrations have been
        applied on device (page copy + page-table rewrite), so commit
        the host accounting -- pool ownership and holder transfer,
        every host-side page-id array, each affected request's replay
        placement -- then retire fully-drained quarantined blocks
        through the adopted allocator."""
        for k, sh in enumerate(self._shards):
            p = sh.pool
            pairs = self._pending_mig[k]
            if pairs:
                for src, dst in pairs:
                    p.migrate(src, dst)
                    self._emit("migration", shard=k, src=int(src),
                               dst=int(dst))
                sh.migrations += len(pairs)

                def rewrite(arr):
                    if arr is None or not len(arr):
                        return
                    for src, dst in pairs:
                        arr[arr == src] = dst

                s0 = k * self.slots_per_shard
                for g in range(s0, s0 + self.slots_per_shard):
                    if self._slots[g] is None:
                        continue
                    rewrite(self._slot_priv[g])
                    rewrite(self._slot_shared[g])
                    rewrite(self._slot_plan[g].row)
                    rewrite(self._slot_plan[g].retained)
                # Only LIVE requests move: a retired request's recorded
                # placement is its decode-time history, and its freed
                # pages may since back a different tenant entirely.
                live = {self._slots[g]
                        for g in range(s0, s0 + self.slots_per_shard)
                        if self._slots[g] is not None}
                for rid in live:
                    meta = self._meta[rid]
                    rewrite(meta.page_ids)
                    meta.placement = p.request_placement(meta.page_ids)
                pad = jnp.full((self._mig_slots,), p.scratch_id,
                               jnp.int32)
                self.state["mig_src"] = (
                    self.state["mig_src"].at[k].set(pad))
                self.state["mig_dst"] = (
                    self.state["mig_dst"].at[k].set(pad))
                self._pending_mig[k] = []
            # Block retirement: quarantined-page blocks with no live or
            # free pages left can never serve again -- pull them out of
            # the allocator's recycling for good.
            wpc = p.faultmap.geometry.bytes_per_pc // 4
            segs = [
                s for s in p.retirable_blocks()
                if (s.pc, (s.phys_base_word - s.pc * wpc) // ALIGN_WORDS)
                not in sh.retired_blocks]
            if segs:
                sh.allocator.quarantine(tuple(segs))
                new_blocks = [
                    (s.pc,
                     (s.phys_base_word - s.pc * wpc) // ALIGN_WORDS)
                    for s in segs]
                sh.retired_blocks.update(new_blocks)
                for pc, blk in new_blocks:
                    self._emit("block_retire", shard=k, pc=int(pc),
                               block=int(blk))

    def _fold_telemetry(self) -> None:
        """Diff the donated correction counters (read host-side at the
        existing token-gather sync) and fold each changed page's counts
        into its shard's per-row posterior; refresh the suspect set and
        re-plan adaptive governors when it moves."""
        corr = np.asarray(self.state["telem"], np.int64)
        bad = np.asarray(self.state["telem_u"], np.int64)
        d_corr = corr - self._telem_last
        d_bad = bad - self._telem_u_last
        self._telem_last, self._telem_u_last = corr, bad
        for k, sh in enumerate(self._shards):
            hits = np.flatnonzero((d_corr[k] > 0) | (d_bad[k] > 0))
            if len(hits):
                cw = sh.pool.page_codewords()
                for pid in hits:
                    for (pc, row) in sh.pool.page_rows(int(pid)):
                        sh.posterior.observe(
                            pc, row, corrected=int(d_corr[k, pid]),
                            codewords=cw, voltage=sh.voltage,
                            uncorrectable=int(d_bad[k, pid]))
            new = set(sh.posterior.suspect_rows(
                sh.voltage, self._heal.suspect_threshold))
            if new != sh.suspects:
                sh.suspects = new
                if (sh.governor is not None
                        and sh.governor.config.mode == "adaptive"):
                    sh.governor.replan(sh.posterior)
                    self._emit("replan", shard=k,
                               suspect_rows=len(new))

    # ---- observability hooks ----------------------------------------------
    def _emit(self, kind: str, **kw) -> None:
        """Emit one trace event stamped with the current step index and
        the scheduler's cache-layout mix (no-op when tracing is
        disabled)."""
        if self.trace is not None:
            kw.setdefault("layout", "+".join(self.layout_kinds))
            self.trace.emit(kind, step=self.steps, **kw)

    def _pool_event(self, shard: int, kind: str, **data) -> None:
        """Pool-side event hook (quarantine / prefix_evict), bound
        per shard at construction."""
        self._emit(kind, shard=shard, **data)

    @property
    def pricing_voltages(self) -> List[float]:
        """Per-shard voltage the energy accountant prices HBM traffic
        at: the operating rail for placed (undervolted) shards, the
        nominal rail for clean ones."""
        return [sh.voltage if sh.pool.placement is not None else V_NOM
                for sh in self._shards]

    def step_once(self) -> None:
        """One mixed step: every prefilling slot consumes a prompt
        chunk, every decoding slot one token (single compiled call
        across all shards); then transition finished prefills, collect
        tokens, and retire finished requests."""
        self._feed_chunks()
        if self._heal is not None:
            self._plan_self_heal()
        t0 = time.perf_counter()
        self.state, nt = self._step(self.params, self.state,
                                    self._volt_vec())
        # (n_shards, S, 1) -> global slot order g = shard * S + slot
        toks = np.asarray(nt).reshape(-1)
        if self.metrics is not None:
            # toks materialization above is the device sync, so this
            # wall-clock span covers the whole donated step
            self.metrics.record_step(time.perf_counter() - t0)
        self.steps += 1
        if self._heal is not None:
            self._finalize_self_heal()
            self._fold_telemetry()
        for g, rid in enumerate(self._slots):
            if rid is None:
                continue
            if self._dec_h[g]:
                self._collect(g, rid, toks[g])
                continue
            cur = self._cursor_h[g]
            fin = self._plen_h[g] - cur <= self.chunk
            self._cursor_h[g] = min(cur + self.chunk,
                                    self._plen_h[g])
            if fin:
                self._transition(g)
                self._collect(g, rid, toks[g])

    def run(self) -> Dict[Any, RequestResult]:
        """Drain the queue: admit / step / retire until every submitted
        request has finished.  Returns ``results`` (also kept on the
        scheduler)."""
        while self.queue or self.n_active:
            self.admit_pending()
            if not self.n_active:
                if not self.queue:
                    break
                # Nothing running anywhere and the head request still
                # cannot be admitted: it can never fit.  Re-run its
                # admission checks on the best-provisioned shard so the
                # capacity source raises its own error, naming the
                # shard.
                req = self.queue[0]
                prompt = np.asarray(req.tokens, np.int32).reshape(-1)
                n_new = int(req.max_new_tokens
                            if req.max_new_tokens is not None
                            else self.sc.max_new_tokens)
                k = max(range(self.n_shards),
                        key=lambda i: self._shards[i].pool.free_pages)
                sh = self._shards[k]
                plan = self._plan_pages(k, req, prompt, n_new)
                self._rollback(k, plan, req.rid)
                if sh.governor is not None:
                    sh.governor.admit(sh.pool.request_words * 4,
                                      setpoint=sh.setpoint)
                raise CapacityError(
                    "scheduler", sh.pool.request_words * 4,
                    sh.pool.free_pages * sh.pool.page_set_words * 4,
                    "admission stuck with an idle pool",
                    shard=sh.pool.shard)
            self.step_once()
        return self.results

    @property
    def stats(self) -> Dict[str, Any]:
        shards = [{
            "shard": sh.index,
            "active": self.shard_active(sh.index),
            "free_pages": sh.pool.free_pages,
            "weak_pages": sh.pool.num_weak_pages,
            "shared_pages": sh.pool.shared_pages,
            "voltage": sh.voltage,
            "setpoint": sh.setpoint,
            "map_seed": sh.seed,
        } for sh in self._shards]
        if self._heal is not None:
            for row, sh in zip(shards, self._shards):
                ps = sh.posterior.stats()
                row.update({
                    "corrected": ps["corrected"],
                    "uncorrectable": ps["uncorrectable"],
                    "tracked_rows": ps["tracked_rows"],
                    "suspect_rows": len(sh.suspects),
                    "migrations": sh.migrations,
                    "migration_stalls": sh.migration_stalls,
                    "quarantined_pages": len(sh.pool.quarantined_pages),
                    "quarantined_blocks": len(
                        sh.allocator.quarantined_blocks),
                    "setpoint_escalations": sh.setpoint_escalations,
                    "governor_replans": (sh.governor.replans
                                         if sh.governor is not None
                                         else 0),
                })
        out = {
            "route": "paged",
            "cache_layouts": list(self.layout_kinds),
            "steps": self.steps,
            "admitted": self.admitted,
            "peak_active": self.peak_active,
            "decode_traces": len(self.traces),
            "free_pages": sum(s["free_pages"] for s in shards),
            "voltage": self._voltage,
            "prefill_chunk": self.chunk,
            "shared_pages": sum(s["shared_pages"] for s in shards),
            "prefix_entries": sum(sh.pool.prefix_entries
                                  for sh in self._shards),
            "n_shards": self.n_shards,
            "shards": shards,
        }
        if self._heal is not None:
            for key in ("corrected", "uncorrectable", "migrations",
                        "quarantined_pages", "quarantined_blocks",
                        "setpoint_escalations"):
                out[key] = sum(s[key] for s in shards)
        if any(sh.governor is not None for sh in self._shards):
            from repro.training.governor import fleet_report
            out["fleet"] = fleet_report(
                [sh.governor for sh in self._shards],
                [sh.voltage for sh in self._shards],
                [sh.setpoint for sh in self._shards])
        if self.metrics is not None:
            out["obs"] = self.metrics.snapshot(
                self.state, voltages=self.pricing_voltages)
        if self.trace is not None:
            out["events"] = dict(self.trace.counts)
        return out
