"""Continuous-batching serving scheduler over the reliability-aware
paged KV cache.

PR 3's serving path decodes one fixed, contiguously placed batch at a
time: admission happens once, at ``generate()``, and capacity is
whatever that batch's placement grabbed.  This module replaces that
with an admission -> prefill -> decode -> retire loop over concurrent
requests:

  * requests wait in a FIFO queue; admission takes a free serving slot
    plus ``max_len / page_slots`` pool pages matching the request's
    criticality tier (weak-block pages go to tolerant requests first).
    :class:`~repro.core.domains.CapacityError` from the page pool -- or
    from the admission governor -- is *backpressure*: the request simply
    waits for pages to be retired, it never crashes the loop.
  * prefill is *chunked into the decode step*: each compiled step
    consumes up to ``ServeConfig.prefill_chunk`` prompt tokens for
    every prefilling slot (written through the paged path, attended
    with clean gathered attention) while decoding slots advance one
    token through the fused paged kernel.  There is no separate
    prefill program, so the compile count is flat in prompt length
    *and* traffic -- ONE jitted donated step serves any mix of phases,
    lengths and tiers, and the per-step KV voltage stays a traced
    scalar the admission governor can re-plan without a recompile.
  * prompt prefixes are shared copy-on-write: an admitted prompt is
    matched against the pool's content-hash prefix cache and maps the
    longest page-aligned cached prefix read-only (per-page refcounts);
    a partially-filled boundary page is forked onto a private page
    before first write.  Pages that may become shared are allocated
    under the strictest placement tier (``shared_prefix``: weak-free
    blocks, most-reliable pseudo-channels first), because one
    corrupted shared page would poison every tenant mapping it.
  * retirement releases per-page references; pages whose holder sets
    empty return to the pool (reliability-ordered recycling), turning
    capacity reclaimed by tolerating weak blocks -- and by not storing
    shared prefixes twice -- directly into extra concurrent traffic.

Token-equivalence contract (asserted in tests/test_scheduler.py):
every request's tokens are bit-identical to running it alone through
PR 3's ``generate()`` with the request's page placement
(:meth:`PagePool.request_placement`) -- greedy and sampled, read and
write injection modes, with and without ECC, shared prefix or not.
The mechanism behind sharing-compatible injection: shared pages store
*clean* K/V in every mode and the decode kernel's read-path masks are
applied at load in every mode -- the stuck-at masks and the ECC round
are idempotent, so privately-stored-corrupt pages re-mask to
themselves while clean shared pages corrupt to exactly the standalone
stored values.  The one exclusion is a *governor-driven* run whose
voltage actually moves mid-request: the domain rail is global, so a
re-plan triggered by a later admission also retunes the in-flight
requests' thresholds, and a standalone replay (one constant
``kv_voltage``) cannot reproduce that trajectory --
``RequestResult.voltage`` records the admission-time re-plan, not a
promise that the whole lifetime ran there.  ``kv_injection='rewrite'``
(the legacy full-cache oracle) cannot address pages and is rejected up
front.  Prompts longer than ``max_len`` are rejected at submit:
chunked prefill writes the prompt through the ring in place and
cannot rotate it the way the standalone prefill's tail-keep does.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains import CapacityError
from repro.core.engine import _static_value, resolve_method
from repro.core.faultmodel import V_MIN
from repro.models.base import ArchBundle, ArchConfig
from repro.serving.engine import ServeConfig, sample_tokens
from repro.serving.paged import PagedKVCache, PagePool, RequestPlacement


@dataclasses.dataclass
class Request:
    """One serving request.

    ``max_new_tokens`` defaults to the scheduler's ServeConfig value;
    ``tier`` routes page allocation (a name from
    ``repro.core.domains.TIERS`` or a CriticalityTier); ``key`` is the
    request's sampling PRNGKey (defaults to PRNGKey(0), exactly like
    ``generate``)."""

    rid: Any
    tokens: Any                       # prompt token ids, shape (prompt_len,)
    max_new_tokens: Optional[int] = None
    tier: Any = "cheap"
    key: Optional[jax.Array] = None


@dataclasses.dataclass
class RequestResult:
    rid: Any
    tokens: np.ndarray                # (1, max_new_tokens), like generate()
    page_ids: np.ndarray
    placement: Optional[RequestPlacement]
    voltage: Optional[float]          # KV-domain voltage at admission
    ttft_steps: Optional[int] = None  # steps from admission to token 0
    pages_shared: int = 0             # prefix pages mapped read-only


@dataclasses.dataclass
class _AdmitPlan:
    """Host-side page plan of one admission."""

    row: np.ndarray                   # (n_logical_pages,) page-table row
    retained: np.ndarray              # shared prefix pages mapped read-only
    eligible: bool                    # may register / extend the prefix cache
    matched: int                      # shared prefix length (tokens)
    fs: int                           # retained page count (full pages)
    cover: int                        # pages holding prompt rows
    fork_src: int                     # shared boundary page (scratch = none)
    fork_rows: int                    # clean rows to COW-copy
    cursor0: int                      # first prompt position to prefill
    wstart0: int                      # write floor (shared rows are r/o)


class ContinuousBatchingScheduler:
    """Serve overlapping requests through one compiled mixed
    prefill/decode step.

    ``num_slots`` bounds concurrent requests (the compiled step's batch
    width); ``num_pages`` x ``page_slots`` sizes the shared KV pool;
    ``max_active`` optionally throttles admissions below ``num_slots``
    (benchmarks use it to sweep concurrency on one compiled step).
    """

    def __init__(self, bundle: ArchBundle, cfg: ArchConfig, params,
                 sc: ServeConfig, *, num_slots: int, num_pages: int,
                 page_slots: int, max_active: Optional[int] = None,
                 dist=None, interpret: Optional[bool] = None):
        if sc.kv_injection == "rewrite":
            raise ValueError(
                "kv_injection='rewrite' re-injects whole contiguous "
                "caches every token; the scheduler's caches are paged "
                "and the legacy segment walker cannot address pages. "
                "Use 'read' (fused, default via 'auto') or 'write' "
                "(incremental), or serve one-shot batches through "
                "generate() if you need the rewrite oracle")
        if sc.kv_injection not in ("auto", "read", "write"):
            raise ValueError(f"unknown kv_injection {sc.kv_injection!r}")
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.dist = dist
        self.num_slots = int(num_slots)
        self.max_active = int(num_slots if max_active is None
                              else max_active)
        if self.num_slots < 1 or not 1 <= self.max_active <= self.num_slots:
            raise ValueError(
                f"need 1 <= max_active ({self.max_active}) <= num_slots "
                f"({self.num_slots})")
        self.chunk = int(sc.prefill_chunk)
        if self.chunk < 1:
            raise ValueError(
                f"prefill_chunk={sc.prefill_chunk} must be >= 1: every "
                "step consumes at least one prompt token per prefilling "
                "slot")

        plan = (sc.undervolt
                if sc.undervolt is not None and sc.undervolt.enabled
                else None)
        self.pool = PagePool(bundle.module, cfg, max_len=sc.max_len,
                             page_slots=page_slots, num_pages=num_pages,
                             plan=plan)
        self.kvc = PagedKVCache(self.pool, interpret=interpret)

        # ---- voltage control / injection mode (mirrors generate()) ----
        placed = self.pool.placement is not None
        self.governor = sc.governor
        if self.governor is not None:
            if sc.kv_voltage is not None:
                raise ValueError(
                    "ServeConfig.governor and kv_voltage are mutually "
                    "exclusive voltage controls")
            if sc.undervolt is None or self.governor.plan is not sc.undervolt:
                raise ValueError(
                    "sc.governor must be built from sc.undervolt (its "
                    "frontier/capacity tables belong to that plan's "
                    "fault map and domains)")
            if not placed:
                raise ValueError(
                    "ServeConfig.governor is set but the undervolt plan "
                    "does not place 'kv_cache' (or is disabled): "
                    "admission governance would silently be a no-op")
            if self.governor.config.domain != self.pool.domain.name:
                raise ValueError(
                    f"sc.governor governs domain "
                    f"{self.governor.config.domain!r} but the KV cache "
                    f"is placed in domain {self.pool.domain.name!r}")
        eff_v = sc.kv_voltage if sc.kv_voltage is not None else (
            self.pool.domain.voltage if placed else None)
        sv = _static_value(eff_v) if eff_v is not None else None
        self.active = placed and (
            self.governor is not None
            or eff_v is None
            or sv is None                       # traced: assume live
            or sv < V_MIN - 1e-9)
        mode = sc.kv_injection
        if mode == "auto":
            mode = "read"
        self.mode = mode
        method = sc.kv_method
        if self.active and method == "auto":
            if self.governor is not None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch under an admission "
                    "governor (the KV voltage is re-planned per "
                    "admission); pass kv_method='word' or 'bitwise' "
                    "explicitly")
            if sv is None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch from a traced "
                    "kv_voltage (method selection is static); pass "
                    "kv_method='word' or 'bitwise' explicitly for "
                    "traced voltage schedules")
            method = ("word" if self.pool.domain.ecc
                      else resolve_method(self.pool.faultmap,
                                          self.pool.placement, sv))
        self.method = method
        self._voltage = float(sv) if sv is not None else (
            eff_v if eff_v is not None else 0.0)

        # ---- bookkeeping ----------------------------------------------
        self.queue: collections.deque = collections.deque()
        self.results: Dict[Any, RequestResult] = {}
        s = self.num_slots
        self._slots: List[Optional[Any]] = [None] * s
        self._slot_priv: List[Optional[np.ndarray]] = [None] * s
        self._slot_shared: List[Optional[np.ndarray]] = [None] * s
        self._slot_plan: List[Optional[_AdmitPlan]] = [None] * s
        self._ptoks: List[Optional[np.ndarray]] = [None] * s
        self._dec_h = [True] * s
        self._cursor_h = [0] * s
        self._plen_h = [0] * s
        self._admit_step: Dict[Any, int] = {}
        self._out: Dict[Any, List[int]] = {}
        self._remaining: Dict[Any, int] = {}
        self._meta: Dict[Any, RequestResult] = {}
        self.steps = 0
        self.admitted = 0
        self.peak_active = 0
        self.traces: List[int] = []

        self.state = self._init_state()
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        self._admit_reset = jax.jit(self._admit_reset_fn,
                                    donate_argnums=(0,))
        self._transition_pool = jax.jit(self._transition_pool_fn,
                                        donate_argnums=(0,))

    # ---- compiled pieces --------------------------------------------------
    def _init_state(self):
        s, c = self.num_slots, self.chunk
        return {
            "pool": self.kvc.init_pool(),
            "ptab": jnp.full((s, self.pool.n_logical_pages),
                             self.pool.scratch_id, jnp.int32),
            "qpos": jnp.zeros((s,), jnp.int32),
            "tok": jnp.zeros((s, c), jnp.int32),
            "keys": jnp.zeros((s, 2), jnp.uint32),
            "active": jnp.zeros((s,), bool),
            # per-slot phase: decoding (True) vs chunked-prefilling
            "dec": jnp.ones((s,), bool),
            "cursor": jnp.zeros((s,), jnp.int32),
            "plen": jnp.zeros((s,), jnp.int32),
            "wstart": jnp.zeros((s,), jnp.int32),
        }

    def _sample_one(self, logits, key):
        """Standalone-identical sampling on one (1, vocab) logits row
        (the engine's shared implementation, so the bit-equality
        contract has a single sampling code path)."""
        return sample_tokens(logits, key, self.sc.temperature)

    def _step_fn(self, params, state, v):
        self.traces.append(1)
        module = self.bundle.module
        c = self.chunk
        act, dec = state["active"], state["dec"]
        cursor, plen = state["cursor"], state["plen"]
        cols = jnp.arange(c, dtype=jnp.int32)
        # Token-lane positions: decode lanes use column 0 only, prefill
        # lanes are this step's prompt chunk; -1 lanes are causally
        # dead and their cache writes are suppressed.
        pref_pos = cursor[:, None] + cols[None, :]
        pref_pos = jnp.where(pref_pos < plen[:, None], pref_pos, -1)
        dec_pos = jnp.where(cols[None, :] == 0, state["qpos"][:, None], -1)
        pos = jnp.where(dec[:, None], dec_pos, pref_pos)
        prefill_end = jnp.where(act & ~dec,
                                jnp.minimum(cursor + c, plen), 0)
        # Read-path masks run in EVERY mode: idempotent on privately
        # stored-corrupt pages, and the only way clean shared pages can
        # read as each tenant's standalone stored-corrupt values.
        ctx = self.kvc.make_ctx(
            state["ptab"], v, method=self.method, inject=self.active,
            dec=dec, wstart=state["wstart"], prefill_end=prefill_end)
        ks = jax.vmap(jax.random.split)(state["keys"])
        new_keys, ki = ks[:, 0], ks[:, 1]
        logits, pool = module.decode_step(
            params, state["pool"], {"tokens": state["tok"]}, pos,
            self.cfg, self.dist, fault_ctx=ctx)
        if self.active and self.mode in ("read", "write"):
            # write-path injection covers only decoding slots' writes;
            # prefill writes stay clean until the transition injection
            ptab_inj = jnp.where(dec[:, None], state["ptab"],
                                 self.pool.scratch_id)
            pool = self.kvc.post_step_inject(
                pool, ptab_inj, state["qpos"], v, mode=self.mode,
                method=self.method)
        # sample column: decode lanes at 0, a finishing prefill at its
        # last prompt lane (the standalone post-prefill logits row)
        fin = act & ~dec & (plen - cursor <= c)
        sampling = act & (dec | fin)
        if c == 1:
            lg = logits
        else:
            col = jnp.where(dec, 0, jnp.clip(plen - 1 - cursor, 0, c - 1))
            lg = jnp.take_along_axis(logits, col[:, None, None],
                                     axis=1)[:, 0]
        nt = jax.vmap(lambda l, kk: self._sample_one(l[None], kk)[0])(
            lg, ki)[:, None]
        pad = jnp.zeros((self.num_slots, c - 1), jnp.int32)
        nt_row = jnp.concatenate([nt, pad], axis=1) if c > 1 else nt
        new_state = {
            "pool": pool,
            "ptab": state["ptab"],
            "qpos": state["qpos"] + (act & dec).astype(jnp.int32),
            "tok": jnp.where(sampling[:, None], nt_row, state["tok"]),
            # keys advance only where a token was sampled, so a
            # request's key trajectory matches standalone generate()
            "keys": jnp.where(sampling[:, None], new_keys, state["keys"]),
            "active": act,
            "dec": dec,       # the prefill->decode flip happens on host
            "cursor": jnp.where(act & ~dec,
                                jnp.minimum(cursor + c, plen), cursor),
            "plen": plen,
            "wstart": state["wstart"],
        }
        return new_state, nt

    def _admit_reset_fn(self, pool_tree, reset_ids, fork_src, fork_dst,
                        fork_rows, fork_pos0):
        return self.kvc.reset_and_fork(pool_tree, reset_ids, fork_src,
                                       fork_dst, fork_rows, fork_pos0)

    def _transition_pool_fn(self, pool_tree, priv, shared, v):
        """Prefill->decode transition injection: the paged twin of the
        standalone engine's post-prefill ``init_inject`` over the whole
        cache.  Private pages take the mode's full treatment; pages
        that are (or just became) shared keep their K/V clean in every
        mode -- the kernel's always-on read-path masks reproduce the
        standalone stored corruption at load -- and only their ``pos``
        bookkeeping takes write-path faults (same physical words and
        values for every tenant, so replays agree)."""
        tree = self.kvc.inject_pages(
            pool_tree, priv, v, method=self.method,
            skip_kv=(self.mode == "read"))
        return self.kvc.inject_pages(tree, shared, v, method=self.method,
                                     skip_kv=True)

    # ---- host loop --------------------------------------------------------
    def submit(self, request: Request) -> None:
        n_new = (request.max_new_tokens
                 if request.max_new_tokens is not None
                 else self.sc.max_new_tokens)
        if int(n_new) < 1:
            raise ValueError(
                f"request {request.rid!r}: max_new_tokens={n_new} must "
                "be >= 1 (every admitted request samples at least the "
                "prefill token)")
        plen = int(np.asarray(request.tokens).reshape(-1).shape[0])
        if plen < 1:
            raise ValueError(
                f"request {request.rid!r}: empty prompt")
        if plen > self.sc.max_len:
            raise ValueError(
                f"request {request.rid!r}: prompt length {plen} exceeds "
                f"max_len={self.sc.max_len}; chunked prefill writes the "
                "prompt through the paged ring in place and cannot "
                "rotate it (serve long prompts through generate())")
        self.queue.append(request)

    @property
    def n_active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self._slots):
            if r is None:
                return i
        return None

    def _plan_pages(self, req: Request, prompt: np.ndarray,
                    n_new: int) -> _AdmitPlan:
        """Match the prompt against the prefix cache, retain the shared
        pages, and allocate the rest: prospective-shared pages (those
        that will hold prompt rows and be published at the transition)
        under the strictest ``shared_prefix`` tier, the remainder under
        the request's own tier.  Raises CapacityError with every
        side effect rolled back."""
        p = self.pool
        ps = p.page_slots
        plen = prompt.shape[0]
        holder = ("__req__", req.rid)
        # no sharing when generation would wrap the ring into the
        # read-only prefix pages
        eligible = bool(self.sc.share_prefix) and plen + n_new <= p.max_len
        if eligible:
            matched, spids = p.match_prefix(prompt)
        else:
            matched, spids = 0, np.zeros((0,), np.int32)
        fs, r = matched // ps, matched % ps
        # partial matches are page-aligned by construction; only a
        # full-prompt match can end inside a page (COW boundary fork)
        assert r == 0 or matched == plen
        cover = -(-plen // ps)
        retained = spids[:fs].astype(np.int32)
        if fs:
            p.retain(retained, holder)
        try:
            fork_dst = -1
            if r:
                fork_dst = p.cow_fork(int(spids[fs]), "shared_prefix")
            try:
                n_share = cover - fs - (1 if r else 0)
                share_new = (p.alloc(n_share, "shared_prefix")
                             if eligible and n_share else
                             np.zeros((0,), np.int32))
                try:
                    n_rest = (p.n_logical_pages - cover if eligible
                              else p.n_logical_pages)
                    rest = p.alloc(n_rest, req.tier)
                except CapacityError:
                    if len(share_new):
                        p.free(share_new)
                    raise
            except CapacityError:
                if fork_dst >= 0:
                    p.free([fork_dst])
                raise
        except CapacityError:
            if fs:
                p.release(retained, holder)
            raise
        fork = (np.array([fork_dst], np.int32) if r
                else np.zeros((0,), np.int32))
        row = np.concatenate([retained, fork, share_new, rest])
        assert row.shape[0] == p.n_logical_pages
        return _AdmitPlan(
            row=row, retained=retained, eligible=eligible,
            matched=matched, fs=fs, cover=(cover if eligible else 0),
            fork_src=(int(spids[fs]) if r else p.scratch_id),
            fork_rows=r,
            cursor0=(matched if matched < plen else plen - 1),
            wstart0=(matched if matched < plen else plen))

    def _rollback(self, plan: _AdmitPlan, rid) -> None:
        if plan.fs:
            self.pool.release(plan.retained, ("__req__", rid))
        self.pool.free(plan.row[plan.fs:])

    def admit_pending(self) -> int:
        """Admit queued requests FIFO until a slot, the page pool, or
        the governor pushes back (evicting idle prefix-cache entries
        before giving up).  Returns the number admitted."""
        n = 0
        while self.queue and self.n_active < self.max_active:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue[0]
            prompt = np.asarray(req.tokens, np.int32).reshape(-1)
            n_new = int(req.max_new_tokens
                        if req.max_new_tokens is not None
                        else self.sc.max_new_tokens)
            plan = None
            while plan is None:
                try:
                    plan = self._plan_pages(req, prompt, n_new)
                except CapacityError:
                    if not self.pool.evict_prefix():
                        break                  # backpressure: wait
            if plan is None:
                break
            if self.governor is not None:
                try:
                    # the governed domain must keep the WHOLE post-
                    # admission working set usable (the scheduler's
                    # analog of generate()'s whole-batch bytes), not
                    # just the new request's cache
                    self._voltage = self.governor.admit(
                        (self.n_active + 1) * self.pool.request_words * 4)
                except CapacityError:
                    self._rollback(plan, req.rid)
                    break
            self.queue.popleft()
            self._admit(req, slot, plan, prompt, n_new)
            n += 1
        return n

    def _admit(self, req: Request, slot: int, plan: _AdmitPlan,
               prompt: np.ndarray, n_new: int) -> None:
        p = self.pool
        plen = prompt.shape[0]
        # scrub the freshly allocated pages (stale-tenant data) and COW-
        # copy the shared boundary page's clean prompt rows; retained
        # shared entries are passed as scratch (reset there is a no-op)
        reset_row = plan.row.copy()
        reset_row[:plan.fs] = p.scratch_id
        st = self.state
        pool_tree = self._admit_reset(
            st["pool"], jnp.asarray(reset_row),
            jnp.int32(plan.fork_src),
            jnp.int32(plan.row[plan.fs] if plan.fork_rows
                      else p.scratch_id),
            jnp.int32(plan.fork_rows), jnp.int32(plan.fs * p.page_slots))
        key = req.key if req.key is not None else jax.random.PRNGKey(0)
        self.state = {
            "pool": pool_tree,
            "ptab": st["ptab"].at[slot].set(jnp.asarray(plan.row)),
            "qpos": st["qpos"].at[slot].set(plen),
            "tok": st["tok"],
            "keys": st["keys"].at[slot].set(key),
            "active": st["active"].at[slot].set(True),
            "dec": st["dec"].at[slot].set(False),
            "cursor": st["cursor"].at[slot].set(plan.cursor0),
            "plen": st["plen"].at[slot].set(plen),
            "wstart": st["wstart"].at[slot].set(plan.wstart0),
        }
        self._slots[slot] = req.rid
        self._slot_shared[slot] = plan.retained.copy()
        self._slot_priv[slot] = plan.row[plan.fs:].copy()
        self._slot_plan[slot] = plan
        self._ptoks[slot] = prompt
        self._dec_h[slot] = False
        self._cursor_h[slot] = plan.cursor0
        self._plen_h[slot] = plen
        self._admit_step[req.rid] = self.steps
        self._out[req.rid] = []
        self._remaining[req.rid] = n_new
        self._meta[req.rid] = RequestResult(
            rid=req.rid, tokens=None, page_ids=plan.row.copy(),
            placement=p.request_placement(plan.row),
            voltage=(self._voltage if p.placement is not None else None),
            pages_shared=plan.fs)
        self.admitted += 1
        self.peak_active = max(self.peak_active, self.n_active)

    def _transition(self, slot: int) -> None:
        """Prefill finished this step: publish shareable pages, inject
        the request's pages (the standalone ``init_inject`` twin), and
        flip the slot to the decode phase."""
        rid = self._slots[slot]
        plan = self._slot_plan[slot]
        p = self.pool
        if plan.eligible:
            own = plan.row[plan.fs:plan.cover]
            if len(own):
                p.share(own, ("__req__", rid))
                self._slot_shared[slot] = np.concatenate(
                    [self._slot_shared[slot], own])
                self._slot_priv[slot] = plan.row[plan.cover:].copy()
            prompt = self._ptoks[slot]
            plen = prompt.shape[0]
            lengths = list(range(p.page_slots, plen, p.page_slots))
            for ln in lengths + [plen]:
                p.register_prefix(prompt[:ln],
                                  plan.row[:-(-ln // p.page_slots)])
        st = self.state
        new_state = {**st, "dec": st["dec"].at[slot].set(True)}
        if self.active:
            pad = np.full(p.n_logical_pages, p.scratch_id, np.int32)
            priv = pad.copy()
            priv[:len(self._slot_priv[slot])] = self._slot_priv[slot]
            shared = pad.copy()
            nsh = plan.cover if plan.eligible else 0
            shared[:nsh] = plan.row[:nsh]
            new_state["pool"] = self._transition_pool(
                st["pool"], jnp.asarray(priv), jnp.asarray(shared),
                jnp.float32(self._voltage))
        self.state = new_state
        self._dec_h[slot] = True

    def _collect(self, slot: int, rid, token: int) -> None:
        out = self._out[rid]
        if not out:
            self._meta[rid].ttft_steps = (self.steps
                                          - self._admit_step[rid])
        out.append(int(token))
        self._remaining[rid] -= 1
        if self._remaining[rid] == 0:
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        rid = self._slots[slot]
        res = self._meta.pop(rid)
        res.tokens = np.asarray(self._out.pop(rid), np.int32)[None, :]
        self.results[rid] = res
        if len(self._slot_shared[slot]):
            self.pool.release(self._slot_shared[slot], ("__req__", rid))
        if len(self._slot_priv[slot]):
            self.pool.free(self._slot_priv[slot])
        del self._remaining[rid]
        del self._admit_step[rid]
        self._slots[slot] = None
        self._slot_priv[slot] = None
        self._slot_shared[slot] = None
        self._slot_plan[slot] = None
        self._ptoks[slot] = None
        self._dec_h[slot] = True
        st = self.state
        self.state = {
            **st,
            "ptab": st["ptab"].at[slot].set(self.pool.scratch_id),
            "active": st["active"].at[slot].set(False),
            "dec": st["dec"].at[slot].set(True),
        }

    def _feed_chunks(self) -> None:
        """Host -> device refresh of the prompt-chunk token lanes of
        every prefilling slot (decoding slots keep their sampled
        token in lane 0)."""
        idx = [i for i, r in enumerate(self._slots)
               if r is not None and not self._dec_h[i]]
        if not idx:
            return
        rows = np.zeros((len(idx), self.chunk), np.int32)
        for j, i in enumerate(idx):
            cur = self._cursor_h[i]
            t = self._ptoks[i][cur:cur + self.chunk]
            rows[j, :len(t)] = t
        self.state["tok"] = self.state["tok"].at[
            np.asarray(idx)].set(jnp.asarray(rows))

    def step_once(self) -> None:
        """One mixed step: every prefilling slot consumes a prompt
        chunk, every decoding slot one token (single compiled call);
        then transition finished prefills, collect tokens, and retire
        finished requests."""
        self._feed_chunks()
        self.state, nt = self._step(self.params, self.state,
                                    jnp.float32(self._voltage))
        toks = np.asarray(nt)[:, 0]
        self.steps += 1
        for slot, rid in enumerate(self._slots):
            if rid is None:
                continue
            if self._dec_h[slot]:
                self._collect(slot, rid, toks[slot])
                continue
            cur = self._cursor_h[slot]
            fin = self._plen_h[slot] - cur <= self.chunk
            self._cursor_h[slot] = min(cur + self.chunk,
                                       self._plen_h[slot])
            if fin:
                self._transition(slot)
                self._collect(slot, rid, toks[slot])

    def run(self) -> Dict[Any, RequestResult]:
        """Drain the queue: admit / step / retire until every submitted
        request has finished.  Returns ``results`` (also kept on the
        scheduler)."""
        while self.queue or self.n_active:
            self.admit_pending()
            if not self.n_active:
                if not self.queue:
                    break
                # Nothing running and the head request still cannot be
                # admitted: it can never fit.  Re-run its admission
                # checks so the capacity source raises its own error.
                req = self.queue[0]
                prompt = np.asarray(req.tokens, np.int32).reshape(-1)
                n_new = int(req.max_new_tokens
                            if req.max_new_tokens is not None
                            else self.sc.max_new_tokens)
                plan = self._plan_pages(req, prompt, n_new)
                self._rollback(plan, req.rid)
                if self.governor is not None:
                    self.governor.admit(self.pool.request_words * 4)
                raise CapacityError(
                    "scheduler", self.pool.request_words * 4,
                    self.pool.free_pages * self.pool.page_set_words * 4,
                    "admission stuck with an idle pool")
            self.step_once()
        return self.results

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "steps": self.steps,
            "admitted": self.admitted,
            "peak_active": self.peak_active,
            "decode_traces": len(self.traces),
            "free_pages": self.pool.free_pages,
            "voltage": self._voltage,
            "prefill_chunk": self.chunk,
            "shared_pages": self.pool.shared_pages,
            "prefix_entries": self.pool.prefix_entries,
        }
