"""Continuous-batching serving scheduler over the reliability-aware
paged KV cache.

PR 3's serving path decodes one fixed, contiguously placed batch at a
time: admission happens once, at ``generate()``, and capacity is
whatever that batch's placement grabbed.  This module replaces that
with an admission -> prefill -> decode -> retire loop over concurrent
requests:

  * requests wait in a FIFO queue; admission takes a free serving slot
    plus ``max_len / page_slots`` pool pages matching the request's
    criticality tier (weak-block pages go to tolerant requests first).
    :class:`~repro.core.domains.CapacityError` from the page pool -- or
    from the admission governor -- is *backpressure*: the request simply
    waits for pages to be retired, it never crashes the loop.
  * prefill runs per request (batch 1, exactly the standalone prefill)
    and is scattered into the request's pages; the post-prefill
    injection pass corrupts those pages the same way the standalone
    engine's ``init_inject`` would.
  * the decode step is ONE jitted function over a fixed-capacity slot
    array -- active mask, per-slot positions/tokens/keys, the page
    table, and the donated pool -- so the compile count is flat in
    traffic: requests of any mix of lengths and tiers ride the same
    compiled step, and the per-step KV voltage is a traced scalar the
    admission governor can re-plan at every admission without a
    recompile.
  * retirement frees the request's pages back to the pool (reliability-
    ordered recycling), turning capacity reclaimed by tolerating weak
    blocks directly into extra concurrent traffic.

Token-equivalence contract (asserted in tests/test_scheduler.py):
every request's tokens are bit-identical to running it alone through
PR 3's ``generate()`` with the request's page placement
(:meth:`PagePool.request_placement`) -- greedy and sampled, read and
write injection modes, with and without ECC.  The one exclusion is a
*governor-driven* run whose voltage actually moves mid-request: the
domain rail is global, so a re-plan triggered by a later admission
also retunes the in-flight requests' thresholds, and a standalone
replay (one constant ``kv_voltage``) cannot reproduce that trajectory
-- ``RequestResult.voltage`` records the admission-time re-plan, not a
promise that the whole lifetime ran there.  ``kv_injection='rewrite'``
(the legacy full-cache oracle) cannot address pages and is rejected up
front.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains import CapacityError
from repro.core.engine import _static_value, resolve_method
from repro.core.faultmodel import V_MIN
from repro.models.base import ArchBundle, ArchConfig
from repro.serving.engine import ServeConfig, sample_tokens
from repro.serving.paged import PagedKVCache, PagePool, RequestPlacement


@dataclasses.dataclass
class Request:
    """One serving request.

    ``max_new_tokens`` defaults to the scheduler's ServeConfig value;
    ``tier`` routes page allocation (a name from
    ``repro.core.domains.TIERS`` or a CriticalityTier); ``key`` is the
    request's sampling PRNGKey (defaults to PRNGKey(0), exactly like
    ``generate``)."""

    rid: Any
    tokens: Any                       # prompt token ids, shape (prompt_len,)
    max_new_tokens: Optional[int] = None
    tier: Any = "cheap"
    key: Optional[jax.Array] = None


@dataclasses.dataclass
class RequestResult:
    rid: Any
    tokens: np.ndarray                # (1, max_new_tokens), like generate()
    page_ids: np.ndarray
    placement: Optional[RequestPlacement]
    voltage: Optional[float]          # KV-domain voltage at admission


class ContinuousBatchingScheduler:
    """Serve overlapping requests through one compiled decode step.

    ``num_slots`` bounds concurrent requests (the compiled step's batch
    width); ``num_pages`` x ``page_slots`` sizes the shared KV pool;
    ``max_active`` optionally throttles admissions below ``num_slots``
    (benchmarks use it to sweep concurrency on one compiled step).
    """

    def __init__(self, bundle: ArchBundle, cfg: ArchConfig, params,
                 sc: ServeConfig, *, num_slots: int, num_pages: int,
                 page_slots: int, max_active: Optional[int] = None,
                 dist=None, interpret: Optional[bool] = None):
        if sc.kv_injection == "rewrite":
            raise ValueError(
                "kv_injection='rewrite' re-injects whole contiguous "
                "caches every token; the scheduler's caches are paged "
                "and the legacy segment walker cannot address pages. "
                "Use 'read' (fused, default via 'auto') or 'write' "
                "(incremental), or serve one-shot batches through "
                "generate() if you need the rewrite oracle")
        if sc.kv_injection not in ("auto", "read", "write"):
            raise ValueError(f"unknown kv_injection {sc.kv_injection!r}")
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.dist = dist
        self.num_slots = int(num_slots)
        self.max_active = int(num_slots if max_active is None
                              else max_active)
        if self.num_slots < 1 or not 1 <= self.max_active <= self.num_slots:
            raise ValueError(
                f"need 1 <= max_active ({self.max_active}) <= num_slots "
                f"({self.num_slots})")

        plan = (sc.undervolt
                if sc.undervolt is not None and sc.undervolt.enabled
                else None)
        self.pool = PagePool(bundle.module, cfg, max_len=sc.max_len,
                             page_slots=page_slots, num_pages=num_pages,
                             plan=plan)
        self.kvc = PagedKVCache(self.pool, interpret=interpret)

        # ---- voltage control / injection mode (mirrors generate()) ----
        placed = self.pool.placement is not None
        self.governor = sc.governor
        if self.governor is not None:
            if sc.kv_voltage is not None:
                raise ValueError(
                    "ServeConfig.governor and kv_voltage are mutually "
                    "exclusive voltage controls")
            if sc.undervolt is None or self.governor.plan is not sc.undervolt:
                raise ValueError(
                    "sc.governor must be built from sc.undervolt (its "
                    "frontier/capacity tables belong to that plan's "
                    "fault map and domains)")
            if not placed:
                raise ValueError(
                    "ServeConfig.governor is set but the undervolt plan "
                    "does not place 'kv_cache' (or is disabled): "
                    "admission governance would silently be a no-op")
            if self.governor.config.domain != self.pool.domain.name:
                raise ValueError(
                    f"sc.governor governs domain "
                    f"{self.governor.config.domain!r} but the KV cache "
                    f"is placed in domain {self.pool.domain.name!r}")
        eff_v = sc.kv_voltage if sc.kv_voltage is not None else (
            self.pool.domain.voltage if placed else None)
        sv = _static_value(eff_v) if eff_v is not None else None
        self.active = placed and (
            self.governor is not None
            or eff_v is None
            or sv is None                       # traced: assume live
            or sv < V_MIN - 1e-9)
        mode = sc.kv_injection
        if mode == "auto":
            mode = "read"
        self.mode = mode
        method = sc.kv_method
        if self.active and method == "auto":
            if self.governor is not None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch under an admission "
                    "governor (the KV voltage is re-planned per "
                    "admission); pass kv_method='word' or 'bitwise' "
                    "explicitly")
            if sv is None:
                raise ValueError(
                    "kv_method='auto' cannot dispatch from a traced "
                    "kv_voltage (method selection is static); pass "
                    "kv_method='word' or 'bitwise' explicitly for "
                    "traced voltage schedules")
            method = ("word" if self.pool.domain.ecc
                      else resolve_method(self.pool.faultmap,
                                          self.pool.placement, sv))
        self.method = method
        self._voltage = float(sv) if sv is not None else (
            eff_v if eff_v is not None else 0.0)

        # ---- bookkeeping ----------------------------------------------
        self.queue: collections.deque = collections.deque()
        self.results: Dict[Any, RequestResult] = {}
        self._slots: List[Optional[Any]] = [None] * self.num_slots
        self._slot_pages: List[Optional[np.ndarray]] = (
            [None] * self.num_slots)
        self._out: Dict[Any, List[int]] = {}
        self._remaining: Dict[Any, int] = {}
        self._meta: Dict[Any, RequestResult] = {}
        self.steps = 0
        self.admitted = 0
        self.peak_active = 0
        self.traces: List[int] = []

        self.state = self._init_state()
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        self._admit_pool = jax.jit(self._admit_pool_fn,
                                   donate_argnums=(0,))
        # one jitted prefill: jax.jit itself specializes per prompt
        # length, so compile count stays one per distinct length
        module, cfg = self.bundle.module, self.cfg
        self._prefill = jax.jit(
            lambda p, bt: module.prefill(p, bt, cfg, sc.max_len,
                                         self.dist))

    # ---- compiled pieces --------------------------------------------------
    def _init_state(self):
        s = self.num_slots
        return {
            "pool": self.kvc.init_pool(),
            "ptab": jnp.full((s, self.pool.n_logical_pages),
                             self.pool.scratch_id, jnp.int32),
            "qpos": jnp.zeros((s,), jnp.int32),
            "tok": jnp.zeros((s, 1), jnp.int32),
            "keys": jnp.zeros((s, 2), jnp.uint32),
            "active": jnp.zeros((s,), bool),
        }

    def _sample_one(self, logits, key):
        """Standalone-identical sampling on one (1, vocab) logits row
        (the engine's shared implementation, so the bit-equality
        contract has a single sampling code path)."""
        return sample_tokens(logits, key, self.sc.temperature)

    def _step_fn(self, params, state, v):
        self.traces.append(1)
        module = self.bundle.module
        ctx = self.kvc.make_ctx(
            state["ptab"], v, method=self.method,
            inject=(self.active and self.mode == "read"))
        ks = jax.vmap(jax.random.split)(state["keys"])
        new_keys, ki = ks[:, 0], ks[:, 1]
        logits, pool = module.decode_step(
            params, state["pool"], {"tokens": state["tok"]},
            state["qpos"][:, None], self.cfg, self.dist, fault_ctx=ctx)
        if self.active and self.mode in ("read", "write"):
            pool = self.kvc.post_step_inject(
                pool, state["ptab"], state["qpos"], v, mode=self.mode,
                method=self.method)
        nt = jax.vmap(lambda lg, kk: self._sample_one(lg[None], kk)[0])(
            logits, ki)[:, None]
        act = state["active"]
        new_state = {
            "pool": pool,
            "ptab": state["ptab"],
            "qpos": state["qpos"] + act.astype(jnp.int32),
            "tok": jnp.where(act[:, None], nt, state["tok"]),
            "keys": jnp.where(act[:, None], new_keys, state["keys"]),
            "active": act,
        }
        return new_state, nt

    def _admit_pool_fn(self, pool_tree, cache, pids, v):
        tree = self.kvc.scatter_request(pool_tree, cache, pids)
        if self.active:
            tree = self.kvc.inject_pages(
                tree, pids, v, method=self.method,
                skip_kv=(self.mode == "read"))
        return tree

    # ---- host loop --------------------------------------------------------
    def submit(self, request: Request) -> None:
        n_new = (request.max_new_tokens
                 if request.max_new_tokens is not None
                 else self.sc.max_new_tokens)
        if int(n_new) < 1:
            raise ValueError(
                f"request {request.rid!r}: max_new_tokens={n_new} must "
                "be >= 1 (every admitted request samples at least the "
                "prefill token)")
        self.queue.append(request)

    @property
    def n_active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self._slots):
            if r is None:
                return i
        return None

    def admit_pending(self) -> int:
        """Admit queued requests FIFO until a slot, the page pool, or
        the governor pushes back.  Returns the number admitted."""
        n = 0
        while self.queue and self.n_active < self.max_active:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue[0]
            try:
                pids = self.pool.alloc(self.pool.n_logical_pages,
                                       req.tier)
            except CapacityError:
                break                          # backpressure: wait
            if self.governor is not None:
                try:
                    # the governed domain must keep the WHOLE post-
                    # admission working set usable (the scheduler's
                    # analog of generate()'s whole-batch bytes), not
                    # just the new request's cache
                    self._voltage = self.governor.admit(
                        (self.n_active + 1) * self.pool.request_words * 4)
                except CapacityError:
                    self.pool.free(pids)
                    break
            self.queue.popleft()
            self._admit(req, slot, pids)
            n += 1
        return n

    def _admit(self, req: Request, slot: int, pids: np.ndarray) -> None:
        sc = self.sc
        prompt = np.asarray(req.tokens, np.int32).reshape(1, -1)
        prompt_len = prompt.shape[1]
        n_new = int(req.max_new_tokens if req.max_new_tokens is not None
                    else sc.max_new_tokens)      # >= 1, checked at submit
        v_arr = jnp.float32(self._voltage)

        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompt)})
        key = req.key if req.key is not None else jax.random.PRNGKey(0)
        key, k0 = jax.random.split(key)
        tok0 = self._sample_one(logits, k0)        # (1,)

        st = self.state
        st["pool"] = self._admit_pool(st["pool"], cache,
                                      jnp.asarray(pids), v_arr)
        self.state = {
            "pool": st["pool"],
            "ptab": st["ptab"].at[slot].set(jnp.asarray(pids)),
            "qpos": st["qpos"].at[slot].set(prompt_len),
            "tok": st["tok"].at[slot].set(tok0),
            "keys": st["keys"].at[slot].set(key),
            "active": st["active"].at[slot].set(True),
        }
        self._slots[slot] = req.rid
        self._slot_pages[slot] = np.asarray(pids)
        self._out[req.rid] = [int(tok0[0])]
        self._remaining[req.rid] = n_new - 1
        self._meta[req.rid] = RequestResult(
            rid=req.rid, tokens=None, page_ids=np.asarray(pids),
            placement=self.pool.request_placement(pids),
            voltage=(self._voltage if self.pool.placement is not None
                     else None))
        self.admitted += 1
        self.peak_active = max(self.peak_active, self.n_active)
        if self._remaining[req.rid] == 0:
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        rid = self._slots[slot]
        res = self._meta.pop(rid)
        res.tokens = np.asarray(self._out.pop(rid), np.int32)[None, :]
        self.results[rid] = res
        self.pool.free(self._slot_pages[slot])
        del self._remaining[rid]
        self._slots[slot] = None
        self._slot_pages[slot] = None
        st = self.state
        self.state = {
            **st,
            "ptab": st["ptab"].at[slot].set(self.pool.scratch_id),
            "active": st["active"].at[slot].set(False),
        }

    def step_once(self) -> None:
        """One decode step for every active slot (single compiled
        call), then collect tokens and retire finished requests."""
        self.state, nt = self._step(self.params, self.state,
                                    jnp.float32(self._voltage))
        toks = np.asarray(nt)[:, 0]
        self.steps += 1
        for slot, rid in enumerate(self._slots):
            if rid is None:
                continue
            self._out[rid].append(int(toks[slot]))
            self._remaining[rid] -= 1
            if self._remaining[rid] == 0:
                self._retire(slot)

    def run(self) -> Dict[Any, RequestResult]:
        """Drain the queue: admit / step / retire until every submitted
        request has finished.  Returns ``results`` (also kept on the
        scheduler)."""
        while self.queue or self.n_active:
            self.admit_pending()
            if not self.n_active:
                if not self.queue:
                    break
                # Nothing running and the head request still cannot be
                # admitted: it can never fit.  Re-run its admission
                # checks so the capacity source raises its own error.
                pids = self.pool.alloc(self.pool.n_logical_pages,
                                       self.queue[0].tier)
                self.pool.free(pids)
                if self.governor is not None:
                    self.governor.admit(self.pool.request_words * 4)
                raise CapacityError(
                    "scheduler", self.pool.request_words * 4,
                    self.pool.free_pages * self.pool.page_set_words * 4,
                    "admission stuck with an idle pool")
            self.step_once()
        return self.results

    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "steps": self.steps,
            "admitted": self.admitted,
            "peak_active": self.peak_active,
            "decode_traces": len(self.traces),
            "free_pages": self.pool.free_pages,
            "voltage": self._voltage,
        }
