"""State-arena continuous batching: the scheduler route for families
whose decode state does not page.

The paged scheduler (serving/scheduler.py) serves families that
advertise ``SUPPORTS_PAGED``: uniform/window ring caches scattered into
a shared page pool.  Everything else in the zoo -- MoE with MLA
latents, recurrent-state hybrids (recurrentgemma), xLSTM matrix
memories, whisper's enc-dec decoder, the VLM wrapper -- carries cache
leaves the page pool cannot address (slotless recurrent state, cross-
attention K/V written once at prefill, full rings behind a module that
lacks the paged decode plumbing).  This module serves those families
through the SAME scheduler front door (``ContinuousBatchingScheduler``
dispatches here from ``__new__``) and the same contracts:

  * ONE jitted donated decode step for any mix of tenants -- the whole
    per-slot cache tree is batched along dim 0 (slot = batch row) and
    every step advances all active rows with a per-row position vector
    (``decode_traces == 1``, flat launch budget).
  * Admission runs the request's prefill through the *identical*
    memoized jitted prefill ``generate()`` uses (bucketed where the
    family pads, exact otherwise), samples the first token with the
    same key trajectory, applies the standalone post-prefill
    ``init_inject`` (``inject_group`` on the slot's placement), then
    scatters the (1, max_len) tree into the slot's batch row.
  * Placement is *tiered arena placement per slot*, fixed at
    construction: ``place_groups_tiered`` lays out ``num_slots``
    disjoint copies of the (batch=1, max_len) cache across the plan's
    domains at the state tier (default ``"cheap"`` -- carried state is
    fault-tolerant by default).  Fixed placements keep every per-slot
    threshold table a trace-time constant of the one donated step.
  * Persistent-fault semantics for carried state: the step's per-slot
    write-path injection (``inject_placement_slice``) corrupts ring
    leaves only at the slot just written but slotless ``state`` leaves
    *whole* -- and since recurrent state is rewritten every step, the
    stuck-at masks re-apply to every new value: a fault acquired on
    write persists for the lifetime of the request (corrupt-once-on-
    write), unlike ring rows that are written once and only re-masked
    idempotently.
  * Token equivalence: every request's tokens are bit-identical to a
    standalone ``generate()`` replay with ``kv_placement`` set to the
    slot's placement -- the scheduler performs the standalone engine's
    exact jitted calls (same prefill, same ``inject_group`` init, same
    ``inject_placement_slice`` post-step with the same placement
    constants, same ``sample_tokens`` key trajectory), just batched
    into slot rows.  MoE decode capacity is forced lossless at C=1
    (see ``models.moe.moe_ffn``) so batched routing cannot drop a
    token a solo replay would keep.

Extras (``Request.extras``): modality inputs beyond tokens -- whisper
``frames``, VLM ``patches`` -- passed unbatched and admitted with a
leading batch axis, exactly as ``generate()`` takes them.  VLM query
positions start at ``prompt_len + cfg.enc_len`` (image tokens occupy
the front of the ring), mirroring the engine's ``pos0``.

Whisper encoder sharing: with ``ServeConfig.share_prefix`` the
admission prefill is content-addressed -- identical (tokens, extras)
bytes reuse the previously computed (logits, cache) device buffers, so
repeated audio skips the encoder entirely.  This is a *host-side*
result reuse rather than the paged pool's COW page mapping (cross
leaves live in per-slot arena state, not shared pages); it is
numerically risk-free because the reused values come from the same
compiled prefill the replay runs.

MoE expert criticality tiering (``expert_probe=``): a probe token
batch drives ``module.routing_frequency``; experts are ranked and
placed tiered (hot quarter -> ``safe``, cold quarter ->
``disposable``, rest -> ``cheap``) via ``place_groups_tiered`` over
the plan's domains, and expert weights in unsafe domains are corrupted
ONCE at construction (write-path ``inject_group``).  Weights are never
rewritten, so the corruption is persistent by construction, and solo
replays are bit-exact trivially because they run on ``self.params``.

Not supported on this route (clear errors, not silent fallbacks):
serve meshes, self-healing (both need paged read-mode pools),
admission governors, ``kv_injection='read'``/``'rewrite'`` (no
read-path kernel addresses these layouts; auto resolves to 'write'),
and per-request tier routing (placements are fixed per slot; pass
``state_tier=`` at construction instead).
"""
from __future__ import annotations

import collections
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as arena
from repro.core.engine import _static_value, resolve_method
from repro.core.domains import place_groups, place_groups_tiered
from repro.core.faultmodel import V_MIN, V_NOM
from repro.core.injection import inject_group
from repro.models import cache as C
from repro.models.base import (ArchBundle, ArchConfig, cache_batch_axes,
                               cache_layouts, cache_slot_axes, spec_avals)
from repro.obs.metrics import (MetricsRegistry, ObsConfig,
                               init_step_counters, N_STEP_COUNTERS)
from repro.obs.trace import EventTrace
from repro.serving import scheduler as _sched
from repro.serving.engine import ServeConfig, bucketed_prefill, sample_tokens


def _batch_bytes(tree) -> bytes:
    """Content address of one admission batch (tokens + extras)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    h = []
    for p, a in flat:
        arr = np.asarray(a)
        h.append(jax.tree_util.keystr(p).encode())
        h.append(str(arr.shape).encode() + str(arr.dtype).encode())
        h.append(arr.tobytes())
    return b"|".join(h)


class StateArenaScheduler(_sched.ContinuousBatchingScheduler):
    """Continuous batching over per-slot arena-placed whole caches.

    Constructed through ``ContinuousBatchingScheduler(...)`` -- its
    ``__new__`` dispatches here when the family lacks
    ``SUPPORTS_PAGED``.  ``num_pages``/``page_slots`` are accepted for
    signature compatibility and ignored (there is no page pool).
    """

    def __init__(self, bundle: ArchBundle, cfg: ArchConfig, params,
                 sc: ServeConfig, *, num_slots: int, num_pages: int = 0,
                 page_slots: int = 0, max_active: Optional[int] = None,
                 dist=None, interpret: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mesh_axis: str = "serve",
                 shard_seeds: Optional[Sequence[int]] = None,
                 shard_setpoints: Optional[Sequence[float]] = None,
                 self_heal=None, obs: Optional[ObsConfig] = None,
                 state_tier: Any = "cheap",
                 expert_probe: Optional[Any] = None):
        module = bundle.module
        fam = getattr(cfg, "family", "?")
        if mesh is not None or shard_seeds is not None \
                or shard_setpoints is not None:
            raise _sched.ShardLayoutError(
                f"family {fam!r} serves through the state-arena route "
                "(no SUPPORTS_PAGED), which is single-shard: serve "
                "meshes partition the page pool, and this family's "
                "cache does not page")
        if self_heal is not None:
            raise ValueError(
                "self_heal needs paged read-mode caches (ECC telemetry "
                "and page migration address pool pages); the state-"
                f"arena route serving family {fam!r} has no page pool")
        if sc.governor is not None:
            raise ValueError(
                "ServeConfig.governor walks the paged pool's capacity "
                "frontier; the state-arena route has fixed per-slot "
                "placements decided at construction (re-plan by "
                "rebuilding the scheduler)")
        if sc.kv_injection == "rewrite":
            raise ValueError(
                "kv_injection='rewrite' is the legacy one-shot oracle; "
                "the scheduler's donated step injects incrementally. "
                "Use 'write' (or 'auto')")
        if sc.kv_injection == "read":
            raise ValueError(
                f"kv_injection='read' needs a family with read-path "
                f"support; family {fam!r} serves on the state-arena "
                "route where faults ride the write path ('write' or "
                "'auto')")
        if sc.kv_injection not in ("auto", "write"):
            raise ValueError(f"unknown kv_injection {sc.kv_injection!r}")
        self.mode = "write"

        self.bundle, self.cfg, self.params = bundle, cfg, params
        self.sc, self.dist = sc, dist
        self.mesh = None
        self.n_shards = 1
        self.num_slots = int(num_slots)
        self.slots_per_shard = self.num_slots
        self.max_active = int(num_slots if max_active is None
                              else max_active)
        if self.num_slots < 1 or not 1 <= self.max_active <= self.num_slots:
            raise ValueError(
                f"need 1 <= max_active ({self.max_active}) <= num_slots "
                f"({self.num_slots})")

        # ---- cache geometry / layouts ---------------------------------
        S = self.num_slots
        self._specs1 = module.cache_specs(cfg, 1, sc.max_len)
        self._specsS = module.cache_specs(cfg, S, sc.max_len)
        self.cache_avals1 = spec_avals(self._specs1)
        self.slot_axes1 = cache_slot_axes(self._specs1)
        # The serving-batch axis is located by name per leaf -- period-
        # stacked leaves carry the layer stack at dim 0, so slot
        # scatter/slice must NOT assume the batch lives in front.
        self.batch_axes = cache_batch_axes(self._specs1)
        for ax in jax.tree_util.tree_leaves(self.batch_axes):
            if ax < 0:
                raise ValueError(
                    f"family {fam!r} has a cache leaf without a "
                    "'batch' axis; the state arena slices per-request "
                    "rows by that name")
        self.layouts = cache_layouts(self._specs1, sc.max_len)
        self.layout_kinds = tuple(sorted(
            set(jax.tree_util.tree_leaves(self.layouts))))

        # ---- per-slot tiered arena placement (fixed at construction) --
        plan = (sc.undervolt
                if sc.undervolt is not None and sc.undervolt.enabled
                else None)
        self.plan = plan
        self.state_tier = state_tier
        self.placements: List[Optional[Any]] = [None] * S
        self.fmap = None
        if plan is not None and plan.covers("kv_cache"):
            self.fmap = plan.fault_map()
            groups = {f"kv_cache[{i:04d}]": self.cache_avals1
                      for i in range(S)}
            if plan.tiers is not None:
                # tiered plan: per-slot caches ride the state tier
                # (fault-tolerant by default -- carried state degrades
                # gracefully and solo replay is exact either way)
                placed = place_groups_tiered(
                    groups, {g: state_tier for g in groups},
                    plan.domains, plan.geometry, self.fmap)
            else:
                # policy plan: honor the plan's kv_cache -> domain pin
                # without tier gating, exactly like generate()'s
                # plan.place() on a policy plan
                dname = plan.policy["kv_cache"]
                placed = place_groups(
                    groups, {g: dname for g in groups}, plan.domains,
                    plan.geometry)
            self.placements = [placed[f"kv_cache[{i:04d}]"]
                               for i in range(S)]

        # ---- per-slot voltage / method / liveness (mirrors generate) --
        if (sc.kv_voltage is not None and sc.kv_method == "auto"
                and _static_value(sc.kv_voltage) is None):
            raise ValueError(
                "kv_method='auto' cannot dispatch from a traced "
                "kv_voltage (method selection is static); pass "
                "kv_method='word' or 'bitwise' explicitly")
        self._slot_volt: List[Optional[float]] = [None] * S
        self._slot_method: List[str] = ["word"] * S
        self._slot_live: List[bool] = [False] * S
        for i, plc in enumerate(self.placements):
            if plc is None:
                continue
            eff = (sc.kv_voltage if sc.kv_voltage is not None
                   else plc.domain.voltage)
            sv = _static_value(eff)
            live = not (sv is not None and sv >= V_MIN - 1e-9)
            meth = sc.kv_method
            if live and meth == "auto":
                meth = ("word" if plc.domain.ecc
                        else resolve_method(self.fmap, plc, sv))
            self._slot_volt[i] = eff
            self._slot_method[i] = meth
            self._slot_live[i] = live
        self.active = any(self._slot_live)
        self.governor = None

        # ---- MoE expert criticality tiering ---------------------------
        self.expert_tiers: Optional[Dict[int, str]] = None
        self.expert_freq = None
        self._expert_placements = None
        if expert_probe is not None:
            self._tier_experts(np.asarray(expert_probe, np.int64))

        # ---- prefill (the standalone engine's exact entry) ------------
        self._prefill = bucketed_prefill(module, cfg, sc.max_len, dist)
        if self._prefill is None:
            self._prefill = jax.jit(
                lambda p, bt: module.prefill(p, bt, cfg, sc.max_len,
                                             dist))
        self._prefill_cache: Dict[bytes, Any] = {}
        self.prefill_reuse = 0
        self._admit_jits: Dict[int, Any] = {}

        # ---- donated state / host bookkeeping -------------------------
        self.queue: collections.deque = collections.deque()
        self.results: Dict[Any, _sched.RequestResult] = {}
        self._slots: List[Optional[Any]] = [None] * S
        self._out: Dict[Any, List[int]] = {}
        self._remaining: Dict[Any, int] = {}
        self._meta: Dict[Any, _sched.RequestResult] = {}
        self._admit_step: Dict[Any, int] = {}
        self.steps = 0
        self.admitted = 0
        self.peak_active = 0
        self.traces: List[int] = []

        self.obs = (obs if obs is not None
                    else sc.obs if sc.obs is not None else ObsConfig())
        self.metrics: Optional[MetricsRegistry] = None
        self.trace: Optional[EventTrace] = None
        if self.obs.enabled:
            self.metrics = MetricsRegistry(
                1, None, config=self.obs,
                kv_slot_bytes=self._step_write_bytes(),
                kv_page_bytes=self._slot_read_bytes(),
                layouts=self.layout_kinds)
            self.trace = EventTrace(capacity=self.obs.trace_capacity)

        self.state = self._init_state()
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))

    # ---- static byte geometry (obs) -----------------------------------
    def _payload_leaves(self):
        flat = jax.tree_util.tree_leaves(
            self.cache_avals1,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        axes = jax.tree_util.tree_leaves(self.slot_axes1)
        lays = jax.tree_util.tree_leaves(self.layouts)
        for a, ax, lay in zip(flat, axes, lays):
            if a.dtype == jnp.int32:
                continue               # pos bookkeeping, not payload
            yield a, ax, lay

    def _step_write_bytes(self) -> int:
        """Bytes one active lane writes per decode step: one ring row
        per ring leaf, the WHOLE leaf for carried state (rewritten --
        and re-corrupted -- every step)."""
        total = 0
        for a, ax, lay in self._payload_leaves():
            nb = int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
            total += nb // a.shape[ax] if ax >= 0 else nb
        return total

    def _slot_read_bytes(self) -> int:
        """Bytes one active lane reads per decode step (its whole
        per-slot cache payload: rings, cross K/V, carried state)."""
        return sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                   for a, _, _ in self._payload_leaves())

    # ---- MoE expert tiering -------------------------------------------
    def _tier_experts(self, probe: np.ndarray) -> None:
        module, cfg, plan = self.bundle.module, self.cfg, self.plan
        if not hasattr(module, "routing_frequency") or not cfg.n_experts:
            raise ValueError(
                f"expert_probe given but family {cfg.family!r} has no "
                "routing_frequency (expert tiering is MoE-only)")
        if plan is None or self.fmap is None:
            raise ValueError(
                "expert_probe needs an enabled undervolt plan covering "
                "'kv_cache' (expert weights are placed on the same "
                "fault map as the per-slot caches)")
        freq = np.asarray(module.routing_frequency(
            self.params, probe.reshape(1, -1), cfg))
        e = cfg.n_experts
        order = np.argsort(-freq, kind="stable")
        quarter = max(e // 4, 1)
        tiers: Dict[int, str] = {}
        for rank, ex in enumerate(int(x) for x in order):
            tiers[ex] = ("safe" if rank < quarter
                         else "disposable" if rank >= e - quarter
                         else "cheap")
        # per-expert weight slices across every MoE layer group
        trees: Dict[int, Dict[str, Any]] = {ex: {} for ex in range(e)}
        sites = []
        for cname in ("prefix", "periods", "rest"):
            for gkey, grp in self.params["stack"].get(cname, {}).items():
                if "we_g" not in grp:
                    continue
                for w in ("we_g", "we_u", "we_d"):
                    sites.append((cname, gkey, w))
                    for ex in range(e):
                        trees[ex][f"{cname}/{gkey}/{w}"] = \
                            grp[w][..., ex, :, :]
        groups = {f"moe_expert[{ex:03d}]": trees[ex] for ex in range(e)}
        placed = place_groups_tiered(
            groups, {f"moe_expert[{ex:03d}]": tiers[ex]
                     for ex in range(e)},
            plan.domains, plan.geometry, self.fmap)
        # one-time write-path corruption: expert weights are never
        # rewritten, so faults taken here persist for the scheduler's
        # lifetime (the paper's stuck-at-on-write semantics for
        # read-mostly tensors)
        corrupted: Dict[int, Any] = {}
        for ex in range(e):
            plc = placed[f"moe_expert[{ex:03d}]"]
            v = plc.domain.voltage
            if v >= V_MIN - 1e-9:
                continue
            meth = ("word" if plc.domain.ecc
                    else resolve_method(self.fmap, plc, v))
            corrupted[ex], _ = inject_group(
                trees[ex], plc, self.fmap, voltage=jnp.float32(v),
                method=meth)
        if corrupted:
            stack = {cn: dict(gr)
                     for cn, gr in self.params["stack"].items()}
            touched = {(cn, gk) for cn, gk, _ in sites}
            for cn, gk in touched:
                stack[cn][gk] = dict(stack[cn][gk])
            for cn, gk, w in sites:
                arr = jnp.asarray(stack[cn][gk][w])
                for ex, tree in corrupted.items():
                    arr = arr.at[..., ex, :, :].set(
                        tree[f"{cn}/{gk}/{w}"])
                stack[cn][gk][w] = arr
            self.params = {**self.params, "stack": stack}
        self.expert_tiers = tiers
        self.expert_freq = freq
        self._expert_placements = placed

    # ---- compiled pieces ----------------------------------------------
    def _init_state(self):
        S = self.num_slots
        out = {
            "cache": C.init_cache(self._specsS),
            "qpos": jnp.full((S,), -1, jnp.int32),
            "tok": jnp.zeros((S, 1), jnp.int32),
            "keys": jnp.zeros((S, 2), jnp.uint32),
            "active": jnp.zeros((S,), bool),
        }
        if self.obs.enabled:
            out["mtr"] = init_step_counters(1)
        return out

    def _volt_vec(self):
        return jnp.asarray(
            [v if v is not None else 0.0 for v in self._slot_volt],
            jnp.float32)

    def _post_inject(self, cache, qpos, v):
        """Per-slot write-path injection, unrolled over slots: the
        standalone engine's ``post_inject`` on each slot's own
        placement constants (ring leaves at the slot just written,
        carried state whole -- the persistent-fault semantic)."""
        for s in range(self.num_slots):
            if not self._slot_live[s]:
                continue
            sub = jax.tree_util.tree_map(
                lambda x, ax: jax.lax.slice_in_dim(x, s, s + 1, axis=ax),
                cache, self.batch_axes)
            sub, _ = arena.inject_placement_slice(
                sub, self.placements[s], self.fmap,
                slot_axes=self.slot_axes1, pos=qpos[s], voltage=v[s],
                method=self._slot_method[s])
            cache = jax.tree_util.tree_map(
                lambda full, one, ax: self._set_row(full, one, ax, s),
                cache, sub, self.batch_axes)
        return cache

    @staticmethod
    def _set_row(full, one, ax: int, s: int):
        """Write the (batch=1) tree's single row into batch row ``s``
        of the batched tree, along the leaf's own batch axis."""
        idx = (slice(None),) * ax + (s,)
        return full.at[idx].set(
            jax.lax.index_in_dim(one, 0, axis=ax, keepdims=False))

    def _step_fn(self, params, state, v):
        self.traces.append(1)
        module, cfg = self.bundle.module, self.cfg
        act = state["active"]
        pos = jnp.where(act, state["qpos"], -1)
        logits, cache = module.decode_step(
            params, state["cache"], {"tokens": state["tok"]}, pos, cfg,
            self.dist)
        if self.active:
            cache = self._post_inject(cache, state["qpos"], v)
        ks = jax.vmap(jax.random.split)(state["keys"])
        new_keys, ki = ks[:, 0], ks[:, 1]
        nt = jax.vmap(
            lambda l, kk: sample_tokens(l[None], kk,
                                        self.sc.temperature)[0]
        )(logits, ki)[:, None]
        new_state = {
            "cache": cache,
            "qpos": state["qpos"] + act.astype(jnp.int32),
            "tok": jnp.where(act[:, None], nt, state["tok"]),
            "keys": jnp.where(act[:, None], new_keys, state["keys"]),
            "active": act,
        }
        if self.obs.enabled:
            decoded = act.astype(jnp.int32).sum()
            delta = jnp.zeros((N_STEP_COUNTERS,), jnp.int32)
            delta = delta.at[0].set(decoded)   # tokens_decoded
            delta = delta.at[2].set(decoded)   # kv_slots_written
            delta = delta.at[3].set(decoded)   # cache reads (per lane)
            new_state["mtr"] = state["mtr"] + delta[None]
        return new_state, nt

    def _admit_fn(self, s: int):
        """Per-slot jitted admit: standalone ``init_inject`` on the
        (1, max_len) prefill tree with the slot's placement, then a
        donated scatter into the batched cache's row ``s``."""
        fn = self._admit_jits.get(s)
        if fn is not None:
            return fn
        plc, meth = self.placements[s], self._slot_method[s]
        live, fmap = self._slot_live[s], self.fmap

        def admit(big, one, v):
            if live:
                one, _ = inject_group(one, plc, fmap, voltage=v,
                                      method=meth)
            return jax.tree_util.tree_map(
                lambda full, x, ax: self._set_row(full, x, ax, s),
                big, one, self.batch_axes)

        fn = jax.jit(admit, donate_argnums=(0,))
        self._admit_jits[s] = fn
        return fn

    # ---- host loop ----------------------------------------------------
    def _emit(self, kind: str, **kw) -> None:
        if self.trace is not None:
            self.trace.emit(kind, step=self.steps,
                            layout="+".join(self.layout_kinds), **kw)

    def submit(self, request: _sched.Request) -> None:
        n_new = (request.max_new_tokens
                 if request.max_new_tokens is not None
                 else self.sc.max_new_tokens)
        if int(n_new) < 1:
            raise ValueError(
                f"request {request.rid!r}: max_new_tokens={n_new} must "
                "be >= 1")
        prompt = np.asarray(request.tokens).reshape(-1)
        plen = int(prompt.shape[0])
        if plen < 1:
            raise ValueError(f"request {request.rid!r}: empty prompt")
        enc = (self.cfg.enc_len if self.cfg.family == "vlm" else 0)
        if plen + enc + int(n_new) > self.sc.max_len:
            raise ValueError(
                f"request {request.rid!r}: prompt ({plen}) + "
                f"{'image tokens + ' if enc else ''}new tokens "
                f"({n_new}) exceed max_len={self.sc.max_len}; the "
                "state-arena ring holds the whole request")
        self.queue.append(request)

    @property
    def n_active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    def _free_slot(self) -> Optional[int]:
        for s, r in enumerate(self._slots):
            if r is None:
                return s
        return None

    def admit_pending(self) -> int:
        n = 0
        while self.queue and self.n_active < self.max_active:
            s = self._free_slot()
            if s is None:
                break
            req = self.queue.popleft()
            self._admit(req, s)
            n += 1
        if self.queue and n == 0 and self.n_active >= self.max_active:
            self._emit("backpressure", rid=self.queue[0].rid,
                       queued=len(self.queue), active=self.n_active)
        return n

    def _admit(self, req: _sched.Request, s: int) -> None:
        prompt = np.asarray(req.tokens, np.int32).reshape(-1)
        n_new = int(req.max_new_tokens
                    if req.max_new_tokens is not None
                    else self.sc.max_new_tokens)
        batch = {"tokens": jnp.asarray(prompt)[None]}
        for k_, v_ in (req.extras or {}).items():
            batch[k_] = jnp.asarray(v_)[None]
        reused = False
        if self.sc.share_prefix:
            ck = _batch_bytes(batch)
            hit = self._prefill_cache.get(ck)
            if hit is None:
                hit = self._prefill(self.params, batch)
                self._prefill_cache[ck] = hit
            else:
                self.prefill_reuse += 1
                reused = True
            logits, cache1 = hit
        else:
            logits, cache1 = self._prefill(self.params, batch)
        key = req.key if req.key is not None else jax.random.PRNGKey(0)
        key, k0 = jax.random.split(key)
        tok0 = sample_tokens(logits, k0, self.sc.temperature)

        plen = int(prompt.shape[0])
        qpos0 = plen + (self.cfg.enc_len
                        if self.cfg.family == "vlm" else 0)
        volt = self._slot_volt[s]
        st = self.state
        new_cache = self._admit_fn(s)(
            st["cache"], cache1,
            jnp.float32(volt if volt is not None else 0.0))
        self.state = {
            **st,
            "cache": new_cache,
            "qpos": st["qpos"].at[s].set(qpos0),
            "tok": st["tok"].at[s].set(tok0),
            "keys": st["keys"].at[s].set(key),
            "active": st["active"].at[s].set(True),
        }
        self._slots[s] = req.rid
        self._admit_step[req.rid] = self.steps
        self._out[req.rid] = []
        self._remaining[req.rid] = n_new
        self._meta[req.rid] = _sched.RequestResult(
            rid=req.rid, tokens=None,
            page_ids=np.zeros((0,), np.int32),
            placement=self.placements[s],
            voltage=(volt if self.placements[s] is not None else None),
            pages_shared=int(reused), shard=0)
        self.admitted += 1
        self.peak_active = max(self.peak_active, self.n_active)
        self._emit("admission", rid=req.rid, plen=plen,
                   n_new=int(n_new), voltage=volt,
                   prefill_reused=reused)
        # token 0 is the admission-time prefill sample (standalone tok0)
        self._collect(s, req.rid, int(np.asarray(tok0)[0]))

    def _collect(self, s: int, rid, token: int) -> None:
        out = self._out[rid]
        if not out:
            self._meta[rid].ttft_steps = (self.steps
                                          - self._admit_step[rid])
        out.append(int(token))
        self._remaining[rid] -= 1
        if self._remaining[rid] == 0:
            self._retire(s)

    def _retire(self, s: int) -> None:
        rid = self._slots[s]
        res = self._meta.pop(rid)
        res.tokens = np.asarray(self._out.pop(rid), np.int32)[None, :]
        self.results[rid] = res
        self._emit("retirement", rid=rid,
                   tokens=int(res.tokens.shape[1]),
                   ttft_steps=res.ttft_steps)
        del self._remaining[rid]
        del self._admit_step[rid]
        self._slots[s] = None
        st = self.state
        self.state = {
            **st,
            "qpos": st["qpos"].at[s].set(-1),
            "active": st["active"].at[s].set(False),
        }

    def step_once(self) -> None:
        t0 = time.perf_counter()
        self.state, nt = self._step(self.params, self.state,
                                    self._volt_vec())
        toks = np.asarray(nt).reshape(-1)
        if self.metrics is not None:
            self.metrics.record_step(time.perf_counter() - t0)
        self.steps += 1
        for s, rid in enumerate(self._slots):
            if rid is not None:
                self._collect(s, rid, toks[s])

    def run(self) -> Dict[Any, _sched.RequestResult]:
        while self.queue or self.n_active:
            n = self.admit_pending()
            if self.n_active:
                self.step_once()
            elif n == 0:
                raise RuntimeError(
                    f"stuck: {len(self.queue)} queued, none admitted, "
                    "none active")
            # else: every admission retired at its prefill token
            # (max_new_tokens == 1); loop to drain the queue
        return self.results

    @property
    def pricing_voltages(self) -> List[float]:
        vs = [v for v, p in zip(self._slot_volt, self.placements)
              if p is not None and v is not None]
        return [min(vs) if vs else V_NOM]

    @property
    def stats(self) -> Dict[str, Any]:
        vs = [v for v, live in zip(self._slot_volt, self._slot_live)
              if live and v is not None]
        out = {
            "route": "state",
            "cache_layouts": list(self.layout_kinds),
            "steps": self.steps,
            "admitted": self.admitted,
            "peak_active": self.peak_active,
            "decode_traces": len(self.traces),
            "voltage": (min(vs) if vs else None),
            "n_shards": 1,
            "prefill_reuse": self.prefill_reuse,
            "shards": [{
                "shard": 0,
                "active": self.n_active,
                "voltage": (min(vs) if vs else None),
                "setpoint": None,
                "map_seed": (self.plan.map_seed
                             if self.plan is not None else None),
            }],
        }
        if self.expert_tiers is not None:
            tiers = collections.Counter(self.expert_tiers.values())
            out["expert_tiers"] = dict(tiers)
        if self.metrics is not None:
            out["obs"] = self.metrics.snapshot(
                self.state, voltages=self.pricing_voltages)
        if self.trace is not None:
            out["events"] = dict(self.trace.counts)
        return out
