"""Training launcher: ``--arch <id>`` end-to-end driver.

Runs the reduced config on CPU by default (the full configs are only
lowered AOT via dryrun.py on this container).  Wires together the data
pipeline, trainer, undervolt plan, async checkpointing, and crash/
restore handling -- the same step function the dry-run lowers for the
production meshes.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 100 --undervolt 0.93 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core.domains import DeviceCrashError
from repro.core.hbm import TPU_V5E
from repro.data.pipeline import DataConfig, make_batch
from repro.models.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training import trainer
from repro.training.undervolt import aggressive_plan, guardband_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--undervolt", type=float, default=0.0,
                    help="unsafe-domain voltage; 0 = guardband plan")
    ap.add_argument("--mitigation", default="clamp",
                    choices=["none", "clamp"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full-size config (needs real HW)")
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    cfg = bundle.cfg if args.full_config else bundle.reduced
    try:
        plan = (aggressive_plan(v_unsafe=args.undervolt,
                                mitigation=args.mitigation,
                                geometry=TPU_V5E)
                if args.undervolt else guardband_plan(TPU_V5E))
    except DeviceCrashError as e:
        raise SystemExit(f"refusing to launch: {e}")

    report = plan.power_report(utilization=0.7)
    print(f"[undervolt] blended HBM savings "
          f"{report['blended_savings_x']:.2f}x, "
          f"{report['pcs_powered']}/{TPU_V5E.num_pcs} PCs powered")

    tc = trainer.TrainConfig(
        microbatches=args.microbatches,
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps),
        undervolt=plan, grad_compression=args.grad_compression)
    step_fn = jax.jit(trainer.make_train_step(bundle, cfg, tc))
    state = trainer.init_state(bundle, cfg, jax.random.PRNGKey(0))
    if tc.grad_compression == "int8_ef":
        from repro.optim.compress import init_ef
        state["ef"] = init_ef(state["params"])

    start = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
            restored, meta = ckpt.restore(args.ckpt_dir, state)
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            start = meta["step"]
            print(f"[resume] restored step {start}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(dc, i, cfg).items()}
        state, m = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if writer and (i + 1) % args.ckpt_every == 0:
            writer.submit(i + 1, state, {"loss": float(m["loss"])})
    if writer:
        writer.submit(args.steps, state, {"loss": float(m["loss"])})
        writer.finalize()
        print(f"[ckpt] final checkpoint at step {args.steps}")


if __name__ == "__main__":
    main()
