"""Per-cell step plans: step function + input avals + shardings.

A *cell* is (architecture x input shape x mesh).  ``build_cell`` returns
everything ``dryrun.py`` needs to lower AOT: the step callable, its
argument avals (ShapeDtypeStructs -- nothing is allocated), and matching
NamedShardings.  The same plans drive real launches: ``train.py`` feeds
concrete arrays through the identical jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.launch.sharding import ShardingRules, resolve_spec, tree_shardings
from repro.models.base import (SHAPES, ArchBundle, ParamSpec, ShapeCell,
                               get_arch, spec_avals)
from repro.models.dist import DistContext
from repro.optim import adamw
from repro.training import trainer


@dataclasses.dataclass
class CellPlan:
    arch_id: str
    shape: ShapeCell
    mesh: jax.sharding.Mesh
    step_fn: Any
    in_avals: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    layer_scan_trips: Dict[str, int]     # scan name -> trip count (roofline)
    microbatches: int = 1

    dist: Any = None

    def lower(self):
        from repro.models import dist as dist_mod
        fn = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                     out_shardings=self.out_shardings,
                     donate_argnums=self.donate_argnums)
        with self.mesh, dist_mod.use(self.dist):
            return fn.lower(*self.in_avals)


def _rules_for(shape: ShapeCell,
               overrides=None) -> ShardingRules:
    return ShardingRules.default(
        long_context=(shape.name == "long_500k"), overrides=overrides)


def _batch_avals(cfg, shape: ShapeCell, kind: str):
    b = shape.global_batch
    if kind == "decode":
        toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        s = shape.seq_len
        if cfg.family == "vlm":
            s = shape.seq_len - cfg.enc_len   # total context = seq_len
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {"tokens": toks}
    if cfg.family == "vlm" and kind != "decode":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_len, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio" and kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_len, cfg.d_model), jnp.float32)
    return out


def _batch_shardings(batch_avals, mesh, rules):
    def shard_one(a):
        spec = ParamSpec(shape=a.shape,
                         axes=("batch",) + (None,) * (a.ndim - 1),
                         dtype=a.dtype)
        return NamedSharding(mesh, resolve_spec(spec, rules, mesh))
    return jax.tree_util.tree_map(shard_one, batch_avals)


def _dp_shards(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def pick_microbatches(shape: ShapeCell, mesh) -> int:
    """Default grad-accumulation factor: one sequence per DP shard per
    microbatch (bounds live activations; §Perf knob)."""
    dp = _dp_shards(mesh)
    m = max(1, shape.global_batch // dp)
    while shape.global_batch % m or (shape.global_batch // m) % dp:
        m -= 1
    return max(m, 1)


def build_cell(arch_id: str, shape_name: str, mesh: jax.sharding.Mesh,
               rule_overrides=None, microbatches: Optional[int] = None,
               undervolt=None, remat: Optional[str] = None) -> CellPlan:
    bundle = get_arch(arch_id)
    cfg = bundle.cfg
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    rules = _rules_for(shape, rule_overrides)
    dist = DistContext(mesh=mesh, batch_axes=batch_axes(mesh),
                       model_axis="model", rules=rules)
    scan_trips = _scan_trips(bundle, cfg)

    if shape.kind == "train":
        m = (microbatches if microbatches is not None
             else pick_microbatches(shape, mesh))
        tc = trainer.TrainConfig(microbatches=m, undervolt=undervolt)
        step = trainer.make_train_step(bundle, cfg, tc, dist)
        sspecs = trainer.state_specs(bundle, cfg, tc)
        state_avals = spec_avals(sspecs)
        state_sh = tree_shardings(sspecs, rules, mesh)
        batch_avals = _batch_avals(cfg, shape, "train")
        batch_sh = _batch_shardings(batch_avals, mesh, rules)
        scan_trips = {**scan_trips, "microbatch": m}
        return CellPlan(
            arch_id=arch_id, shape=shape, mesh=mesh, step_fn=step,
            in_avals=(state_avals, batch_avals),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,), layer_scan_trips=scan_trips,
            microbatches=m, dist=dist)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return bundle.module.prefill(params, batch, cfg,
                                         shape.seq_len, dist)
        pspecs = bundle.module.param_specs(cfg)
        cache_len = shape.seq_len + (cfg.enc_len if cfg.family == "vlm"
                                     else 0)
        cspecs = bundle.module.cache_specs(cfg, shape.global_batch,
                                           cache_len)
        return CellPlan(
            arch_id=arch_id, shape=shape, mesh=mesh, step_fn=prefill_step,
            in_avals=(spec_avals(pspecs),
                      _batch_avals(cfg, shape, "prefill")),
            in_shardings=(tree_shardings(pspecs, rules, mesh),
                          _batch_shardings(
                              _batch_avals(cfg, shape, "prefill"),
                              mesh, rules)),
            out_shardings=(None, tree_shardings(cspecs, rules, mesh)),
            donate_argnums=(), layer_scan_trips=scan_trips, dist=dist)

    # decode: one new token against a seq_len-deep cache
    def decode_step(params, cache, batch, pos):
        return bundle.module.decode_step(params, cache, batch, pos, cfg,
                                         dist)

    pspecs = bundle.module.param_specs(cfg)
    cache_len = shape.seq_len + (cfg.enc_len if cfg.family == "vlm" else 0)
    cspecs = bundle.module.cache_specs(cfg, shape.global_batch, cache_len)
    cache_sh = tree_shardings(cspecs, rules, mesh)
    batch_avals = _batch_avals(cfg, shape, "decode")
    return CellPlan(
        arch_id=arch_id, shape=shape, mesh=mesh, step_fn=decode_step,
        in_avals=(spec_avals(pspecs), spec_avals(cspecs), batch_avals,
                  jax.ShapeDtypeStruct((), jnp.int32)),
        in_shardings=(tree_shardings(pspecs, rules, mesh), cache_sh,
                      _batch_shardings(batch_avals, mesh, rules),
                      NamedSharding(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,), layer_scan_trips=scan_trips, dist=dist)


def _scan_trips(bundle: ArchBundle, cfg) -> Dict[str, int]:
    """Known scan trip counts, for weighting collectives found inside
    while-loop bodies in the roofline analysis."""
    trips: Dict[str, int] = {}
    if hasattr(bundle.module, "layout"):
        trips["layers"] = bundle.module.layout(cfg).n_periods
    if cfg.family == "audio":
        from repro.models import whisper as W
        trips["enc_layers"] = W.enc_layout(cfg).n_periods
        trips["layers"] = W.dec_layout(cfg).n_periods
    return trips
