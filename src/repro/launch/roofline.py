"""Roofline model: compute / memory / collective terms per cell.

Hardware target: TPU v5e --
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs  / (peak FLOP/s)          [per chip]
    memory term     = HLO_bytes  / (HBM bandwidth)        [per chip]
    collective term = coll_bytes / (ICI link bandwidth)   [per chip]

HLO quantities come from the weighted HLO analysis of the compiled
dry-run artifact (post-SPMD shapes are per-device, so terms are already
per-chip).  MODEL_FLOPS is the analytic useful compute (6*N*D dense /
6*N_active*D MoE for training; 2*N*D for inference) -- the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/replication waste.

The paper's power model is integrated here: the memory term over the
step time gives HBM bandwidth utilization, which feeds P(v, util) -- so
every roofline row also reports the undervolting energy savings this
cell would see (1.5x guardband, up to ~2.3x deep undervolt).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.voltage import DEFAULT_POWER_MODEL
from repro.models.base import SHAPES, get_arch

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

KNOWN_PARAMS: Dict[str, float] = {}


def _total_params(arch_id: str) -> float:
    if arch_id not in KNOWN_PARAMS:
        from repro.models.base import count_params
        b = get_arch(arch_id)
        KNOWN_PARAMS[arch_id] = float(count_params(
            b.module.param_specs(b.cfg)))
    return KNOWN_PARAMS[arch_id]


def _active_params(arch_id: str) -> float:
    """Active (per-token) parameters: MoE counts top_k + shared experts."""
    b = get_arch(arch_id)
    cfg = b.cfg
    total = _total_params(arch_id)
    if cfg.n_experts == 0:
        return total
    expert_block = 3 * cfg.d_model * cfg.d_ff        # gate/up/down
    routed_all = cfg.n_layers * cfg.n_experts * expert_block
    routed_active = cfg.n_layers * cfg.top_k * expert_block
    return total - routed_all + routed_active


def model_flops(arch_id: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step (global, all chips)."""
    shape = SHAPES[shape_name]
    n_act = _active_params(arch_id)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    useful_ratio: float
    step_s: float
    hbm_util: float
    memory_gb: Dict[str, float]
    energy_savings: Dict[str, float]
    collective_breakdown: Optional[Dict[str, float]] = None

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def build_row(arch: str, shape: str, mesh_name: str, chips: int,
              costs, memory_gb: Dict[str, float]) -> RooflineRow:
    compute_s = costs.flops / PEAK_FLOPS
    memory_s = costs.bytes_accessed / HBM_BW
    collective_s = costs.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(compute_s, memory_s, collective_s)
    mf = model_flops(arch, shape)
    useful = mf / max(costs.flops * chips, 1.0)
    hbm_util = min(1.0, memory_s / max(step_s, 1e-12))

    pm = DEFAULT_POWER_MODEL
    energy = {
        "guardband_0.98V_x": round(float(pm.savings(0.98, hbm_util)), 3),
        "tradeoff_0.91V_x": round(float(pm.savings(0.91, hbm_util)), 3),
        "deep_0.85V_x": round(float(pm.savings(0.85, hbm_util)), 3),
    }
    return RooflineRow(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=costs.flops,
        hlo_bytes_per_chip=costs.bytes_accessed,
        collective_bytes_per_chip=costs.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops_global=mf,
        useful_ratio=useful, step_s=step_s, hbm_util=hbm_util,
        memory_gb=memory_gb, energy_savings=energy,
        collective_breakdown={k: round(v, 1) for k, v in
                              costs.collective_breakdown.items()})
