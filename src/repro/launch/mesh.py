"""Production meshes.

Single pod: 16 x 16 = 256 chips (data x model).
Multi-pod: 2 x 16 x 16 = 512 chips (pod x data x model); the ``pod``
axis carries only data parallelism (gradient all-reduce over DCI), the
in-pod axes are unchanged -- so the multi-pod dry-run proves the pod
axis shards without touching the in-pod layout.

``make_production_mesh`` is a function (never module-level state): the
dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before
any jax import, and importing this module must not lock device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

SERVE_AXIS = "serve"


def make_mesh_auto(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported
    (``jax.sharding.AxisType`` only exists in jax >= 0.5; Auto is the
    default behavior on older releases)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_smoke_mesh() -> jax.sharding.Mesh:
    """1-device mesh with production axis names (CPU tests)."""
    return make_mesh_auto((1, 1), ("data", "model"))


def make_serve_mesh(num_shards: Optional[int] = None,
                    axis: str = SERVE_AXIS) -> jax.sharding.Mesh:
    """1-D mesh for the sharded serving scheduler.

    Each device along the ``serve`` axis owns one scheduler shard: its
    own slot range, page-pool arena blocks, fault map and governor
    setpoint.  ``num_shards`` defaults to every visible device.  CPU
    smoke runs fan out with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    any jax import, like the production dry-runs).
    """
    devices = jax.devices()
    if num_shards is None:
        num_shards = len(devices)
    if not 1 <= num_shards <= len(devices):
        raise ValueError(
            f"make_serve_mesh(num_shards={num_shards}): need 1 <= "
            f"num_shards <= {len(devices)} visible devices (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax to fan out on CPU)")
    return jax.sharding.Mesh(np.asarray(devices[:num_shards]), (axis,))
