"""Weighted HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, but our
models scan over layers/microbatches/attention chunks, so FLOPs, bytes
and collective traffic must be weighted by loop trip counts.  This
module parses the post-SPMD HLO text (shapes are PER-DEVICE there),
recovers trip counts from each while's condition computation, and
propagates multiplicative weights down the call graph (while bodies,
fusions, to_apply reducers, conditional branches).

  * FLOPs: dot ops (2 * prod(out) * contracted), convolution approx.
  * bytes: sum of operand+output sizes of top-level compute ops
    (fusion parameters/outputs = actual HBM traffic of the fused kernel).
  * collectives: per-op effective bytes with ring factors
    (all-reduce 2x, all-gather/reduce-scatter (n-1)/n, all-to-all and
    collective-permute 1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLEE_RES = [
    ("while", re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")),
    ("calls", re.compile(r"calls=%([\w.\-]+)")),
    ("calls", re.compile(r"to_apply=%([\w.\-]+)")),
    ("branches", re.compile(r"branch_computations=\{([^}]*)\}")),
]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# effective bytes moved per device, as a fraction of the op result size
COLLECTIVE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                     "reduce-scatter": 1.0, "all-to-all": 1.0,
                     "collective-permute": 1.0}

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "reshape", "broadcast", "iota", "copy-start",
                   "copy-done", "after-all", "partition-id", "while",
                   "conditional", "call",
                   # aliased in-place update: real traffic is slice-sized
                   # and already counted at the update's producer
                   "dynamic-update-slice"}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_bytes: int
    body: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        r = line.rstrip()
        # computation definition: "%name (params...) -> type {"
        # (params may be tuple-typed with nested parens and /*index=N*/
        # comments -- only an assignment "%x = ..." marks an instruction)
        if (r.endswith("{") and "->" in r
                and not _INSTR_RE.match(line)):
            hm = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if hm:
                cur = Computation(name=hm.group(1), instrs=[])
                comps[cur.name] = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # rest: "<type> <opcode>(...)" -- find opcode after the type
        type_end = 0
        depth = 0
        # type may be a tuple "(f32[..], ...)" or plain "f32[..]{..}"
        rest_s = rest.lstrip()
        if rest_s.startswith("("):
            for i, ch in enumerate(rest_s):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        type_end = i + 1
                        break
            type_str = rest_s[:type_end]
            tail = rest_s[type_end:].strip()
        else:
            sp = rest_s.find(" ")
            type_str = rest_s[:sp] if sp > 0 else rest_s
            tail = rest_s[sp + 1:].strip() if sp > 0 else ""
        opcode = tail.split("(")[0].strip() if "(" in tail else tail
        cur.instrs.append(Instr(name=name, opcode=opcode,
                                out_bytes=_shapes_bytes(type_str),
                                body=rest))
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition: the integer constant that
    feeds the ROOT compare (not just any constant -- decode conditions
    also mention sequence-length constants)."""
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.body)
        if m and ins.opcode == "constant":
            consts[ins.name] = int(m.group(1))
    root = cond.instrs[-1] if cond.instrs else None
    if root is not None:
        operands = re.findall(r"%([\w.\-]+)", root.body.split("(", 1)[-1])
        vals = [consts[o] for o in operands if o in consts]
        if vals:
            return max(max(vals), 1)
    return max(list(consts.values()) + [1])


def computation_weights(comps: Dict[str, Computation],
                        entry: str) -> Dict[str, float]:
    """Execution-count weight per computation (entry = 1)."""
    weights: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        w = weights[cname]
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            for kind, rx in _CALLEE_RES:
                for m in rx.finditer(ins.body):
                    if kind == "while":
                        cond, body = m.group(1), m.group(2)
                        trips = _trip_count(comps[cond]) if cond in comps \
                            else 1
                        for callee, ww in ((cond, w * (trips + 1)),
                                           (body, w * trips)):
                            weights[callee] = weights.get(callee, 0) + ww
                            if callee not in seen:
                                seen.add(callee)
                                order.append(callee)
                    elif kind == "calls":
                        callee = m.group(1)
                        weights[callee] = weights.get(callee, 0) + w
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
                    else:
                        for callee in re.findall(r"%([\w.\-]+)",
                                                 m.group(1)):
                            weights[callee] = weights.get(callee, 0) + w
                            if callee not in seen:
                                seen.add(callee)
                                order.append(callee)
    return weights


def _operand_bytes(ins: Instr, comp: Computation,
                   by_name: Dict[str, Instr]) -> int:
    total = 0
    for m in re.finditer(r"%([\w.\-]+)", ins.body.split("(", 1)[-1]):
        op = by_name.get(m.group(1))
        if op is not None and op.opcode not in ("constant",):
            total += op.out_bytes
    return total


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_OPERANDS_RE = re.compile(r"\(\s*%([\w.\-]+)")


def _dot_flops(ins: Instr, by_name: Dict[str, Instr]) -> float:
    m = _DOT_CONTRACT_RE.search(ins.body)
    ops = re.findall(r"%([\w.\-]+)", ins.body.split("(", 1)[-1])
    if not ops:
        return 0.0
    lhs = by_name.get(ops[0])
    if lhs is None:
        return 0.0
    shape_m = _SHAPE_RE.search(lhs.body)
    if shape_m is None:
        return 0.0
    lhs_dims = [int(d) for d in shape_m.group(2).split(",") if d]
    contract = 1
    if m:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    out_elems = ins.out_bytes  # bytes; need elements:
    # recompute elements from the instr type string
    tm = _SHAPE_RE.search(ins.body)
    out_n = 1
    if tm:
        for d in tm.group(2).split(","):
            if d:
                out_n *= int(d)
    return 2.0 * out_n * contract


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    per_collective_count: Dict[str, int]


def analyze(text: str, entry_hint: str = "main") -> HloCosts:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if name.startswith(entry_hint) or ".main" in name or name == "main":
            entry = name
            break
    if entry is None:
        # ENTRY computation is usually the last one
        entry = list(comps)[-1]
    weights = computation_weights(comps, entry)

    flops = 0.0
    byte_total = 0.0
    coll: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    coll_n: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for cname, comp in comps.items():
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        by_name = {i.name: i for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            base = op.split(".")[0].split(" ")[0]
            if base.startswith("all-reduce-start"):
                base = "all-reduce"
            if base in ("dot",):
                flops += w * _dot_flops(ins, by_name)
            matched = None
            for c in COLLECTIVES:
                if base == c or base == c + "-start":
                    matched = c
                    break
            if matched:
                eff = COLLECTIVE_FACTOR[matched] * ins.out_bytes
                coll[matched] += w * eff
                coll_n[matched] += int(w)
            if base not in _SKIP_BYTES_OPS and not base.endswith("-done"):
                # traffic model: every materialized tensor is written once
                # and read once downstream (fusion internals never hit
                # HBM; slices count at slice granularity).
                byte_total += w * 2.0 * ins.out_bytes
    return HloCosts(flops=flops, bytes_accessed=byte_total,
                    collective_bytes=sum(coll.values()),
                    collective_breakdown=coll,
                    per_collective_count=coll_n)
