"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with
divisibility checking and ordered fallbacks.

The default table gives: TP over 'model' for heads/ffn/vocab/experts,
FSDP-style 2D weight sharding ('embed' -> 'data', so every large matrix
is sharded over both axes and optimizer state is fully distributed --
ZeRO-3 equivalent under GSPMD), batch over ('pod','data'), and optional
sequence sharding for batch-1 long-context caches.  A rule that doesn't
divide the dimension falls back down its candidate list (e.g. internvl2's
vocab 92553 is not 16-divisible -> replicated embedding rows), so every
(arch x shape x mesh) cell resolves without hand-tuning -- resolution is
pure logic over ParamSpecs, unit-tested per arch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.base import ParamSpec

AxisAssign = Union[None, str, Tuple[str, ...]]

# candidate lists, tried in order until one divides the dimension
DEFAULT_TABLE: Dict[str, Tuple[AxisAssign, ...]] = {
    "vocab": ("model", None),
    "embed": ("data", None),          # FSDP 2D weight sharding
    "heads": ("model", None),
    "kv": ("model", None),
    "mlp": ("model", None),
    "experts": ("model", None),
    "layers": (None,),
    "frontend": (None,),
    "batch": (("pod", "data"), ("data",), None),
    "cache_seq": (None,),
    "kv_heads": ("model", None),
    # kv_heads rarely divides the model axis (GQA); the fused fallback is
    # sharding the head_dim / MLA latent dim instead (memory first --
    # the resulting per-layer all-reduce is a §Perf lever).
    "head_dim": ("model", None),
    "kv_lora": ("model", None),
    # attention activations: heads replicated by default (few archs have
    # model-axis-divisible head counts); hillclimb override shards them.
    "attn_act_heads": (None,),
}

LONG_CONTEXT_OVERRIDES: Dict[str, Tuple[AxisAssign, ...]] = {
    # batch=1: shard the KV/cache sequence instead of the batch
    "batch": (None,),
    "cache_seq": ("data", None),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Dict[str, Tuple[AxisAssign, ...]]

    @classmethod
    def default(cls, long_context: bool = False,
                overrides: Optional[Dict[str, Tuple[AxisAssign, ...]]] = None,
                ) -> "ShardingRules":
        table = dict(DEFAULT_TABLE)
        if long_context:
            table.update(LONG_CONTEXT_OVERRIDES)
        if overrides:
            table.update(overrides)
        return cls(table=table)


def _axis_size(mesh: jax.sharding.Mesh, assign: AxisAssign) -> int:
    if assign is None:
        return 1
    names = (assign,) if isinstance(assign, str) else assign
    return int(np.prod([mesh.shape[a] for a in names]))


def _names(assign: AxisAssign) -> Tuple[str, ...]:
    if assign is None:
        return ()
    return (assign,) if isinstance(assign, str) else tuple(assign)


def resolve_spec(spec: ParamSpec, rules: ShardingRules,
                 mesh: jax.sharding.Mesh) -> P:
    """PartitionSpec for one ParamSpec under the rules and mesh."""
    out = []
    used: set = set()
    for dim, logical in zip(spec.shape, spec.axes):
        chosen: AxisAssign = None
        if logical is not None:
            for cand in rules.table.get(logical, (None,)):
                names = tuple(n for n in _names(cand)
                              if n in mesh.axis_names and n not in used)
                if not names:
                    if cand is None:
                        chosen = None
                        break
                    continue
                size = int(np.prod([mesh.shape[n] for n in names]))
                if dim % size == 0:
                    chosen = names if len(names) > 1 else names[0]
                    used.update(names)
                    break
        out.append(chosen)
    return P(*out)


def tree_shardings(specs, rules: ShardingRules, mesh: jax.sharding.Mesh):
    """Pytree of NamedShardings mirroring a pytree of ParamSpecs."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, resolve_spec(s, rules, mesh)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def serve_sharding(mesh: jax.sharding.Mesh,
                   axis: str = "serve") -> NamedSharding:
    """Sharding for serving-scheduler state: leading axis split over the
    1-D ``serve`` mesh, every other axis replicated.  Applied uniformly
    to every leaf of the stacked ``(n_shards, ...)`` scheduler state, so
    each shard's slots, page pool and page tables live wholly on its own
    device."""
    return NamedSharding(mesh, P(axis))


def batch_sharding(mesh: jax.sharding.Mesh, rules: ShardingRules,
                   ndim: int, batch_dim_divisible: int):
    """NamedSharding for a batch-leading input array."""
    spec = ParamSpec(shape=(batch_dim_divisible,) + (1,) * (ndim - 1),
                     axes=("batch",) + (None,) * (ndim - 1),
                     dtype=np.int32)
    return NamedSharding(mesh, resolve_spec(spec, rules, mesh))
