import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh)
cell and extract the roofline terms.

The two lines above MUST run before any jax import: jax locks the device
count on first init, and the production meshes need 512 host-platform
placeholder devices.  Everything else (smoke tests, benches, examples)
sees the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch all|<id>[,<id>...]] [--shape all|<name>] \
      [--mesh both|single|multi] [--out results/dryrun.json]

Results stream to the JSON file incrementally; rerunning skips cells
already present (resumable -- each compile is minutes of CPU work).
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import ALL_ARCHS                      # noqa: E402
from repro.launch import hlo_analysis, roofline          # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.specs import build_cell                # noqa: E402
from repro.models.base import SHAPES, get_arch           # noqa: E402

GIB = 2.0 ** 30


def run_cell(arch: str, shape: str, mesh_name: str, *,
             rule_overrides=None, microbatches=None, remat=None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    plan = build_cell(arch, shape, mesh, rule_overrides=rule_overrides,
                      microbatches=microbatches, remat=remat)
    lowered = plan.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    memory_gb = {
        "args": ma.argument_size_in_bytes / GIB,
        "out": ma.output_size_in_bytes / GIB,
        "temp": ma.temp_size_in_bytes / GIB,
        "alias": ma.alias_size_in_bytes / GIB,
        "peak": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes
                 - ma.alias_size_in_bytes) / GIB,
    }
    xla_costs = compiled.cost_analysis()
    costs = hlo_analysis.analyze(compiled.as_text())
    row = roofline.build_row(arch, shape, mesh_name, chips, costs,
                             memory_gb)
    out = row.to_json()
    out.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_flops_unweighted": xla_costs.get("flops", 0.0),
        "xla_bytes_unweighted": xla_costs.get("bytes accessed", 0.0),
        "microbatches": plan.microbatches,
    })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ALL_ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        bundle = get_arch(arch)
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}|{shape}|{mesh_name}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped"):
                    print(f"[cached] {key}")
                    continue
                if shape in bundle.skip_cells:
                    results[key] = {
                        "status": "skipped",
                        "reason": bundle.skip_reasons.get(shape, "")}
                    print(f"[skip]   {key}: "
                          f"{bundle.skip_reasons.get(shape, '')[:60]}")
                else:
                    print(f"[run]    {key} ...", flush=True)
                    try:
                        results[key] = run_cell(arch, shape, mesh_name)
                        r = results[key]
                        print(f"  ok: peak {r['memory_gb']['peak']:.1f} GiB"
                              f"/chip, bottleneck {r['bottleneck']}, "
                              f"compile {r['compile_s']}s", flush=True)
                    except Exception as e:   # noqa: BLE001
                        results[key] = {"status": "failed",
                                        "error": f"{type(e).__name__}: {e}",
                                        "trace": traceback.format_exc()[-2000:]}
                        print(f"  FAILED: {e}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values()
                 if r.get("status") == "skipped")
    n_fail = sum(1 for r in results.values()
                 if r.get("status") == "failed")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
