"""The paper's own experimental configuration: the VCU128 testbench
(2 x 4 GB HBM2 stacks, 32 pseudo-channels) and the calibrated models.
Not an LM architecture -- this is the configuration consumed by the
paper-reproduction benchmarks and the undervolt-aware training examples.
"""
import dataclasses

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.faultmodel import DEFAULT_FAULT_MODEL
from repro.core.hbm import TPU_V5E, VCU128
from repro.core.voltage import DEFAULT_POWER_MODEL


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    geometry = VCU128
    tpu_geometry = TPU_V5E
    map_seed: int = PAPER_MAP_SEED
    fault_model = DEFAULT_FAULT_MODEL
    power_model = DEFAULT_POWER_MODEL

    def fault_map(self, geometry=None) -> FaultMap:
        return FaultMap.from_seed(geometry or self.geometry, self.map_seed)


PAPER = PaperConfig()
