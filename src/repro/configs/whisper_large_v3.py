"""whisper-large-v3 [audio]: enc-dec, 32+32L d_model=1280 20H (MHA)
d_ff=5120 vocab=51866, conv frontend STUB (precomputed frame
embeddings, enc_len=1500), plain-GELU MLPs.
[arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models import base, whisper

CFG = base.ArchConfig(
    arch_id="whisper-large-v3", family="audio", n_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64, d_ff=5120,
    vocab=51866, enc_layers=32, enc_len=1500, mlp_gated=False,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, enc_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    head_dim=12, d_ff=96, vocab=251, enc_len=12)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=whisper, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "full-attention enc-dec; audio "
                      "contexts are bounded by the 30 s frontend window "
                      "(DESIGN.md)"},
    )


base.register("whisper-large-v3", bundle)
