"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 -- InternViT frontend (STUB: precomputed patch embeddings)
+ InternLM2 decoder.  [arXiv:2404.16821; hf]"""
import dataclasses

from repro.models import base, vlm

CFG = base.ArchConfig(
    arch_id="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92553,
    enc_len=256, frontend_dim=1024, rope_theta=1_000_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=96, vocab=251, enc_len=6, frontend_dim=16)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=vlm, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "full-attention LM decoder "
                      "(DESIGN.md)"},
    )


base.register("internvl2-2b", bundle)
