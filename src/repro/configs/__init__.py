"""Assigned-architecture configs.  Importing this package registers all
architectures with the model registry (``--arch <id>`` in the launcher)."""
from repro.configs import (gemma3_4b, yi_34b, llama3_2_3b, llama3_8b,  # noqa
                           recurrentgemma_9b, deepseek_v2_lite_16b,
                           deepseek_v2_236b, xlstm_350m, internvl2_2b,
                           whisper_large_v3, paper)

ALL_ARCHS = (
    "gemma3-4b", "yi-34b", "llama3.2-3b", "llama3-8b", "recurrentgemma-9b",
    "deepseek-v2-lite-16b", "deepseek-v2-236b", "xlstm-350m",
    "internvl2-2b", "whisper-large-v3",
)
