"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, llama-style GQA.  [arXiv:2403.04652; hf]"""
import dataclasses

from repro.models import base, dense

CFG = base.ArchConfig(
    arch_id="yi-34b", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
    rope_theta=5_000_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=3, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=112, vocab=251)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=dense, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "pure full attention: every layer's "
                      "KV cache is O(context); sub-quadratic attention "
                      "required for the 500k cell (DESIGN.md)"},
    )


base.register("yi-34b", bundle)
