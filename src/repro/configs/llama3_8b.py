"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  [arXiv:2407.21783; unverified]"""
import dataclasses

from repro.models import base, dense

CFG = base.ArchConfig(
    arch_id="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=128256,
    rope_theta=500_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab=263)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=dense, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "pure full attention (DESIGN.md)"},
    )


base.register("llama3-8b", bundle)
