"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 --
alternating mLSTM (matrix memory) + sLSTM blocks.
[arXiv:2405.04517; unverified]"""
import dataclasses

from repro.models import base, xlstm

CFG = base.ArchConfig(
    arch_id="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, head_dim=256, d_ff=0, vocab=50304,
    pattern=("mlstm", "slstm"), conv_width=4,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=4, d_model=32, n_heads=4, head_dim=8, vocab=251)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=xlstm, reduced=REDUCED,
        # constant-size matrix/scalar memory => long_500k RUNS.
        skip_cells=(),
    )


base.register("xlstm-350m", bundle)
