"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B; unverified]"""
import dataclasses

from repro.models import base, dense

CFG = base.ArchConfig(
    arch_id="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=128256,
    rope_theta=500_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=96, vocab=263)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=dense, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "pure full attention (DESIGN.md)"},
    )


base.register("llama3.2-3b", bundle)
