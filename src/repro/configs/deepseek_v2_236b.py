"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512 q_lora=1536, 160 routed experts top-6 +
2 shared, dense layer 0 (d_ff 12288).  [arXiv:2405.04434; hf]"""
import dataclasses

from repro.models import base, moe

CFG = base.ArchConfig(
    arch_id="deepseek-v2-236b", family="moe", n_layers=60,
    d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128, d_ff=1536,
    vocab=102400, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    n_experts=160, n_shared_experts=2, top_k=6, capacity_factor=2.0,
    d_ff_dense=12288, rope_theta=10_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    rope_head_dim=8, kv_lora_rank=24, q_lora_rank=32, d_ff=32,
    d_ff_dense=96, vocab=257, n_experts=8, top_k=2)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=moe, reduced=REDUCED,
        skip_cells=("long_500k",),
        skip_reasons={"long_500k": "MLA is full attention: latent cache "
                      "is O(context) (DESIGN.md)"},
    )


base.register("deepseek-v2-236b", bundle)
