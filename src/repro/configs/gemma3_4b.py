"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5 local : 1 global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses

from repro.models import base, dense

CFG = base.ArchConfig(
    arch_id="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, rope_theta=1_000_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=257, window=8)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=dense, reduced=REDUCED,
        # long_500k RUNS: 5/6 layers are 1024-token sliding window; the
        # global layers' cache is linear in context (decode-only cell).
        skip_cells=(),
    )


base.register("gemma3-4b", bundle)
