"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 -- RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427; unverified]"""
import dataclasses

from repro.models import base, rglru

CFG = base.ArchConfig(
    arch_id="recurrentgemma-9b", family="hybrid", n_layers=38,
    d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288,
    vocab=256000, pattern=("rec", "rec", "local"), window=2048,
    lru_width=4096, conv_width=4,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=5, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
    d_ff=96, vocab=251, window=8, lru_width=48)


def bundle() -> base.ArchBundle:
    return base.ArchBundle(
        cfg=CFG, module=rglru, reduced=REDUCED,
        # constant-size recurrent state + 2048-window attention
        # => long_500k RUNS (the cell this family exists for).
        skip_cells=(),
    )


base.register("recurrentgemma-9b", bundle)
