"""Structured event trace: a bounded ring of typed scheduler events.

Every control-plane decision the serving loop makes on the host --
admission, retirement, CapacityError backpressure, COW forks, page
migrations, quarantines, block retirements, governor replans, setpoint
escalations -- lands here as one typed event with a step-index
timestamp.  The ring is bounded (old events drop), but per-kind counts
are cumulative, so exporters can report lifetime totals even after the
ring wraps.  Export is JSONL (one event per line) for offline
debugging of a serving incident: "which tenant's admission forced the
replan that moved shard 3 to 0.94 V?" is a grep, not a re-run.

Events are host-side by construction -- the compiled step emits
nothing -- so the trace adds zero work to the donated step and cannot
perturb the trace/launch budgets.
"""
from __future__ import annotations

import collections
import dataclasses
import io
import json
from typing import Any, Dict, Iterator, Optional

# The closed set of event kinds the scheduler emits.  Exporters and
# dashboards key on these; adding a kind is backward-compatible,
# renaming is not.
EVENT_KINDS = (
    "admission", "retirement", "backpressure", "cow_fork", "migration",
    "quarantine", "block_retire", "prefix_evict", "replan", "escalation",
)


@dataclasses.dataclass(frozen=True)
class Event:
    """One typed scheduler event.

    ``step`` is the scheduler's step index at emission time (the only
    clock the serving loop has that survives replay); ``shard``/``rid``
    are optional labels; ``data`` carries kind-specific fields.
    """

    kind: str
    step: int
    shard: Optional[int] = None
    rid: Any = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"kind": self.kind, "step": self.step}
        if self.shard is not None:
            out["shard"] = self.shard
        if self.rid is not None:
            out["rid"] = str(self.rid)
        if self.data:
            out.update(self.data)
        return out


class EventTrace:
    """Bounded ring of :class:`Event` with cumulative per-kind counts."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"trace capacity {capacity} must be >= 1")
        self.capacity = int(capacity)
        self._ring: collections.deque = collections.deque(
            maxlen=self.capacity)
        self.counts: collections.Counter = collections.Counter()
        self.emitted = 0

    def emit(self, kind: str, *, step: int, shard: Optional[int] = None,
             rid: Any = None, **data: Any) -> Event:
        if kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown event kind {kind!r}; known kinds: "
                f"{EVENT_KINDS}")
        ev = Event(kind=kind, step=int(step), shard=shard, rid=rid,
                   data=data)
        self._ring.append(ev)
        self.counts[kind] += 1
        self.emitted += 1
        return ev

    def __len__(self) -> int:
        return len(self._ring)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._ring)

    def events(self, kind: Optional[str] = None):
        """Events still in the ring, oldest first (optionally one kind)."""
        return [e for e in self._ring if kind is None or e.kind == kind]

    # ---- export ----------------------------------------------------------
    def to_jsonl(self, path_or_file) -> int:
        """Write the ring as JSON Lines; returns the event count."""
        own = isinstance(path_or_file, (str, bytes))
        f = open(path_or_file, "w") if own else path_or_file
        try:
            for ev in self._ring:
                f.write(json.dumps(ev.to_dict(), default=str) + "\n")
        finally:
            if own:
                f.close()
        return len(self._ring)

    def jsonl(self) -> str:
        buf = io.StringIO()
        self.to_jsonl(buf)
        return buf.getvalue()
