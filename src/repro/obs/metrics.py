"""In-step metrics: per-shard donated counters + host-side latency
histograms.

Generalizes the self-healing loop's donated-telemetry pattern (the
``telem``/``telem_u`` SECDED counters) into a registry of named
counters that live as ONE extra ``(n_shards, N)`` int32 leaf of the
scheduler's donated state.  Each compiled step accumulates the deltas
with pure ``jnp`` arithmetic on values the step already has in
registers (active/decode masks, cursors, the migration lanes) -- zero
extra pallas launches, zero host syncs per step; the host reads the
cumulative counters only at ``stats()`` / export time.

Counter units are chosen to keep int32 honest over long runs: discrete
events (tokens, cache slots, logical pages), converted to bytes on the
host with the pool's static K/V page geometry.  ``kv_pages_read``
counts *useful* traffic -- every active lane reads its full page table
once per step through the paged attention gather (the ring is
fixed-shape; inactive lanes' scratch reads are patrol traffic and
excluded on purpose, so joules/token prices the work tenants bought).

Host-side, the registry also keeps a bounded ring of per-step wall
times for the p50/p95/p99 step-latency report.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.obs.energy import DEFAULT_ENERGY_MODEL, EnergyModel

# Donated-counter layout: one row per shard, one column per name, in
# this order.  Appending is backward-compatible (the state leaf is
# rebuilt per scheduler); reordering is not.
STEP_COUNTERS = (
    "tokens_decoded",     # decode lanes that sampled a token this step
    "prefill_tokens",     # prompt tokens consumed by prefilling lanes
    "kv_slots_written",   # cache slots written (COW write floor applied)
    "kv_pages_read",      # (active lane, logical page) reads via the
                          # page table -- the paged-attention gather
    "pages_migrated",     # self-healing page copies staged this step
)
_IDX = {name: i for i, name in enumerate(STEP_COUNTERS)}
N_STEP_COUNTERS = len(STEP_COUNTERS)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs of one scheduler.

    ``enabled=False`` removes the donated counter leaf, the event
    trace and the step timer entirely -- the metrics-off baseline the
    launch-budget and overhead tests compare against.
    """

    enabled: bool = True
    trace_capacity: int = 4096        # event ring entries kept
    latency_capacity: int = 4096      # step wall-times kept
    energy: EnergyModel = DEFAULT_ENERGY_MODEL


def init_step_counters(n_shards: int) -> jnp.ndarray:
    """The donated ``(n_shards, N_STEP_COUNTERS)`` counter leaf."""
    return jnp.zeros((n_shards, N_STEP_COUNTERS), jnp.int32)


def step_counter_delta(*, act, dec, cursor, plen, wstart, chunk: int,
                       n_logical_pages: int, mig_src,
                       scratch_id: int) -> jnp.ndarray:
    """One shard's per-step counter increments (traced, pure jnp).

    All inputs are the *pre-step* values the compiled step body already
    holds; the result is a length-``N_STEP_COUNTERS`` int32 vector.
    Write accounting mirrors the paged write path exactly: decode lanes
    write one slot at ``qpos`` (always at/above the COW floor), prefill
    lanes write their consumed chunk clipped below by ``wstart`` (rows
    of a shared prefix are mapped read-only and never written).
    """
    pre = act & ~dec
    consumed = jnp.where(pre, jnp.minimum(cursor + chunk, plen) - cursor,
                         0).astype(jnp.int32)
    written = jnp.where(
        pre,
        jnp.maximum(jnp.minimum(cursor + chunk, plen)
                    - jnp.maximum(cursor, wstart), 0),
        0).astype(jnp.int32)
    decoded = (act & dec).astype(jnp.int32)
    return jnp.stack([
        decoded.sum(),
        consumed.sum(),
        decoded.sum() + written.sum(),
        act.astype(jnp.int32).sum() * jnp.int32(n_logical_pages),
        (mig_src != scratch_id).astype(jnp.int32).sum(),
    ]).astype(jnp.int32)


class MetricsRegistry:
    """Host half of the in-step metrics: static byte geometry, the
    cumulative-counter reader, and the step-latency ring.

    The device half is :func:`step_counter_delta` inside the compiled
    step; this class never touches the device during serving -- it
    reads the donated leaf once per ``stats()``/export call.
    """

    def __init__(self, n_shards: int, pool, config: ObsConfig, *,
                 kv_page_bytes: Optional[int] = None,
                 kv_slot_bytes: Optional[int] = None,
                 layouts: Optional[Sequence[str]] = None):
        self.n_shards = int(n_shards)
        self.config = config
        # Which cache layouts this scheduler's counters price: the
        # paged route tags "full"/"window", the state-arena route
        # whatever mix its family carries ("full"/"cross"/"state").
        self.layouts = tuple(layouts) if layouts is not None else None
        if pool is not None:
            # Static K/V payload geometry (bytes): what one page-table
            # read and one written cache slot move, over every k/v leaf
            # & layer (``pos`` bookkeeping words excluded -- they are
            # not payload).
            self.kv_page_bytes = 4 * sum(
                leaf.n_layers * leaf.page_words
                for leaf in pool.leaves if leaf.which in ("k", "v"))
            self.kv_slot_bytes = 4 * sum(
                leaf.n_layers * leaf.wps
                for leaf in pool.leaves if leaf.which in ("k", "v"))
        else:
            # Pool-less (state-arena) route: the scheduler supplies its
            # own static geometry -- ``kv_page_bytes`` is one lane's
            # whole per-slot cache payload (read every step),
            # ``kv_slot_bytes`` one lane's per-step write payload.
            self.kv_page_bytes = int(kv_page_bytes or 0)
            self.kv_slot_bytes = int(kv_slot_bytes or 0)
        cap = max(int(config.latency_capacity), 1)
        self._lat = np.zeros(cap, np.float64)
        self._lat_n = 0               # total recorded (ring may wrap)
        self.wall_seconds = 0.0

    # ---- latency ---------------------------------------------------------
    def record_step(self, seconds: float) -> None:
        self._lat[self._lat_n % len(self._lat)] = seconds
        self._lat_n += 1
        self.wall_seconds += seconds

    def latency(self) -> Dict[str, float]:
        n = min(self._lat_n, len(self._lat))
        if n == 0:
            return {"count": 0}
        w = self._lat[:n]
        p50, p95, p99 = np.percentile(w, [50, 95, 99])
        return {"count": self._lat_n, "mean_s": float(w.mean()),
                "p50_s": float(p50), "p95_s": float(p95),
                "p99_s": float(p99)}

    # ---- counters --------------------------------------------------------
    def counters_np(self, state) -> np.ndarray:
        """Cumulative ``(n_shards, N)`` counters off the donated leaf
        (one device->host read; no per-step sync)."""
        return np.asarray(state["mtr"], np.int64)

    def shard_bytes_moved(self, counters: np.ndarray) -> np.ndarray:
        """Per-shard K/V bytes moved (read + written) from the
        discrete-unit counters and the static page geometry."""
        return (counters[:, _IDX["kv_pages_read"]] * self.kv_page_bytes
                + counters[:, _IDX["kv_slots_written"]]
                * self.kv_slot_bytes)

    def totals(self, state) -> Dict[str, int]:
        """Fleet-total counters plus derived byte totals."""
        c = self.counters_np(state)
        out = {name: int(c[:, i].sum())
               for i, name in enumerate(STEP_COUNTERS)}
        out["kv_bytes_read"] = int(
            (c[:, _IDX["kv_pages_read"]] * self.kv_page_bytes).sum())
        out["kv_bytes_written"] = int(
            (c[:, _IDX["kv_slots_written"]] * self.kv_slot_bytes).sum())
        out["kv_bytes_moved"] = (out["kv_bytes_read"]
                                 + out["kv_bytes_written"])
        return out

    # ---- energy ----------------------------------------------------------
    def energy(self, state,
               voltages: Sequence[float]) -> Dict[str, Any]:
        """Joules/token and $/1M-tokens per shard and fleet-wide.

        ``voltages`` is each shard's operating rail voltage (shards of
        an unplaced/clean scheduler price at nominal).  Every shard is
        charged the full recorded wall time for its static watts --
        shards step concurrently inside the one compiled call.
        """
        em = self.config.energy
        c = self.counters_np(state)
        bytes_k = self.shard_bytes_moved(c)
        toks_k = c[:, _IDX["tokens_decoded"]]
        shards = []
        joules_total = 0.0
        for k in range(self.n_shards):
            rep = em.report(seconds=self.wall_seconds,
                            bytes_moved=float(bytes_k[k]),
                            tokens=max(int(toks_k[k]), 1),
                            v=float(voltages[k]))
            rep["shard"] = k
            rep["tokens"] = int(toks_k[k])
            rep["kv_bytes_moved"] = int(bytes_k[k])
            shards.append(rep)
            joules_total += rep["joules"]
        tokens_total = int(toks_k.sum())
        jpt = joules_total / max(tokens_total, 1)
        return {
            "shards": shards,
            "wall_seconds": self.wall_seconds,
            "tokens": tokens_total,
            "kv_bytes_moved": int(bytes_k.sum()),
            "joules": joules_total,
            "joules_per_token": jpt,
            "usd_per_mtok": em.usd_per_mtok(jpt),
            "tokens_per_joule": (tokens_total / joules_total
                                 if joules_total > 0 else 0.0),
        }

    def snapshot(self, state, voltages: Optional[Sequence[float]] = None,
                 ) -> Dict[str, Any]:
        """Counters + latency (+ energy when voltages are supplied)."""
        c = self.counters_np(state)
        out: Dict[str, Any] = {
            "counters": {name: c[:, i].tolist()
                         for i, name in enumerate(STEP_COUNTERS)},
            "totals": self.totals(state),
            "step_latency": self.latency(),
        }
        if self.layouts is not None:
            out["cache_layouts"] = list(self.layouts)
        if voltages is not None:
            out["energy"] = self.energy(state, voltages)
        return out
