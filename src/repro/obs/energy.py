"""Energy accounting: joules/token and $/1M-tokens at any frontier
voltage.

The paper's headline results are *power* numbers -- 1.5x total saving
inside the guardband (V_min = 0.98 V), 2.3x below it (0.85 V, where the
stuck-bit capacitance drop compounds the V^2 law) -- but serving fleets
buy *energy per unit of work*.  This module joins the calibrated power
curve (:class:`repro.core.voltage.PowerModel`) with the byte counters
the scheduler accumulates inside its donated step
(:mod:`repro.obs.metrics`) into that unit:

  * ``pj_per_byte(v)``: dynamic HBM energy per byte moved at voltage
    ``v``, derived from the power curve -- nominal dynamic watts
    (full-load minus idle) over peak bandwidth, scaled along the
    frontier.  At (V_nom, 819 GB/s, 20 W) this lands ~16 pJ/byte,
    the HBM2e-generation sibling of the 31.2 pJ/byte HBM2 figure
    reallm-style cost models use.
  * ``static_watts(v)``: the idle third of the rail (C10), paid for
    wall time whether or not bytes move.
  * ``step_joules(seconds, bytes_moved, v)`` = dynamic + static.  This
    is algebraically identical to
    ``PowerModel.energy_joules(seconds, v, util)`` at
    ``util = bytes_moved / (bandwidth * seconds)`` -- the two paths are
    the same model, one priced per byte, one per utilization.

Because undervolting preserves frequency (and therefore bandwidth and
step time), pricing the *same* measured workload at two voltages
reproduces the paper's ratios exactly: joules/token improves 1.5x at
0.98 V and 2.3x at 0.85 V, independent of utilization.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.faultmodel import V_NOM
from repro.core.voltage import (DEFAULT_POWER_MODEL, W_HBM_NOMINAL_V5E,
                                PowerModel)
from repro.launch.roofline import HBM_BW

# Joules per kWh: the $/1M-token conversion runs through the unit
# datacenters are billed in.
_J_PER_KWH = 3.6e6

# Default energy price used for the $/1M-token reports.  A round
# datacenter-ish $/kWh; like W_HBM_NOMINAL_V5E it scales absolute
# reports only, never the validated ratios.
COST_PER_KWH = 0.10


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Prices (bytes moved, wall seconds, tokens) at a rail voltage.

    ``nominal_watts`` and ``bandwidth_bytes`` anchor the absolute
    scale (HBM watts at full streaming load, peak bytes/sec);
    ``cost_per_kwh`` converts joules into dollars.  All voltage
    dependence comes from ``power_model`` -- the paper's calibrated
    V^2 x alpha_factor curve -- so every ratio this model reports is a
    ratio of that curve.
    """

    power_model: PowerModel = DEFAULT_POWER_MODEL
    nominal_watts: float = W_HBM_NOMINAL_V5E
    bandwidth_bytes: float = HBM_BW
    cost_per_kwh: float = COST_PER_KWH

    # ---- components ------------------------------------------------------
    def watts(self, v: float, util: float = 1.0) -> float:
        """Absolute HBM watts at voltage ``v`` and utilization."""
        return float(self.nominal_watts * self.power_model.power(v, util))

    def static_watts(self, v: float) -> float:
        """Idle (zero-traffic) watts at voltage ``v``."""
        return self.watts(v, 0.0)

    def pj_per_byte(self, v: float = V_NOM) -> float:
        """Dynamic energy per byte moved at voltage ``v`` (picojoules):
        full-load minus idle watts, over peak bandwidth."""
        dyn_watts = self.watts(v, 1.0) - self.watts(v, 0.0)
        return dyn_watts / self.bandwidth_bytes * 1e12

    def savings(self, v: float, util: float = 1.0) -> float:
        """Energy-per-token improvement factor vs. nominal voltage for
        the same workload (same bytes, same wall time -- undervolting
        preserves f).  Exactly the paper's power-saving factor."""
        return float(self.power_model.savings(v, util))

    # ---- workload pricing ------------------------------------------------
    def step_joules(self, *, seconds: float, bytes_moved: float,
                    v: float) -> float:
        """Energy of a measured serving interval at voltage ``v``."""
        if seconds < 0 or bytes_moved < 0:
            raise ValueError(
                f"negative workload: seconds={seconds}, "
                f"bytes_moved={bytes_moved}")
        return (bytes_moved * self.pj_per_byte(v) * 1e-12
                + self.static_watts(v) * seconds)

    def joules_per_token(self, *, seconds: float, bytes_moved: float,
                         tokens: int, v: float) -> float:
        if tokens <= 0:
            raise ValueError(f"tokens={tokens} must be positive")
        return self.step_joules(seconds=seconds, bytes_moved=bytes_moved,
                                v=v) / tokens

    def usd_per_mtok(self, joules_per_token: float) -> float:
        """Dollars per 1M tokens at the configured energy price."""
        return joules_per_token * 1e6 / _J_PER_KWH * self.cost_per_kwh

    def report(self, *, seconds: float, bytes_moved: float, tokens: int,
               v: float) -> Dict[str, float]:
        """Full per-setpoint pricing of one measured workload."""
        joules = self.step_joules(seconds=seconds,
                                  bytes_moved=bytes_moved, v=v)
        jpt = joules / max(tokens, 1)
        util = (bytes_moved / (self.bandwidth_bytes * seconds)
                if seconds > 0 else 0.0)
        return {
            "voltage": float(v),
            "joules": joules,
            "joules_per_token": jpt,
            "usd_per_mtok": self.usd_per_mtok(jpt),
            "tokens_per_joule": (tokens / joules if joules > 0 else 0.0),
            "watts_avg": (joules / seconds if seconds > 0 else 0.0),
            "pj_per_byte": self.pj_per_byte(v),
            "hbm_util": min(util, 1.0),
            "savings_x": self.savings(v),
        }


DEFAULT_ENERGY_MODEL = EnergyModel()
