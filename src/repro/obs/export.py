"""Exporters: Prometheus text format and JSON snapshots of one
scheduler's observability plane, plus the JSONL event-trace dump.

Pull-based and allocation-free on the serving path: each export reads
the donated counter leaf once, walks the host-side gauges, and
formats.  Metric names are stable (see the README "Observability"
reference table); per-shard series carry a ``shard`` label, event
totals a ``kind`` label.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

_PREFIX = "repro"

# HELP strings for the Prometheus exposition (name -> help, type).
_COUNTER_HELP = {
    "tokens_decoded": "Decode-lane tokens sampled inside the donated step",
    "prefill_tokens": "Prompt tokens consumed by chunked prefill",
    "kv_slots_written": "KV cache slots written (COW write floor applied)",
    "kv_pages_read": "Logical-page reads through the page tables",
    "pages_migrated": "Self-healing page migrations applied in-step",
}
_HEAL_GAUGES = ("corrected", "uncorrectable", "migrations",
                "quarantined_pages", "quarantined_blocks",
                "setpoint_escalations")


def _fmt(name: str, value, labels: Dict[str, Any] = None) -> str:
    lab = ""
    if labels:
        inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
        lab = "{" + inner + "}"
    if isinstance(value, float):
        return f"{_PREFIX}_{name}{lab} {value:.6g}"
    return f"{_PREFIX}_{name}{lab} {value}"


def _head(lines: List[str], name: str, help_: str, type_: str) -> None:
    lines.append(f"# HELP {_PREFIX}_{name} {help_}")
    lines.append(f"# TYPE {_PREFIX}_{name} {type_}")


def shard_voltages(sched) -> List[float]:
    """Each shard's pricing voltage: the operating rail voltage for
    placed (undervolted) schedulers, nominal for clean ones."""
    return list(sched.pricing_voltages)


def json_snapshot(sched) -> Dict[str, Any]:
    """One JSON-serializable snapshot: scheduler stats + counters +
    latency + energy + event counts."""
    out: Dict[str, Any] = {"stats": _plain(sched.stats)}
    if sched.metrics is not None:
        out["metrics"] = _plain(sched.metrics.snapshot(
            sched.state, voltages=shard_voltages(sched)))
    if sched.trace is not None:
        out["events"] = {"counts": dict(sched.trace.counts),
                         "emitted": sched.trace.emitted,
                         "in_ring": len(sched.trace)}
    return out


def _plain(x):
    """Coerce numpy scalars/arrays into plain JSON types."""
    import numpy as np
    if isinstance(x, dict):
        return {k: _plain(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_plain(v) for v in x]
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    return x


def prometheus_text(sched) -> str:
    """Prometheus exposition-format snapshot of one scheduler."""
    st = sched.stats
    lines: List[str] = []

    # ---- gauges: per-shard operating point ---------------------------
    _head(lines, "voltage", "Shard operating rail voltage (V)", "gauge")
    for sh in st["shards"]:
        lines.append(_fmt("voltage", float(sh["voltage"]),
                          {"shard": sh["shard"]}))
    _head(lines, "free_pages", "Free KV pool pages", "gauge")
    for sh in st["shards"]:
        lines.append(_fmt("free_pages", int(sh["free_pages"]),
                          {"shard": sh["shard"]}))
    _head(lines, "active_requests", "Live requests on the shard", "gauge")
    for sh in st["shards"]:
        lines.append(_fmt("active_requests", int(sh["active"]),
                          {"shard": sh["shard"]}))
    for sh in st["shards"]:
        if sh.get("setpoint") is not None:
            _head(lines, "governor_setpoint",
                  "Shard governor walk target", "gauge")
            break
    for sh in st["shards"]:
        if sh.get("setpoint") is not None:
            lines.append(_fmt("governor_setpoint", float(sh["setpoint"]),
                              {"shard": sh["shard"]}))
    if "corrected" in st:                     # self-healing telemetry
        for key in _HEAL_GAUGES:
            _head(lines, f"heal_{key}",
                  f"Self-healing telemetry: {key}", "gauge")
            for sh in st["shards"]:
                lines.append(_fmt(f"heal_{key}", int(sh.get(key, 0)),
                                  {"shard": sh["shard"]}))
    _head(lines, "decode_traces",
          "Compiled decode traces (the ONE-step contract)", "gauge")
    lines.append(_fmt("decode_traces", int(st["decode_traces"])))

    # ---- counters: the donated in-step metrics -----------------------
    if sched.metrics is not None:
        snap = sched.metrics.snapshot(sched.state,
                                      voltages=shard_voltages(sched))
        for name, per_shard in snap["counters"].items():
            _head(lines, f"{name}_total",
                  _COUNTER_HELP.get(name, name), "counter")
            for k, v in enumerate(per_shard):
                lines.append(_fmt(f"{name}_total", int(v), {"shard": k}))
        for name in ("kv_bytes_read", "kv_bytes_written"):
            _head(lines, f"{name}_total",
                  "KV payload bytes via the page tables", "counter")
            lines.append(_fmt(f"{name}_total",
                              int(snap["totals"][name])))
        lat = snap["step_latency"]
        if lat.get("count"):
            _head(lines, "step_latency_seconds",
                  "Donated-step wall time", "summary")
            for q, key in (("0.5", "p50_s"), ("0.95", "p95_s"),
                           ("0.99", "p99_s")):
                lines.append(_fmt("step_latency_seconds", float(lat[key]),
                                  {"quantile": q}))
            lines.append(_fmt("step_latency_seconds_count",
                              int(lat["count"])))
        en = snap["energy"]
        _head(lines, "joules_per_token",
              "HBM energy per decoded token", "gauge")
        for rep in en["shards"]:
            lines.append(_fmt("joules_per_token",
                              float(rep["joules_per_token"]),
                              {"shard": rep["shard"]}))
        _head(lines, "usd_per_mtok",
              "Energy cost per 1M decoded tokens (USD)", "gauge")
        for rep in en["shards"]:
            lines.append(_fmt("usd_per_mtok", float(rep["usd_per_mtok"]),
                              {"shard": rep["shard"]}))
        _head(lines, "fleet_joules_per_token",
              "Fleet HBM energy per decoded token", "gauge")
        lines.append(_fmt("fleet_joules_per_token",
                          float(en["joules_per_token"])))
        _head(lines, "fleet_usd_per_mtok",
              "Fleet energy cost per 1M tokens (USD)", "gauge")
        lines.append(_fmt("fleet_usd_per_mtok",
                          float(en["usd_per_mtok"])))

    # ---- event totals ------------------------------------------------
    if sched.trace is not None:
        _head(lines, "events_total",
              "Scheduler control-plane events by kind", "counter")
        for kind, n in sorted(sched.trace.counts.items()):
            lines.append(_fmt("events_total", int(n), {"kind": kind}))
    return "\n".join(lines) + "\n"


def write_trace_jsonl(sched, path: str) -> int:
    """Dump the scheduler's event ring as JSON Lines; returns the
    number of events written (0 when tracing is disabled)."""
    if sched.trace is None:
        return 0
    return sched.trace.to_jsonl(path)
