"""JSON Schema for ``results/benchmarks.json`` and a validator CLI.

The benchmark driver (:mod:`benchmarks.run`) writes one JSON object
mapping section names to row arrays; CI validates the file after every
bench run so a malformed row (a stringified number, a dropped
``derived`` field, a telemetry dict that stopped being numeric) fails
the job instead of silently rotting the published results.

Row shapes, by construction of the writers:

  * timing rows: ``{"name", "us_per_call", "derived"}`` plus an
    optional ``"telemetry"`` dict of numeric fault/energy counters;
  * section-skip rows: ``{"name", "status": "skipped", "error"}``;
  * paper-figure rows: ``{"fig", ...}`` free-form numeric fields;
  * roofline cells: ``{"cell", ...}`` (ok cells carry the model
    breakdown, skipped cells ``status``/``reason``);
  * model-zoo rows (``sched_zoo_*``): a structured ``zoo`` object --
    arch/family/route/cache layouts plus tokens/sec and joules/token
    as real numbers, so per-family dashboards never parse the
    ``derived`` string.

Usage::

    PYTHONPATH=src python -m repro.obs.schema results/benchmarks.json
"""
from __future__ import annotations

import json
import sys

BENCHMARKS_SCHEMA = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "title": "repro benchmark results",
    "type": "object",
    "minProperties": 1,
    "additionalProperties": {
        "type": "array",
        "items": {
            "type": "object",
            "anyOf": [
                {"required": ["name"]},
                {"required": ["fig"]},
                {"required": ["cell"]},
            ],
            "properties": {
                "name": {"type": "string", "minLength": 1},
                "fig": {"type": "string"},
                "cell": {"type": "string"},
                "status": {"const": "skipped"},
                "error": {"type": "string"},
                "reason": {"type": "string"},
                "us_per_call": {"type": "number", "minimum": 0},
                "derived": {"type": "string"},
                "telemetry": {
                    "type": "object",
                    "minProperties": 1,
                    "additionalProperties": {
                        "type": "number", "minimum": 0},
                },
                "zoo": {
                    "type": "object",
                    "required": ["arch", "family", "route",
                                 "cache_layouts", "tokens_per_sec",
                                 "joules_per_token", "decode_traces"],
                    "properties": {
                        "arch": {"type": "string", "minLength": 1},
                        "family": {"type": "string", "minLength": 1},
                        "route": {"enum": ["paged", "state"]},
                        "cache_layouts": {
                            "type": "array",
                            "minItems": 1,
                            "items": {"enum": ["full", "window",
                                               "cross", "state"]},
                        },
                        "tokens_per_sec": {
                            "type": "number", "exclusiveMinimum": 0},
                        "joules_per_token": {
                            "type": "number", "exclusiveMinimum": 0},
                        "decode_traces": {"const": 1},
                    },
                },
            },
            # A named timing row that was not skipped must carry the
            # CSV columns the drivers print.
            "if": {
                "required": ["name"],
                "not": {"required": ["status"]},
            },
            "then": {"required": ["us_per_call", "derived"]},
        },
    },
}


def validate_benchmarks(path: str) -> dict:
    """jsonschema-validate one results file; returns the parsed doc.

    Raises ``jsonschema.ValidationError`` on schema violations and
    ``ValueError`` on unparseable JSON.
    """
    import jsonschema
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}: not valid JSON: {e}") from e
    jsonschema.validate(doc, BENCHMARKS_SCHEMA)
    return doc


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "results/benchmarks.json"
    doc = validate_benchmarks(path)
    n_rows = sum(len(rows) for rows in doc.values())
    print(f"{path}: OK ({len(doc)} sections, {n_rows} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
