"""Fleet observability plane: in-step metrics, energy accounting, and
a structured event trace for the undervolted serving scheduler.

Three layers, all preserving the one-donated-step / flat-trace /
flat-pallas-launch serving contracts:

  * :mod:`repro.obs.metrics` -- a :class:`MetricsRegistry` of per-shard
    *donated* counters (tokens decoded, prefill tokens, KV bytes moved
    through the page tables, pages migrated) accumulated inside the
    compiled step with zero extra pallas launches, plus host-side
    step-latency histograms (p50/p95/p99).
  * :mod:`repro.obs.energy` -- an :class:`EnergyModel` that converts
    bytes-moved counters and measured wall time into joules/token and
    $/1M-tokens at any frontier voltage (pJ/byte from the paper's power
    curve + static watts), the unit fleets actually buy.
  * :mod:`repro.obs.trace` -- a bounded ring buffer of typed scheduler
    events (admission, retirement, backpressure, COW fork, migration,
    quarantine, block retirement, replan, escalation), exported as
    JSONL and as Prometheus-text / JSON snapshots
    (:mod:`repro.obs.export`).
"""
from repro.obs.energy import DEFAULT_ENERGY_MODEL, EnergyModel
from repro.obs.metrics import (STEP_COUNTERS, MetricsRegistry, ObsConfig,
                               step_counter_delta)
from repro.obs.trace import Event, EventTrace

__all__ = [
    "DEFAULT_ENERGY_MODEL", "EnergyModel", "STEP_COUNTERS",
    "MetricsRegistry", "ObsConfig", "step_counter_delta", "Event",
    "EventTrace",
]
