"""Arena injection engine: bit-exactness, launch count, zero-recompile.

Three-way equality is the engine's correctness contract: the legacy
per-segment path (independent implementation, static thresholds), the
fused arena kernel (scalar-prefetch thresholds), and the table-driven
pure-jnp oracle must agree bit-for-bit over dtype x method x ECC, on a
placement whose leaves straddle pseudo-channel boundaries.

The performance contract is structural, asserted on the jaxpr: one
``pallas_call`` per domain (vs. one per segment per leaf), and a jitted
5-point voltage sweep traces exactly once.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, injection
from repro.core.domains import ALIGN_WORDS, MemoryDomain, place_groups
from repro.core.faultmap import (COL_PAR_Q_STRONG, COL_Q01_WEAK,
                                 COL_T01_WEAK, COL_WEAK_ROW_Q, NUM_THR_COLS,
                                 PAPER_MAP_SEED, FaultMap)
from repro.core.hbm import HBMGeometry, VCU128

# Small PCs (4 arena blocks each) so modest test tensors straddle
# pseudo-channel boundaries and exercise multi-segment leaves.
TINY = HBMGeometry(name="tiny", num_stacks=2, channels_per_stack=2,
                   pcs_per_channel=2, bytes_per_pc=64 * 1024)
TINY_FMAP = FaultMap.from_seed(TINY, seed=7)
FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


def _bits(x):
    return np.asarray(jax.lax.bitcast_convert_type(
        x.reshape(-1),
        {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[x.dtype.itemsize]))


def _tree(dtype):
    rng = np.random.RandomState(3)
    if jnp.issubdtype(dtype, jnp.floating):
        mk = lambda shape: jnp.asarray(rng.rand(*shape), dtype)
    else:
        mk = lambda shape: jnp.asarray(rng.randint(-100, 100, shape), dtype)
    # ~47k words across three leaves -> spans 3+ tiny PCs.
    return {"a": mk((40000,)), "b": mk((123, 45)), "c": mk((4097,))}


def _place(tree, *, v, ecc, fmap=TINY_FMAP):
    domains = {"d": MemoryDomain("d", v, tuple(range(6)), ecc=ecc)}
    return place_groups({"g": tree}, {"g": "d"}, domains, fmap.geometry)["g"]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("method,v", [("word", 0.90), ("bitwise", 0.86)])
def test_three_way_equality(dtype, method, v):
    tree = _tree(dtype)
    placement = _place(tree, v=v, ecc=False)
    assert len(set(placement.block_table().block_pc)) >= 2  # multi-PC arena
    old, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                    method=method, engine="segments")
    new, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                    method=method)
    ref, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                    method=method, use_ref=True)
    changed = 0
    for k in tree:
        np.testing.assert_array_equal(_bits(old[k]), _bits(new[k]))
        np.testing.assert_array_equal(_bits(new[k]), _bits(ref[k]))
        changed += int((_bits(new[k]) != _bits(tree[k])).sum())
    assert changed > 0  # the sweep point actually injects something


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("v", [0.90, 0.88])
def test_three_way_equality_ecc(dtype, v):
    tree = _tree(dtype)
    placement = _place(tree, v=v, ecc=True)
    old, bad_old = injection.inject_group(tree, placement, TINY_FMAP,
                                          engine="segments")
    new, bad_new = injection.inject_group(tree, placement, TINY_FMAP)
    ref, bad_ref = injection.inject_group(tree, placement, TINY_FMAP,
                                          use_ref=True)
    for k in tree:
        np.testing.assert_array_equal(_bits(old[k]), _bits(new[k]))
        np.testing.assert_array_equal(_bits(new[k]), _bits(ref[k]))
    assert int(bad_old) == int(bad_new) == int(bad_ref)


def test_one_launch_per_domain():
    tree = _tree(jnp.float32)
    placement = _place(tree, v=0.90, ecc=False)
    n_segments = sum(len(l.segments) for l in placement.leaves)
    assert n_segments > len(placement.leaves)  # leaves really straddle PCs

    arena_jaxpr = jax.make_jaxpr(lambda t: injection.inject_group(
        t, placement, TINY_FMAP, method="word"))(tree)
    legacy_jaxpr = jax.make_jaxpr(lambda t: injection.inject_group(
        t, placement, TINY_FMAP, method="word", engine="segments"))(tree)
    assert engine.count_pallas_calls(arena_jaxpr.jaxpr) == 1
    assert engine.count_pallas_calls(legacy_jaxpr.jaxpr) == n_segments


def test_voltage_sweep_compiles_once():
    """The headline property: a jitted sweep over runtime voltages
    retraces nothing -- thresholds are data, not trace constants."""
    tree = _tree(jnp.float32)
    placement = _place(tree, v=0.91, ecc=False)
    traces = []

    @jax.jit
    def step(t, v):
        traces.append(1)
        out, bad = injection.inject_group(t, placement, TINY_FMAP,
                                          voltage=v, method="word")
        return out

    outs = {}
    for v in (0.93, 0.92, 0.91, 0.90, 0.89):
        outs[v] = step(tree, jnp.float32(v))
    assert len(traces) == 1, f"voltage sweep retraced {len(traces)} times"

    # Each traced-sweep point is bit-identical to an eager static-voltage
    # arena call (same compiled threshold graph).
    for v in (0.93, 0.91, 0.89):
        eager, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                          voltage=v, method="word")
        for k in tree:
            np.testing.assert_array_equal(_bits(outs[v][k]), _bits(eager[k]))

    # Guardband via traced voltage: numerically the identity.
    safe = step(tree, jnp.float32(1.0))
    for k in tree:
        np.testing.assert_array_equal(_bits(safe[k]), _bits(tree[k]))


def test_voltage_sweep_compiles_once_ecc():
    tree = _tree(jnp.float32)
    placement = _place(tree, v=0.91, ecc=True)
    traces = []

    @jax.jit
    def step(t, v):
        traces.append(1)
        return injection.inject_group(t, placement, TINY_FMAP, voltage=v)

    bads = [int(step(tree, jnp.float32(v))[1])
            for v in (0.92, 0.90, 0.88, 0.86, 0.84)]
    assert len(traces) == 1
    assert bads == sorted(bads)  # uncorrectables grow as voltage drops


def test_list_pytree_leaf_order():
    """Placement order is keystr-sorted ('[10]' < '[2]'), which diverges
    from jax's flatten order on list pytrees with >= 11 leaves -- the
    arena must still hand every leaf back to its own position."""
    tree = [jnp.full((100,), float(i), jnp.float32) for i in range(12)]
    placement = _place(tree, v=0.90, ecc=False)
    out, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                    method="word")
    old, _ = injection.inject_group(tree, placement, TINY_FMAP,
                                    method="word", engine="segments")
    for i, (n, o) in enumerate(zip(out, old)):
        np.testing.assert_array_equal(_bits(n), _bits(o),
                                      err_msg=f"leaf {i}")
        # the vast majority of words are un-flipped and must equal i
        assert float(jnp.median(n)) == float(i)


def test_voltage_override_spares_safe_domains():
    """A sweep scalar must never drag guardband domains (master params,
    optimizer state) below their configured protection; explicit
    per-domain dicts may."""
    from repro.core.engine import inject_groups
    groups = {"mu": {"m": jnp.ones((20000,), jnp.float32)},
              "params": {"w": jnp.zeros((20000,), jnp.float32)}}
    domains = {"safe": MemoryDomain("safe", 0.98, (0, 1)),
               "cheap": MemoryDomain("cheap", 0.91, (2, 3, 4))}
    placements = place_groups(groups, {"mu": "safe", "params": "cheap"},
                              domains, TINY)
    out, _ = inject_groups(groups, placements, TINY_FMAP,
                           voltage=jnp.float32(0.88), method="word")
    assert out["mu"]["m"] is groups["mu"]["m"]  # untouched, exact
    assert int((out["params"]["w"] != 0).sum()) > 0  # swept domain injects
    # explicit per-domain dict targets exactly what it names; unnamed
    # domains keep their configured behavior
    out2, _ = inject_groups(groups, placements, TINY_FMAP,
                            voltage={"safe": 0.88}, method="word")
    assert int((_bits(out2["mu"]["m"]) != _bits(groups["mu"]["m"])).sum()) > 0
    base, _ = inject_groups(groups, placements, TINY_FMAP, method="word")
    np.testing.assert_array_equal(_bits(out2["params"]["w"]),
                                  _bits(base["params"]["w"]))


def test_static_guardband_is_exact_identity():
    tree = _tree(jnp.float32)
    placement = _place(tree, v=0.98, ecc=False)
    out, bad = injection.inject_group(tree, placement, TINY_FMAP)
    assert all(out[k] is tree[k] for k in tree)
    assert int(bad) == 0


def test_block_table_invariants():
    tree = _tree(jnp.bfloat16)
    placement = _place(tree, v=0.90, ecc=False)
    table = placement.block_table()
    words_per_pc = TINY.bytes_per_pc // 4
    assert table.num_blocks == sum(nb for _, nb, _ in table.leaf_blocks)
    for pc, base in zip(table.block_pc, table.block_base):
        assert pc in placement.domain.pc_ids
        assert base % ALIGN_WORDS == 0
        assert base // words_per_pc == pc  # base lies inside its PC extent
    for (start, n_blocks, n_words), leaf in zip(table.leaf_blocks,
                                                placement.leaves):
        assert n_words == leaf.n_words
        assert (n_blocks - 1) * ALIGN_WORDS < n_words <= n_blocks * ALIGN_WORDS


def test_thresholds_match_table_row():
    """The legacy KernelThresholds are literally a table row -- the
    bridge that keeps both engines bit-exact."""
    tab = np.asarray(FMAP.threshold_table(0.90))
    assert tab.shape == (32, NUM_THR_COLS) and tab.dtype == np.uint32
    for pc in (0, 4, 18, 31):
        thr = FMAP.thresholds(0.90, pc)
        assert thr.q01_weak == int(tab[pc, COL_Q01_WEAK])
        assert thr.t01_weak == int(tab[pc, COL_T01_WEAK])
        assert thr.weak_row_q == int(tab[pc, COL_WEAK_ROW_Q])
        assert thr.par_q_strong == int(tab[pc, COL_PAR_Q_STRONG])
        assert thr.p01_weak == thr.t01_weak / 2.0 ** 20


def test_public_u32_views():
    from repro.kernels.bitflip import ops
    x = jnp.asarray(np.random.RandomState(0).rand(33, 7), jnp.bfloat16)
    u32, meta = ops.to_u32(x)
    back = ops.from_u32(u32, meta)
    np.testing.assert_array_equal(_bits(back), _bits(x))
    assert ops._to_u32 is ops.to_u32 and ops._from_u32 is ops.from_u32
