"""Integration tests: Algorithm 1 reliability tester on the fault model."""
import numpy as np
import pytest

from repro.core import reliability as rel
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
MEM_WORDS = 1 << 18  # scaled-down memSize (1 MiB per test array)


def test_guardband_sweep_no_faults():
    res = rel.sweep(FMAP, pcs=[0, 18], mem_words=MEM_WORDS,
                    v_grid=[1.2, 1.1, 1.0, 0.98], method="word")
    for v, results in res.items():
        for r in results:
            assert r.fault_counts == (0,), (v, r.pc)


def test_fault_counts_grow_as_voltage_drops():
    counts = []
    for v in (0.92, 0.90, 0.88, 0.86):
        r = rel.run_pc_test(FMAP, v, pc=19, mem_words=MEM_WORDS,
                            pattern=rel.ALL_ZEROS, method="auto")
        counts.append(r.fault_counts[0])
    assert counts == sorted(counts)
    assert counts[-1] > counts[0] > 0


def test_pattern_asymmetry():
    # 0->1 flips (zeros pattern) outnumber 1->0 flips (ones pattern).
    z = rel.run_pc_test(FMAP, 0.88, pc=19, mem_words=MEM_WORDS,
                        pattern=rel.ALL_ZEROS)
    o = rel.run_pc_test(FMAP, 0.88, pc=19, mem_words=MEM_WORDS,
                        pattern=rel.ALL_ONES)
    assert z.fault_counts[0] > o.fault_counts[0]


def test_batches_consistent_without_transients():
    r = rel.run_pc_test(FMAP, 0.89, pc=4, mem_words=MEM_WORDS,
                        batch_size=3)
    assert len(set(r.fault_counts)) == 1


def test_transient_noise_varies_batches():
    r = rel.run_pc_test(FMAP, 0.89, pc=4, mem_words=MEM_WORDS,
                        batch_size=3, transient_rate=1e-5, seed=7)
    assert len(set(r.fault_counts)) > 1


def test_observed_rate_matches_model():
    v, pc = 0.88, 18
    r = rel.run_pc_test(FMAP, v, pc=pc, mem_words=MEM_WORDS,
                        pattern=rel.ALL_ZEROS)
    observed = rel.observed_rate(r)
    expected = float(FMAP.pc_rates(v)[0][pc])
    assert observed == pytest.approx(expected, rel=0.2)


def test_all_faulty_region():
    r = rel.run_pc_test(FMAP, 0.83, pc=0, mem_words=1 << 14,
                        pattern=rel.ALL_ZEROS, method="bitwise")
    # essentially every 0 flipped to 1 in the 0->1 share of cells
    assert rel.observed_rate(r) > 0.4
