"""Paged KV-cache primitives.

Three contracts back the continuous-batching scheduler:

  * the batched *paged* decode-attention kernel is bit-identical to the
    PR 3 contiguous read-path kernel on the same operands -- injection
    on/off x ECC on/off x constant/traced voltage -- because both share
    one flash tile body and one mask math addressed by physical word
    ids;
  * per-page physical tables are pure refinements of the arena block
    tables (a page never straddles a block), and the same candidate-
    select addressing resolves them at page granularity;
  * the page pool routes criticality tiers (weak pages to tolerant
    requests first, weak-avoiding tiers never see weak pages), recycles
    freed pages deterministically, and turns exhaustion into a typed
    CapacityError.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.domains import CapacityError, MemoryDomain, place_groups
from repro.core.faultmap import FaultMap
from repro.core.hbm import VCU128, HBMGeometry
from repro.kernels.flash_attention import faulty
from repro.models.base import get_arch
from repro.serving.paged import PagedLayoutError, PagePool
from repro.training.undervolt import UndervoltPlan

TINY = HBMGeometry(name="tiny", num_stacks=2, channels_per_stack=2,
                   pcs_per_channel=2, bytes_per_pc=64 * 1024)
FMAP = FaultMap.from_seed(TINY, seed=7)

B, L, KH, G, D = 2, 32, 2, 3, 8
H = KH * G
PS = 8                                  # page_slots
N_LP = L // PS


def _bits(x):
    return np.asarray(jax.lax.bitcast_convert_type(
        x.reshape(-1),
        {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[x.dtype.itemsize]))


def _operands(seed, v, ecc, *, method):
    """Contiguous cache + both granularities of physical tables."""
    rng = np.random.RandomState(seed)
    tree = {"k": jnp.asarray(rng.randn(B, L, KH, D), jnp.bfloat16),
            "v": jnp.asarray(rng.randn(B, L, KH, D), jnp.bfloat16)}
    domains = {"d": MemoryDomain("d", v, tuple(range(6)), ecc=ecc)}
    placement = place_groups({"g": tree}, {"g": "d"}, domains, TINY)["g"]
    table = FMAP.threshold_table(v)
    tabs = engine.leaf_block_tables(placement)
    paths = [lp.path for lp in placement.leaves]
    wps = faulty.kv_words_per_slot(KH, D, jnp.bfloat16)
    page_words = PS * wps
    block_t, page_t = {}, {}
    for name in ("k", "v"):
        bb, bp = tabs[paths.index(f"['{name}']")]
        block_t[name] = (jnp.asarray(bb), table[jnp.asarray(bp)])
        pb, pp = engine.refine_tables(bb, bp, page_words)
        page_t[name] = (jnp.asarray(pb), table[jnp.asarray(pp)])
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.bfloat16)
    pos_vals = np.arange(L)[None, :].repeat(B, 0).astype(np.int32)
    pos_vals[:, -3:] = -1               # empty ring slots stay masked
    pos = jnp.asarray(pos_vals)
    kw = dict(causal=True, window=0, seed=FMAP.seed, method=method,
              words_per_row_log2=FMAP.words_per_row_log2, ecc=ecc)
    return tree, q, pos, block_t, page_t, page_words, kw


def _pool_view(tree, pos):
    """The same cache as a page pool with identity page tables."""
    pool_k = tree["k"].reshape(B * N_LP, PS, KH, D)
    pool_v = tree["v"].reshape(B * N_LP, PS, KH, D)
    pool_pos = pos.reshape(B * N_LP, PS)
    ptab = jnp.asarray(
        np.arange(B * N_LP, dtype=np.int32).reshape(B, N_LP))
    return pool_k, pool_v, pool_pos, ptab


CASES = [("word", 0.88, False), ("bitwise", 0.86, False),
         ("word", 0.86, True)]


@pytest.mark.parametrize("inject", [True, False])
@pytest.mark.parametrize("method,v,ecc", CASES)
def test_paged_kernel_bit_identical_to_contiguous(method, v, ecc, inject):
    """The satellite contract: batched paged attention == the PR 3
    contiguous kernel on the same operands, including the clean-slot
    exemption, with and without injection."""
    tree, q, pos, block_t, page_t, page_words, kw = _operands(
        1, v, ecc, method=method)
    q_pos = jnp.int32(L + 4)
    ref = faulty.faulty_decode_attention(
        q, tree["k"], tree["v"], pos, q_pos=q_pos,
        k_tables=block_t["k"], v_tables=block_t["v"],
        k_word0=jnp.uint32(0), v_word0=jnp.uint32(0), inject=inject,
        clean_slot=(q_pos % L), bkv=PS, **kw)

    # same kernel addressed through page-granular tables
    lg2 = page_words.bit_length() - 1
    out_pg = faulty.faulty_decode_attention(
        q, tree["k"], tree["v"], pos, q_pos=q_pos,
        k_tables=page_t["k"], v_tables=page_t["v"],
        k_word0=jnp.uint32(0), v_word0=jnp.uint32(0), inject=inject,
        clean_slot=(q_pos % L), bkv=PS, words_log2=lg2, **kw)
    np.testing.assert_array_equal(_bits(ref), _bits(out_pg))

    # the batched paged kernel over the pool view of the same cache
    pool_k, pool_v, pool_pos, ptab = _pool_view(tree, pos)
    out_paged = faulty.paged_decode_attention(
        q, pool_k, pool_v, pool_pos, ptab,
        q_pos=jnp.full((B,), L + 4, jnp.int32),
        k_tables=page_t["k"], v_tables=page_t["v"], inject=inject, **kw)
    np.testing.assert_array_equal(_bits(ref), _bits(out_paged))


def test_paged_kernel_per_slot_positions():
    """Every serving slot carries its own decode position (and hence
    its own causal mask and clean-slot exemption): each batched row
    equals a standalone single-request call at that position."""
    tree, q, pos, _, page_t, _, kw = _operands(2, 0.86, False,
                                               method="bitwise")
    pool_k, pool_v, pool_pos, ptab = _pool_view(tree, pos)
    q_pos = jnp.asarray([L + 4, L - 9], jnp.int32)
    out = faulty.paged_decode_attention(
        q, pool_k, pool_v, pool_pos, ptab, q_pos=q_pos,
        k_tables=page_t["k"], v_tables=page_t["v"], inject=True, **kw)
    for b in range(B):
        single = faulty.paged_decode_attention(
            q[b:b + 1], pool_k, pool_v, pool_pos, ptab[b:b + 1],
            q_pos=q_pos[b:b + 1], k_tables=page_t["k"],
            v_tables=page_t["v"], inject=True, **kw)
        np.testing.assert_array_equal(_bits(out[b]), _bits(single[0]))


def test_paged_kernel_traced_voltage_traces_once():
    """Page threshold tables derive from a traced voltage inside the
    caller's trace: a 5-point sweep compiles once and matches eager."""
    tree, q, pos, _, _, page_words, kw = _operands(3, 0.90, False,
                                                   method="word")
    pool_k, pool_v, pool_pos, ptab = _pool_view(tree, pos)
    domains = {"d": MemoryDomain("d", 0.90, tuple(range(6)))}
    placement = place_groups({"g": {k: tree[k] for k in ("k", "v")}},
                             {"g": "d"}, domains, TINY)["g"]
    tabs = engine.leaf_block_tables(placement)
    paths = [lp.path for lp in placement.leaves]
    refined = {name: engine.refine_tables(*tabs[paths.index(f"['{name}']")],
                                          page_words)
               for name in ("k", "v")}
    traces = []

    def run(vv):
        traces.append(1)
        table = FMAP.threshold_table(vv)
        t = {name: (jnp.asarray(pb), table[jnp.asarray(pp)])
             for name, (pb, pp) in refined.items()}
        return faulty.paged_decode_attention(
            q, pool_k, pool_v, pool_pos, ptab,
            q_pos=jnp.full((B,), L, jnp.int32), k_tables=t["k"],
            v_tables=t["v"], inject=True, **kw)

    jrun = jax.jit(run)
    outs = {vv: jrun(jnp.float32(vv))
            for vv in (0.90, 0.89, 0.88, 0.87, 0.86)}
    assert len(traces) == 1, f"voltage sweep retraced {len(traces)} times"
    assert bool(jnp.any(outs[0.90] != outs[0.86]))
    for vv in (0.90, 0.86):
        np.testing.assert_array_equal(_bits(outs[vv]),
                                      _bits(run(jnp.float32(vv))))


def test_refine_tables_is_pure_index_transform():
    bb = np.asarray([4096 * 7, 4096 * 11], np.uint32)
    bp = np.asarray([3, 5], np.int32)
    pb, pp = engine.refine_tables(bb, bp, 1024)
    np.testing.assert_array_equal(
        pb, [4096 * 7, 4096 * 7 + 1024, 4096 * 7 + 2048, 4096 * 7 + 3072,
             4096 * 11, 4096 * 11 + 1024, 4096 * 11 + 2048,
             4096 * 11 + 3072])
    np.testing.assert_array_equal(pp, [3, 3, 3, 3, 5, 5, 5, 5])
    with pytest.raises(ValueError, match="divide"):
        engine.refine_tables(bb, bp, 24)


# ---------------------------------------------------------------------------
# PagePool: tier routing, recycling, layout validation
# ---------------------------------------------------------------------------

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
ALL_PCS = tuple(range(VCU128.num_pcs))


def _plan(v=0.88, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _pool(num_pages=16, page_slots=8, plan=None, max_len=32, cfg=CFG):
    return PagePool(BUNDLE.module, cfg, max_len=max_len,
                    page_slots=page_slots, num_pages=num_pages,
                    plan=plan if plan is not None else _plan())


def test_pool_tier_routing_and_capacity_backpressure():
    pool = _pool()
    n_strong, n_weak = len(pool._strong), len(pool._weak)
    assert n_strong + n_weak == 16
    assert n_weak >= 1, "fault map should make some pages weak"

    strict = pool.alloc(2, "critical")
    assert not any(int(p) in pool._weak_set for p in strict)
    tolerant = pool.alloc(min(n_weak, 2), "cheap")
    assert all(int(p) in pool._weak_set for p in tolerant), (
        "tolerant tiers must consume weak pages first")

    with pytest.raises(CapacityError) as ei:
        pool.alloc(n_strong + n_weak, "critical")
    assert ei.value.domain == "kv"
    assert "weak" in str(ei.value)
    # ...but the same footprint is admissible for a tolerant tier if it
    # fits the whole pool
    assert pool.free_pages == 16 - len(strict) - len(tolerant)


def test_pool_free_realloc_deterministic_and_double_free_raises():
    pool = _pool()
    a = pool.alloc(4, "cheap")
    b = pool.alloc(3, "critical")
    pool.free(a)
    a2 = pool.alloc(4, "cheap")
    np.testing.assert_array_equal(np.sort(a), np.sort(a2))
    pool.free(a2)
    with pytest.raises(ValueError, match="double free"):
        pool.free(a2)
    with pytest.raises(ValueError, match="double free"):
        pool.free(np.asarray([pool.scratch_id]))  # never handed out
    pool.free(b)
    assert pool.free_pages == 16


def test_pool_request_words_match_standalone_cache():
    pool = _pool()
    from repro.models.base import spec_avals
    avals = spec_avals(BUNDLE.module.cache_specs(CFG, 1, 32))
    n_words = sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize // 4
                  for a in jax.tree_util.tree_leaves(avals))
    assert pool.request_words == n_words


def test_pool_layout_errors_are_typed_and_actionable():
    # page count not dividing the ring length
    with pytest.raises(PagedLayoutError, match="divide"):
        _pool(page_slots=7)
    # page words not dividing the arena block size (kv slot = 8 words,
    # 3 slots -> 24-word pages; 4096 % 24 != 0)
    with pytest.raises(PagedLayoutError, match="block size"):
        _pool(page_slots=3, max_len=24)
    # window rings page cleanly now (window-modular tables), but a ring
    # shorter than one page cannot hold it: page must fit in the window
    cfg = dataclasses.replace(CFG, pattern=("local", "global"), window=8)
    with pytest.raises(PagedLayoutError, match="page_slots <= 8"):
        _pool(cfg=cfg, page_slots=16)
    # and the page size must divide the window (cfg.window named)
    cfg = dataclasses.replace(CFG, pattern=("local", "global"), window=12)
    with pytest.raises(PagedLayoutError, match="cfg.window"):
        _pool(cfg=cfg, page_slots=8)
    # ECC pools need even per-slot word counts (codeword pairs):
    # 1 kv-head x head_dim 2 = one bf16 word per slot
    cfg = dataclasses.replace(CFG, n_kv_heads=1, head_dim=2, n_heads=3)
    with pytest.raises(PagedLayoutError, match="ECC"):
        _pool(cfg=cfg, plan=_plan(ecc=True))
    # unpaged families are rejected up front
    from repro.models import moe
    with pytest.raises(ValueError, match="paged"):
        PagePool(moe, CFG, max_len=32, page_slots=8, num_pages=4,
                 plan=_plan())


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing: refcounts, typed guards, prefix cache
# ---------------------------------------------------------------------------


def test_pool_sharing_refcounts_and_typed_guards():
    """Every sharing-protocol violation is a typed PageSharingError:
    double release by the same holder, COW-forking an unshared page,
    re-sharing, re-retaining, and free()-ing a page with live holders."""
    from repro.serving.paged import PageSharingError
    pool = _pool()
    pids = pool.alloc(2, "cheap")
    a, b = ("__req__", "a"), ("__req__", "b")

    with pytest.raises(PageSharingError, match="not a shared page"):
        pool.cow_fork(pids[0])          # private pages fork nothing
    with pytest.raises(PageSharingError, match="not a shared page"):
        pool.retain(pids, a)

    pool.share(pids, a)
    assert pool.shared_pages == 2
    with pytest.raises(PageSharingError, match="already shared"):
        pool.share(pids[:1], b)
    with pytest.raises(PageSharingError, match="released per holder"):
        pool.free(pids)                 # live holders block free()

    pool.retain(pids, b)
    with pytest.raises(PageSharingError, match="already"):
        pool.retain(pids[:1], b)        # double retain, same holder

    fork = pool.cow_fork(pids[0], "critical")
    assert fork not in set(int(p) for p in pids)
    assert int(fork) not in pool._weak_set

    pool.release(pids, a)
    with pytest.raises(PageSharingError, match="double release"):
        pool.release(pids, a)           # second release, same request
    assert pool.shared_pages == 2       # b still holds both
    pool.release(pids, b)
    assert pool.shared_pages == 0
    pool.free([fork])
    assert pool.free_pages == 16        # refcounted release recycles


def test_pool_prefix_cache_match_register_evict():
    """Longest-prefix matching is content-hashed and page-aligned (the
    full prompt may end inside a page), registration is idempotent, and
    LRU eviction releases only the cache's own holds."""
    pool = _pool()
    ps = pool.page_slots
    toks = np.arange(20, dtype=np.int32)          # 2 full pages + 4 rows
    pids = pool.alloc(3, "cheap")
    pool.share(pids, ("__req__", "creator"))
    assert pool.register_prefix(toks[:ps], pids[:1])
    assert pool.register_prefix(toks[:2 * ps], pids[:2])
    assert pool.register_prefix(toks, pids)
    assert not pool.register_prefix(toks, pids)   # already cached
    assert pool.prefix_entries == 3

    ln, got = pool.match_prefix(toks)             # full match first
    assert ln == 20 and np.array_equal(got, pids)
    other = np.concatenate([toks[:2 * ps], [999, 998]]).astype(np.int32)
    ln, got = pool.match_prefix(other)            # page-aligned fallback
    assert ln == 2 * ps and np.array_equal(got, pids[:2])
    ln, got = pool.match_prefix(toks[:ps - 1])    # shorter than a page
    assert ln == 0 and got.shape == (0,)
    ln, _ = pool.match_prefix(np.array([7, 7, 7], np.int32))
    assert ln == 0

    # LRU eviction: oldest entry first; pages survive through the
    # holders that remain (later entries, the creating request)
    assert pool.evict_prefix()
    assert pool.prefix_entries == 2
    assert pool.match_prefix(other)[0] == 2 * ps  # longer entry intact
    pool.release(pids, ("__req__", "creator"))
    assert pool.shared_pages == 3                 # cache holds remain
    while pool.evict_prefix():
        pass
    assert pool.prefix_entries == 0 and pool.shared_pages == 0
    assert pool.free_pages == 16                  # fully recycled
