"""Energy accounting + the efficiency governor.

The EnergyModel's contract is an algebraic identity with the paper's
power curve: pricing a recorded workload (bytes moved over wall time)
at voltage ``v`` must equal ``PowerModel.energy_joules`` at the
implied HBM utilization -- so re-pricing the SAME workload at two
voltages reproduces the paper's power ratios in joules/token exactly
(~1.5x at the 0.98 V guardband, ~2.3x at the deepest 0.85 V point),
independent of what the workload was.

``mode='efficiency'`` picks, among frontier points meeting a fault-
rate SLO, the tokens-per-joule argmax -- an INTERIOR optimum (the
retry-probability penalty makes the deepest feasible point lose), no
worse than any fixed setpoint, and walkable with a traced SLO.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.domains import CapacityError, MemoryDomain
from repro.core.faultmodel import V_NOM
from repro.core.hbm import VCU128
from repro.core.voltage import DEFAULT_POWER_MODEL
from repro.obs.energy import DEFAULT_ENERGY_MODEL, EnergyModel
from repro.training.governor import GovernorConfig, fleet_report
from repro.training.undervolt import UndervoltPlan

ALL_PCS = tuple(range(VCU128.num_pcs))


def _plan(v, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


# ---------------------------------------------------------------------------
# EnergyModel
# ---------------------------------------------------------------------------
def test_step_joules_is_power_model_identity():
    """step_joules == PowerModel.energy_joules at util = bytes/(bw*s),
    exactly -- the accountant is the power curve, not a refit."""
    em = DEFAULT_ENERGY_MODEL
    s, v = 2.5, 0.93
    nbytes = 0.4 * em.bandwidth_bytes * s          # util 0.4
    j = em.step_joules(seconds=s, bytes_moved=nbytes, v=v)
    ref = DEFAULT_POWER_MODEL.energy_joules(
        s, v, util=nbytes / (em.bandwidth_bytes * s))
    assert j == pytest.approx(ref, rel=1e-12)


@pytest.mark.parametrize("util", [0.05, 0.5, 1.0])
def test_repriced_workload_reproduces_paper_ratios(util):
    """The SAME workload priced at two voltages gives exactly the
    power-curve ratio in joules/token -- 1.5x-class at the guardband,
    2.3x-class at the deepest point -- at ANY utilization."""
    em = DEFAULT_ENERGY_MODEL
    s = 3.0
    nbytes = util * em.bandwidth_bytes * s
    jpt = {v: em.joules_per_token(seconds=s, bytes_moved=nbytes,
                                  tokens=1000, v=v)
           for v in (V_NOM, 0.98, 0.85)}
    assert jpt[V_NOM] / jpt[0.98] == pytest.approx(1.4994, rel=1e-3)
    assert jpt[V_NOM] / jpt[0.85] == pytest.approx(2.3175, rel=1e-3)


def test_usd_scales_linearly_with_rate_and_joules():
    em = DEFAULT_ENERGY_MODEL
    em2 = EnergyModel(cost_per_kwh=2 * em.cost_per_kwh)
    assert em2.usd_per_mtok(0.5) == pytest.approx(
        2 * em.usd_per_mtok(0.5))
    assert em.usd_per_mtok(1.0) == pytest.approx(
        2 * em.usd_per_mtok(0.5))
    # 1 J/token at $0.10/kWh: 1e6 J / 3.6e6 J-per-kWh * 0.10 $/kWh
    assert em.usd_per_mtok(1.0) == pytest.approx(1e6 / 3.6e6 * 0.10)


def test_report_fields_and_validation():
    em = DEFAULT_ENERGY_MODEL
    rep = em.report(seconds=1.0, bytes_moved=1e9, tokens=100, v=0.95)
    for key in ("voltage", "joules", "joules_per_token", "usd_per_mtok",
                "tokens_per_joule", "watts_avg", "pj_per_byte",
                "hbm_util", "savings_x"):
        assert key in rep, key
    assert rep["joules_per_token"] * rep["tokens_per_joule"] == (
        pytest.approx(1.0))
    assert rep["savings_x"] > 1.0            # 0.95 V beats nominal
    with pytest.raises(ValueError):
        em.step_joules(seconds=-1.0, bytes_moved=1.0, v=0.95)
    with pytest.raises(ValueError):
        em.joules_per_token(seconds=1.0, bytes_moved=1.0, tokens=0,
                            v=0.95)


# ---------------------------------------------------------------------------
# mode='efficiency'
# ---------------------------------------------------------------------------
def _gov(**kw):
    kw.setdefault("mode", "efficiency")
    kw.setdefault("tolerable_rate", 1e-4)
    kw.setdefault("setpoint", 1e-4)
    kw.setdefault("v_lo", 0.85)
    return _plan(0.88).make_governor("kv", **kw)


def test_efficiency_interior_argmax_beats_fixed_setpoints():
    gov = _gov()
    v_eff = float(gov.voltage_at(1e-4))
    # the optimum is interior: strictly below the guardband, strictly
    # above the deepest feasible point
    assert 0.85 < v_eff < 0.98, v_eff
    tpj_eff = float(gov.efficiency_at(v_eff))
    for v in (0.98, 0.95, 0.92, 0.90, 0.88, 0.86):
        assert tpj_eff + 1e-9 >= float(gov.efficiency_at(v)), (
            v_eff, v, tpj_eff, gov.efficiency_at(v))


def test_efficiency_respects_rate_slo():
    gov = _gov()
    v = float(gov.voltage_at(1e-4))
    rate = float(np.interp(v, gov._v_np, gov._rate_np))
    assert rate <= 1e-4, (v, rate)
    # an impossible SLO clamps to the highest feasible voltage
    v_clamp = float(gov.voltage_at(0.0))
    assert v_clamp == pytest.approx(float(gov._v_np[gov._feasible][-1]))


def test_efficiency_walk_is_traceable():
    gov = _gov()
    walked = jax.jit(gov.voltage_at)(jnp.float32(1e-4))
    assert float(walked) == pytest.approx(float(gov.voltage_at(1e-4)))


def test_efficiency_admit_and_capacity():
    gov = _gov()
    v = gov.admit(4096)                    # tiny ask: SLO governs
    assert v == pytest.approx(float(gov.voltage_at(1e-4)))
    with pytest.raises(CapacityError):
        gov.admit(10 ** 15)


def test_efficiency_sharper_exposure_prefers_shallower():
    """More governed words read per token -> a given stuck rate costs
    more retries -> the argmax moves up (shallower), never down."""
    v_lo = float(_gov(read_words_per_token=256).voltage_at(1e-4))
    v_hi = float(_gov(read_words_per_token=65536).voltage_at(1e-4))
    assert v_hi >= v_lo, (v_lo, v_hi)


def test_unknown_mode_and_bad_exposure_rejected():
    with pytest.raises(ValueError):
        _plan(0.88).make_governor("kv", mode="thermal")
    with pytest.raises(ValueError):
        _gov(read_words_per_token=0)


def test_fleet_report_carries_energy_columns():
    gov = _gov()
    v = float(gov.voltage_at(1e-4))
    rep = fleet_report([gov], [v], [1e-4])
    sh = rep["shards"][0]
    assert sh["watts"] > 0
    assert sh["pj_per_byte"] > 0
    assert rep["watts_total"] == pytest.approx(
        sum(s["watts"] for s in rep["shards"]))
    # pricing at nominal costs more watts than the governed point
    em = DEFAULT_ENERGY_MODEL
    assert em.watts(V_NOM, 1.0) > sh["watts"]
