"""Unit tests: memory domains, physical placement, pytree injection,
criticality-tiered placement and spare-row avoidance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import injection
from repro.core.domains import (ALIGN_WORDS, CapacityError, CriticalityTier,
                                DeviceCrashError, DomainAllocator,
                                MemoryDomain, Segment, place_groups,
                                place_groups_tiered, resolve_tier)
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128, HBMGeometry

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)

# Small PCs so modest allocations straddle PCs and hit weak blocks.
TINY = HBMGeometry(name="tiny", num_stacks=2, channels_per_stack=2,
                   pcs_per_channel=2, bytes_per_pc=64 * 1024)
TINY_FMAP = FaultMap.from_seed(TINY, seed=7)


def test_domain_validation():
    MemoryDomain("safe", 0.98, (0, 1)).validate(VCU128)
    with pytest.raises(ValueError):
        MemoryDomain("dup", 0.98, (1, 1)).validate(VCU128)
    with pytest.raises(ValueError):
        MemoryDomain("oob", 0.98, (99,)).validate(VCU128)
    with pytest.raises(DeviceCrashError):
        MemoryDomain("dead", 0.80, (0,)).validate(VCU128)


def test_allocator_alignment_and_split():
    d = MemoryDomain("d", 0.95, (3, 7))
    a = DomainAllocator(VCU128, d)
    words_per_pc = VCU128.bytes_per_pc // 4
    # fill PC 3 up to one aligned block before its end
    segs = a.alloc(words_per_pc - ALIGN_WORDS - 5)
    assert segs[0].pc == 3 and segs[0].phys_base_word == 3 * words_per_pc
    segs2 = a.alloc(4 * ALIGN_WORDS)      # must straddle into PC 7
    assert len(segs2) == 2
    assert segs2[0].pc == 3 and segs2[1].pc == 7
    assert segs2[0].n_words + segs2[1].n_words == 4 * ALIGN_WORDS
    assert segs2[0].n_words == ALIGN_WORDS
    assert segs2[1].phys_base_word == 7 * words_per_pc


def test_allocator_capacity_error():
    d = MemoryDomain("tiny", 0.95, (0,))
    a = DomainAllocator(VCU128, d)
    with pytest.raises(MemoryError):
        a.alloc(VCU128.bytes_per_pc // 4 + 1)


def test_capacity_error_is_typed():
    d = MemoryDomain("tiny", 0.95, (0,))
    a = DomainAllocator(VCU128, d)
    with pytest.raises(CapacityError) as ei:
        a.alloc(VCU128.bytes_per_pc // 4 + 1)
    e = ei.value
    assert isinstance(e, MemoryError)
    assert e.domain == "tiny"
    assert e.requested_bytes == VCU128.bytes_per_pc + ALIGN_WORDS * 4
    assert e.free_bytes == VCU128.bytes_per_pc
    assert "tiny" in str(e) and str(e.requested_bytes) in str(e)


def test_allocator_reliability_ordering():
    """With a fault map, PCs are handed out most-reliable-first."""
    dom = MemoryDomain("d", 0.91, tuple(range(TINY.num_pcs)))
    a = DomainAllocator(TINY, dom, faultmap=TINY_FMAP)
    best = int(TINY_FMAP.reliability_order(0.91)[0])
    segs = a.alloc(ALIGN_WORDS)
    assert segs[0].pc == best
    # without a fault map the declared order is preserved
    b = DomainAllocator(TINY, MemoryDomain("d", 0.91, (5, 2)))
    assert b.alloc(ALIGN_WORDS)[0].pc == 5


def test_allocator_weak_row_avoidance():
    """avoid_weak_rows=True never lands on a block containing a weak
    row, and the skipped weak blocks are recycled for tolerant allocs."""
    dom = MemoryDomain("d", 0.90, tuple(range(TINY.num_pcs)))
    a = DomainAllocator(TINY, dom, faultmap=TINY_FMAP)
    total_blocks = TINY.num_pcs * (TINY.bytes_per_pc // 4 // ALIGN_WORDS)
    n_weak = sum(int(TINY_FMAP.weak_block_mask(pc, ALIGN_WORDS).sum())
                 for pc in range(TINY.num_pcs))
    assert 0 < n_weak < total_blocks
    clean_words = (total_blocks - n_weak) * ALIGN_WORDS
    segs = a.alloc(clean_words, avoid_weak_rows=True)
    wpp = TINY.bytes_per_pc // 4
    for s in segs:
        for blk in range(-(-s.n_words // ALIGN_WORDS)):
            pc = s.phys_base_word // wpp
            block = (s.phys_base_word % wpp) // ALIGN_WORDS + blk
            assert not TINY_FMAP.weak_block_mask(pc, ALIGN_WORDS)[block]
    # one more clean block does not exist
    with pytest.raises(CapacityError):
        a.alloc(ALIGN_WORDS, avoid_weak_rows=True)
    # ...but the weak spares remain allocatable for tolerant groups
    spare_segs = a.alloc(n_weak * ALIGN_WORDS)
    assert sum(s.n_words for s in spare_segs) == n_weak * ALIGN_WORDS
    assert a.free_words == 0


def test_resolve_tier():
    assert resolve_tier("cheap").max_rate == pytest.approx(1e-3)
    t = CriticalityTier("custom", 1e-5, avoid_weak_rows=True)
    assert resolve_tier(t) is t
    with pytest.raises(ValueError):
        resolve_tier("nope")
    assert resolve_tier("safe").admits(0.0, VCU128.bits_per_pc)
    assert not resolve_tier("safe").admits(1e-6, VCU128.bits_per_pc)


def test_tiered_placement_routes_by_criticality():
    """Acceptance: a cheap-tier group lands on lower-voltage PCs than a
    safe-tier group on the same fault map."""
    domains = {
        "hi": MemoryDomain("hi", 0.98, tuple(range(16))),
        "lo": MemoryDomain("lo", 0.91, tuple(range(16, 32))),
    }
    groups = {
        "mu": {"m": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)},
        "kv": {"k": jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)},
    }
    placed = place_groups_tiered(groups, {"mu": "safe", "kv": "cheap"},
                                 domains, VCU128, FMAP)
    assert placed["mu"].domain.voltage > placed["kv"].domain.voltage
    assert placed["kv"].domain.name == "lo"
    # the cheap group's PCs are the *most reliable free* PCs of its domain
    kv_pcs = {s.pc for l in placed["kv"].leaves for s in l.segments}
    best_lo = int(min(domains["lo"].pc_ids,
                      key=lambda pc: FMAP.pc_total_rate(0.91)[pc]))
    assert best_lo in kv_pcs


def test_weak_row_avoidance_reduces_injected_faults():
    """End-to-end: an extent placed with weak-row avoidance takes far
    fewer stuck bits through the real injection path than the same data
    placed without it.  Single-PC domain so PC reliability ordering is
    out of the picture, and a high process-variation multiplier so the
    (clustered) exponential regime dominates the (spatially uniform)
    saturation regime."""
    from repro.core.faultmodel import DEFAULT_FAULT_MODEL
    fmap = FaultMap(geometry=TINY, seed=7, model=DEFAULT_FAULT_MODEL,
                    pc_multiplier=tuple([200.0] * TINY.num_pcs))
    tree = {"a": jnp.zeros((2 * ALIGN_WORDS,), jnp.float32)}
    # PC 5's first blocks contain weak rows, so the plain bump placement
    # lands on them while the avoiding one takes the clean blocks.
    dom = {"d": MemoryDomain("d", 0.88, (5,))}
    assert bool(fmap.weak_block_mask(5, ALIGN_WORDS)[0])

    def flips(avoid):
        tier = CriticalityTier("t", 1.0, avoid_weak_rows=avoid)
        placed = place_groups_tiered({"g": tree}, {"g": tier}, dom, TINY,
                                     fmap)["g"]
        out, _ = injection.inject_group(tree, placed, fmap)
        return int(jnp.sum(out["a"] != 0))

    n_avoid, n_plain = flips(True), flips(False)
    assert n_plain > 0
    assert n_avoid < n_plain * 0.5


def test_tiered_placement_rejects_impossible_tier():
    domains = {"lo": MemoryDomain("lo", 0.88, tuple(range(32)))}
    groups = {"mu": {"m": jax.ShapeDtypeStruct((64, 64), jnp.float32)}}
    with pytest.raises(CapacityError) as ei:
        place_groups_tiered(groups, {"mu": "safe"}, domains, VCU128, FMAP)
    assert "mu" in str(ei.value) and "safe" in str(ei.value)


def test_place_groups_on_avals():
    groups = {
        "weights": {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)},
        "opt": {"m": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)},
    }
    domains = {
        "safe": MemoryDomain("safe", 0.98, tuple(range(16))),
        "cheap": MemoryDomain("cheap", 0.91, tuple(range(16, 32))),
    }
    placement = place_groups(groups, {"weights": "cheap", "opt": "safe"},
                             domains, VCU128)
    assert placement["weights"].domain.name == "cheap"
    assert placement["weights"].total_words == 1024 * 1024 // 2
    assert placement["opt"].leaves[0].segments[0].pc == 0
    assert placement["weights"].leaves[0].segments[0].pc == 16


def test_inject_group_guardband_identity():
    tree = {"a": jnp.ones((512, 16), jnp.float32)}
    domains = {"safe": MemoryDomain("safe", 1.0, (0, 1))}
    placement = place_groups({"g": tree}, {"g": "safe"}, domains, VCU128)
    out, bad = injection.inject_group(tree, placement["g"], FMAP)
    assert out["a"] is tree["a"]  # exact no-op
    assert int(bad) == 0


def test_inject_group_applies_faults():
    tree = {"a": jnp.zeros((1 << 18,), jnp.float32),
            "b": jnp.zeros((333, 55), jnp.bfloat16)}
    domains = {"deep": MemoryDomain("deep", 0.88, (18, 19))}
    placement = place_groups({"g": tree}, {"g": "deep"}, domains, VCU128)
    out, _ = injection.inject_group(tree, placement["g"], FMAP)
    changed = sum(int(jnp.sum(out[k] != tree[k])) for k in tree)
    assert changed > 10
    # deterministic across calls (stuck-at persistence)
    out2, _ = injection.inject_group(tree, placement["g"], FMAP)
    for k in tree:
        a16 = jax.lax.bitcast_convert_type(
            out[k].reshape(-1), jnp.uint16 if out[k].dtype.itemsize == 2
            else jnp.uint32)
        b16 = jax.lax.bitcast_convert_type(
            out2[k].reshape(-1), jnp.uint16 if out2[k].dtype.itemsize == 2
            else jnp.uint32)
        np.testing.assert_array_equal(np.asarray(a16), np.asarray(b16))


def test_inject_group_ecc_domain():
    tree = {"a": jnp.zeros((1 << 18,), jnp.float32)}
    raw_domain = {"d": MemoryDomain("d", 0.88, (18, 19))}
    ecc_domain = {"d": MemoryDomain("d", 0.88, (18, 19), ecc=True)}
    p_raw = place_groups({"g": tree}, {"g": "d"}, raw_domain, VCU128)
    p_ecc = place_groups({"g": tree}, {"g": "d"}, ecc_domain, VCU128)
    raw, _ = injection.inject_group(tree, p_raw["g"], FMAP)
    fixed, bad = injection.inject_group(tree, p_ecc["g"], FMAP)
    assert int(jnp.sum(fixed["a"] != 0)) < int(jnp.sum(raw["a"] != 0))
    assert int(bad) >= 0


def test_clamp_nonfinite():
    t = {"x": jnp.asarray([1.0, jnp.inf, -jnp.inf, jnp.nan, 2.0]),
         "i": jnp.asarray([1, 2, 3])}
    out = injection.clamp_nonfinite(t)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  [1.0, 0.0, 0.0, 0.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out["i"]), [1, 2, 3])


# ---------------------------------------------------------------------------
# DomainAllocator.free(): block recycling for long-lived serving pools
# ---------------------------------------------------------------------------


def test_allocator_free_then_realloc_returns_same_blocks():
    """The recycling invariant a serving allocator (requests arriving
    and retiring forever) depends on: freed blocks come back in the
    same reliability order, so identical footprints land on identical
    physical blocks."""
    d = MemoryDomain("d", 0.90, tuple(range(6)))
    a = DomainAllocator(TINY, d, faultmap=TINY_FMAP)
    s1 = a.alloc(3 * ALIGN_WORDS)
    s2 = a.alloc(2 * ALIGN_WORDS)
    free_before = a.free_words
    a.free(s1)
    assert a.free_words == free_before + 3 * ALIGN_WORDS
    assert a.alloc(3 * ALIGN_WORDS) == s1
    # freed blocks of several allocations merge back in rank order
    a.free(s2)
    a.free(s1)
    assert a.alloc(3 * ALIGN_WORDS) == s1
    assert a.alloc(2 * ALIGN_WORDS) == s2


def test_allocator_double_free_raises():
    d = MemoryDomain("d", 0.90, tuple(range(6)))
    a = DomainAllocator(TINY, d, faultmap=TINY_FMAP)
    segs = a.alloc(2 * ALIGN_WORDS)
    a.free(segs)
    with pytest.raises(ValueError, match="double free"):
        a.free(segs)
    with pytest.raises(ValueError, match="double free"):
        # never handed out by this allocator
        a.free((Segment(leaf_start_word=0, n_words=ALIGN_WORDS, pc=5,
                        phys_base_word=5 * (TINY.bytes_per_pc // 4)),))
    with pytest.raises(ValueError, match="not in domain"):
        a.free((Segment(leaf_start_word=0, n_words=ALIGN_WORDS, pc=7,
                        phys_base_word=0),))


def test_allocator_freed_weak_blocks_stay_avoided():
    """Recycled weak blocks must not leak into weak-row-avoiding
    allocations."""
    d = MemoryDomain("d", 0.90, tuple(range(6)))
    a = DomainAllocator(TINY, d, faultmap=TINY_FMAP)
    wpc = TINY.bytes_per_pc // 4
    segs = a.alloc(12 * ALIGN_WORDS)          # plain: weak blocks included
    blocks = []
    for s in segs:
        b0 = (s.phys_base_word - s.pc * wpc) // ALIGN_WORDS
        blocks += [(s.pc, b0 + i) for i in range(-(-s.n_words // ALIGN_WORDS))]
    assert any(a._is_weak(pc, blk) for pc, blk in blocks), (
        "fault map should mark some of these blocks weak")
    a.free(segs)
    avoided = a.alloc(4 * ALIGN_WORDS, avoid_weak_rows=True)
    for s in avoided:
        b0 = (s.phys_base_word - s.pc * wpc) // ALIGN_WORDS
        for i in range(-(-s.n_words // ALIGN_WORDS)):
            assert not a._is_weak(s.pc, b0 + i)
