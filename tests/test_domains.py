"""Unit tests: memory domains, physical placement, pytree injection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import injection
from repro.core.domains import (ALIGN_WORDS, DeviceCrashError,
                                DomainAllocator, MemoryDomain, place_groups)
from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


def test_domain_validation():
    MemoryDomain("safe", 0.98, (0, 1)).validate(VCU128)
    with pytest.raises(ValueError):
        MemoryDomain("dup", 0.98, (1, 1)).validate(VCU128)
    with pytest.raises(ValueError):
        MemoryDomain("oob", 0.98, (99,)).validate(VCU128)
    with pytest.raises(DeviceCrashError):
        MemoryDomain("dead", 0.80, (0,)).validate(VCU128)


def test_allocator_alignment_and_split():
    d = MemoryDomain("d", 0.95, (3, 7))
    a = DomainAllocator(VCU128, d)
    words_per_pc = VCU128.bytes_per_pc // 4
    # fill PC 3 up to one aligned block before its end
    segs = a.alloc(words_per_pc - ALIGN_WORDS - 5)
    assert segs[0].pc == 3 and segs[0].phys_base_word == 3 * words_per_pc
    segs2 = a.alloc(4 * ALIGN_WORDS)      # must straddle into PC 7
    assert len(segs2) == 2
    assert segs2[0].pc == 3 and segs2[1].pc == 7
    assert segs2[0].n_words + segs2[1].n_words == 4 * ALIGN_WORDS
    assert segs2[0].n_words == ALIGN_WORDS
    assert segs2[1].phys_base_word == 7 * words_per_pc


def test_allocator_capacity_error():
    d = MemoryDomain("tiny", 0.95, (0,))
    a = DomainAllocator(VCU128, d)
    with pytest.raises(MemoryError):
        a.alloc(VCU128.bytes_per_pc // 4 + 1)


def test_place_groups_on_avals():
    groups = {
        "weights": {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)},
        "opt": {"m": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)},
    }
    domains = {
        "safe": MemoryDomain("safe", 0.98, tuple(range(16))),
        "cheap": MemoryDomain("cheap", 0.91, tuple(range(16, 32))),
    }
    placement = place_groups(groups, {"weights": "cheap", "opt": "safe"},
                             domains, VCU128)
    assert placement["weights"].domain.name == "cheap"
    assert placement["weights"].total_words == 1024 * 1024 // 2
    assert placement["opt"].leaves[0].segments[0].pc == 0
    assert placement["weights"].leaves[0].segments[0].pc == 16


def test_inject_group_guardband_identity():
    tree = {"a": jnp.ones((512, 16), jnp.float32)}
    domains = {"safe": MemoryDomain("safe", 1.0, (0, 1))}
    placement = place_groups({"g": tree}, {"g": "safe"}, domains, VCU128)
    out, bad = injection.inject_group(tree, placement["g"], FMAP)
    assert out["a"] is tree["a"]  # exact no-op
    assert int(bad) == 0


def test_inject_group_applies_faults():
    tree = {"a": jnp.zeros((1 << 18,), jnp.float32),
            "b": jnp.zeros((333, 55), jnp.bfloat16)}
    domains = {"deep": MemoryDomain("deep", 0.88, (18, 19))}
    placement = place_groups({"g": tree}, {"g": "deep"}, domains, VCU128)
    out, _ = injection.inject_group(tree, placement["g"], FMAP)
    changed = sum(int(jnp.sum(out[k] != tree[k])) for k in tree)
    assert changed > 10
    # deterministic across calls (stuck-at persistence)
    out2, _ = injection.inject_group(tree, placement["g"], FMAP)
    for k in tree:
        a16 = jax.lax.bitcast_convert_type(
            out[k].reshape(-1), jnp.uint16 if out[k].dtype.itemsize == 2
            else jnp.uint32)
        b16 = jax.lax.bitcast_convert_type(
            out2[k].reshape(-1), jnp.uint16 if out2[k].dtype.itemsize == 2
            else jnp.uint32)
        np.testing.assert_array_equal(np.asarray(a16), np.asarray(b16))


def test_inject_group_ecc_domain():
    tree = {"a": jnp.zeros((1 << 18,), jnp.float32)}
    raw_domain = {"d": MemoryDomain("d", 0.88, (18, 19))}
    ecc_domain = {"d": MemoryDomain("d", 0.88, (18, 19), ecc=True)}
    p_raw = place_groups({"g": tree}, {"g": "d"}, raw_domain, VCU128)
    p_ecc = place_groups({"g": tree}, {"g": "d"}, ecc_domain, VCU128)
    raw, _ = injection.inject_group(tree, p_raw["g"], FMAP)
    fixed, bad = injection.inject_group(tree, p_ecc["g"], FMAP)
    assert int(jnp.sum(fixed["a"] != 0)) < int(jnp.sum(raw["a"] != 0))
    assert int(bad) >= 0


def test_clamp_nonfinite():
    t = {"x": jnp.asarray([1.0, jnp.inf, -jnp.inf, jnp.nan, 2.0]),
         "i": jnp.asarray([1, 2, 3])}
    out = injection.clamp_nonfinite(t)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  [1.0, 0.0, 0.0, 0.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out["i"]), [1, 2, 3])
