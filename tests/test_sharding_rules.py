"""Unit + property tests for the sharding-rule resolution logic (pure
logic over ParamSpecs -- no devices needed beyond the default one)."""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS
from repro.launch.sharding import ShardingRules, resolve_spec
from repro.models.base import ParamSpec, get_arch


class FakeMesh:
    """Shape-only stand-in (resolve_spec touches shape/axis_names only)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
RULES = ShardingRules.default()


def test_basic_2d_weight():
    s = ParamSpec((4096, 14336), ("embed", "mlp"))
    assert resolve_spec(s, RULES, MESH) == P("data", "model")


def test_divisibility_fallback():
    # internvl2 vocab 92553 is not 16-divisible -> replicated
    s = ParamSpec((92553, 2048), ("vocab", "embed"))
    assert resolve_spec(s, RULES, MESH) == P(None, "data")


def test_no_axis_reuse():
    s = ParamSpec((64, 64, 64), ("kv_heads", "head_dim", None))
    spec = resolve_spec(s, RULES, MESH)
    assert spec == P("model", None, None)  # head_dim can't reuse model


def test_batch_axes_multi_pod():
    s = ParamSpec((256, 4096), ("batch", None))
    assert resolve_spec(s, RULES, MESH3) == P(("pod", "data"), None)
    s1 = ParamSpec((1, 4096), ("batch", None))
    assert resolve_spec(s1, RULES, MESH3) == P(None, None)


def test_long_context_overrides():
    r = ShardingRules.default(long_context=True)
    s = ParamSpec((1, 524288, 4, 256),
                  ("batch", "cache_seq", "kv_heads", "head_dim"))
    spec = resolve_spec(s, r, MESH)
    assert spec == P(None, "data", None, "model")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_every_arch_resolves(arch):
    """Every parameter of every arch gets a legal PartitionSpec: no
    repeated mesh axes, all sharded dims divisible."""
    bundle = get_arch(arch)
    specs = bundle.module.param_specs(bundle.cfg)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    for s in flat:
        spec = resolve_spec(s, RULES, MESH3)
        used = []
        for dim, assign in zip(s.shape, tuple(spec) + (None,) * 8):
            if assign is None:
                continue
            names = (assign,) if isinstance(assign, str) else assign
            for n in names:
                assert n not in used, (arch, s)
                used.append(n)
            size = int(np.prod([MESH3.shape[n] for n in names]))
            assert dim % size == 0, (arch, s, spec)


@hypothesis.given(
    dim=st.integers(min_value=1, max_value=8192),
    logical=st.sampled_from(["vocab", "embed", "heads", "mlp", "batch",
                             "kv_heads", "experts"]),
)
@hypothesis.settings(max_examples=80, deadline=None)
def test_resolution_never_breaks_divisibility(dim, logical):
    s = ParamSpec((dim,), (logical,))
    spec = resolve_spec(s, RULES, MESH)
    assign = spec[0]
    if assign is not None:
        names = (assign,) if isinstance(assign, str) else assign
        size = int(np.prod([MESH.shape[n] for n in names]))
        assert dim % size == 0
