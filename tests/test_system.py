"""End-to-end system tests: training convergence, undervolt integration,
crash/restore, serving consistency, data determinism."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.domains import DeviceCrashError, MemoryDomain
from repro.core.hbm import TPU_V5E, VCU128
from repro.data.pipeline import DataConfig, make_batch
from repro.models.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServeConfig, generate
from repro.training import trainer
from repro.training.undervolt import (UndervoltPlan, aggressive_plan,
                                      guardband_plan)

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
ADAMW = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200)


def _run(tc, steps, seed=3, state=None, start=0):
    dc = DataConfig(vocab=CFG.vocab, seq_len=48, global_batch=8, seed=seed)
    step = jax.jit(trainer.make_train_step(BUNDLE, CFG, tc))
    if state is None:
        state = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))
    losses = []
    for i in range(start, start + steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in
                                make_batch(dc, i).items()})
        losses.append(float(m["loss"]))
    return state, losses, m


def test_training_reduces_loss():
    _, losses, _ = _run(trainer.TrainConfig(adamw=ADAMW), 50)
    assert losses[-1] < losses[0] - 0.4
    assert np.isfinite(losses).all()


def test_microbatched_matches_unbatched_direction():
    _, l1, _ = _run(trainer.TrainConfig(adamw=ADAMW, microbatches=1), 10)
    _, l4, _ = _run(trainer.TrainConfig(adamw=ADAMW, microbatches=4), 10)
    # same data, same init: losses should track closely (bf16 noise)
    assert abs(l1[-1] - l4[-1]) < 0.15


def test_guardband_training_is_faultless():
    tc = trainer.TrainConfig(adamw=ADAMW,
                             undervolt=guardband_plan(TPU_V5E))
    _, losses, m = _run(tc, 10)
    assert int(m["uncorrectable_faults"]) == 0
    assert np.isfinite(losses).all()


def test_aggressive_undervolt_training_survives():
    tc = trainer.TrainConfig(
        adamw=ADAMW, undervolt=aggressive_plan(v_unsafe=0.91,
                                               geometry=VCU128))
    _, losses, _ = _run(tc, 15)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]          # still learns through faults


def test_subcritical_voltage_crashes():
    with pytest.raises(DeviceCrashError):
        UndervoltPlan(
            domains={"d": MemoryDomain("d", 0.79, (0,))},
            policy={"params": "d", "mu": "d", "nu": "d"},
            geometry=TPU_V5E).place(
                {"params": {}, "mu": {}, "nu": {}})


def test_checkpoint_crash_restore_bit_exact():
    tc = trainer.TrainConfig(adamw=ADAMW)
    state, _, _ = _run(tc, 5)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, state)
        # uninterrupted continuation
        s_cont, l_cont, _ = _run(tc, 3, state=state, start=5)
        # crash + restore continuation
        restored, meta = ckpt.restore(d, state)
        s_rest, l_rest, _ = _run(
            tc, 3, state=jax.tree_util.tree_map(jnp.asarray, restored),
            start=meta["step"])
        assert l_cont == l_rest


def test_serving_guardband_matches_nominal():
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                          0, CFG.vocab)}
    base = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=8))
    fmap_pcs = tuple(range(VCU128.num_pcs))
    plan = UndervoltPlan(domains={"kv": MemoryDomain("kv", 0.98, fmap_pcs)},
                         policy={"kv_cache": "kv"}, geometry=VCU128)
    safe = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=8,
                                undervolt=plan))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(safe))


def test_dynamic_voltage_key_threads_into_step():
    """TrainConfig.undervolt_voltage_key: the batch scalar must actually
    steer injection (guardband override -> clean step, deep override ->
    faulted params), within one compiled step."""
    plan = aggressive_plan(v_unsafe=0.91, mitigation="none",
                           geometry=VCU128)
    tc = trainer.TrainConfig(adamw=ADAMW, undervolt=plan,
                             undervolt_voltage_key="hbm_v")
    dc = DataConfig(vocab=CFG.vocab, seq_len=48, global_batch=8, seed=3)
    traces = []

    def counted_step(state, batch):
        traces.append(1)
        return trainer.make_train_step(BUNDLE, CFG, tc)(state, batch)

    step = jax.jit(counted_step)
    state = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, 0).items()}

    def at(v):
        s, _ = step(state, {**batch, "hbm_v": jnp.float32(v)})
        return jax.tree_util.tree_flatten(s["params"])[0]

    safe_a, safe_b, deep = at(0.98), at(0.98), at(0.88)
    assert len(traces) == 1  # the sweep shares one compiled step
    safe_eq = all(bool(jnp.all(x == y)) for x, y in zip(safe_a, safe_b))
    assert safe_eq  # guardband override: deterministic, no injection
    assert any(bool(jnp.any(x != y)) for x, y in zip(safe_a, deep))


def test_serving_kv_voltage_override():
    """ServeConfig.kv_voltage: a guardband override on an unsafe KV
    domain must make generation match the no-undervolt baseline."""
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                          0, CFG.vocab)}
    base = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6))
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.89, tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    lifted = generate(BUNDLE, CFG, params, batch,
                      ServeConfig(max_len=40, max_new_tokens=6,
                                  undervolt=plan, kv_voltage=0.98))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(lifted))
    # deep override through the bitwise path just has to run cleanly
    deep = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6,
                                undervolt=plan, kv_voltage=0.86,
                                kv_method="bitwise"))
    assert deep.shape == base.shape


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=4)
    a = make_batch(dc, step=7)
    b = make_batch(dc, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding partitions the global batch
    dc2 = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=4,
                     host_count=2, host_index=0)
    s0 = make_batch(dc2, step=7)
    assert s0["tokens"].shape == (4, 16)


def test_grad_compression_error_feedback_bounded():
    from repro.optim.compress import ef_quantize_grads, init_ef
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(128, 64),
                          jnp.float32)}
    ef = init_ef(g)
    for _ in range(5):
        dq, ef = ef_quantize_grads(g, ef)
    # error feedback keeps the residual bounded by one quantization step
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(ef["w"]))) <= scale * 1.01
    # and the dequantized gradient is close to the true gradient
    assert float(jnp.max(jnp.abs(dq["w"] - g["w"]))) <= scale * 1.01
