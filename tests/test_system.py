"""End-to-end system tests: training convergence, undervolt integration,
crash/restore, serving consistency, data determinism."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.domains import DeviceCrashError, MemoryDomain
from repro.core.hbm import TPU_V5E, VCU128
from repro.data.pipeline import DataConfig, make_batch
from repro.models.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServeConfig, generate
from repro.training import trainer
from repro.training.undervolt import (UndervoltPlan, aggressive_plan,
                                      guardband_plan)

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
ADAMW = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200)


def _run(tc, steps, seed=3, state=None, start=0):
    dc = DataConfig(vocab=CFG.vocab, seq_len=48, global_batch=8, seed=seed)
    step = jax.jit(trainer.make_train_step(BUNDLE, CFG, tc))
    if state is None:
        state = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))
    losses = []
    for i in range(start, start + steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in
                                make_batch(dc, i).items()})
        losses.append(float(m["loss"]))
    return state, losses, m


def test_training_reduces_loss():
    _, losses, _ = _run(trainer.TrainConfig(adamw=ADAMW), 50)
    assert losses[-1] < losses[0] - 0.4
    assert np.isfinite(losses).all()


def test_microbatched_matches_unbatched_direction():
    _, l1, _ = _run(trainer.TrainConfig(adamw=ADAMW, microbatches=1), 10)
    _, l4, _ = _run(trainer.TrainConfig(adamw=ADAMW, microbatches=4), 10)
    # same data, same init: losses should track closely (bf16 noise)
    assert abs(l1[-1] - l4[-1]) < 0.15


def test_guardband_training_is_faultless():
    tc = trainer.TrainConfig(adamw=ADAMW,
                             undervolt=guardband_plan(TPU_V5E))
    _, losses, m = _run(tc, 10)
    assert int(m["uncorrectable_faults"]) == 0
    assert np.isfinite(losses).all()


def test_aggressive_undervolt_training_survives():
    tc = trainer.TrainConfig(
        adamw=ADAMW, undervolt=aggressive_plan(v_unsafe=0.91,
                                               geometry=VCU128))
    _, losses, _ = _run(tc, 15)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]          # still learns through faults


def test_subcritical_voltage_crashes():
    with pytest.raises(DeviceCrashError):
        UndervoltPlan(
            domains={"d": MemoryDomain("d", 0.79, (0,))},
            policy={"params": "d", "mu": "d", "nu": "d"},
            geometry=TPU_V5E).place(
                {"params": {}, "mu": {}, "nu": {}})


def test_checkpoint_crash_restore_bit_exact():
    tc = trainer.TrainConfig(adamw=ADAMW)
    state, _, _ = _run(tc, 5)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, state)
        # uninterrupted continuation
        s_cont, l_cont, _ = _run(tc, 3, state=state, start=5)
        # crash + restore continuation
        restored, meta = ckpt.restore(d, state)
        s_rest, l_rest, _ = _run(
            tc, 3, state=jax.tree_util.tree_map(jnp.asarray, restored),
            start=meta["step"])
        assert l_cont == l_rest


def test_serving_guardband_matches_nominal():
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                          0, CFG.vocab)}
    base = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=8))
    fmap_pcs = tuple(range(VCU128.num_pcs))
    plan = UndervoltPlan(domains={"kv": MemoryDomain("kv", 0.98, fmap_pcs)},
                         policy={"kv_cache": "kv"}, geometry=VCU128)
    safe = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=8,
                                undervolt=plan))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(safe))


def test_dynamic_voltage_key_threads_into_step():
    """TrainConfig.undervolt_voltage_key: the batch scalar must actually
    steer injection (guardband override -> clean step, deep override ->
    faulted params), within one compiled step."""
    plan = aggressive_plan(v_unsafe=0.91, mitigation="none",
                           geometry=VCU128)
    tc = trainer.TrainConfig(adamw=ADAMW, undervolt=plan,
                             undervolt_voltage_key="hbm_v")
    dc = DataConfig(vocab=CFG.vocab, seq_len=48, global_batch=8, seed=3)
    traces = []

    def counted_step(state, batch):
        traces.append(1)
        return trainer.make_train_step(BUNDLE, CFG, tc)(state, batch)

    step = jax.jit(counted_step)
    state = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, 0).items()}

    def at(v):
        s, _ = step(state, {**batch, "hbm_v": jnp.float32(v)})
        return jax.tree_util.tree_flatten(s["params"])[0]

    safe_a, safe_b, deep = at(0.98), at(0.98), at(0.88)
    assert len(traces) == 1  # the sweep shares one compiled step
    safe_eq = all(bool(jnp.all(x == y)) for x, y in zip(safe_a, safe_b))
    assert safe_eq  # guardband override: deterministic, no injection
    assert any(bool(jnp.any(x != y)) for x, y in zip(safe_a, deep))


def test_governor_step_replans_every_step_traces_once():
    """Acceptance: a jitted train step with the governor enabled re-plans
    voltage every step from a traced power budget and compiles exactly
    once; the guardband re-plan is deterministic and the deep re-plan
    actually faults the cheap-domain tensors."""
    plan = aggressive_plan(v_unsafe=0.91, mitigation="none",
                           geometry=VCU128)
    gov = plan.make_governor("cheap", mode="power", tolerable_rate=1e-3)
    tc = trainer.TrainConfig(adamw=ADAMW, undervolt=plan, governor=gov,
                             governor_key="power_budget",
                             undervolt_method="word")
    dc = DataConfig(vocab=CFG.vocab, seq_len=48, global_batch=8, seed=3)
    traces = []

    def counted_step(state, batch):
        traces.append(1)
        return trainer.make_train_step(BUNDLE, CFG, tc)(state, batch)

    step = jax.jit(counted_step)
    state = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, 0).items()}

    def at(budget):
        s, m = step(state, {**batch, "power_budget": jnp.float32(budget)})
        return (jax.tree_util.tree_flatten(s["params"])[0],
                float(m["governor_voltage"]))

    lax_a, v_a = at(1.0)      # loose budget -> guardband voltage
    lax_b, v_b = at(1.0)
    deep, v_d = at(0.55)      # tight budget -> deep voltage, faults
    assert len(traces) == 1   # re-planning every step, one compile
    assert v_a == pytest.approx(0.98, abs=1e-6)
    assert v_d < 0.90
    assert all(bool(jnp.all(x == y)) for x, y in zip(lax_a, lax_b))
    assert any(bool(jnp.any(x != y)) for x, y in zip(lax_a, deep))


def test_governor_requires_matching_plan():
    plan = aggressive_plan(v_unsafe=0.91, geometry=VCU128)
    other = aggressive_plan(v_unsafe=0.90, geometry=VCU128)
    gov = plan.make_governor("cheap", tolerable_rate=1e-3)
    with pytest.raises(ValueError):
        trainer.make_train_step(BUNDLE, CFG, trainer.TrainConfig(
            adamw=ADAMW, undervolt=other, governor=gov,
            undervolt_method="word"))
    with pytest.raises(ValueError):
        trainer.make_train_step(BUNDLE, CFG, trainer.TrainConfig(
            adamw=ADAMW, undervolt=plan, governor=gov,
            undervolt_voltage_key="hbm_v", undervolt_method="word"))
    with pytest.raises(ValueError, match="undervolt_method"):
        trainer.make_train_step(BUNDLE, CFG, trainer.TrainConfig(
            adamw=ADAMW, undervolt=plan, governor=gov))


def test_serving_governor_admission_replans_kv_voltage():
    """ServeConfig.governor: admission picks the deepest voltage whose
    usable capacity covers the request's KV cache; a zero-tolerance
    governor capped at the guardband reproduces the baseline exactly."""
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                          0, CFG.vocab)}
    base = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6))
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.89,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    safe_gov = plan.make_governor("kv", mode="rate", tolerable_rate=0.0,
                                  v_lo=0.98)   # guardband-only frontier
    lifted = generate(BUNDLE, CFG, params, batch,
                      ServeConfig(max_len=40, max_new_tokens=6,
                                  undervolt=plan, governor=safe_gov))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(lifted))
    # an unconstrained governor admits at the deepest grid voltage
    deep_gov = plan.make_governor("kv", mode="rate", tolerable_rate=0.5,
                                  v_lo=0.88)
    cache_bytes = 1   # trivially satisfiable capacity requirement
    assert deep_gov.admit(cache_bytes) == pytest.approx(0.88, abs=1e-6)
    deep = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6,
                                undervolt=plan, governor=deep_gov,
                                kv_method="bitwise"))
    assert deep.shape == base.shape
    # misconfigurations fail loudly rather than silently no-op
    uncovered = UndervoltPlan(
        domains=plan.domains, policy={"params": "kv"}, geometry=VCU128)
    with pytest.raises(ValueError, match="kv_cache"):
        generate(BUNDLE, CFG, params, batch,
                 ServeConfig(max_len=40, max_new_tokens=6,
                             undervolt=uncovered,
                             governor=uncovered.make_governor(
                                 "kv", mode="rate", tolerable_rate=0.5,
                                 v_lo=0.88)))
    two_dom = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.89, tuple(range(16))),
                 "spare": MemoryDomain("spare", 0.98,
                                       tuple(range(16, 32)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    with pytest.raises(ValueError, match="spare"):
        generate(BUNDLE, CFG, params, batch,
                 ServeConfig(max_len=40, max_new_tokens=6,
                             undervolt=two_dom,
                             governor=two_dom.make_governor(
                                 "spare", mode="rate",
                                 tolerable_rate=0.5)))


def test_serving_auto_method_with_traced_kv_voltage_raises():
    """Satellite: kv_method='auto' cannot dispatch from a traced
    kv_voltage -- generate must raise a clear ValueError instead of
    silently falling back to the domain's configured voltage."""
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.89,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)

    def gen(v):
        batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
        sc = ServeConfig(max_len=16, max_new_tokens=1, undervolt=plan,
                         kv_voltage=v)
        return generate(BUNDLE, CFG, params, batch, sc)

    with pytest.raises(ValueError, match="kv_method='auto'"):
        jax.jit(gen)(jnp.float32(0.98))
    # concrete voltages keep working through 'auto'
    out = gen(jnp.float32(0.98))
    assert out.shape == (1, 1)


def test_serving_kv_voltage_override():
    """ServeConfig.kv_voltage: a guardband override on an unsafe KV
    domain must make generation match the no-undervolt baseline."""
    params = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                          0, CFG.vocab)}
    base = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6))
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.89, tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    lifted = generate(BUNDLE, CFG, params, batch,
                      ServeConfig(max_len=40, max_new_tokens=6,
                                  undervolt=plan, kv_voltage=0.98))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(lifted))
    # deep override through the bitwise path just has to run cleanly
    deep = generate(BUNDLE, CFG, params, batch,
                    ServeConfig(max_len=40, max_new_tokens=6,
                                undervolt=plan, kv_voltage=0.86,
                                kv_method="bitwise"))
    assert deep.shape == base.shape


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=4)
    a = make_batch(dc, step=7)
    b = make_batch(dc, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding partitions the global batch
    dc2 = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=4,
                     host_count=2, host_index=0)
    s0 = make_batch(dc2, step=7)
    assert s0["tokens"].shape == (4, 16)


def test_grad_compression_error_feedback_bounded():
    from repro.optim.compress import ef_quantize_grads, init_ef
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(128, 64),
                          jnp.float32)}
    ef = init_ef(g)
    for _ in range(5):
        dq, ef = ef_quantize_grads(g, ef)
    # error feedback keeps the residual bounded by one quantization step
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(ef["w"]))) <= scale * 1.01
    # and the dequantized gradient is close to the true gradient
    assert float(jnp.max(jnp.abs(dq["w"] - g["w"]))) <= scale * 1.01
