"""Scanned serving decode: token-for-token equality across injection
modes and drivers, zero-recompile voltage sweeps, the fused-launch
budget, and cache-buffer donation.

The equality matrix is the acceptance contract of the read-path
refactor: the scanned decode (read-path fused kernel + incremental
write-path) must reproduce the legacy per-token full-cache re-inject
loop exactly -- greedy and sampled, with and without ECC, at any
constant voltage -- because stuck-at faults are deterministic,
idempotent properties of physical words.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch
from repro.models.cache import init_cache
from repro.serving.engine import ServeConfig, build_decode_engine, generate
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
BATCH = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 12),
                                      0, CFG.vocab)}
ALL_PCS = tuple(range(VCU128.num_pcs))


def _plan(v, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _gen(sc, key=3):
    return np.asarray(generate(BUNDLE, CFG, PARAMS, BATCH, sc,
                               key=jax.random.PRNGKey(key)))


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_scan_matches_loop_clean(temperature):
    a = _gen(ServeConfig(max_len=40, max_new_tokens=8,
                         temperature=temperature))
    b = _gen(ServeConfig(max_len=40, max_new_tokens=8,
                         temperature=temperature, decode="loop"))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("ecc", [False, True])
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_injection_modes_token_identical(ecc, temperature):
    """read-path fused == incremental write-path == full re-inject,
    scanned and python-loop, deep in the collapse regime."""
    plan = _plan(0.86, ecc)
    outs = {}
    for mode, dec in (("read", "scan"), ("write", "scan"),
                      ("rewrite", "scan"), ("rewrite", "loop")):
        outs[(mode, dec)] = _gen(ServeConfig(
            max_len=40, max_new_tokens=8, temperature=temperature,
            undervolt=plan, decode=dec, kv_injection=mode,
            kv_method="bitwise"))
    ref = outs[("rewrite", "loop")]
    for k, v in outs.items():
        np.testing.assert_array_equal(ref, v, err_msg=str(k))
    clean = _gen(ServeConfig(max_len=40, max_new_tokens=8,
                             temperature=temperature))
    assert (ref != clean).any()   # the undervolted cache really faults


def test_traced_kv_voltage_sweep_compiles_once():
    """A jitted 5-point KV-voltage sweep over the scanned decode traces
    exactly once, and each traced point matches the eager run at the
    same concrete voltage."""
    plan = _plan(0.86)
    traces = []

    def gen(v):
        traces.append(1)
        sc = ServeConfig(max_len=40, max_new_tokens=6, undervolt=plan,
                         kv_voltage=v, kv_method="bitwise")
        return generate(BUNDLE, CFG, PARAMS, BATCH, sc,
                        key=jax.random.PRNGKey(3))

    jg = jax.jit(gen)
    sweep = (0.93, 0.91, 0.89, 0.87, 0.86)
    outs = {v: np.asarray(jg(jnp.float32(v))) for v in sweep}
    assert len(traces) == 1, f"sweep retraced {len(traces)} times"
    assert (outs[0.93] != outs[0.86]).any()
    for v in (0.93, 0.86):
        eager = _gen(ServeConfig(max_len=40, max_new_tokens=6,
                                 undervolt=plan, kv_voltage=v,
                                 kv_method="bitwise"))
        np.testing.assert_array_equal(outs[v], eager)


def test_auto_method_with_traced_kv_voltage_raises():
    plan = _plan(0.89)

    def gen(v):
        sc = ServeConfig(max_len=16, max_new_tokens=1, undervolt=plan,
                         kv_voltage=v)
        return generate(BUNDLE, CFG, PARAMS,
                        {"tokens": jnp.zeros((1, 4), jnp.int32)}, sc)

    with pytest.raises(ValueError, match="kv_method='auto'"):
        jax.jit(gen)(jnp.float32(0.98))
    # concrete voltages keep working through 'auto'
    assert gen(jnp.float32(0.98)).shape == (1, 1)


def test_read_mode_requires_family_support(monkeypatch):
    from repro.models import dense
    monkeypatch.setattr(dense, "SUPPORTS_READ_PATH", False)
    sc = ServeConfig(max_len=32, max_new_tokens=2, undervolt=_plan(0.88),
                     kv_injection="read")
    with pytest.raises(ValueError, match="read-path"):
        build_decode_engine(BUNDLE, CFG, sc, 1, 4, static_voltage=0.88)
    # 'auto' falls back to the incremental write path
    eng = build_decode_engine(
        BUNDLE, CFG, ServeConfig(max_len=32, max_new_tokens=2,
                                 undervolt=_plan(0.88)),
        1, 4, static_voltage=0.88)
    assert eng.mode == "write" and not eng.use_fused


def _engine_and_args(max_len, mode="auto", v=0.88):
    sc = ServeConfig(max_len=max_len, max_new_tokens=6,
                     undervolt=_plan(v), kv_injection=mode)
    b, s = 2, 8
    eng = build_decode_engine(BUNDLE, CFG, sc, b, s, static_voltage=v)
    cache = init_cache(BUNDLE.module.cache_specs(CFG, b, max_len))
    args = (PARAMS, cache, jnp.zeros((b, 1), jnp.int32),
            jax.random.PRNGKey(0), jnp.float32(v))
    return eng, args


def test_pallas_launch_budget_flat_in_sequence_length():
    """The decode step's kernel-launch count must not grow with the
    cache length: read-path fusion folds injection into the attention
    launch (1 fused launch inside the layer scan), and the write modes
    pay only the one-time post-prefill arena pass."""
    counts = {}
    for max_len in (256, 512):
        for mode in ("read", "write"):
            eng, args = _engine_and_args(max_len, mode)
            jaxpr = jax.make_jaxpr(lambda *a: eng.decode_all(*a))(*args)
            counts[(mode, max_len)] = arena.count_pallas_calls(jaxpr.jaxpr)
    # fused attention inside the (length-independent) layer scan
    assert counts[("read", 256)] == counts[("read", 512)] == 1
    # + the single post-prefill arena pass
    assert counts[("write", 256)] == counts[("write", 512)] == 2


def test_decode_donates_and_reuses_cache_buffers():
    """donate_argnums satellite: the cache crosses the decode jit
    boundary aliased, not copied -- the compiled module aliases every
    cache leaf input to an output, the entry computation contains no
    copy of a cache-shaped parameter, and the donated input buffers are
    actually consumed at run time."""
    eng, args = _engine_and_args(64, "read")
    params, cache, tok0, key, v = args
    compiled = eng.decode_all.lower(*args).compile()
    text = compiled.as_text()
    assert "input_output_alias" in text

    leaf_shapes = set()
    dt_names = {np.dtype(jnp.bfloat16): "bf16", np.dtype(jnp.int32): "s32",
                np.dtype(jnp.float32): "f32"}
    for leaf in jax.tree_util.tree_leaves(cache):
        leaf_shapes.add(
            f"{dt_names[np.dtype(leaf.dtype)]}"
            f"[{','.join(map(str, leaf.shape))}]")
    entry = next(c for c in text.split("\n\n") if "ENTRY" in c)
    for line in entry.splitlines():
        if not re.search(r"= \S+ copy\(", line):
            continue
        if any(s in line for s in leaf_shapes):
            # a cache-sized copy at the jit boundary is only legal if it
            # copies generated data (e.g. a broadcast), never the cache
            # parameter the caller donated
            assert "param" not in line, f"cache parameter copied: {line}"

    out = eng.decode_all(*args)
    jax.block_until_ready(out)
    assert all(leaf.is_deleted()
               for leaf in jax.tree_util.tree_leaves(cache))
