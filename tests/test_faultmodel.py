"""Unit tests: the calibrated fault model reproduces the paper's anchors."""
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.faultmodel import (DEFAULT_FAULT_MODEL as M, V_ALL_FAULTY,
                                   V_CRITICAL, V_MIN, V_NOM, V_ONSET_01,
                                   V_ONSET_10)


def test_guardband_is_19_percent():
    assert M.guardband_fraction() == pytest.approx(0.19, abs=0.005)


def test_guardband_zero_faults():
    # C1: no faults anywhere in [V_min, V_nom].
    for v in [round(V_MIN + 0.01 * i, 4) for i in range(23)]:
        assert float(M.stuck_fraction(v)) == 0.0, v


def test_fault_onsets():
    # C4: first 1->0 flips at 0.97 V, first 0->1 flips at 0.96 V.
    assert float(M.rate_10(V_ONSET_10)) > 0.0
    assert float(M.rate_01(V_ONSET_10)) < float(M.rate_10(V_ONSET_10)) * 1e-3
    assert float(M.rate_01(V_ONSET_01)) > 0.0
    # The onset rate is a detection-floor rate: ~10 bits in 8 GB.
    bits_8gb = 8 * 2**30 * 8
    assert 1.0 < float(M.rate_10(V_ONSET_10)) * bits_8gb < 100.0


def test_asymmetry_21_percent():
    # C6: 0->1 flips 21% more frequent than 1->0 in the exponential regime.
    for v in (0.96, 0.94, 0.92, 0.90, 0.88):
        ratio = float(M.rate_01(v)) / float(M.rate_10(v))
        assert ratio == pytest.approx(1.21, rel=0.02), v


def test_exponential_growth():
    # C5: each 10 mV step multiplies the rate by a constant factor.
    rates = [float(M.rate_10(round(0.97 - 0.01 * i, 4))) for i in range(6)]
    factors = [rates[i + 1] / rates[i] for i in range(5)]
    for f in factors:
        assert f == pytest.approx(factors[0], rel=0.02)
    assert factors[0] > 2.0  # genuinely exponential


def test_all_faulty_region():
    # C5: essentially all bits faulty between 0.84 and V_critical.
    for v in (V_ALL_FAULTY, 0.83, 0.82, V_CRITICAL):
        assert float(M.stuck_fraction(v)) > 0.99, v


def test_alpha_drop_14_percent_at_085():
    # C3 / Fig. 3: active capacitance 14% below nominal at 0.85 V.
    assert 1.0 - float(M.alpha_factor(0.85)) == pytest.approx(0.14, abs=0.01)
    # And within 3% of nominal anywhere in the guardband.
    assert float(M.alpha_factor(0.98)) == pytest.approx(1.0, abs=0.03)


def test_regions():
    assert M.region(V_NOM) == "guardband"
    assert M.region(0.99) == "guardband"
    assert M.region(0.95) == "unsafe"
    assert M.region(0.83) == "all_faulty"
    assert M.region(0.80) == "crash"


@hypothesis.given(
    v1=st.floats(min_value=V_CRITICAL, max_value=V_NOM),
    v2=st.floats(min_value=V_CRITICAL, max_value=V_NOM),
    mult=st.floats(min_value=0.01, max_value=100.0),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_rates_monotone_in_voltage(v1, v2, mult):
    """Lower voltage never has fewer faults (guardband invariant)."""
    lo, hi = min(v1, v2), max(v1, v2)
    assert float(M.stuck_fraction(lo, mult)) >= float(
        M.stuck_fraction(hi, mult)) - 1e-12


@hypothesis.given(v=st.floats(min_value=V_CRITICAL, max_value=V_NOM),
                  mult=st.floats(min_value=0.01, max_value=1000.0))
@hypothesis.settings(max_examples=60, deadline=None)
def test_rates_are_probabilities(v, mult):
    r01, r10 = M.rates(v, mult)
    assert 0.0 <= float(r01) <= 1.0
    assert 0.0 <= float(r10) <= 1.0
    assert float(r01) + float(r10) <= 1.0 + 1e-6
