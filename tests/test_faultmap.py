"""Unit tests: fault-map synthesis (process variation + clustering)."""
import numpy as np
import pytest

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import TPU_V5E, VCU128


@pytest.fixture(scope="module")
def fmap():
    return FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


def test_deterministic(fmap):
    again = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
    assert again.pc_multiplier == fmap.pc_multiplier


def test_stack_skew(fmap):
    # C7: HBM1's mean fault rate above HBM0's in the unsafe region, while
    # V_min / V_critical (the saturation regime) stay shared.
    r0 = fmap.stack_mean_rate(0.92, 0)
    r1 = fmap.stack_mean_rate(0.92, 1)
    assert r1 > r0
    assert r1 / r0 == pytest.approx(1.13, abs=0.15)
    # same collapse behavior for both stacks
    assert fmap.stack_mean_rate(0.83, 0) == pytest.approx(
        fmap.stack_mean_rate(0.83, 1), rel=0.01)


def test_hot_pcs_are_more_sensitive(fmap):
    # C8: the paper's named hot PCs sit well above the median.
    total = fmap.pc_total_rate(0.92)
    median = float(np.median(total))
    hot = [total[pc] for pc in (4, 5, 18, 19, 20)]
    assert all(h > 1.3 * median for h in hot)
    assert np.mean(hot) > 3.0 * median


def test_guardband_fault_free(fmap):
    assert fmap.pc_total_rate(0.98).max() == 0.0
    assert fmap.num_usable_pcs(0.98, 0.0) == 32


def test_fig6_anchor_points(fmap):
    # Fig. 6 worked examples from section III-C.
    assert fmap.num_usable_pcs(0.95, 0.0) == pytest.approx(7, abs=2)
    assert fmap.num_usable_pcs(0.90, 1e-6) == pytest.approx(16, abs=3)
    # at collapse voltages nothing is usable at any practical tolerance
    assert fmap.num_usable_pcs(0.83, 0.01) == 0


def test_usable_pcs_monotone(fmap):
    for tol in (0.0, 1e-8, 1e-6, 1e-4):
        prev = 33
        for v in (0.97, 0.95, 0.93, 0.91, 0.89, 0.87, 0.85):
            n = fmap.num_usable_pcs(v, tol)
            assert n <= prev, (v, tol)
            prev = n
    # looser tolerance never shrinks the usable set
    for v in (0.95, 0.92, 0.89):
        assert (fmap.num_usable_pcs(v, 1e-6)
                <= fmap.num_usable_pcs(v, 1e-4))


def test_clustering_mass_preserving(fmap):
    weak, strong = fmap.row_multipliers()
    f = fmap.weak_row_frac
    assert f * weak + (1 - f) * strong == pytest.approx(1.0, rel=1e-9)
    assert weak > 10.0  # faults really are concentrated (C9)


def test_thresholds_monotone_in_voltage(fmap):
    t_hi = fmap.thresholds(0.93, pc=3)
    t_lo = fmap.thresholds(0.91, pc=3)
    assert t_lo.q01_weak >= t_hi.q01_weak
    assert t_lo.q10_strong >= t_hi.q10_strong


def test_v5e_geometry_scales():
    m = FaultMap.from_seed(TPU_V5E, seed=0)
    assert m.geometry.total_bytes == 16 * 2**30
    assert m.geometry.num_pcs == 32


def test_row_level_reliability_exports(fmap):
    # weak rows carry the clustered exponential mass: weak > blended >
    # strong, and the blended per-PC rate is their mass-weighted mix
    v = 0.90
    weak, strong = fmap.row_rates(v)
    blended = fmap.pc_total_rate(v)
    f = fmap.weak_row_frac
    assert (weak >= blended - 1e-18).all()
    assert (strong <= blended + 1e-18).all()
    np.testing.assert_allclose(f * weak + (1 - f) * strong, blended,
                               rtol=1e-6)
    # predicted_rates: avoidance sees only the strong-row rate
    np.testing.assert_array_equal(fmap.predicted_rates(v, True), strong)
    np.testing.assert_array_equal(fmap.predicted_rates(v, False), blended)
    # reliability order sorts by blended rate, most reliable first
    order = fmap.reliability_order(v)
    assert (np.diff(blended[order]) >= 0).all()


def test_weak_row_mask_matches_kernel_draw(fmap):
    from repro.core import hashing
    from repro.kernels.bitflip.ref import _weak_rows
    import jax.numpy as jnp
    pc = 4
    mask = fmap.weak_row_mask(pc)
    assert mask.shape == (fmap.rows_per_pc,)
    assert 0.0 < mask.mean() < 0.15           # ~WEAK_ROW_FRAC of rows
    # same draw the injection kernels make from physical word ids
    wprl2 = fmap.words_per_row_log2
    words_per_pc = fmap.geometry.bytes_per_pc // 4
    wid = jnp.asarray(
        pc * words_per_pc
        + np.arange(0, words_per_pc, 1 << wprl2, dtype=np.int64),
        jnp.uint32)
    q = np.uint32(hashing.rate_to_u32_threshold(fmap.weak_row_frac))
    kernel_mask = np.asarray(_weak_rows(wid, fmap.seed, q, wprl2))
    np.testing.assert_array_equal(mask, kernel_mask)
    # block mask flags exactly the blocks containing a weak row
    block = fmap.weak_block_mask(pc, 4096)
    rows_per_block = 4096 // (fmap.geometry.row_bytes // 4)
    np.testing.assert_array_equal(
        block, mask.reshape(-1, rows_per_block).any(axis=1))
