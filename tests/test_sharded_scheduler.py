"""Mesh-sharded continuous batching: the fleet acceptance contract.

With a 1-D serve mesh, the scheduler's slots, page pool and page
tables partition across shards; each shard owns an independently
seeded fault map, its own governor setpoint, and its own traced
voltage -- while the decode step stays ONE jitted donated program with
one pallas launch per shard and ZERO collectives (requests never cross
shards).  Every request served on shard k is bit-identical to
replaying it alone through ``generate()`` against shard k's fault map.

Single-device CI still exercises the whole surface: layout validation,
seed derivation/independence (host-side fault-map checks), and the
mesh(1) == unsharded equivalence.  Multi-shard cases skip unless the
process was started with ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` (the ci bench-smoke multi-device job does).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import CapacityError, MemoryDomain
from repro.core.hbm import VCU128, fleet_map_seeds
from repro.launch.mesh import make_serve_mesh
from repro.models.base import get_arch
from repro.serving.engine import ServeConfig, generate
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ShardLayoutError,
                                     validate_shard_layout)
from repro.training import trainer
from repro.training.governor import GovernorConfig, VoltageGovernor
from repro.training.undervolt import UndervoltPlan

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
ALL_PCS = tuple(range(VCU128.num_pcs))

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
               "all-to-all", "collective-permute")

needs2 = pytest.mark.skipif(len(jax.devices()) < 2,
                            reason="needs >= 2 devices (set XLA_FLAGS="
                            "--xla_force_host_platform_device_count)")
needs4 = pytest.mark.skipif(len(jax.devices()) < 4,
                            reason="needs >= 4 devices")


def _plan(v=0.88, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _sc(mode="read", temperature=0.0, plan=None, method="bitwise", **kw):
    return ServeConfig(max_len=32, max_new_tokens=4,
                       temperature=temperature, undervolt=plan,
                       kv_injection=mode, kv_method=method, **kw)


def _reqs(n, base_len=6):
    r = np.random.RandomState(7)
    return [(i, r.randint(0, CFG.vocab, (base_len + i,)), 4, "cheap",
             100 + i) for i in range(n)]


def _serve(sc, n_shards, reqs, **kw):
    kw.setdefault("num_slots", 2 * n_shards)
    kw.setdefault("num_pages", 8 * n_shards)
    kw.setdefault("page_slots", 8)
    if n_shards > 1 or kw.pop("force_mesh", False):
        kw["mesh"] = make_serve_mesh(n_shards)
    sched = ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc, **kw)
    for rid, toks, n, tier, seed in reqs:
        sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                             tier=tier, key=jax.random.PRNGKey(seed)))
    res = sched.run()
    return sched, res


def _replay(sched, sc, res, reqs):
    """Each request alone through generate() on ITS SHARD's fault map
    and page placement."""
    out = {}
    for rid, toks, n, tier, seed in reqs:
        sc_k = dataclasses.replace(
            sc, undervolt=sched.shard_plan(res[rid].shard),
            max_new_tokens=n)
        out[rid] = np.asarray(generate(
            BUNDLE, CFG, PARAMS, {"tokens": jnp.asarray(toks[None])},
            sc_k, key=jax.random.PRNGKey(seed),
            kv_placement=res[rid].placement))
    return out


# ---- layout validation (pure host, no devices needed) ---------------------

def test_layout_rejects_indivisible_slots():
    with pytest.raises(ShardLayoutError, match="num_slots=6 is not "
                       "divisible by the shard count 4"):
        validate_shard_layout(4, 6, 16)


def test_layout_rejects_indivisible_pages():
    with pytest.raises(ShardLayoutError, match="num_pages=18 is not "
                       "divisible"):
        validate_shard_layout(4, 8, 18)


def test_layout_rejects_seed_collision():
    with pytest.raises(ShardLayoutError, match="seed collision"):
        validate_shard_layout(2, 4, 16, seeds=[7, 7])


def test_layout_rejects_wrong_seed_count():
    with pytest.raises(ShardLayoutError, match="exactly one fault-map "
                       "seed per shard"):
        validate_shard_layout(2, 4, 16, seeds=[1, 2, 3])


def test_layout_rejects_wrong_setpoint_count():
    with pytest.raises(ShardLayoutError, match="one governor setpoint "
                       "per shard"):
        validate_shard_layout(2, 4, 16, setpoints=[1.0])


def test_mesh_axis_missing_is_loud():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ShardLayoutError, match="mesh axis 'serve' "
                       "missing"):
        ContinuousBatchingScheduler(
            BUNDLE, CFG, PARAMS, _sc(plan=_plan()), num_slots=2,
            num_pages=8, page_slots=8, mesh=mesh)


def test_shard_kwargs_require_mesh():
    with pytest.raises(ShardLayoutError, match="require a serve mesh"):
        ContinuousBatchingScheduler(
            BUNDLE, CFG, PARAMS, _sc(plan=_plan()), num_slots=2,
            num_pages=8, page_slots=8, shard_seeds=[1, 2])


def test_setpoints_require_governor():
    with pytest.raises(ShardLayoutError, match="need an admission "
                       "governor"):
        ContinuousBatchingScheduler(
            BUNDLE, CFG, PARAMS, _sc(plan=_plan()), num_slots=2,
            num_pages=8, page_slots=8, mesh=make_serve_mesh(1),
            shard_setpoints=[0.9])


# ---- per-shard fault-map independence (host-side, any device count) -------

def test_fleet_seeds_deterministic_and_distinct():
    a = fleet_map_seeds(469, 8)
    assert a == fleet_map_seeds(469, 8)          # reproducible
    assert len(set(a)) == 8                      # distinct
    assert a[0] == 469                           # shard 0 keeps the base


def test_shard_fault_maps_draw_distinct_weak_rows():
    plan = _plan()
    sched = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, _sc(plan=plan), num_slots=2, num_pages=8,
        page_slots=8, mesh=make_serve_mesh(1))
    # shard 0 reproduces the single-device map exactly
    assert sched.shard_plan(0).fault_map() is plan.fault_map()
    # derived shard plans draw independent maps: distinct weak rows
    # and distinct per-PC threshold calibrations, deterministically
    seeds = fleet_map_seeds(plan.map_seed, 4)
    maps = [dataclasses.replace(plan, map_seed=s).fault_map()
            for s in seeds]
    for a in range(4):
        again = dataclasses.replace(plan, map_seed=seeds[a]).fault_map()
        assert np.array_equal(again.weak_row_mask(0),
                              maps[a].weak_row_mask(0))
        for b in range(a + 1, 4):
            assert not all(
                np.array_equal(maps[a].weak_row_mask(pc),
                               maps[b].weak_row_mask(pc))
                for pc in range(VCU128.num_pcs))
            assert not np.array_equal(
                np.asarray(maps[a].threshold_table(0.88)),
                np.asarray(maps[b].threshold_table(0.88)))


# ---- mesh(1) == unsharded ------------------------------------------------

def test_mesh1_matches_unsharded_bitwise():
    reqs = _reqs(3)
    sc = _sc(plan=_plan())
    base, bres = _serve(sc, 1, reqs)
    mesh, mres = _serve(sc, 1, reqs, force_mesh=True)
    for rid, *_ in reqs:
        assert np.array_equal(bres[rid].tokens, mres[rid].tokens)
    assert mesh.stats["decode_traces"] == 1
    assert mesh.stats["n_shards"] == 1


def test_mesh1_step_donates_and_launches_once():
    sc = _sc(plan=_plan())
    sched, _ = _serve(sc, 1, _reqs(2), force_mesh=True)
    hlo = sched._step.lower(PARAMS, sched.state,
                            sched._volt_vec()).compile().as_text()
    assert "input_output_alias" in hlo
    assert not any(c in hlo for c in COLLECTIVES)
    jaxpr = jax.make_jaxpr(sched._step_fn)(
        PARAMS, sched.state, jnp.float32(0.88))
    assert arena.count_pallas_calls(jaxpr) == 1
    old = jax.tree_util.tree_leaves(sched.state)[0]
    sched.step_once()
    assert old.is_deleted()                      # cache donation held


# ---- multi-shard contracts -----------------------------------------------

@needs4
@pytest.mark.parametrize("mode,temperature,ecc", [
    ("read", 0.0, False), ("read", 0.7, False),
    ("write", 0.0, False), ("read", 0.0, True),
])
def test_sharded_requests_match_solo_generate(mode, temperature, ecc):
    reqs = _reqs(6)
    sc = _sc(mode, temperature, _plan(ecc=ecc),
             method=("word" if ecc else "bitwise"))
    sched, res = _serve(sc, 4, reqs)
    assert sched.stats["decode_traces"] == 1
    assert {res[rid].shard for rid, *_ in reqs} == {0, 1, 2, 3}
    refs = _replay(sched, sc, res, reqs)
    for rid, *_ in reqs:
        assert np.array_equal(refs[rid], res[rid].tokens), rid


@needs4
def test_sharded_step_is_one_program_no_collectives():
    sc = _sc(plan=_plan())
    sched, _ = _serve(sc, 4, _reqs(4))
    assert sched.stats["decode_traces"] == 1
    hlo = sched._step.lower(PARAMS, sched.state,
                            sched._volt_vec()).compile().as_text()
    assert "input_output_alias" in hlo           # donated on the jit
    assert not any(c in hlo for c in COLLECTIVES)
    # launch budget: flat per shard -- one pallas call per shard branch
    # on the reference jaxpr surface
    jaxpr = jax.make_jaxpr(sched._step_fn)(
        PARAMS, sched.state, jnp.float32(0.88))
    assert arena.count_pallas_calls(jaxpr) == 4
    old = jax.tree_util.tree_leaves(sched.state)[0]
    sched._feed_chunks()
    sched.state, _ = sched._step(PARAMS, sched.state, sched._volt_vec())
    assert old.is_deleted()


@needs2
def test_heterogeneous_setpoints_give_heterogeneous_voltages():
    plan = _plan(0.91)
    gov = VoltageGovernor(plan, GovernorConfig(
        domain="kv", mode="rate", tolerable_rate=1e-3, v_lo=0.87))
    sc = _sc(plan=plan, governor=gov)
    setpoints = (1e-9, 1e-4)           # strict shard vs tolerant shard
    sched, res = _serve(sc, 2, _reqs(4), shard_setpoints=setpoints)
    st = sched.stats
    vs = [s["voltage"] for s in st["shards"]]
    assert vs[0] > vs[1]               # stricter rate cap -> higher V
    assert [s["setpoint"] for s in st["shards"]] == list(setpoints)
    fleet = st["fleet"]
    assert len(fleet["shards"]) == 2
    assert fleet["power_factor_max"] >= fleet["power_factor_mean"]
    assert fleet["worst_rate"] <= 1e-4 * (1 + 1e-9)
    assert {res[rid].shard for rid in res} == {0, 1}


@needs2
def test_replay_against_wrong_shard_map_is_rejected():
    reqs = _reqs(4)
    sc = _sc(plan=_plan())
    sched, res = _serve(sc, 2, reqs)
    rid = next(r for r, *_ in reqs if res[r].shard == 1)
    toks = dict((r, t) for r, t, *_ in reqs)[rid]
    # the placement is stamped with shard 1's map seed; replaying it
    # against the base (shard 0) plan must refuse, not silently diverge
    with pytest.raises(ValueError, match="ITS shard's plan"):
        generate(BUNDLE, CFG, PARAMS, {"tokens": jnp.asarray(toks[None])},
                 sc, key=jax.random.PRNGKey(0),
                 kv_placement=res[rid].placement)


@needs2
def test_capacity_error_names_exhausted_shard():
    sc = _sc(plan=_plan())
    sched = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, sc, num_slots=4, num_pages=2, page_slots=8,
        mesh=make_serve_mesh(2))            # 1 page/shard < 4-page need
    sched.submit(Request(rid="big", tokens=np.arange(1, 9)))
    with pytest.raises(CapacityError, match="on shard") as ei:
        sched.run()
    assert ei.value.shard in (0, 1)
    assert ei.value.free_bytes >= 0


@needs2
def test_stats_report_per_shard_occupancy_and_weak_pages():
    sc = _sc(plan=_plan())
    sched, _ = _serve(sc, 2, _reqs(4))
    st = sched.stats
    assert st["n_shards"] == 2
    assert [s["shard"] for s in st["shards"]] == [0, 1]
    for s in st["shards"]:
        assert s["active"] == 0                    # all retired
        assert s["free_pages"] == 8
        assert s["weak_pages"] >= 0
        assert s["map_seed"] is not None
    assert st["free_pages"] == 16
    assert len({s["map_seed"] for s in st["shards"]}) == 2
