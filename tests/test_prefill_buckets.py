"""pow2-bucketed standalone prefill: O(log max_len) compiles while the
logits AND the whole post-prefill cache stay bit-identical to the
unpadded prefill (pad positions are causally dead, their ring rows are
scrubbed back to the init state, and logits are read at the real last
prompt column)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import get_arch
from repro.serving.engine import (ServeConfig, _BucketedPrefill,
                                  _next_pow2, bucketed_prefill, generate)
from repro.training import trainer

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
MAX_LEN = 32


def _assert_trees_equal(a, b, msg):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for la, lb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def test_next_pow2():
    assert [_next_pow2(n) for n in (1, 2, 3, 5, 8, 9, 31, 32)] == [
        1, 2, 4, 8, 8, 16, 32, 32]


def test_padded_prefill_bitexact_and_trace_count():
    bp = _BucketedPrefill(BUNDLE.module, CFG, MAX_LEN)
    assert bp.uniform
    rng = np.random.RandomState(3)
    lengths = (1, 5, 8, 9, 12, 16, 31, 32)
    for s in lengths:
        toks = jnp.asarray(rng.randint(0, CFG.vocab, (1, s)), jnp.int32)
        lg, cache = bp(PARAMS, {"tokens": toks})
        lg_ref, cache_ref = BUNDLE.module.prefill(
            PARAMS, {"tokens": toks}, CFG, MAX_LEN)
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref),
                                      err_msg=f"logits s={s}")
        _assert_trees_equal(cache, cache_ref, f"cache s={s}")
    # one retrace per pow2 bucket actually hit, not per length
    buckets = {min(_next_pow2(s), MAX_LEN) for s in lengths}
    assert len(bp.traces) == len(buckets), (len(bp.traces), buckets)


def test_window_cache_families_fall_back_to_exact():
    """Sliding-window rings rotate once the padded length exceeds the
    window -- padding is unsound there, so the bucket wrapper must route
    to the per-length exact prefill instead of mis-scrubbing."""
    import dataclasses
    cfg = dataclasses.replace(CFG, pattern=("local", "global"), window=8)
    params = trainer.init_state(BUNDLE, cfg, jax.random.PRNGKey(1))["params"]
    bp = _BucketedPrefill(BUNDLE.module, cfg, MAX_LEN)
    assert not bp.uniform
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (1, 12)), jnp.int32)
    lg, cache = bp(params, {"tokens": toks})
    lg_ref, cache_ref = BUNDLE.module.prefill(params, {"tokens": toks},
                                              cfg, MAX_LEN)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))
    _assert_trees_equal(cache, cache_ref, "window fallback cache")
    assert len(bp.traces) == 0          # padded path never traced


def test_generate_shares_buckets_across_calls():
    """generate() routes its prefill through the process-wide bucket
    instance: four distinct prompt lengths in two buckets cost at most
    two prefill retraces (and zero once the buckets are warm)."""
    bp = bucketed_prefill(BUNDLE.module, CFG, 48)
    assert bp is not None
    before = len(bp.traces)
    sc = ServeConfig(max_len=48, max_new_tokens=2, temperature=0.0)
    rng = np.random.RandomState(11)
    for s in (5, 6, 7, 9):              # buckets: 8, 8, 8, 16
        toks = jnp.asarray(rng.randint(0, CFG.vocab, (1, s)), jnp.int32)
        generate(BUNDLE, CFG, PARAMS, {"tokens": toks}, sc)
    assert len(bp.traces) - before <= 2
    # warm path: a fresh length in a warm bucket does not retrace
    warm = len(bp.traces)
    toks = jnp.asarray(rng.randint(0, CFG.vocab, (1, 10)), jnp.int32)
    generate(BUNDLE, CFG, PARAMS, {"tokens": toks}, sc)
    assert len(bp.traces) == warm
