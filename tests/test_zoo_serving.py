"""The model-zoo serving matrix: one scheduler front door, every family.

Every registered architecture serves through
``ContinuousBatchingScheduler`` -- the paged route for families whose
ring caches page (dense/llama/yi/gemma3-window), the state-arena route
for everything else (MoE/MLA, recurrent-state hybrids, xLSTM, whisper
enc-dec, the VLM wrapper) -- under the same contracts:

  * bit-equivalence: every request's tokens are identical to replaying
    it ALONE through ``generate()`` on its placement, greedy and
    sampled, ECC off and on;
  * ONE compiled decode step per scheduler (``decode_traces == 1``);
  * a flat pallas-launch budget (launch count independent of slot
    count, == 1 for the paged route's fused kernel on uniform-full
    layouts);
  * persistent-fault semantics for carried ``state`` leaves
    (corrupt-once-on-write, asserted against the one-shot whole-tree
    injection oracle), placed in a fault-tolerant tier by default;
  * MoE expert weights criticality-tiered by routing frequency;
  * loud errors, not silent fallbacks, for the combinations a route
    cannot serve.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.core.injection import inject_group
from repro.kernels.bitflip.ops import to_u32
from repro.models.base import (cache_layouts, cache_slot_axes, get_arch,
                               list_archs, spec_avals)
from repro.serving import readpath
from repro.serving.engine import ServeConfig, bucketed_prefill, generate
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SelfHealConfig, ShardLayoutError)
from repro.serving.statearena import StateArenaScheduler
from repro.training import trainer
from repro.training.undervolt import (UndervoltPlan, aggressive_plan,
                                      tiered_plan)

ZOO = list_archs()
ALL_PCS = tuple(range(VCU128.num_pcs))
MAX_LEN = 32
V_DEEP = 0.86


@functools.lru_cache(maxsize=None)
def _setup(name):
    bundle = get_arch(name)
    cfg = bundle.reduced
    params = trainer.init_state(bundle, cfg,
                                jax.random.PRNGKey(0))["params"]
    return bundle, cfg, params


def _plan(v, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _extras(cfg, rng):
    """Unbatched modality inputs for the enc-dec / VLM families."""
    if cfg.family == "audio":
        return {"frames": rng.standard_normal(
            (cfg.enc_len, cfg.d_model)).astype(np.float32)}
    if cfg.family == "vlm":
        return {"patches": rng.standard_normal(
            (cfg.enc_len, cfg.frontend_dim)).astype(np.float32)}
    return None


def _requests(cfg, rng, n=2):
    """Overlapping requests with distinct prompt lengths/lifetimes."""
    out = []
    for i in range(n):
        out.append((f"r{i}", rng.randint(0, cfg.vocab, (4 + 3 * i,)),
                    3 + i, 10 * i + 7, _extras(cfg, np.random.RandomState(3))))
    return out


def _serve(bundle, cfg, params, sc, reqs, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_slots", 8)
    sched = ContinuousBatchingScheduler(bundle, cfg, params, sc, **kw)
    for rid, toks, n, seed, extras in reqs:
        sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                             tier="cheap", key=jax.random.PRNGKey(seed),
                             extras=extras))
    return sched, sched.run()


def _replay(bundle, cfg, params, sc, reqs, res):
    """Each request alone through generate() on its own placement."""
    out = {}
    for rid, toks, n, seed, extras in reqs:
        batch = {"tokens": jnp.asarray(np.asarray(toks)[None])}
        for k, v in (extras or {}).items():
            batch[k] = jnp.asarray(v)[None]
        out[rid] = np.asarray(generate(
            bundle, cfg, params, batch,
            dataclasses.replace(sc, max_new_tokens=n),
            key=jax.random.PRNGKey(seed),
            kv_placement=res[rid].placement))
    return out


# ---------------------------------------------------------------------------
# The matrix: every family x {greedy, sampled} x {ECC off, on}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ZOO)
def test_zoo_matrix(name):
    """Overlapped undervolted serving == solo replay, bit for bit, for
    every registered config, greedy+sampled x ECC on/off, on ONE
    compiled decode step."""
    bundle, cfg, params = _setup(name)
    rng = np.random.RandomState(1)
    reqs = _requests(cfg, rng)
    for temperature, ecc in [(0.0, False), (0.7, False),
                             (0.0, True), (0.7, True)]:
        sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=4,
                         temperature=temperature,
                         undervolt=_plan(V_DEEP, ecc=ecc),
                         kv_injection="write",
                         kv_method="word" if ecc else "bitwise")
        sched, res = _serve(bundle, cfg, params, sc, reqs)
        assert len(sched.traces) == 1, (name, temperature, ecc,
                                        sched.stats)
        refs = _replay(bundle, cfg, params, sc, reqs, res)
        for rid, *_ in reqs:
            np.testing.assert_array_equal(
                refs[rid], res[rid].tokens,
                err_msg=f"{name} temp={temperature} ecc={ecc} {rid}")


@pytest.mark.parametrize("name", ZOO)
def test_zoo_clean_matches_solo(name):
    """Without a plan the scheduler is pure serving mechanics and must
    reproduce plain generate() for every family."""
    bundle, cfg, params = _setup(name)
    rng = np.random.RandomState(2)
    reqs = _requests(cfg, rng)
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=4)
    sched, res = _serve(bundle, cfg, params, sc, reqs)
    assert len(sched.traces) == 1, sched.stats
    refs = _replay(bundle, cfg, params, sc, reqs, res)
    for rid, *_ in reqs:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=f"{name} {rid}")
    # the undervolted matrix really faults at this depth: at least the
    # deep bitwise cell must disagree with clean serving somewhere
    sc_f = dataclasses.replace(sc, undervolt=_plan(V_DEEP),
                               kv_injection="write",
                               kv_method="bitwise")
    _, res_f = _serve(bundle, cfg, params, sc_f, reqs)
    assert any((res[rid].tokens != res_f[rid].tokens).any()
               for rid, *_ in reqs), (
        f"{name}: deep undervolt produced no observable corruption")


@pytest.mark.parametrize("name", ZOO)
def test_zoo_launch_budget_flat(name):
    """The pallas-launch count of the one decode step is a per-family
    constant: independent of slot provision.  On the paged route's
    fused read path it is == 1 for uniform-full families (the single
    batched paged-attention launch); window families launch once per
    period slot (still flat in slots and pool).  The state route has
    no read path, so it rides write-mode injection."""
    bundle, cfg, params = _setup(name)
    paged = bool(getattr(bundle.module, "SUPPORTS_PAGED", False))
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=4,
                     undervolt=_plan(V_DEEP),
                     kv_injection="read" if paged else "write",
                     kv_method="bitwise")
    counts = {}
    for slots in (2, 4):
        s = ContinuousBatchingScheduler(
            bundle, cfg, params, sc, num_slots=slots,
            num_pages=8 * slots, page_slots=8)
        jaxpr = jax.make_jaxpr(s._step_fn)(params, s.state,
                                           s._volt_vec())
        counts[slots] = arena.count_pallas_calls(jaxpr.jaxpr)
    assert counts[2] == counts[4], (name, counts)
    if not isinstance(s, StateArenaScheduler) and \
            set(s.layout_kinds) == {"full"}:
        assert counts[2] == 1, (name, counts)


def test_zoo_routes():
    """__new__ dispatch: families with SUPPORTS_PAGED page, everything
    else rides the state arena -- through the same constructor."""
    for name in ZOO:
        bundle, cfg, params = _setup(name)
        sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=2)
        s = ContinuousBatchingScheduler(bundle, cfg, params, sc,
                                        num_slots=2, num_pages=8,
                                        page_slots=8)
        paged = bool(getattr(bundle.module, "SUPPORTS_PAGED", False))
        assert isinstance(s, StateArenaScheduler) == (not paged), name
        assert isinstance(s, ContinuousBatchingScheduler), name
        if not paged:
            assert s.stats["route"] == "state", name
            assert set(s.stats["cache_layouts"]) <= {
                "full", "window", "cross", "state"}, name


# ---------------------------------------------------------------------------
# Window-cache prefill soundness (the engine.py bucketing hole)
# ---------------------------------------------------------------------------


def test_window_prefill_exact_fallback():
    """gemma3's window rings must NOT ride the pow2-padded prefill
    (padding rewrites rotated-out rows): the bucketed entry routes
    every prompt length to the exact per-shape prefill, bit-identical
    to module.prefill, and never traces the padded path."""
    bundle, cfg, params = _setup("gemma3-4b")
    bp = bucketed_prefill(bundle.module, cfg, MAX_LEN)
    assert bp is not None and bp.uniform is False
    toks = jnp.asarray(
        np.random.RandomState(3).randint(0, cfg.vocab, (1, 11)))
    logits, cache = bp(params, {"tokens": toks})
    ref_logits, ref_cache = jax.jit(
        lambda p, bt: bundle.module.prefill(p, bt, cfg, MAX_LEN))(
            params, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(logits),
                                  np.asarray(ref_logits))
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(ref_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not bp.traces, "padded prefill traced on a window family"
    # uniform full-length rings still bucket
    bundle_l, cfg_l, _ = _setup("llama3.2-3b")
    assert bucketed_prefill(bundle_l.module, cfg_l, MAX_LEN).uniform


# ---------------------------------------------------------------------------
# Persistent-fault semantics for carried state
# ---------------------------------------------------------------------------


def _random_cache(avals, key):
    flat, treedef = jax.tree_util.tree_flatten(avals)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for a, k in zip(flat, keys):
        if jnp.issubdtype(a.dtype, jnp.floating):
            leaves.append(jax.random.normal(k, a.shape,
                                            jnp.float32).astype(a.dtype))
        else:
            leaves.append(jnp.zeros(a.shape, a.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _assert_bits_equal(x, y, path):
    """Bit-exact leaf equality via the injection engine's u32 view
    (NaN-safe for ml_dtypes bf16, which numpy's comparison is not)."""
    np.testing.assert_array_equal(np.asarray(to_u32(x)[0]),
                                  np.asarray(to_u32(y)[0]), err_msg=path)


def test_persistent_fault_oracle():
    """The write-path step injection corrupts carried ``state`` leaves
    WHOLE (== the one-shot whole-tree oracle), deterministically and
    idempotently -- so state rewritten every decode step re-acquires
    the same stuck-at faults: corrupt-once-on-write, persistent across
    the scan.  Ring leaves stay incremental (only the written row)."""
    bundle, cfg, _ = _setup("recurrentgemma-9b")
    module = bundle.module
    specs = module.cache_specs(cfg, 1, MAX_LEN)
    avals = spec_avals(specs)
    slot_axes = cache_slot_axes(specs)
    plan = _plan(0.84)
    fmap = plan.fault_map()
    placement = plan.place({"kv_cache": avals})["kv_cache"]
    state_paths = set(readpath.state_leaf_paths(specs, MAX_LEN))
    assert state_paths, "recurrentgemma must carry state leaves"
    v = jnp.float32(0.84)

    tree = _random_cache(avals, jax.random.PRNGKey(5))
    step, _ = arena.inject_placement_slice(
        tree, placement, fmap, slot_axes=slot_axes, pos=jnp.int32(3),
        voltage=v, method="bitwise")
    oracle, _ = inject_group(tree, placement, fmap, voltage=v,
                             method="bitwise")
    again, _ = arena.inject_placement_slice(
        step, placement, fmap, slot_axes=slot_axes, pos=jnp.int32(4),
        voltage=v, method="bitwise")

    flat_t = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat_s = jax.tree_util.tree_leaves(step)
    flat_o = jax.tree_util.tree_leaves(oracle)
    flat_a = jax.tree_util.tree_leaves(again)
    axes = jax.tree_util.tree_leaves(slot_axes)
    corrupted_state = 0
    for (p, t), s, o, a, ax in zip(flat_t, flat_s, flat_o, flat_a,
                                   axes):
        path = jax.tree_util.keystr(p)
        if path in state_paths:
            # whole-leaf == the one-shot oracle; re-injecting the
            # already-corrupt value is a no-op (stuck-at idempotence).
            # Compare raw bits: corrupted bf16 values include NaNs, and
            # numpy's NaN-aware equality doesn't cover ml_dtypes.
            _assert_bits_equal(s, o, path)
            _assert_bits_equal(a, s, path)
            corrupted_state += int(np.any(np.asarray(to_u32(s)[0])
                                          != np.asarray(to_u32(t)[0])))
            continue
        t, s = np.asarray(t), np.asarray(s)
        if ax >= 0 and np.issubdtype(t.dtype, np.floating):
            # ring leaf: rows other than the written slot untouched
            other = [i for i in range(t.shape[ax]) if i != 3]
            np.testing.assert_array_equal(
                np.take(s, other, axis=ax),
                np.take(t, other, axis=ax), err_msg=path)
    assert corrupted_state >= 1, (
        "no carried-state leaf faulted at 0.84 V (oracle vacuous)")


def test_state_tier_default_fault_tolerant():
    """On a tiered plan the per-slot caches land on the ``cheap``
    (fault-tolerant) tier by default, and requests still replay
    bit-exactly on their placement."""
    bundle, cfg, params = _setup("xlstm-350m")
    plan = tiered_plan(v_unsafe=V_DEEP, geometry=VCU128)
    assert plan.tiers is not None
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=3, undervolt=plan,
                     kv_injection="write", kv_method="bitwise")
    rng = np.random.RandomState(4)
    reqs = _requests(cfg, rng, n=1)
    sched, res = _serve(bundle, cfg, params, sc, reqs)
    assert isinstance(sched, StateArenaScheduler)
    assert sched.state_tier == "cheap"
    assert all(p is not None for p in sched.placements)
    refs = _replay(bundle, cfg, params, sc, reqs, res)
    np.testing.assert_array_equal(refs["r0"], res["r0"].tokens)


# ---------------------------------------------------------------------------
# MoE expert criticality tiering
# ---------------------------------------------------------------------------


def test_moe_expert_tiering():
    """Routing-frequency-driven expert placement: hot quarter 'safe',
    cold quarter 'disposable', rest 'cheap'; weights in unsafe domains
    corrupt ONCE at construction; serving replays bit-exactly on
    sched.params while the corruption is observable vs clean params."""
    bundle, cfg, params = _setup("deepseek-v2-lite-16b")
    plan = aggressive_plan(v_unsafe=V_DEEP)
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=4, undervolt=plan,
                     kv_injection="write", kv_method="bitwise")
    rng = np.random.RandomState(5)
    probe = rng.randint(0, cfg.vocab, (24,))
    reqs = _requests(cfg, rng, n=1)
    sched, res = _serve(bundle, cfg, params, sc, reqs,
                        expert_probe=probe)
    tiers = sched.stats["expert_tiers"]
    q = max(cfg.n_experts // 4, 1)
    assert tiers.get("safe", 0) == q and tiers.get("disposable", 0) == q
    assert sum(tiers.values()) == cfg.n_experts
    refs = _replay(bundle, cfg, sched.params, sc, reqs, res)
    np.testing.assert_array_equal(refs["r0"], res["r0"].tokens)
    clean = _replay(bundle, cfg, params,
                    dataclasses.replace(sc, undervolt=None), reqs,
                    {"r0": dataclasses.replace(res["r0"],
                                               placement=None)})
    assert (clean["r0"] != res["r0"].tokens).any(), (
        "expert corruption not observable in tokens")


def test_expert_probe_rejected_off_moe():
    bundle, cfg, params = _setup("xlstm-350m")
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=2,
                     undervolt=aggressive_plan(v_unsafe=V_DEEP),
                     kv_injection="write", kv_method="bitwise")
    with pytest.raises(ValueError, match="MoE-only"):
        ContinuousBatchingScheduler(bundle, cfg, params, sc,
                                    num_slots=1,
                                    expert_probe=np.arange(8))


# ---------------------------------------------------------------------------
# Whisper encoder sharing (content-addressed prefill reuse)
# ---------------------------------------------------------------------------


def test_whisper_prefill_reuse():
    """share_prefix on the state route: identical (tokens, frames)
    admissions reuse the prefill result -- the encoder runs once --
    with identical tokens out and pages_shared flagging the reuse."""
    bundle, cfg, params = _setup("whisper-large-v3")
    rng = np.random.RandomState(6)
    toks = rng.randint(0, cfg.vocab, (5,))
    frames = _extras(cfg, rng)
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=3,
                     share_prefix=True)
    sched = ContinuousBatchingScheduler(bundle, cfg, params, sc,
                                        num_slots=3)
    for i in range(3):
        sched.submit(Request(rid=i, tokens=toks, max_new_tokens=3,
                             key=jax.random.PRNGKey(9), extras=frames))
    res = sched.run()
    assert sched.prefill_reuse == 2, sched.stats
    assert [res[i].pages_shared for i in range(3)] == [0, 1, 1]
    for i in (1, 2):
        np.testing.assert_array_equal(res[0].tokens, res[i].tokens)


# ---------------------------------------------------------------------------
# Route boundaries: loud errors, not silent fallbacks
# ---------------------------------------------------------------------------


def test_paged_route_rejects_extras():
    bundle, cfg, params = _setup("llama3.2-3b")
    sc = ServeConfig(max_len=MAX_LEN, max_new_tokens=2)
    s = ContinuousBatchingScheduler(bundle, cfg, params, sc,
                                    num_slots=2, num_pages=8,
                                    page_slots=8)
    with pytest.raises(ValueError, match="extras"):
        s.submit(Request(rid="x", tokens=np.arange(4),
                         max_new_tokens=2,
                         extras={"frames": np.zeros((2, 4))}))


def test_state_route_rejections():
    bundle, cfg, params = _setup("recurrentgemma-9b")
    plan = _plan(V_DEEP)

    def build(sc, **kw):
        return ContinuousBatchingScheduler(bundle, cfg, params, sc,
                                           num_slots=2, **kw)

    base = ServeConfig(max_len=MAX_LEN, max_new_tokens=2,
                       undervolt=plan, kv_injection="write",
                       kv_method="bitwise")
    with pytest.raises(ShardLayoutError, match="single-shard"):
        from repro.launch.mesh import make_serve_mesh
        build(base, mesh=make_serve_mesh(1))
    with pytest.raises(ValueError, match="page pool"):
        build(base, self_heal=SelfHealConfig())
    with pytest.raises(ValueError, match="governor"):
        build(dataclasses.replace(
            base, governor=plan.make_governor(
                "kv", mode="rate", tolerable_rate=1e-3, v_lo=0.85)))
    with pytest.raises(ValueError, match="read-path"):
        build(dataclasses.replace(base, kv_injection="read"))
    with pytest.raises(ValueError, match="rewrite"):
        build(dataclasses.replace(base, kv_injection="rewrite"))
