"""Self-healing undervolted serving: the acceptance contract.

A DRAM row that turns weak *at runtime* (chaos hook) is detected from
the SECDED correction counters the fused read path exports, accused by
the live fault-map posterior, and healed by an in-step page migration
-- while every affected request stays bit-identical to a solo
``generate()`` replay on its *final* placement, the decode step keeps
compiling exactly once, and the pallas-launch budget stays flat with
telemetry + migration enabled.  Quarantine is monotone; fully-drained
blocks retire through the long-lived ``DomainAllocator``, whose
free/quarantine guards reject blocks still backing live pages; under
quarantine pressure an adaptive governor's admission CapacityError
degrades into a setpoint escalation instead of a crash.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import DomainAllocator, MemoryDomain
from repro.core.faultmap_posterior import FaultMapPosterior
from repro.core.hbm import VCU128
from repro.launch.mesh import make_serve_mesh
from repro.models.base import get_arch
from repro.serving.engine import ServeConfig, generate
from repro.serving.paged import PagePool
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SelfHealConfig)
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]

# The four statistically least-reliable VCU128 pseudo-channels: weak
# rows there throw correctable SECDED events at 0.91 V (~2-3 stuck
# bits per 64-word page) while strong rows stay clean -- the telemetry
# regime the self-healing loop is built for.  (On the full-PC domain
# the reliability-ordered pool would park every page on channels whose
# weak rows are still silent at test-sized pools.)
WORST_PCS = (8, 15, 18, 29)

_R = np.random.RandomState(7)
REQS = [
    ("a", _R.randint(0, CFG.vocab, (5,)), 8, "cheap", 11),
    ("b", _R.randint(0, CFG.vocab, (9,)), 10, "critical", 22),
    ("c", _R.randint(0, CFG.vocab, (12,)), 12, "cheap", 33),
]


def _plan(v=0.91):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, WORST_PCS, ecc=True)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _sc(plan=None, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("max_new_tokens", 8)
    return ServeConfig(temperature=0.0,
                       undervolt=(plan if plan is not None else _plan()),
                       kv_injection="read", kv_method="word", **kw)


def _sched(sc, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_slots", 8)
    kw.setdefault("self_heal", SelfHealConfig())
    return ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc, **kw)


def _submit(sched, reqs):
    for rid, toks, n, tier, seed in reqs:
        sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                             tier=tier, key=jax.random.PRNGKey(seed)))


def _replay(sc, res, reqs):
    """Each request alone through generate() on its FINAL placement."""
    out = {}
    for rid, toks, n, tier, seed in reqs:
        out[rid] = np.asarray(generate(
            BUNDLE, CFG, PARAMS, {"tokens": jnp.asarray(toks[None])},
            dataclasses.replace(sc, max_new_tokens=n),
            key=jax.random.PRNGKey(seed),
            kv_placement=res[rid].placement))
    return out


# ---------------------------------------------------------------------------
# the tentpole contract: detect -> migrate -> continue, bit-exact
# ---------------------------------------------------------------------------

def test_chaos_row_goes_weak_detect_migrate_bit_exact():
    """Mid-serve, a row under live pages turns weak.  Telemetry picks
    it up, the posterior accuses it, the donated step migrates the
    pages and host accounting quarantines the sources -- with ZERO
    request failures, ONE compiled decode step, and every request
    bit-identical to its solo replay on the final placement."""
    sc = _sc()
    sched = _sched(sc)
    _submit(sched, REQS)
    sched.admit_pending()
    for _ in range(2):
        sched.step_once()
    # quiet before the chaos: strong pages throw no ECC events
    assert int(np.asarray(sched.state["telem"]).sum()) == 0
    assert int(np.asarray(sched.state["telem_u"]).sum()) == 0

    owned = sorted(sched.pool._owned)
    pc, row = sched.pool.page_rows(owned[0])[0]
    pids = sched.weaken_row(0, pc, row)
    assert len(pids) >= 1

    res = sched.run()
    assert len(res) == len(REQS)            # zero request failures
    assert len(sched.traces) == 1, sched.stats

    st = sched.stats
    sh = st["shards"][0]
    assert sh["corrected"] > 0              # telemetry really flowed
    assert sh["uncorrectable"] == 0         # single-fault regime
    assert sh["suspect_rows"] >= 1          # posterior accused the row
    assert sh["migrations"] >= 1            # live pages moved
    assert sh["quarantined_pages"] >= 1     # sources retired
    assert (pc, row) in sched._shards[0].posterior.tracked_rows
    # top-level sums mirror the per-shard counters
    assert st["corrected"] == sh["corrected"]
    assert st["migrations"] == sh["migrations"]

    # quarantined pages can never serve again
    quarantined = set(sched.pool.quarantined_pages)
    assert quarantined & set(int(p) for p in pids)
    for rid, *_ in REQS:
        assert not (set(int(p) for p in res[rid].page_ids) & quarantined)

    refs = _replay(sc, res, REQS)
    for rid, *_ in REQS:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=rid)


def test_randomized_chaos_under_churn_monotone_and_bit_exact():
    """Property run: rows go weak at random times while six requests
    churn through two slots.  No replay divergence, no request
    failures, and the quarantine set only ever grows."""
    rng = np.random.RandomState(3)
    reqs = [(i, rng.randint(0, CFG.vocab, (4 + i,)), 3 + (i % 3),
             "cheap", 7 * i + 1) for i in range(6)]
    sc = _sc(max_new_tokens=5)
    sched = _sched(sc, num_slots=2, num_pages=24)
    _submit(sched, reqs)

    weaken_at = {2, 5}
    quar_prev: set = set()
    weakened = 0
    while sched.queue or sched.n_active:
        sched.admit_pending()
        if not sched.n_active:
            break
        if sched.steps in weaken_at:
            owned = sorted(sched.pool._owned)
            if owned:
                pid = owned[rng.randint(len(owned))]
                pc, row = sched.pool.page_rows(pid)[0]
                sched.weaken_row(0, pc, row)
                weakened += 1
        sched.step_once()
        quar = set(sched.pool.quarantined_pages)
        assert quar >= quar_prev, "quarantine must be monotone"
        quar_prev = quar

    res = sched.results
    assert len(res) == 6 and weakened == 2
    assert len(sched.traces) == 1, sched.stats
    assert sched.stats["quarantined_pages"] >= 1
    assert sched.stats["shards"][0]["uncorrectable"] == 0
    refs = _replay(sc, res, reqs)
    for rid, *_ in reqs:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=str(rid))


def test_weak_block_retires_through_allocator():
    """With block-sized pages (page_slots=512 -> one 4096-word block
    per layer per page), migrating away from a weakened row drains its
    blocks completely: they retire through the adopted DomainAllocator
    and drop out of reliability-ordered recycling for good."""
    sc = _sc(max_len=512, max_new_tokens=10)
    sched = _sched(sc, num_slots=2, num_pages=10, page_slots=512)
    rng = np.random.RandomState(7)
    reqs = [("x", rng.randint(0, CFG.vocab, (6,)), 10, "cheap", 1),
            ("y", rng.randint(0, CFG.vocab, (7,)), 10, "cheap", 2)]
    _submit(sched, reqs)
    sched.admit_pending()
    for _ in range(2):
        sched.step_once()
    owned = sorted(sched.pool._owned)
    pc, row = sched.pool.page_rows(owned[0])[0]
    sched.weaken_row(0, pc, row)
    res = sched.run()

    sh = sched.stats["shards"][0]
    assert len(res) == 2 and len(sched.traces) == 1
    assert sh["migrations"] >= 1
    assert sh["quarantined_blocks"] >= 1
    alloc = sched._shards[0].allocator
    retired = set(alloc.quarantined_blocks)
    assert retired and all(b[0] in WORST_PCS for b in retired)
    # retired blocks are exactly the quarantined pages' fully-drained
    # blocks, and none of them back a live or free page
    live_or_free = sched.pool.live_blocks() | sched.pool.page_blocks(
        [p for p in range(sched.pool.num_pages)
         if not sched.pool.is_quarantined(p)
         and not sched.pool.is_owned(p)])
    assert not (retired & live_or_free)


def test_launch_budget_flat_with_telemetry_and_migration():
    """Telemetry accumulation, the chaos threshold swap, and the
    in-step page copy are pure jnp on donated leaves: the healing
    scheduler's step carries exactly as many pallas launches as the
    plain one (the single fused paged-attention call)."""
    counts = {}
    for heal in (None, SelfHealConfig()):
        sched = _sched(_sc(), num_slots=2, num_pages=8, self_heal=heal)
        jaxpr = jax.make_jaxpr(sched._step_fn)(
            PARAMS, sched.state, sched._volt_vec())
        counts[heal is not None] = arena.count_pallas_calls(jaxpr.jaxpr)
    assert counts[True] == counts[False] == 1, counts


# ---------------------------------------------------------------------------
# allocator guards (satellite: free()/quarantine() vs live pages)
# ---------------------------------------------------------------------------

def test_allocator_rejects_freeing_blocks_backing_live_pages():
    pool = PagePool(BUNDLE.module, CFG, max_len=32, page_slots=8,
                    num_pages=8, plan=_plan())
    alloc = DomainAllocator(VCU128, pool.domain, pool.faultmap)
    alloc.adopt(pool.placement)
    alloc.register_pool(pool)
    pids = pool.alloc(2, "cheap")
    segs = pool.placement.leaves[0].segments
    with pytest.raises(ValueError, match="live pages"):
        alloc.free(segs)
    with pytest.raises(ValueError, match="live pages"):
        alloc.quarantine(segs)
    # after the pool releases the pages, quarantine goes through -- and
    # the blocks can never be freed or quarantined again
    pool.free(pids)
    alloc.quarantine(segs)
    assert alloc.quarantined_blocks
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.free(segs)
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.quarantine(segs)
    # adopt() is a fresh-allocator-only operation
    with pytest.raises(ValueError, match="fresh allocator"):
        alloc.adopt(pool.placement)


# ---------------------------------------------------------------------------
# posterior unit contract
# ---------------------------------------------------------------------------

def test_posterior_accuses_and_absolves_rows():
    fmap = _plan().fault_map()
    post = FaultMapPosterior(fmap)
    pc = WORST_PCS[-1]
    weak_rows = np.flatnonzero(fmap.weak_row_mask(pc))
    strong_rows = np.flatnonzero(~fmap.weak_row_mask(pc))
    wr, sr = int(weak_rows[0]), int(strong_rows[0])

    # priors: the static map's draw
    assert post.p_weak(pc, sr) == pytest.approx(1e-3, rel=0.01)
    assert post.p_weak(pc, wr) == pytest.approx(1.0, abs=1e-3)

    # corrected events at an unsafe voltage overturn a strong prior
    for _ in range(3):
        post.observe(pc, sr, corrected=4, codewords=128, voltage=0.91)
    assert post.p_weak(pc, sr) > 0.9
    assert (pc, sr) in post.suspect_rows(0.91)
    # ...but weakness does not matter in the guardband
    assert post.suspect_rows(0.98) == []

    # a statically-weak row that reads clean is absolved
    post.observe(pc, wr, corrected=0, codewords=5000, voltage=0.91)
    assert post.p_weak(pc, wr) < 0.9

    # uncorrectable events are (strong) evidence too
    post.observe(pc, sr + 1, corrected=0, uncorrectable=4,
                 codewords=128, voltage=0.91)
    post.observe(pc, sr + 1, corrected=0, uncorrectable=4,
                 codewords=128, voltage=0.91)
    assert post.p_weak(pc, sr + 1) > 0.9

    # accused rows raise the PC's predicted rate; zero-codeword
    # observations are no-ops
    base = fmap.pc_total_rate(0.91)
    pred = post.predicted_rates(0.91)
    assert pred[pc] > base[pc]
    n_rows = len(post.tracked_rows)
    post.observe(pc, sr + 2, corrected=9, codewords=0, voltage=0.91)
    assert len(post.tracked_rows) == n_rows
    s = post.stats()
    assert s["tracked_rows"] == n_rows and s["corrected"] == 12


# ---------------------------------------------------------------------------
# adaptive governor: posterior-driven re-planning
# ---------------------------------------------------------------------------

def test_adaptive_governor_replans_from_posterior():
    plan = _plan()
    gov = plan.make_governor("kv", mode="adaptive", tolerable_rate=1.0,
                             v_hi=0.93, v_lo=0.91)
    post = FaultMapPosterior(plan.fault_map())
    # just above the deep frontier edge (rate_at interpolates in the
    # log domain, so the exact edge value rounds either way in f32)
    s = gov.rate_at(0.91) * 1.00002
    assert float(gov.voltage_at(s)) == pytest.approx(0.91)

    # eight rows of the domain's worst PC turn weak
    for row in range(200, 208):
        for _ in range(3):
            post.observe(29, row, corrected=4, codewords=128,
                         voltage=0.91)
    gov.replan(post)
    assert gov.replans == 1
    # the rate frontier moved up, so the same setpoint now resolves to
    # a shallower (safer) voltage
    assert gov.rate_at(0.91) > s
    assert float(gov.voltage_at(s)) > 0.91

    # replan is an adaptive-mode-only verb
    gov_rate = plan.make_governor("kv", mode="rate", tolerable_rate=1.0,
                                  v_hi=0.93, v_lo=0.91)
    with pytest.raises(ValueError, match="adaptive"):
        gov_rate.replan(post)


def test_setpoint_escalation_degrades_gracefully():
    """After the posterior-driven replan pushes every grid voltage
    above a frontier-edge rate setpoint, admission escalates the
    shard's setpoint one decade (quarantine pressure is real: pages
    are retired) instead of raising CapacityError.

    Single-PC domain on purpose: the governor's worst-rate walk is a
    max over domain PCs, so the accused PC must BE the worst one for
    the replan to move the frontier."""
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.91, WORST_PCS[:1], ecc=True)},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    gov = plan.make_governor("kv", mode="adaptive", tolerable_rate=1.0,
                             v_hi=0.91, v_lo=0.89)
    s0 = gov.rate_at(0.91) * 1.00002        # feasible ONLY pre-replan
    sc = _sc(plan=plan, max_new_tokens=16, governor=gov)
    sched = _sched(sc, mesh=make_serve_mesh(1), shard_setpoints=[s0])
    sched.submit(Request(rid="r1", tokens=_R.randint(0, CFG.vocab, (6,)),
                         max_new_tokens=16, tier="cheap",
                         key=jax.random.PRNGKey(5)))
    sched.admit_pending()
    assert sched.n_active == 1              # edge setpoint admits
    for _ in range(2):
        sched.step_once()
    owned = sorted(sched.pool._owned)
    pc, row = sched.pool.page_rows(owned[0])[0]
    sched.weaken_row(0, pc, row)
    for _ in range(10):
        sched.step_once()
        sh = sched.stats["shards"][0]
        if sh["governor_replans"] >= 1 and sh["quarantined_pages"] >= 1:
            break
    sh = sched.stats["shards"][0]
    assert sh["governor_replans"] >= 1, sh
    assert sh["quarantined_pages"] >= 1, sh

    # the next admission would fail the (now-raised) rate frontier at
    # the old setpoint: it escalates and admits instead of crashing
    sched.submit(Request(rid="r2", tokens=_R.randint(0, CFG.vocab, (7,)),
                         max_new_tokens=4, tier="cheap",
                         key=jax.random.PRNGKey(6)))
    assert sched.admit_pending() == 1
    sh = sched.stats["shards"][0]
    assert sh["setpoint_escalations"] >= 1, sh
    assert sched._shards[0].setpoint > s0
    res = sched.run()
    assert len(res) == 2                    # both requests completed
    assert len(sched.traces) == 1, sched.stats


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_self_heal_config_validation():
    # no ECC -> no telemetry signal
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.91, WORST_PCS, ecc=False)},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    with pytest.raises(ValueError, match="ECC"):
        _sched(_sc(plan=plan))
    # write-path injection stores faulted payloads: migration could
    # not be replay-exact
    with pytest.raises(ValueError, match="read"):
        _sched(ServeConfig(max_len=32, max_new_tokens=8,
                           undervolt=_plan(), kv_injection="write",
                           kv_method="word"))
    with pytest.raises(ValueError, match="max_migrations"):
        _sched(_sc(), self_heal=SelfHealConfig(max_migrations=0))
    # the chaos hook needs the healing lanes
    sched = _sched(_sc(), self_heal=None)
    with pytest.raises(ValueError, match="self_heal"):
        sched.weaken_row(0, WORST_PCS[0], 0)
