"""Read-path injection primitives: the fused decode-attention kernel is
bit-identical to corrupt-then-attend on the same operands, and the
incremental (slice) write path is bit-identical to full re-injection.

These are the two contracts that let the serving engine drop the
per-token O(cache) injection pass: faults are deterministic properties
of physical words, so corrupting data as it is *read* (in VMEM, inside
the attention kernel) or corrupting only the words a step *wrote*
reproduces the legacy corrupt-everything-every-step semantics exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, injection
from repro.core.domains import MemoryDomain, place_groups
from repro.core.faultmap import FaultMap
from repro.core.hbm import HBMGeometry
from repro.kernels.flash_attention import faulty
from repro.models.base import ParamSpec, cache_slot_axes

TINY = HBMGeometry(name="tiny", num_stacks=2, channels_per_stack=2,
                   pcs_per_channel=2, bytes_per_pc=64 * 1024)
FMAP = FaultMap.from_seed(TINY, seed=7)

B, L, KH, G, D, P = 2, 32, 2, 3, 8, 2
H = KH * G


def _bits(x):
    return np.asarray(jax.lax.bitcast_convert_type(
        x.reshape(-1),
        {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[x.dtype.itemsize]))


def _cache_tree(rng, dtype=jnp.bfloat16):
    if jnp.issubdtype(dtype, jnp.floating):
        mk = lambda: jnp.asarray(rng.randn(P, B, L, KH, D), dtype)
    else:
        mk = lambda: jnp.asarray(rng.randint(-100, 100, (P, B, L, KH, D)),
                                 dtype)
    return {
        "k": mk(),
        "v": mk(),
        "pos": jnp.asarray(rng.randint(-1, 60, (P, B, L)), jnp.int32),
    }


def _specs(dtype=jnp.bfloat16):
    kv_axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec((P, B, L, KH, D), kv_axes, dtype, "zeros"),
        "v": ParamSpec((P, B, L, KH, D), kv_axes, dtype, "zeros"),
        "pos": ParamSpec((P, B, L), ("layers", "batch", "cache_seq"),
                         jnp.int32, "zeros"),
    }


def _place(tree, *, v, ecc):
    domains = {"d": MemoryDomain("d", v, tuple(range(6)), ecc=ecc)}
    return place_groups({"g": tree}, {"g": "d"}, domains, TINY)["g"]


def _leaf_tables(placement, v):
    table = FMAP.threshold_table(v)
    tabs = engine.leaf_block_tables(placement)
    paths = [lp.path for lp in placement.leaves]
    out = {}
    for name in ("k", "v"):
        bb, bp = tabs[paths.index(f"['{name}']")]
        out[name] = (jnp.asarray(bb), table[jnp.asarray(bp)])
    return out


CASES = [("word", 0.88, False), ("bitwise", 0.86, False),
         ("word", 0.86, True)]


@pytest.mark.parametrize("method,v,ecc", CASES)
def test_fused_attention_equals_corrupt_then_attend(method, v, ecc):
    """The acceptance contract: read-path corruption inside the kernel
    is bit-identical to write-path corrupt-then-attend on the same
    operands -- including the clean-slot (store-buffer) exemption."""
    rng = np.random.RandomState(1)
    tree = _cache_tree(rng)
    placement = _place(tree, v=v, ecc=ecc)
    tabs = _leaf_tables(placement, v)
    corr, _ = engine.inject_placement_slice(tree, placement, FMAP,
                                            voltage=v, method=method)
    assert any(int((_bits(corr[n]) != _bits(tree[n])).sum()) > 0
               for n in ("k", "v"))  # the sweep point really injects

    layer = 1
    layer_words = B * L * KH * D // 2      # bf16: 2 elements per word
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.bfloat16)
    pos_vals = np.arange(L)[None, :].repeat(B, 0).astype(np.int32)
    pos_vals[:, -3:] = -1                  # empty ring slots stay masked
    pos = jnp.asarray(pos_vals)
    clean_slot = jnp.int32(5)
    kw = dict(q_pos=jnp.int32(L + 4), k_tables=tabs["k"],
              v_tables=tabs["v"], k_word0=jnp.uint32(layer * layer_words),
              v_word0=jnp.uint32(layer * layer_words), causal=True,
              window=0, seed=FMAP.seed, method=method,
              words_per_row_log2=FMAP.words_per_row_log2, ecc=ecc)

    out_read = faulty.faulty_decode_attention(
        q, tree["k"][layer], tree["v"][layer], pos, inject=True,
        clean_slot=clean_slot, **kw)
    # corrupt-then-attend: stored-corrupt cache, current slot's write
    # still in the store buffer (clean)
    kc = corr["k"][layer].at[:, 5].set(tree["k"][layer][:, 5])
    vc = corr["v"][layer].at[:, 5].set(tree["v"][layer][:, 5])
    out_write = faulty.faulty_decode_attention(q, kc, vc, pos,
                                               inject=False, **kw)
    np.testing.assert_array_equal(_bits(out_read), _bits(out_write))

    # without the exemption the current slot's faults do land
    out_all = faulty.faulty_decode_attention(
        q, tree["k"][layer], tree["v"][layer], pos, inject=True, **kw)
    out_all_ref = faulty.faulty_decode_attention(
        q, corr["k"][layer], corr["v"][layer], pos, inject=False, **kw)
    np.testing.assert_array_equal(_bits(out_all), _bits(out_all_ref))


def test_fused_attention_traced_voltage_traces_once():
    rng = np.random.RandomState(2)
    tree = _cache_tree(rng)
    placement = _place(tree, v=0.90, ecc=False)
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.bfloat16)
    pos = jnp.asarray(np.arange(L)[None, :].repeat(B, 0).astype(np.int32))
    tabs0 = engine.leaf_block_tables(placement)
    paths = [lp.path for lp in placement.leaves]
    traces = []

    @jax.jit
    def run(vv):
        traces.append(1)
        table = FMAP.threshold_table(vv)
        t = {}
        for name in ("k", "v"):
            bb, bp = tabs0[paths.index(f"['{name}']")]
            t[name] = (jnp.asarray(bb), table[jnp.asarray(bp)])
        return faulty.faulty_decode_attention(
            q, tree["k"][0], tree["v"][0], pos, q_pos=jnp.int32(L),
            k_tables=t["k"], v_tables=t["v"], k_word0=jnp.uint32(0),
            v_word0=jnp.uint32(0), seed=FMAP.seed, method="word",
            words_per_row_log2=FMAP.words_per_row_log2, ecc=False,
            inject=True)

    outs = [run(jnp.float32(v)) for v in (0.90, 0.89, 0.88, 0.87, 0.86)]
    assert len(traces) == 1, f"voltage sweep retraced {len(traces)} times"
    # deep into the collapse regime the same compiled function injects
    # visibly different faults
    assert bool(jnp.any(outs[0] != outs[-1]))


# Bit-level cross-pipeline equality is asserted on int8 caches: XLA-CPU
# canonicalizes NaN payloads whenever a float op moves bf16/f32 data
# (slice, concat, dynamic-update), so two *different* but individually
# deterministic pipelines can legitimately disagree on the payload bits
# of corrupted float NaNs.  The engine's serving pipelines are
# self-consistent (canonicalization is idempotent), which the bf16
# token-level equality tests in test_serving_scan.py cover.
SLICE_CASES = [("word", 0.87, False, jnp.int8),
               ("bitwise", 0.86, False, jnp.int8),
               ("word", 0.86, True, jnp.int8)]


@pytest.mark.parametrize("method,v,ecc,dtype", SLICE_CASES)
def test_incremental_slice_bit_identical_to_full_reinject(method, v, ecc,
                                                          dtype):
    """The write-path acceptance contract: after one decode step writes
    slot s, injecting only that slice yields the exact cache full
    re-injection would (determinism + idempotence of stuck-at masks)."""
    rng = np.random.RandomState(3)
    tree = _cache_tree(rng, dtype)
    axes = cache_slot_axes(_specs(dtype))
    placement = _place(tree, v=v, ecc=ecc)

    # state after a step: everything previously corrupted, the freshly
    # written slot clean
    pos = jnp.int32(37)
    slot = int(pos) % L
    corr, _ = injection.inject_group(tree, placement, FMAP, voltage=v,
                                     method=method)
    c1 = {n: corr[n].at[:, :, slot].set(tree[n][:, :, slot])
          for n in tree}

    inc, bad_i = engine.inject_placement_slice(
        c1, placement, FMAP, slot_axes=axes, pos=pos, voltage=v,
        method=method)
    ref, bad_f = injection.inject_group(c1, placement, FMAP, voltage=v,
                                        method=method)
    changed = 0
    for n in tree:
        np.testing.assert_array_equal(_bits(inc[n]), _bits(ref[n]),
                                      err_msg=n)
        changed += int((_bits(inc[n]) != _bits(c1[n])).sum())
    assert changed > 0  # the touched slice really takes faults


def test_incremental_slice_traced_pos_and_voltage():
    """slot index and voltage may both be traced: a scanned decode
    re-executes one compiled step across positions and voltages."""
    rng = np.random.RandomState(4)
    tree = _cache_tree(rng)
    axes = cache_slot_axes(_specs())
    placement = _place(tree, v=0.88, ecc=False)
    traces = []

    @jax.jit
    def step(c, pos, v):
        traces.append(1)
        out, _ = engine.inject_placement_slice(
            c, placement, FMAP, slot_axes=axes, pos=pos, voltage=v,
            method="word")
        return out

    for i, v in enumerate((0.90, 0.89, 0.88)):
        out = step(tree, jnp.int32(10 + i), jnp.float32(v))
        eager, _ = engine.inject_placement_slice(
            tree, placement, FMAP, slot_axes=axes, pos=jnp.int32(10 + i),
            voltage=v, method="word")
        for n in tree:
            np.testing.assert_array_equal(_bits(out[n]), _bits(eager[n]))
    assert len(traces) == 1


def test_slotless_and_unaligned_leaves_fall_back_to_full():
    """Leaves without a slot axis (recurrent states) or whose slots are
    not word-aligned are corrupted whole -- still bit-identical to the
    arena engine."""
    rng = np.random.RandomState(5)
    tree = {"state": jnp.asarray(rng.randn(B, 40), jnp.float32),
            "odd": jnp.asarray(rng.randn(B, 7, 3), jnp.bfloat16)}
    axes = {"state": -1, "odd": 1}      # odd: 3 bf16 inner = 6 bytes
    placement = _place(tree, v=0.87, ecc=False)
    inc, _ = engine.inject_placement_slice(
        tree, placement, FMAP, slot_axes=axes, pos=jnp.int32(3),
        voltage=0.87, method="word")
    ref, _ = injection.inject_group(tree, placement, FMAP, voltage=0.87,
                                    method="word")
    for n in tree:
        np.testing.assert_array_equal(_bits(inc[n]), _bits(ref[n]))


def test_select_block_tables_matches_gather():
    """The kernel-side candidate-select addressing equals the oracle's
    jnp.take gather for tiles at arbitrary (unaligned) word offsets."""
    rng = np.random.RandomState(6)
    # 40000 f32 words = 10 arena blocks straddling 3 tiny PCs, so the
    # gathered threshold rows actually vary across the tile.
    tree = {"k": jnp.asarray(rng.randn(40000), jnp.float32)}
    placement = _place(tree, v=0.90, ecc=False)
    (bb, bp), = engine.leaf_block_tables(placement)
    assert len(set(np.asarray(bp))) >= 2
    table = FMAP.threshold_table(0.90)
    thr = table[jnp.asarray(bp)]
    nb = bb.shape[0]
    words = 3 * 4096 + 123
    for start in (0, 1, 4095, 4096 + 17):
        off = np.uint32(start) + jnp.arange(words, dtype=jnp.uint32)
        j0 = jnp.int32(start // 4096)
        n_cand = -(-words // 4096) + 1
        wid_s, thr_s = faulty.select_block_tables(
            off, jnp.asarray(bb), thr, j0=j0, n_cand=n_cand,
            num_blocks=nb)
        jvec = np.asarray(off) >> 12
        wid_g = jnp.asarray(bb)[jvec] + (np.asarray(off) & 4095)
        np.testing.assert_array_equal(np.asarray(wid_s),
                                      np.asarray(wid_g))
        for c in range(thr.shape[1]):
            np.testing.assert_array_equal(np.asarray(thr_s[c]),
                                          np.asarray(thr[jvec, c]))
